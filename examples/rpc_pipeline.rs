//! END-TO-END DRIVER over the wire: the full three-layer system behind
//! the network serving edge. Starts the coordinator, binds the JSON-RPC
//! server on a loopback port, and drives it purely through the client
//! library and the socket load generator — pipelined submits, a
//! streaming batch, mixed-tier traffic, quota sheds, the shutdown RPC —
//! then verifies wire accounting (every frame, submit and result
//! counted) and the clean-drain invariant.
//!
//! Run: `cargo run --release --features rpc --example rpc_pipeline`
//! Results recorded in EXPERIMENTS.md §RPC.

use hrfna::coordinator::rpc::{
    socket_closed_loop, ConnMode, Json, QuotaConfig, RpcClient, RpcServer, RpcServerConfig,
};
use hrfna::coordinator::{
    Backend, ContextRegistry, Coordinator, CoordinatorConfig, Error, InProcess, JobKind, JobSpec,
    Tier,
};
use hrfna::runtime::EngineHandle;
use hrfna::util::cli::Args;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::{Dist, ServeMix};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let clients = args.parse_or("clients", 4usize);
    let jobs = args.parse_or("jobs", 64usize);

    let t0 = Instant::now();
    let engine = EngineHandle::spawn(None).expect("engine load");
    // The `Backend` seam: the server binds an `Arc<dyn Backend>`, so the
    // same edge serves an in-process coordinator here and a `ShardRouter`
    // in `hrfna route`.
    let backend = Arc::new(InProcess::new(Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig::default(),
    )));
    let server = RpcServer::bind(
        Arc::clone(&backend) as Arc<dyn Backend>,
        "127.0.0.1:0",
        RpcServerConfig { quota: QuotaConfig::default(), ..RpcServerConfig::default() },
    )
    .expect("bind rpc server");
    let addr = server.local_addr().to_string();
    println!("rpc server up in {:?} on {addr}", t0.elapsed());

    // --- 1. Correctness through the wire: pipelined dot submits ------
    let mut client = RpcClient::connect(&addr).expect("connect");
    client.ping().expect("ping");
    let mut rng = Rng::new(2026);
    let dist = Dist::moderate();
    let mut fired = Vec::new();
    for i in 0..16usize {
        let n = 512;
        let x = dist.sample_vec(&mut rng, n);
        let y = dist.sample_vec(&mut rng, n);
        let want: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let tier = ServeMix::default_mix().tier_for(i);
        let id = client
            .submit_spec(&JobSpec::dot(x, y).tier(tier))
            .expect("fire");
        fired.push((id, tier, want));
    }
    let mut worst: f64 = 0.0;
    for (id, tier, want) in fired {
        let r = client.wait_submit(id).expect("transport").expect("accepted");
        assert_eq!(r.tier, tier);
        worst = worst.max(((r.values[0] - want) / want.abs().max(1e-300)).abs());
    }
    println!("pipelined mixed-tier dots: worst rel err {worst:.2e}");
    assert!(worst < 1e-6, "wire transport must not cost accuracy");

    // --- 2. Streaming batch submission, including a typed rejection --
    let good =
        |rng: &mut Rng| JobSpec::dot(dist.sample_vec(rng, 512), dist.sample_vec(rng, 512));
    let bad = JobSpec::dot(dist.sample_vec(&mut rng, 512), dist.sample_vec(&mut rng, 7));
    let outcomes = client
        .submit_batch(&[good(&mut rng), bad, good(&mut rng)])
        .expect("batch transport");
    let served = outcomes.iter().filter(|o| o.is_ok()).count();
    let shed = outcomes.iter().filter(|o| o.is_err()).count();
    println!("batch of 3: {served} served, {shed} rejected (typed)");
    assert_eq!((served, shed), (2, 1));
    let err = outcomes[1].as_ref().err().expect("mismatched operands rejected");
    assert!(matches!(err, Error::Rejected(_)), "{err:?}");

    // --- 3. Socket load: persistent vs reconnect-per-job -------------
    let mix = ServeMix::default_mix();
    let make = |c: u64, i: usize| -> JobSpec {
        let (_, mut r) = mix.request_rng(c + 1, i);
        JobSpec::dot(
            mix.dist.sample_vec(&mut r, mix.dot_n),
            mix.dist.sample_vec(&mut r, mix.dot_n),
        )
        .tier(mix.tier_for(i))
    };
    for mode in [ConnMode::Persistent, ConnMode::PerJob] {
        let report = socket_closed_loop(&addr, clients, jobs, 8, mode, &make);
        assert_eq!(report.completed, report.offered, "{mode:?} lost jobs");
        println!(
            "{mode:?}: {} jobs at {:.0} jobs/s (p99 {:.0} us)",
            report.completed,
            report.jobs_per_s,
            report.latency_us.as_ref().map(|l| l.p99).unwrap_or(0.0)
        );
    }

    // --- 4. Server-side report + shutdown over the wire --------------
    let (coord_table, wire_table) = client.server_metrics().expect("metrics rpc");
    println!("{coord_table}");
    println!("{wire_table}");
    client.shutdown_server().expect("shutdown rpc");
    let resp = client.request("ping", Json::Null).expect("still answering during drain");
    drop(resp); // ping stays up while the coordinator drains
    server.wait_shutdown();
    let wire = server.stop();
    assert_eq!(wire.protocol_errors(), 0);
    assert_eq!(wire.conns_opened(), wire.conns_closed(), "leaked connections");

    for tier in Tier::ALL {
        let served = backend
            .with_coordinator(|c| c.metrics.jobs_tier(JobKind::DotHybrid, tier))
            .expect("backend live");
        println!("tier {:<5} served {served} hybrid dots", tier.label());
        assert!(served > 0, "mixed-tier stream must exercise every tier");
    }
    let drain = backend.shutdown().expect("first shutdown");
    println!("{drain}");
    assert!(drain.is_clean(), "shutdown dropped jobs: {drain}");
    println!("rpc_pipeline OK");
}
