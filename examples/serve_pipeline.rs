//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Starts the Layer-3 coordinator (admission → sharded bounded queues →
//! planar batch execution → bulk decode), which serves a mixed stream of
//! dot-product, matmul and RK4 requests across the HRFNA and FP32 lanes.
//! Hybrid batches run on the planar residue lanes (one-pass block encode,
//! lane kernels, one CRT per requested output); FP32 batches run the AOT
//! engine graphs. Reports latency percentiles, throughput, batch sizes,
//! per-lane accuracy vs f64, and the shutdown drain report — proving all
//! layers compose with Python completely absent from the request path.
//!
//! Run: `cargo run --release --example serve_pipeline` (software backend;
//! `make artifacts` + `--features xla` for the PJRT engine).
//! Results recorded in EXPERIMENTS.md §E2E.

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::{
    Backend, ContextRegistry, Coordinator, CoordinatorConfig, ExecMode, InProcess, JobKind,
    JobSpec, Payload, Tier, DEFAULT_WAIT,
};
use hrfna::hybrid::registry::{tier_rel_bound, MagnitudeEnvelope};
use hrfna::runtime::EngineHandle;
use hrfna::util::cli::Args;
use hrfna::util::prng::Rng;
use hrfna::util::stats::Summary;
use hrfna::util::table::Table;
use hrfna::workloads::generators::Dist;
use hrfna::workloads::rk4::{rk4_final_state, Ode};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let jobs = args.parse_or("jobs", 400usize);
    let warmup = args.parse_or("warmup", 20usize);
    let workers = args.parse_or("workers", 2usize);

    let t0 = Instant::now();
    let engine = EngineHandle::spawn(None).expect("engine load");
    let (platform, names) = engine.info().expect("engine info");
    println!("engine up in {:?} on {platform}; artifacts: {names:?}", t0.elapsed());

    // The coordinator behind the unified `Backend` seam — the same API
    // the RPC edge and the cluster router serve (swap `InProcess` for
    // `rpc::Remote` or `ShardRouter` and nothing below changes).
    let registry = Arc::new(ContextRegistry::new());
    let backend = InProcess::new(Coordinator::start(
        engine,
        Arc::clone(&registry),
        CoordinatorConfig {
            workers_per_lane: workers,
            batch: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
                capacity: 4096,
            },
            exec: ExecMode::Planar,
            ..CoordinatorConfig::default()
        },
    ));

    let mut rng = Rng::new(2026);

    // Warmup: first executions trigger lazy initialization.
    for _ in 0..warmup {
        let x = Dist::moderate().sample_vec(&mut rng, 512);
        let y = Dist::moderate().sample_vec(&mut rng, 512);
        backend.call(JobSpec::dot(x.clone(), y.clone())).unwrap();
        backend.call(JobSpec::dot_f32(x, y)).unwrap();
    }

    // Mixed request stream: 40% hybrid dot, 30% fp32 dot, 10% each
    // matmul lane, 10% hybrid RK4.
    struct Truth {
        kind: JobKind,
        expected: Vec<f64>,
    }
    let start = Instant::now();
    let mut pending = Vec::new();
    let mut truths = Vec::new();
    for i in 0..jobs {
        let (kind, payload, expected) = match i % 10 {
            0..=3 => {
                let n = 256 + rng.below(3840) as usize;
                let x = Dist::moderate().sample_vec(&mut rng, n);
                let y = Dist::moderate().sample_vec(&mut rng, n);
                let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
                (JobKind::DotHybrid, Payload::Dot { x, y }, vec![truth])
            }
            4..=6 => {
                let n = 256 + rng.below(3840) as usize;
                let x = Dist::moderate().sample_vec(&mut rng, n);
                let y = Dist::moderate().sample_vec(&mut rng, n);
                let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
                (JobKind::DotF32, Payload::Dot { x, y }, vec![truth])
            }
            7 => {
                let dim = 64;
                let a = Dist::moderate().sample_vec(&mut rng, dim * dim);
                let b = Dist::moderate().sample_vec(&mut rng, dim * dim);
                let truth = hrfna::workloads::matmul::matmul::<f64>(&a, &b, dim, dim, dim, &());
                (JobKind::MatmulHybrid, Payload::Matmul { a, b, dim }, truth)
            }
            8 => {
                let dim = 64;
                let a = Dist::moderate().sample_vec(&mut rng, dim * dim);
                let b = Dist::moderate().sample_vec(&mut rng, dim * dim);
                let truth = hrfna::workloads::matmul::matmul::<f64>(&a, &b, dim, dim, dim, &());
                (JobKind::MatmulF32, Payload::Matmul { a, b, dim }, truth)
            }
            _ => {
                let y0 = vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)];
                let (mu, dt, steps) = (1.0, 0.005, 200u64);
                let truth =
                    rk4_final_state::<f64>(&Ode::VanDerPol { mu }, &y0, dt, steps, &());
                (JobKind::Rk4Hybrid, Payload::Rk4 { y0, mu, dt, steps }, truth)
            }
        };
        truths.push(Truth { kind, expected });
        pending.push(backend.submit(JobSpec::new(kind, payload)).expect("submit"));
    }

    // Collect + accuracy audit.
    let mut lane_err: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    let mut latencies: Vec<f64> = Vec::new();
    for (ticket, truth) in pending.into_iter().zip(&truths) {
        let r = backend.wait(&ticket, DEFAULT_WAIT).expect("job result");
        latencies.push(r.latency_us);
        // Error scale: |w| for well-separated values, the output's RMS for
        // near-zero elements (a 64-term ±uniform dot can land at ~0, where
        // a pure relative metric explodes meaninglessly).
        let scale = hrfna::util::stats::rms(&truth.expected).max(1e-9);
        let errs: Vec<f64> = r
            .values
            .iter()
            .zip(&truth.expected)
            .map(|(g, w)| (g - w).abs() / w.abs().max(scale))
            .collect();
        let worst = errs.iter().cloned().fold(0.0, f64::max);
        lane_err.entry(truth.kind.label()).or_default().push(worst);
    }
    let wall = start.elapsed();

    println!("\n=== E2E results: {jobs} mixed requests in {wall:.2?} ===");
    println!("request throughput: {:.0} req/s", jobs as f64 / wall.as_secs_f64());
    let lat = Summary::of(&latencies);
    println!(
        "latency µs: mean {:.0}  p50 {:.0}  p95 {:.0}  p99 {:.0}  max {:.0}",
        lat.mean, lat.p50, lat.p95, lat.p99, lat.max
    );

    let mut t = Table::new("per-lane worst relative error vs f64", &["lane", "max", "mean"]);
    for (lane, errs) in &lane_err {
        let s = Summary::of(errs);
        t.rowv(&[lane.to_string(), format!("{:.2e}", s.max), format!("{:.2e}", s.mean)]);
    }
    t.print();
    println!("{}", backend.metrics_text());

    // Hard assertions: this is the composition proof, not just a demo.
    for (lane, errs) in &lane_err {
        let max = errs.iter().cloned().fold(0.0, f64::max);
        // RK4 compounds per-step rounding through the dynamics, so its
        // lane budget is looser than one-shot dot/matmul decodes.
        let tol = if *lane == "rk4/hrfna" {
            1e-4
        } else if lane.contains("hrfna") {
            1e-6
        } else {
            1e-3
        };
        assert!(max < tol, "{lane}: max rel error {max} over tolerance {tol}");
    }
    let snap = registry.get(Tier::Paper).snapshot();
    println!(
        "\nHRFNA decode reconstructions (paper tier): {} (1 per requested output, as designed)",
        snap.reconstructions
    );

    // === Tiered segment: the same workload under every precision tier ===
    // One dot payload served under lo/paper/wide; each result must land
    // inside that tier's a-priori relative budget against f64 — and a
    // tolerance below the requested tier's budget must escalate.
    // Exactly the 512 bucket: admission pads nothing, so the resolution
    // envelope (and hence the escalation arithmetic below) uses n terms.
    let n = 512;
    let x = Dist::moderate().sample_vec(&mut rng, n);
    let y = Dist::moderate().sample_vec(&mut rng, n);
    let want: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
    let scale: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
    let envelope = MagnitudeEnvelope::of_slices(&[&x, &y], n as u64, 0);
    for tier in Tier::ALL {
        let r = backend
            .call(JobSpec::dot(x.clone(), y.clone()).tier(tier))
            .expect("tiered dot");
        assert_eq!(r.tier, tier, "moderate dot must not escalate past {tier:?}");
        let budget = tier_rel_bound(registry.cfg(tier), &envelope);
        let rel = (r.values[0] - want).abs() / scale.max(1e-300);
        println!("tier {:<5} rel err {rel:.2e} (budget {budget:.2e})", tier.label());
        assert!(rel <= budget, "{tier:?}: rel {rel:e} over budget {budget:e}");
    }
    let r = backend
        .call(JobSpec::dot(x.clone(), y.clone()).tier(Tier::Lo).tolerance(1e-7))
        .expect("escalated dot");
    assert_eq!(
        r.tier,
        Tier::Paper,
        "a 1e-7 tolerance is below lo's budget and within paper's"
    );
    let escalations = backend
        .with_coordinator(|c| c.metrics.total_escalations())
        .expect("backend live");
    println!(
        "tier escalations recorded: {escalations} (1e-7-tolerance job ran on {})",
        r.tier.label()
    );
    assert!(escalations >= 1);
    println!("{}", backend.metrics_text());

    let drain = backend.shutdown().expect("first shutdown");
    println!("{drain}");
    assert!(drain.is_clean(), "shutdown dropped jobs: {drain}");
    println!("serve_pipeline OK");
}
