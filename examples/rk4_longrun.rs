//! Long-horizon RK4 integration (§VII-D): integrate the Van der Pol
//! oscillator for many steps in HRFNA, FP32 and BFP, tracking the error
//! against a lock-step f64 reference — HRFNA stays FP32-class and bounded,
//! BFP drifts.
//!
//! Run: `cargo run --release --example rk4_longrun [--steps 1000000]`
//! (1e6 steps takes a few minutes in HRFNA; default 200k.)

use hrfna::baselines::{Bfp, BfpConfig};
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::util::cli::Args;
use hrfna::util::table::{eng, Table};
use hrfna::workloads::rk4::{rk4_integrate, Ode};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.parse_or("steps", 200_000u64);
    let dt = args.parse_or("dt", 0.002f64);
    let ode = Ode::VanDerPol { mu: 1.0 };
    let y0 = ode.default_y0();
    let sample_every = (steps / 20).max(1);

    println!("Integrating Van der Pol (mu=1), {steps} steps, dt={dt}\n");

    let hctx = HrfnaContext::paper_default();
    let tr_h = rk4_integrate::<Hrfna>(&ode, &y0, dt, steps, sample_every, &hctx);
    let tr_f = rk4_integrate::<f32>(&ode, &y0, dt, steps, sample_every, &());
    let tr_b = rk4_integrate::<Bfp>(&ode, &y0, dt, steps, sample_every, &BfpConfig::default());

    let mut t = Table::new(
        "Error vs f64 reference along the trajectory",
        &["step", "HRFNA", "FP32", "BFP"],
    );
    for i in 0..tr_h.samples.len() {
        t.rowv(&[
            tr_h.samples[i].0.to_string(),
            eng(tr_h.samples[i].1),
            eng(tr_f.samples[i].1),
            eng(tr_b.samples[i].1),
        ]);
    }
    t.print();

    let snap = hctx.snapshot();
    println!("\nHRFNA: max err {}, drift ratio {:.2}", eng(tr_h.max_error()), tr_h.drift_ratio());
    println!("FP32 : max err {}, drift ratio {:.2}", eng(tr_f.max_error()), tr_f.drift_ratio());
    println!("BFP  : max err {}, drift ratio {:.2}", eng(tr_b.max_error()), tr_b.drift_ratio());
    println!(
        "HRFNA normalization events: {} over {} arithmetic ops (rate {:.2e})",
        snap.norms + snap.guard_norms,
        snap.arithmetic_ops(),
        snap.norm_rate()
    );
    println!("\nPaper §VII-D: error bounded (no exponential growth/drift); BFP error increases.");
}
