//! Design-space exploration (§IX-D "CAD-assisted parameter selection",
//! paper future work): sweep channel count k, modulus width and threshold
//! τ, scoring each point on accuracy, dynamic range, normalization rate
//! and modeled FPGA cost — the trade-off surface a CAD flow would search.
//!
//! Run: `cargo run --release --example design_space`

use hrfna::config::HrfnaConfig;
use hrfna::fpga::pipeline::{model_workload, WorkloadKind};
use hrfna::fpga::power::energy_per_mac_nj;
use hrfna::fpga::resources::{mac_unit, FormatArch};
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::rns::moduli::generate_prime_moduli;
use hrfna::util::table::Table;
use hrfna::workloads::{dot, generators::Dist};

fn config_for(k: usize, width: u32) -> Option<HrfnaConfig> {
    let moduli = generate_prime_moduli(k, width);
    let m_bits: f64 = moduli.iter().map(|&m| (m as f64).log2()).sum();
    // Headroom rule: τ leaves 16 bits, significand uses ~1/4 of M.
    let tau_bits = (m_bits as u32).saturating_sub(16);
    let sig_bits = ((m_bits / 4.0) as u32).clamp(12, 40);
    let cfg = HrfnaConfig {
        moduli,
        exponent_width: 16,
        tau_bits,
        scale_step: 32.min(tau_bits / 2),
        sig_bits,
        clock_mhz: 300.0,
    };
    cfg.validate().ok()?;
    Some(cfg)
}

fn main() {
    let mut t = Table::new(
        "HRFNA design space — accuracy vs hardware cost (dot n=4096)",
        &[
            "k", "width", "M bits", "sig", "rel RMS", "norm rate", "LUT", "DSP",
            "Fmax", "nJ/MAC",
        ],
    );
    for k in [4usize, 6, 8, 10, 12] {
        for width in [12u32, 16, 20] {
            let Some(cfg) = config_for(k, width) else { continue };
            let ctx = HrfnaContext::new(cfg.clone());
            let rms = dot::dot_rms_error::<Hrfna>(2, 4096, Dist::moderate(), 9, &ctx);
            let rate = ctx.snapshot().norm_rate();
            let res = mac_unit(FormatArch::Hrfna, &cfg, 16);
            let timing = model_workload(
                FormatArch::Hrfna,
                WorkloadKind::Dot { n: 65536 },
                &cfg,
                16,
            );
            let energy = energy_per_mac_nj(&res, FormatArch::Hrfna, &timing);
            t.rowv(&[
                k.to_string(),
                width.to_string(),
                format!("{:.0}", cfg.m_bits()),
                cfg.sig_bits.to_string(),
                format!("{rms:.1e}"),
                format!("{rate:.1e}"),
                format!("{:.0}", res.lut),
                format!("{:.0}", res.dsp),
                format!("{:.0}", timing.fmax_mhz),
                format!("{energy:.4}"),
            ]);
        }
    }
    t.print();
    println!(
        "\nReading: k·width sets dynamic range (M bits) and cost; sig_bits sets accuracy;\n\
         the paper's k=8/w=16 point balances FP32-class accuracy against ~10 DSP/MAC."
    );
}
