//! Dot-product accuracy across formats and vector lengths — the §VII-B
//! experiment as a runnable example: HRFNA tracks FP32-or-better accuracy
//! with error flat in N, while BFP error grows and fixed-point saturates.
//!
//! Run: `cargo run --release --example dot_accuracy [--max-n 65536]`

use hrfna::baselines::{Bfp, BfpConfig, Fixed, FixedConfig};
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::util::cli::Args;
use hrfna::util::table::Table;
use hrfna::workloads::{dot, generators::Dist};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let max_n = args.parse_or("max-n", 65536usize);
    let trials = args.parse_or("trials", 3usize);

    for (dist_name, dist) in [
        ("moderate", Dist::moderate()),
        ("high-dynamic-range", Dist::high_dynamic_range()),
    ] {
        let mut t = Table::new(
            &format!("Relative RMS error vs f64 — {dist_name} operands ({trials} trials)"),
            &["n", "HRFNA", "FP32", "BFP", "Fixed Q16.16", "HRFNA norm rate"],
        );
        let mut n = 1024;
        while n <= max_n {
            let hctx = HrfnaContext::paper_default();
            let h = dot::dot_rms_error::<Hrfna>(trials, n, dist, 42, &hctx);
            let rate = hctx.snapshot().norm_rate();
            let f = dot::dot_rms_error::<f32>(trials, n, dist, 42, &());
            let b = dot::dot_rms_error::<Bfp>(trials, n, dist, 42, &BfpConfig::default());
            let fx = dot::dot_rms_error::<Fixed>(trials, n, dist, 42, &FixedConfig::q16_16());
            t.rowv(&[
                n.to_string(),
                format!("{h:.2e}"),
                format!("{f:.2e}"),
                format!("{b:.2e}"),
                format!("{fx:.2e}"),
                format!("{rate:.2e}"),
            ]);
            n *= 4;
        }
        t.print();
        println!();
    }
    println!("Paper §VII-B: HRFNA RMS < 1e-6 at all lengths, no growth with N; BFP grows.");
}
