//! Quickstart: the HRFNA number system in ten lines.
//!
//! Encodes reals as hybrid residue–floating values, shows exact carry-free
//! multiplication (Theorem 1), exponent-synchronized addition, and a
//! threshold normalization event with its Lemma 1/2 error bounds.
//!
//! Run: `cargo run --release --example quickstart`

use hrfna::hybrid::{error, Hrfna, HrfnaContext};

fn main() {
    // Paper-default configuration: k = 8 sixteen-bit prime moduli,
    // M ≈ 2^127.9, τ = 2^112, s = 32 (Table II).
    let ctx = HrfnaContext::paper_default();
    println!(
        "HRFNA context: k={} channels, M ≈ 2^{:.1}, τ = 2^{}, s = {}\n",
        ctx.k(),
        ctx.m_bits,
        ctx.cfg.tau_bits,
        ctx.cfg.scale_step
    );

    // --- Encoding (Definition 1: Φ(r, f) = CRT(r) · 2^f) ---------------
    let a = Hrfna::encode(3.14159265, &ctx);
    let b = Hrfna::encode(-2.71828182e8, &ctx);
    println!("encode  3.14159265   -> f={}, |N| ~ 2^{}", a.f, a.magnitude_bits());
    println!("encode -2.71828182e8 -> f={}, |N| ~ 2^{}", b.f, b.magnitude_bits());
    println!("decode(a) = {}", a.decode(&ctx));
    println!("decode(b) = {}\n", b.decode(&ctx));

    // --- Multiplication is exact and carry-free (Theorem 1) ------------
    let p = a.mul(&b, &ctx);
    println!("a ⊗ b = {}   (f64: {})", p.decode(&ctx), 3.14159265 * -2.71828182e8);

    // --- Addition synchronizes exponents explicitly (§IV-B) ------------
    let s = a.add(&b, &ctx);
    println!("a ⊕ b = {}   (f64: {})\n", s.decode(&ctx), 3.14159265 + -2.71828182e8);

    // --- A long MAC chain: exact accumulation, rare normalization ------
    let mut acc = Hrfna::zero(&ctx, 0);
    let mut truth = 0.0f64;
    for i in 0..10_000 {
        let x = Hrfna::encode(1.0 + (i % 97) as f64, &ctx);
        let y = Hrfna::encode(0.5 - (i % 13) as f64, &ctx);
        truth += x.decode(&ctx) * y.decode(&ctx);
        acc.mac_assign(&x, &y, &ctx);
    }
    let snap = ctx.snapshot();
    println!("10k-MAC accumulator: got {}, truth {}", acc.decode(&ctx), truth);
    println!(
        "ops: {} muls, {} adds — {} normalization events (rate {:.2e})\n",
        snap.muls,
        snap.adds,
        snap.norms + snap.guard_norms,
        snap.norm_rate()
    );

    // --- Normalization with formal bounds (Definitions 3–4, Lemmas 1–2) -
    let mut v = Hrfna::from_signed_int(0x7FFF_FFFF_FFFF, -20, &ctx);
    let sample = error::measure_normalization(&mut v, 16, &ctx);
    println!("normalize by 2^16:");
    println!("  before Φ = {:.6e}, after Φ = {:.6e}", sample.before, sample.after);
    println!("  |ε| = {:.3e}  ≤  Lemma-1 bound {:.3e}", sample.abs_err, sample.abs_bound);
    println!("  rel ε = {:.3e}  ≤  bound {:.3e}", sample.rel_err, sample.rel_bound);
    assert!(sample.within_bounds());
    println!("\nquickstart OK");
}
