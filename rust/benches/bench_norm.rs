//! Normalization-engine bench: the planar bulk path (flagged-scan →
//! gather → batched residue-domain rescale → scatter,
//! `HrfnaBatch::normalize_flagged`) against the per-element reference
//! (`hybrid::norm::reference`, scalar reconstruct/encode per flagged
//! element) at flagged densities 1% / 10% / 50% over a 4096-element
//! batch.
//!
//! Emits `BENCH_norm.json` with absolute ns-per-event records
//! (machine-dependent) and same-run **cost ratios** `bulk / reference`
//! (machine-independent; the CI-gated invariant: the bulk path stays at
//! ≤ 0.6× the per-element cost at 10% density). Quick mode for CI:
//! `BENCH_QUICK=1 cargo bench --bench bench_norm` (or `--quick`).

mod common;

use std::time::Duration;

use hrfna::config::HrfnaConfig;
use hrfna::hybrid::{norm, Hrfna, HrfnaBatch, HrfnaContext};
use hrfna::rns::plane;
use hrfna::util::bench::{bench_with, write_json, BenchRecord, BenchResult};
use hrfna::util::cli::Args;
use hrfna::util::prng::Rng;

/// A record from an already-net ns/iter value (clone overhead removed),
/// normalized to per-event cost.
fn net_record(name: &str, events: usize, net_ns_per_iter: f64) -> BenchRecord {
    let ns_per_op = net_ns_per_iter / events.max(1) as f64;
    BenchRecord {
        name: name.to_string(),
        n: events as u64,
        ns_per_op,
        throughput_per_s: if ns_per_op > 0.0 { 1e9 / ns_per_op } else { 0.0 },
    }
}

fn ratio_record(name: &str, ratio: f64) -> BenchRecord {
    BenchRecord {
        name: name.to_string(),
        n: 1,
        ns_per_op: ratio,
        // Speedup of the bulk path rides along in the throughput column,
        // mirroring the other cost-ratio records.
        throughput_per_s: 1.0 / ratio.max(1e-12),
    }
}

/// A batch with `percent`% of its elements above τ (spread evenly, so
/// the gather walks realistic strides), the rest far below it.
fn batch_with_density(
    percent: u64,
    n: usize,
    ctx: &HrfnaContext,
    rng: &mut Rng,
) -> (HrfnaBatch, usize) {
    let mut flagged = 0usize;
    let items: Vec<Hrfna> = (0..n)
        .map(|j| {
            let over = (j as u64) % 100 < percent;
            flagged += over as usize;
            let bits = if over {
                45 + rng.below(15) as u32
            } else {
                8 + rng.below(20) as u32
            };
            let mut v = (rng.next_u64() >> (64 - bits)).max(1);
            if over {
                // Pin the top bit so the magnitude is genuinely ≥
                // 2^{bits-1} > τ = 2^40 — `flagged` must equal the event
                // count exactly (it is the ns-per-event denominator).
                v |= 1 << (bits - 1);
            }
            let v = v as i64;
            Hrfna::from_signed_int(if rng.bool() { v } else { -v }, -10, ctx)
        })
        .collect();
    (HrfnaBatch::from_items(&items, ctx.k()), flagged)
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick") || std::env::var("BENCH_QUICK").is_ok();
    common::banner(
        "§VI-E normalization engine",
        if quick {
            "bulk vs per-element normalize (quick)"
        } else {
            "bulk vs per-element normalize"
        },
    );
    let budget = Duration::from_millis(if quick { 60 } else { 300 });
    // Tight threshold so the chosen densities are exactly the flagged
    // densities the sweep sees.
    let ctx = HrfnaContext::new(HrfnaConfig {
        tau_bits: 40,
        ..HrfnaConfig::paper_default()
    });
    let mut rng = Rng::new(11);
    let n = 4096;
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut gated_d10_ratio = f64::NAN;

    for (label, percent) in [("d1", 1u64), ("d10", 10), ("d50", 50)] {
        let (base, flagged) = batch_with_density(percent, n, &ctx, &mut rng);
        assert!(flagged > 0);
        // The denominator contract: the intended flag count must be the
        // measured event count (one untimed sweep on a throwaway clone).
        assert_eq!(base.clone().normalize_flagged(&ctx).total(), flagged);
        // Each timed closure must start from a fresh batch, so both paths
        // pay one clone per iteration; measure the clone alone and net it
        // out — otherwise the constant memcpy compresses the cost ratio
        // toward 1 (most severely at 1% density, where it rivals the
        // actual normalization work).
        let r_clone = bench_with(&format!("normalize {label} n={n} (clone only)"), budget, 8, &mut || {
            base.clone().len()
        });
        let r_ref = bench_with(
            &format!("normalize {label} n={n} (reference)"),
            budget,
            8,
            &mut || {
                let mut b = base.clone();
                norm::reference::bulk_normalize(&mut b, &ctx, None).total()
            },
        );
        let r_bulk = bench_with(
            &format!("normalize {label} n={n} (bulk)"),
            budget,
            8,
            &mut || {
                let mut b = base.clone();
                b.normalize_flagged(&ctx).total()
            },
        );
        println!("{}", r_clone.line());
        println!("{}", r_ref.line());
        println!("{}", r_bulk.line());
        let net = |r: &BenchResult| (r.ns_per_iter - r_clone.ns_per_iter).max(1.0);
        let (net_ref, net_bulk) = (net(&r_ref), net(&r_bulk));
        let ratio = net_bulk / net_ref;
        println!("  -> bulk/reference normalize cost ratio at {label} (clone netted out): {ratio:.3}");
        records.push(net_record(&format!("norm_reference_{label}_n{n}"), flagged, net_ref));
        records.push(net_record(&format!("norm_bulk_{label}_n{n}"), flagged, net_bulk));
        records.push(ratio_record(&format!("norm_bulk_cost_ratio_{label}"), ratio));
        if label == "d10" {
            gated_d10_ratio = ratio;
        }
    }

    // --- SIMD gather/scatter at the normalization stride ---------------
    // The bulk path's lane movement (flagged-scan → gather → rescale →
    // scatter) has an AVX2 arm behind the same dispatch-shim pattern as
    // the compute kernels. One machine-independent ratio at n = 4096,
    // 10% flagged density: the dispatched gather+scatter pair over the
    // scalar pair. Emitted only when [`plane::simd_active`] reports the
    // SIMD path is live, so the committed baseline never gates a
    // scalar-only host or a build without `--features simd`.
    {
        let lane_n = 4096usize;
        let src: Vec<u64> = (0..lane_n).map(|_| rng.next_u64()).collect();
        let idx: Vec<usize> = (0..lane_n).filter(|j| j % 10 == 0).collect();
        let mut out = vec![0u64; idx.len()];
        let mut back = vec![0u64; lane_n];
        let r_scalar =
            bench_with(&format!("gather+scatter d10 n={lane_n} (scalar)"), budget, 8, &mut || {
                plane::gather_lane_scalar(&src, &idx, &mut out);
                plane::scatter_lane_scalar(&mut back, &idx, &out);
                out[0]
            });
        println!("{}", r_scalar.line());
        if plane::simd_active() {
            let r_simd =
                bench_with(&format!("gather+scatter d10 n={lane_n} (simd)"), budget, 8, &mut || {
                    plane::gather_lane(&src, &idx, &mut out);
                    plane::scatter_lane(&mut back, &idx, &out);
                    out[0]
                });
            println!("{}", r_simd.line());
            let ratio = r_simd.ns_per_iter / r_scalar.ns_per_iter;
            println!("  -> simd/scalar gather+scatter cost ratio at d10: {ratio:.3}");
            records.push(ratio_record("norm_gather_scatter_simd_cost_ratio_n4096", ratio));
        } else {
            println!("  (simd path inactive: no gather/scatter dispatch record this run)");
        }
    }

    match write_json("BENCH_norm.json", &records) {
        Ok(()) => println!("\nwrote BENCH_norm.json ({} records)", records.len()),
        Err(e) => eprintln!("could not write BENCH_norm.json: {e}"),
    }

    // The protected invariant (also enforced by the CI gate against
    // ci/baselines/BENCH_norm.json): bulk normalization at ≤ 0.6× the
    // per-element reference cost at 10% flagged density. Asserted
    // outright in full mode only — quick-mode timings on shared runners
    // are too noisy to hard-fail.
    if !quick {
        assert!(
            gated_d10_ratio <= 0.6,
            "bulk normalize cost ratio {gated_d10_ratio:.3} exceeds 0.6 at 10% density"
        );
    }
}
