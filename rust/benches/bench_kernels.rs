//! Kernel microbench: the deferred-reduction planar kernels against their
//! per-element reference implementations (`rns::plane::reference`), plus
//! the batched CRT path against per-output reconstruction.
//!
//! Emits `BENCH_kernels.json` with two kinds of records:
//!
//! * absolute ns/op per kernel and size (machine-dependent), and
//! * same-run **cost ratios** `deferred / per-element` (machine-
//!   independent, the CI-gated invariant: the deferred lane dot must stay
//!   at ≤ 0.5× the per-element cost at n ≥ 4096).
//!
//! With `--features simd` on an AVX2 host it additionally records the
//! dispatched-vs-scalar lane_dot cost ratio (≤ 0.6× gated) and, in every
//! build flavor, the dispatch-shim overhead (≤ 1.05× gated).
//!
//! Quick mode for CI: `BENCH_QUICK=1 cargo bench --bench bench_kernels`
//! (or `--quick`).

mod common;

use std::time::Duration;

use hrfna::rns::barrett::barrett_set;
use hrfna::rns::moduli::DEFAULT_MODULI;
use hrfna::rns::plane::{self, reference};
use hrfna::rns::CrtContext;
use hrfna::util::bench::{bench_with, write_json, BenchRecord, BenchResult};
use hrfna::util::cli::Args;
use hrfna::util::prng::Rng;

fn ratio_record(name: &str, deferred: &BenchResult, per_element: &BenchResult) -> BenchRecord {
    let ratio = deferred.ns_per_iter / per_element.ns_per_iter.max(1e-9);
    BenchRecord {
        name: name.to_string(),
        n: 1,
        ns_per_op: ratio,
        // Speedup of the deferred path (higher is better) rides along in
        // the throughput column, mirroring serve_dot_planar_cost_ratio.
        throughput_per_s: 1.0 / ratio.max(1e-12),
    }
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick") || std::env::var("BENCH_QUICK").is_ok();
    common::banner(
        "§Perf kernels",
        if quick {
            "deferred vs per-element lane kernels (quick)"
        } else {
            "deferred vs per-element lane kernels"
        },
    );
    let budget = Duration::from_millis(if quick { 60 } else { 300 });
    let mut rng = Rng::new(7);
    let bars = barrett_set(&DEFAULT_MODULI);
    let bar = bars[0];
    let m = bar.m;
    let mut records: Vec<BenchRecord> = Vec::new();
    let sizes: &[usize] = if quick { &[4096] } else { &[1024, 4096, 65536] };

    let mut gated_dot_ratio_n4096 = f64::NAN;
    for &n in sizes {
        let x: Vec<u64> = (0..n).map(|_| rng.below(m)).collect();
        let y: Vec<u64> = (0..n).map(|_| rng.below(m)).collect();
        let mults: Vec<u64> = (0..n).map(|_| rng.below(m)).collect();

        // --- lane_mul (branch-free Barrett; one path, absolute only) ----
        let mut out = vec![0u64; n];
        let r = bench_with(&format!("lane_mul n={n}"), budget, 8, &mut || {
            plane::lane_mul(bar, &x, &y, &mut out);
            out[n - 1]
        });
        println!("{}", r.line());
        records.push(BenchRecord::from_result(
            &format!("kernel_lane_mul_n{n}"),
            n as u64,
            &r,
        ));

        // --- lane_scale: per-element Barrett vs Shoup ------------------
        let mult = mults[0];
        let r_ref = bench_with(&format!("lane_scale n={n} (reference)"), budget, 8, &mut || {
            reference::lane_scale(bar, &x, mult, &mut out);
            out[n - 1]
        });
        let r_shoup = bench_with(&format!("lane_scale n={n} (shoup)"), budget, 8, &mut || {
            plane::lane_scale(bar, &x, mult, &mut out);
            out[n - 1]
        });
        println!("{}", r_ref.line());
        println!("{}", r_shoup.line());
        records.push(BenchRecord::from_result(
            &format!("kernel_lane_scale_reference_n{n}"),
            n as u64,
            &r_ref,
        ));
        records.push(BenchRecord::from_result(
            &format!("kernel_lane_scale_shoup_n{n}"),
            n as u64,
            &r_shoup,
        ));

        // --- lane_dot: per-element vs deferred single-fold -------------
        let r_ref = bench_with(&format!("lane_dot n={n} (reference)"), budget, 8, &mut || {
            reference::lane_dot(bar, &x, &y)
        });
        let r_def = bench_with(&format!("lane_dot n={n} (deferred)"), budget, 8, &mut || {
            plane::lane_dot(bar, &x, &y)
        });
        println!("{}", r_ref.line());
        println!("{}", r_def.line());
        let ratio = r_def.ns_per_iter / r_ref.ns_per_iter;
        println!("  -> deferred/per-element lane_dot cost ratio at n={n}: {ratio:.3}");
        records.push(BenchRecord::from_result(
            &format!("kernel_lane_dot_reference_n{n}"),
            n as u64,
            &r_ref,
        ));
        records.push(BenchRecord::from_result(
            &format!("kernel_lane_dot_deferred_n{n}"),
            n as u64,
            &r_def,
        ));
        if n >= 4096 {
            records.push(ratio_record(
                &format!("kernel_lane_dot_cost_ratio_n{n}"),
                &r_def,
                &r_ref,
            ));
        }
        if n == 4096 {
            gated_dot_ratio_n4096 = ratio;
        }

        // --- lane_dot_scaled: per-element vs deferred ------------------
        let r_ref = bench_with(
            &format!("lane_dot_scaled n={n} (reference)"),
            budget,
            8,
            &mut || reference::lane_dot_scaled(bar, &x, &y, &mults),
        );
        let r_def = bench_with(
            &format!("lane_dot_scaled n={n} (deferred)"),
            budget,
            8,
            &mut || plane::lane_dot_scaled(bar, &x, &y, &mults),
        );
        println!("{}", r_ref.line());
        println!("{}", r_def.line());
        records.push(BenchRecord::from_result(
            &format!("kernel_lane_dot_scaled_deferred_n{n}"),
            n as u64,
            &r_def,
        ));

        // --- lane_fma: per-element vs deferred -------------------------
        let mut acc = vec![0u64; n];
        let r_ref = bench_with(&format!("lane_fma n={n} (reference)"), budget, 8, &mut || {
            reference::lane_fma(bar, &mut acc, &x, &y);
            acc[n - 1]
        });
        let mut acc2 = vec![0u64; n];
        let r_def = bench_with(&format!("lane_fma n={n} (deferred)"), budget, 8, &mut || {
            plane::lane_fma(bar, &mut acc2, &x, &y);
            acc2[n - 1]
        });
        println!("{}", r_ref.line());
        println!("{}", r_def.line());
        records.push(BenchRecord::from_result(
            &format!("kernel_lane_fma_reference_n{n}"),
            n as u64,
            &r_ref,
        ));
        records.push(BenchRecord::from_result(
            &format!("kernel_lane_fma_deferred_n{n}"),
            n as u64,
            &r_def,
        ));
        if n == 4096 {
            records.push(ratio_record("kernel_lane_fma_cost_ratio_n4096", &r_def, &r_ref));
        }
    }

    // --- SIMD dispatch records at the gated size -----------------------
    // Two machine-independent ratios pinned at n = 4096:
    //
    //  * `kernel_simd_lane_dot_cost_ratio_n4096` — the dispatched kernel
    //    (AVX2 when active) over the scalar deferred kernel. Emitted only
    //    when [`plane::simd_active`] reports the SIMD path is live, so
    //    the committed baseline never gates a scalar-only host or a build
    //    without `--features simd`.
    //  * `kernel_lane_dot_dispatch_overhead_n4096` — the dispatch shim
    //    forced down its scalar arm over the raw scalar kernel: the
    //    runtime feature branch must be (near) free in every build flavor.
    {
        let n = 4096;
        let x: Vec<u64> = (0..n).map(|_| rng.below(m)).collect();
        let y: Vec<u64> = (0..n).map(|_| rng.below(m)).collect();
        let r_scalar = bench_with(&format!("lane_dot n={n} (scalar)"), budget, 8, &mut || {
            plane::lane_dot_scalar(bar, &x, &y)
        });
        println!("{}", r_scalar.line());
        if plane::simd_active() {
            let r_simd = bench_with(&format!("lane_dot n={n} (simd)"), budget, 8, &mut || {
                plane::lane_dot(bar, &x, &y)
            });
            println!("{}", r_simd.line());
            let simd_ratio = r_simd.ns_per_iter / r_scalar.ns_per_iter;
            println!("  -> simd/scalar lane_dot cost ratio at n={n}: {simd_ratio:.3}");
            records.push(ratio_record(
                "kernel_simd_lane_dot_cost_ratio_n4096",
                &r_simd,
                &r_scalar,
            ));
            if !quick {
                assert!(
                    simd_ratio <= 0.6,
                    "AVX2 lane_dot cost ratio {simd_ratio:.3} exceeds 0.6 at n=4096"
                );
            }
        } else {
            println!("  (simd path inactive: no AVX2 dispatch record this run)");
        }
        let r_shim = bench_with(&format!("lane_dot n={n} (dispatch/scalar)"), budget, 8, &mut || {
            plane::lane_dot_dispatch_scalar(bar, &x, &y)
        });
        println!("{}", r_shim.line());
        let shim_ratio = r_shim.ns_per_iter / r_scalar.ns_per_iter;
        println!("  -> dispatch-shim overhead at n={n}: {shim_ratio:.3}x scalar");
        records.push(ratio_record(
            "kernel_lane_dot_dispatch_overhead_n4096",
            &r_shim,
            &r_scalar,
        ));
        if !quick {
            assert!(
                shim_ratio <= 1.05,
                "lane_dot dispatch shim overhead {shim_ratio:.3} exceeds 1.05x at n=4096"
            );
        }
    }

    // --- batched CRT vs per-output reconstruction ----------------------
    // Fixed batch size in both modes so the record names (and thus the
    // committed baseline) stay stable; quick mode only shortens the
    // per-case time budget.
    let crt = CrtContext::new(&DEFAULT_MODULI);
    let b = 1024;
    let k = crt.k();
    let mut lanes = vec![0u64; k * b];
    for j in 0..b {
        let v = rng.next_u64();
        for (c, &mc) in DEFAULT_MODULI.iter().enumerate() {
            lanes[c * b + j] = v % mc;
        }
    }
    let r_per = bench_with(&format!("crt signed b={b} (per-output)"), budget, 8, &mut || {
        let mut negs = 0usize;
        for j in 0..b {
            let rv = hrfna::rns::ResidueVec {
                r: (0..k).map(|c| lanes[c * b + j]).collect(),
            };
            let (neg, _) = crt.reconstruct_signed(&rv);
            negs += neg as usize;
        }
        negs
    });
    let r_batch = bench_with(&format!("crt signed b={b} (batched)"), budget, 8, &mut || {
        crt.reconstruct_signed_batch(&lanes, b).len()
    });
    println!("{}", r_per.line());
    println!("{}", r_batch.line());
    records.push(BenchRecord::from_result(
        &format!("kernel_crt_signed_per_output_b{b}"),
        b as u64,
        &r_per,
    ));
    records.push(BenchRecord::from_result(
        &format!("kernel_crt_signed_batch_b{b}"),
        b as u64,
        &r_batch,
    ));
    records.push(ratio_record("kernel_crt_batch_cost_ratio", &r_batch, &r_per));

    match write_json("BENCH_kernels.json", &records) {
        Ok(()) => println!("\nwrote BENCH_kernels.json ({} records)", records.len()),
        Err(e) => eprintln!("could not write BENCH_kernels.json: {e}"),
    }

    // The protected invariant (also enforced by the CI gate against
    // ci/baselines/BENCH_kernels.json): deferred lane_dot at ≤ 0.5× the
    // per-element cost at n = 4096. Asserted outright in full mode only —
    // quick-mode timings on shared runners are too noisy to hard-fail.
    if !quick {
        assert!(
            gated_dot_ratio_n4096 <= 0.5,
            "deferred lane_dot cost ratio {gated_dot_ratio_n4096:.3} exceeds 0.5 at n=4096"
        );
    }
}
