//! Wire-serving benchmark (`--features rpc`): jobs/sec through the full
//! network edge — JSON encode → length-prefix frame → TCP → server
//! decode → backend → result encode → client decode — against the
//! in-process serving path measured on the *same* backend in the
//! same run. Records `BENCH_rpc.json`; CI gates it `--strict` against
//! `ci/baselines/BENCH_rpc.json`.
//!
//! Absolute jobs/sec drifts with runner hardware, so the protected
//! invariants are ratio records measured within one run:
//!
//! * `rpc_wire_overhead_ratio` — socket per-job cost over in-process
//!   per-job cost (how much the wire costs),
//! * `rpc_conn_reuse_cost_ratio` — persistent-connection per-job cost
//!   over reconnect-per-job cost (what connection reuse saves; the
//!   persistent closed loop is the fix this records).
//!
//! Quick mode for CI: `BENCH_QUICK=1 cargo bench --features rpc --bench
//! bench_rpc` (or `--quick`).

mod common;

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::router::ShapeBuckets;
use hrfna::coordinator::rpc::{
    decode_payload, encode_payload, socket_closed_loop, socket_closed_loop_binary, spec_to_json,
    ConnMode, Request, RpcServer, RpcServerConfig,
};
use hrfna::coordinator::{
    closed_loop, Backend, ContextRegistry, Coordinator, CoordinatorConfig, ExecMode, InProcess,
    JobSpec, Tier,
};
use hrfna::util::bench::{write_json, BenchRecord};
use hrfna::util::cli::Args;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::{Dist, ServeMix};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Dot length for the wire runs: the small shape bucket, so the records
/// measure protocol overhead rather than kernel time.
const DOT_N: usize = 512;
const CLIENTS: usize = 4;
const BURST: usize = 8;

fn backend() -> InProcess {
    let engine = hrfna::runtime::EngineHandle::spawn(None).expect("engine");
    InProcess::new(Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
                capacity: 4096,
            },
            buckets: ShapeBuckets { tiers: Tier::ALL.to_vec(), ..ShapeBuckets::default() },
            exec: ExecMode::Planar,
            ..CoordinatorConfig::default()
        },
    ))
}

fn job_record(name: &str, completed: usize, wall: Duration, jobs_per_s: f64) -> BenchRecord {
    BenchRecord {
        name: name.to_string(),
        n: completed as u64,
        ns_per_op: wall.as_nanos() as f64 / completed.max(1) as f64,
        throughput_per_s: jobs_per_s,
    }
}

fn main() {
    common::banner("§RPC", "jobs/sec over the wire vs in-process serving");
    let args = Args::from_env();
    let quick = args.flag("quick") || std::env::var("BENCH_QUICK").is_ok();
    let jobs_per_client = if quick { 48 } else { 192 };
    let reconnect_jobs = if quick { 16 } else { 64 };

    // Shared operand pool so generation stays out of every measured loop.
    let mut rng = Rng::new(2026);
    let pool: Vec<(Vec<f64>, Vec<f64>)> = (0..16)
        .map(|_| {
            (
                Dist::moderate().sample_vec(&mut rng, DOT_N),
                Dist::moderate().sample_vec(&mut rng, DOT_N),
            )
        })
        .collect();
    let make_dot = |c: u64, i: usize| -> JobSpec {
        let (x, y) = &pool[(c as usize * 7 + i) % pool.len()];
        JobSpec::dot(x.clone(), y.clone())
    };
    let mix = ServeMix::default_mix();
    let make_tiered = |c: u64, i: usize| -> JobSpec {
        make_dot(c, i).tier(mix.tier_for(i))
    };

    let be: Arc<InProcess> = Arc::new(backend());
    let server = RpcServer::bind(
        Arc::clone(&be) as Arc<dyn Backend>,
        "127.0.0.1:0",
        RpcServerConfig::default(),
    )
    .expect("bind rpc server");
    let addr = server.local_addr().to_string();
    println!("rpc server on {addr}");

    // Warmup both paths (threadpool spin-up, first allocations, one
    // full wire round trip per client slot).
    for _ in 0..4 {
        be.call(make_dot(0, 0)).expect("warmup job");
    }
    let warm = socket_closed_loop(&addr, CLIENTS, 2, BURST, ConnMode::Persistent, &make_dot);
    assert_eq!(warm.completed, warm.offered, "warmup lost jobs");

    let mut records: Vec<BenchRecord> = Vec::new();

    // 1. In-process baseline on the same backend — the comparator
    //    every wire number is measured against.
    let inproc = closed_loop(be.as_ref(), CLIENTS, jobs_per_client, BURST, &make_dot);
    assert_eq!(inproc.completed, inproc.offered, "in-process run lost jobs");
    println!(
        "in-process dot n={DOT_N}: {:.0} jobs/s ({} jobs in {:.2?})",
        inproc.jobs_per_s, inproc.completed, inproc.wall
    );

    // 2. Persistent-connection socket run (the steady-state mode).
    let persist = socket_closed_loop(
        &addr,
        CLIENTS,
        jobs_per_client,
        BURST,
        ConnMode::Persistent,
        &make_dot,
    );
    assert_eq!(persist.completed, persist.offered, "persistent run lost jobs");
    let lat = persist.latency_us.as_ref().expect("latencies");
    println!(
        "socket persistent: {:.0} jobs/s (p50 {:.0} us, p99 {:.0} us)",
        persist.jobs_per_s, lat.p50, lat.p99
    );
    records.push(job_record(
        "rpc_dot_persistent_jobs",
        persist.completed,
        persist.wall,
        persist.jobs_per_s,
    ));

    // Machine-independent: wire cost relative to in-process cost in the
    // same run (ns_per_op = socket/in-proc per-job cost, lower is
    // better; throughput_per_s = fraction of in-process throughput the
    // wire retains, higher is better).
    let overhead = inproc.jobs_per_s / persist.jobs_per_s.max(1e-9);
    println!("-> wire overhead: {overhead:.2}x in-process per-job cost");
    records.push(BenchRecord {
        name: "rpc_wire_overhead_ratio".to_string(),
        n: 1,
        ns_per_op: overhead,
        throughput_per_s: 1.0 / overhead.max(1e-9),
    });

    // 3. Reconnect-per-job (the anti-pattern, kept measurable).
    let recon = socket_closed_loop(
        &addr,
        CLIENTS,
        reconnect_jobs,
        1,
        ConnMode::PerJob,
        &make_dot,
    );
    assert_eq!(recon.completed, recon.offered, "reconnect run lost jobs");
    println!("socket reconnect-per-job: {:.0} jobs/s", recon.jobs_per_s);
    records.push(job_record(
        "rpc_dot_reconnect_jobs",
        recon.completed,
        recon.wall,
        recon.jobs_per_s,
    ));
    let reuse_speedup = persist.jobs_per_s / recon.jobs_per_s.max(1e-9);
    println!("-> connection reuse: {reuse_speedup:.2}x reconnect-per-job throughput");
    records.push(BenchRecord {
        name: "rpc_conn_reuse_cost_ratio".to_string(),
        n: 1,
        ns_per_op: 1.0 / reuse_speedup.max(1e-9),
        throughput_per_s: reuse_speedup,
    });
    if !quick {
        assert!(
            reuse_speedup >= 1.0,
            "persistent connections must not be slower than reconnect-per-job \
             (got {reuse_speedup:.2}x)"
        );
    }

    // 4. Mixed-tier traffic over the wire: lo/paper/wide interleaved
    //    3:5:2, the remote counterpart of serve_mixed_tier_dot_jobs.
    let tiered = socket_closed_loop(
        &addr,
        CLIENTS,
        jobs_per_client,
        BURST,
        ConnMode::Persistent,
        &make_tiered,
    );
    assert_eq!(tiered.completed, tiered.offered, "tiered run lost jobs");
    assert_eq!(
        be.with_coordinator(|c| c.metrics.total_escalations()).expect("live coordinator"),
        0,
        "moderate-range traffic must not escalate"
    );
    println!(
        "socket mixed tiers: {} jobs in {:.2?} ({:.0} jobs/s)",
        tiered.completed, tiered.wall, tiered.jobs_per_s
    );
    records.push(job_record(
        "rpc_mixed_tier_socket_jobs",
        tiered.completed,
        tiered.wall,
        tiered.jobs_per_s,
    ));

    // 5. Binary wire payloads: the same matmul traffic (dim 64 — bulk
    //    operands, where framing matters) over pure-JSON frames and over
    //    negotiated binary envelopes. The wire metrics give exact bytes
    //    moved per leg; the ratio is the compression the binary framing
    //    buys on operand-heavy jobs.
    let mm_jobs = if quick { 16 } else { 64 };
    let mm_pool: Vec<(Vec<f64>, Vec<f64>)> = (0..4)
        .map(|_| {
            (
                Dist::moderate().sample_vec(&mut rng, 64 * 64),
                Dist::moderate().sample_vec(&mut rng, 64 * 64),
            )
        })
        .collect();
    let make_mm = |c: u64, i: usize| -> JobSpec {
        let (a, b) = &mm_pool[(c as usize * 3 + i) % mm_pool.len()];
        JobSpec::matmul(a.clone(), b.clone(), 64)
    };
    let wire_now = || {
        let t = server.wire_metrics().totals();
        t.bytes_in() + t.bytes_out()
    };
    let leg = |binary: bool| -> f64 {
        let before = wire_now();
        let rep = socket_closed_loop_binary(
            &addr,
            CLIENTS,
            mm_jobs,
            BURST,
            ConnMode::Persistent,
            binary,
            &make_mm,
        );
        assert_eq!(rep.completed, rep.offered, "binary={binary} matmul leg lost jobs");
        (wire_now() - before) as f64 / rep.completed.max(1) as f64
    };
    let json_bytes_per_job = leg(false);
    let bin_bytes_per_job = leg(true);
    assert!(
        server.wire_metrics().totals().bin_frames_out() > 0,
        "binary leg must negotiate and actually send binary responses"
    );
    let bytes_ratio = bin_bytes_per_job / json_bytes_per_job.max(1e-9);
    println!(
        "matmul d64 wire bytes/job: json {:.0}, binary {:.0} -> {bytes_ratio:.2}x",
        json_bytes_per_job, bin_bytes_per_job
    );
    records.push(BenchRecord {
        name: "rpc_wire_bytes_per_job".to_string(),
        n: 1,
        ns_per_op: bytes_ratio,
        throughput_per_s: 1.0 / bytes_ratio.max(1e-9),
    });
    if !quick {
        assert!(
            bytes_ratio <= 0.4,
            "binary framing must move <= 0.4x the JSON bytes per matmul job \
             (got {bytes_ratio:.2}x)"
        );
    }

    // 6. Encode/decode CPU cost for the same frame, measured off the
    //    socket: one submit request round-tripped through each codec.
    //    Binary must be cheaper — it copies bits instead of formatting
    //    and parsing shortest-round-trip decimals.
    let (a, b) = &mm_pool[0];
    let req = Request::new(1, "submit", spec_to_json(&JobSpec::matmul(a.clone(), b.clone(), 64)))
        .to_json();
    let iters: u32 = if quick { 50 } else { 400 };
    let time_codec = |binary: bool| -> Duration {
        // One warmup round trip outside the clock.
        let bytes = encode_payload(&req, binary);
        decode_payload(&bytes).expect("codec warmup");
        let t0 = Instant::now();
        for _ in 0..iters {
            let bytes = encode_payload(&req, binary);
            let tree = decode_payload(&bytes).expect("codec round trip");
            std::hint::black_box(tree);
        }
        t0.elapsed()
    };
    let json_codec = time_codec(false);
    let bin_codec = time_codec(true);
    let codec_ratio = bin_codec.as_secs_f64() / json_codec.as_secs_f64().max(1e-12);
    println!(
        "matmul d64 codec cost: json {:.1?}, binary {:.1?} -> {codec_ratio:.2}x",
        json_codec / iters,
        bin_codec / iters
    );
    records.push(BenchRecord {
        name: "rpc_binary_encode_cost_ratio".to_string(),
        n: 1,
        ns_per_op: codec_ratio,
        throughput_per_s: 1.0 / codec_ratio.max(1e-9),
    });
    if !quick {
        assert!(
            codec_ratio <= 0.6,
            "binary codec must cost <= 0.6x the JSON codec per frame (got {codec_ratio:.2}x)"
        );
    }

    // Tear the edge down and account for every job. `InProcess::shutdown`
    // takes the coordinator out from under the shared Arc — no
    // `Arc::try_unwrap` teardown dance against the server's clone.
    let wire = server.stop();
    wire.table().print();
    assert!(wire.conns_opened() >= CLIENTS as u64, "persistent conns registered");
    assert_eq!(wire.conns_opened(), wire.conns_closed(), "leaked connections");
    assert_eq!(wire.protocol_errors(), 0, "bench traffic must be well-formed");
    println!("{}", be.metrics_text());
    let drain = be.shutdown().expect("shutdown");
    assert!(drain.is_clean(), "unclean drain after rpc load: {drain}");

    match write_json("BENCH_rpc.json", &records) {
        Ok(()) => println!("\nwrote BENCH_rpc.json ({} records)", records.len()),
        Err(e) => eprintln!("could not write BENCH_rpc.json: {e}"),
    }
}
