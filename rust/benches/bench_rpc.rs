//! Wire-serving benchmark (`--features rpc`): jobs/sec through the full
//! network edge — JSON encode → length-prefix frame → TCP → server
//! decode → backend → result encode → client decode — against the
//! in-process serving path measured on the *same* backend in the
//! same run. Records `BENCH_rpc.json`; CI gates it `--strict` against
//! `ci/baselines/BENCH_rpc.json`.
//!
//! Absolute jobs/sec drifts with runner hardware, so the protected
//! invariants are ratio records measured within one run:
//!
//! * `rpc_wire_overhead_ratio` — socket per-job cost over in-process
//!   per-job cost (how much the wire costs),
//! * `rpc_conn_reuse_cost_ratio` — persistent-connection per-job cost
//!   over reconnect-per-job cost (what connection reuse saves; the
//!   persistent closed loop is the fix this records).
//!
//! Quick mode for CI: `BENCH_QUICK=1 cargo bench --features rpc --bench
//! bench_rpc` (or `--quick`).

mod common;

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::router::ShapeBuckets;
use hrfna::coordinator::rpc::{socket_closed_loop, ConnMode, RpcServer, RpcServerConfig};
use hrfna::coordinator::{
    closed_loop, Backend, ContextRegistry, Coordinator, CoordinatorConfig, ExecMode, InProcess,
    JobSpec, Tier,
};
use hrfna::util::bench::{write_json, BenchRecord};
use hrfna::util::cli::Args;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::{Dist, ServeMix};
use std::sync::Arc;
use std::time::Duration;

/// Dot length for the wire runs: the small shape bucket, so the records
/// measure protocol overhead rather than kernel time.
const DOT_N: usize = 512;
const CLIENTS: usize = 4;
const BURST: usize = 8;

fn backend() -> InProcess {
    let engine = hrfna::runtime::EngineHandle::spawn(None).expect("engine");
    InProcess::new(Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
                capacity: 4096,
            },
            buckets: ShapeBuckets { tiers: Tier::ALL.to_vec(), ..ShapeBuckets::default() },
            exec: ExecMode::Planar,
            ..CoordinatorConfig::default()
        },
    ))
}

fn job_record(name: &str, completed: usize, wall: Duration, jobs_per_s: f64) -> BenchRecord {
    BenchRecord {
        name: name.to_string(),
        n: completed as u64,
        ns_per_op: wall.as_nanos() as f64 / completed.max(1) as f64,
        throughput_per_s: jobs_per_s,
    }
}

fn main() {
    common::banner("§RPC", "jobs/sec over the wire vs in-process serving");
    let args = Args::from_env();
    let quick = args.flag("quick") || std::env::var("BENCH_QUICK").is_ok();
    let jobs_per_client = if quick { 48 } else { 192 };
    let reconnect_jobs = if quick { 16 } else { 64 };

    // Shared operand pool so generation stays out of every measured loop.
    let mut rng = Rng::new(2026);
    let pool: Vec<(Vec<f64>, Vec<f64>)> = (0..16)
        .map(|_| {
            (
                Dist::moderate().sample_vec(&mut rng, DOT_N),
                Dist::moderate().sample_vec(&mut rng, DOT_N),
            )
        })
        .collect();
    let make_dot = |c: u64, i: usize| -> JobSpec {
        let (x, y) = &pool[(c as usize * 7 + i) % pool.len()];
        JobSpec::dot(x.clone(), y.clone())
    };
    let mix = ServeMix::default_mix();
    let make_tiered = |c: u64, i: usize| -> JobSpec {
        make_dot(c, i).tier(mix.tier_for(i))
    };

    let be: Arc<InProcess> = Arc::new(backend());
    let server = RpcServer::bind(
        Arc::clone(&be) as Arc<dyn Backend>,
        "127.0.0.1:0",
        RpcServerConfig::default(),
    )
    .expect("bind rpc server");
    let addr = server.local_addr().to_string();
    println!("rpc server on {addr}");

    // Warmup both paths (threadpool spin-up, first allocations, one
    // full wire round trip per client slot).
    for _ in 0..4 {
        be.call(make_dot(0, 0)).expect("warmup job");
    }
    let warm = socket_closed_loop(&addr, CLIENTS, 2, BURST, ConnMode::Persistent, &make_dot);
    assert_eq!(warm.completed, warm.offered, "warmup lost jobs");

    let mut records: Vec<BenchRecord> = Vec::new();

    // 1. In-process baseline on the same backend — the comparator
    //    every wire number is measured against.
    let inproc = closed_loop(be.as_ref(), CLIENTS, jobs_per_client, BURST, &make_dot);
    assert_eq!(inproc.completed, inproc.offered, "in-process run lost jobs");
    println!(
        "in-process dot n={DOT_N}: {:.0} jobs/s ({} jobs in {:.2?})",
        inproc.jobs_per_s, inproc.completed, inproc.wall
    );

    // 2. Persistent-connection socket run (the steady-state mode).
    let persist = socket_closed_loop(
        &addr,
        CLIENTS,
        jobs_per_client,
        BURST,
        ConnMode::Persistent,
        &make_dot,
    );
    assert_eq!(persist.completed, persist.offered, "persistent run lost jobs");
    let lat = persist.latency_us.as_ref().expect("latencies");
    println!(
        "socket persistent: {:.0} jobs/s (p50 {:.0} us, p99 {:.0} us)",
        persist.jobs_per_s, lat.p50, lat.p99
    );
    records.push(job_record(
        "rpc_dot_persistent_jobs",
        persist.completed,
        persist.wall,
        persist.jobs_per_s,
    ));

    // Machine-independent: wire cost relative to in-process cost in the
    // same run (ns_per_op = socket/in-proc per-job cost, lower is
    // better; throughput_per_s = fraction of in-process throughput the
    // wire retains, higher is better).
    let overhead = inproc.jobs_per_s / persist.jobs_per_s.max(1e-9);
    println!("-> wire overhead: {overhead:.2}x in-process per-job cost");
    records.push(BenchRecord {
        name: "rpc_wire_overhead_ratio".to_string(),
        n: 1,
        ns_per_op: overhead,
        throughput_per_s: 1.0 / overhead.max(1e-9),
    });

    // 3. Reconnect-per-job (the anti-pattern, kept measurable).
    let recon = socket_closed_loop(
        &addr,
        CLIENTS,
        reconnect_jobs,
        1,
        ConnMode::PerJob,
        &make_dot,
    );
    assert_eq!(recon.completed, recon.offered, "reconnect run lost jobs");
    println!("socket reconnect-per-job: {:.0} jobs/s", recon.jobs_per_s);
    records.push(job_record(
        "rpc_dot_reconnect_jobs",
        recon.completed,
        recon.wall,
        recon.jobs_per_s,
    ));
    let reuse_speedup = persist.jobs_per_s / recon.jobs_per_s.max(1e-9);
    println!("-> connection reuse: {reuse_speedup:.2}x reconnect-per-job throughput");
    records.push(BenchRecord {
        name: "rpc_conn_reuse_cost_ratio".to_string(),
        n: 1,
        ns_per_op: 1.0 / reuse_speedup.max(1e-9),
        throughput_per_s: reuse_speedup,
    });
    if !quick {
        assert!(
            reuse_speedup >= 1.0,
            "persistent connections must not be slower than reconnect-per-job \
             (got {reuse_speedup:.2}x)"
        );
    }

    // 4. Mixed-tier traffic over the wire: lo/paper/wide interleaved
    //    3:5:2, the remote counterpart of serve_mixed_tier_dot_jobs.
    let tiered = socket_closed_loop(
        &addr,
        CLIENTS,
        jobs_per_client,
        BURST,
        ConnMode::Persistent,
        &make_tiered,
    );
    assert_eq!(tiered.completed, tiered.offered, "tiered run lost jobs");
    assert_eq!(
        be.with_coordinator(|c| c.metrics.total_escalations()).expect("live coordinator"),
        0,
        "moderate-range traffic must not escalate"
    );
    println!(
        "socket mixed tiers: {} jobs in {:.2?} ({:.0} jobs/s)",
        tiered.completed, tiered.wall, tiered.jobs_per_s
    );
    records.push(job_record(
        "rpc_mixed_tier_socket_jobs",
        tiered.completed,
        tiered.wall,
        tiered.jobs_per_s,
    ));

    // Tear the edge down and account for every job. `InProcess::shutdown`
    // takes the coordinator out from under the shared Arc — no
    // `Arc::try_unwrap` teardown dance against the server's clone.
    let wire = server.stop();
    wire.table().print();
    assert!(wire.conns_opened() >= CLIENTS as u64, "persistent conns registered");
    assert_eq!(wire.conns_opened(), wire.conns_closed(), "leaked connections");
    assert_eq!(wire.protocol_errors(), 0, "bench traffic must be well-formed");
    println!("{}", be.metrics_text());
    let drain = be.shutdown().expect("shutdown");
    assert!(drain.is_clean(), "unclean drain after rpc load: {drain}");

    match write_json("BENCH_rpc.json", &records) {
        Ok(()) => println!("\nwrote BENCH_rpc.json ({} records)", records.len()),
        Err(e) => eprintln!("could not write BENCH_rpc.json: {e}"),
    }
}
