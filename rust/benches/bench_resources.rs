//! §I/§VII headline: 38–55% LUT reduction vs FP32 at iso-throughput, with
//! the full resource comparison tables and a k/width sweep showing where
//! the reduction band comes from.

mod common;

use hrfna::config::HrfnaConfig;
use hrfna::fpga::pipeline::WorkloadKind;
use hrfna::fpga::report::{lut_reduction_vs_fp32, resource_table};
use hrfna::rns::moduli::generate_prime_moduli;
use hrfna::util::table::Table;

fn main() {
    common::banner("§I / §VII", "iso-throughput FPGA resources (LUT reduction)");
    let cfg = HrfnaConfig::paper_default();

    for kind in [
        WorkloadKind::Dot { n: 65536 },
        WorkloadKind::Matmul { m: 64, k: 64, n: 64 },
        WorkloadKind::Matmul { m: 128, k: 128, n: 128 },
    ] {
        resource_table(&cfg, kind, 16).print();
        let red = lut_reduction_vs_fp32(&cfg, kind, 16);
        println!("  -> LUT reduction vs FP32: {:.0}%\n", red * 100.0);
    }

    // Reduction depends on the accumulation-dependence of the workload:
    // the paper's 38–55% band is spanned by the dot-product-style kernels
    // across configurations.
    let dot = WorkloadKind::Dot { n: 65536 };
    let r = lut_reduction_vs_fp32(&cfg, dot, 16);
    assert!(
        (0.35..=0.60).contains(&r),
        "dot LUT reduction {r} outside paper band"
    );

    // --- configuration sweep --------------------------------------------
    let mut t = Table::new(
        "LUT reduction sweep (dot, iso-throughput) over k and width",
        &["k", "width", "M bits", "reduction %"],
    );
    for k in [6usize, 8, 10] {
        for width in [12u32, 16] {
            let moduli = generate_prime_moduli(k, width);
            let m_bits: f64 = moduli.iter().map(|&m| (m as f64).log2()).sum();
            let cfg = HrfnaConfig {
                moduli,
                tau_bits: (m_bits as u32).saturating_sub(16),
                sig_bits: ((m_bits / 4.0) as u32).clamp(12, 40),
                scale_step: 16,
                ..HrfnaConfig::paper_default()
            };
            if cfg.validate().is_err() {
                continue;
            }
            let red = lut_reduction_vs_fp32(&cfg, dot, 16);
            t.rowv(&[
                k.to_string(),
                width.to_string(),
                format!("{m_bits:.0}"),
                format!("{:.0}", red * 100.0),
            ]);
        }
    }
    t.print();
    println!("paper: 38-55% LUT reduction vs IEEE-754 FP32 baselines");
}
