//! Figure-style series (§VII-B.3): RMS error as a function of vector
//! length for HRFNA / FP32 / BFP — HRFNA flat, FP32 slow growth, BFP
//! clear growth. Prints the series the paper plots.

mod common;

use hrfna::baselines::{Bfp, BfpConfig, Fixed, FixedConfig, Lns, LnsConfig};
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::util::table::Table;
use hrfna::workloads::{dot, generators::Dist};

fn main() {
    common::banner("§VII-B fig", "RMS error vs vector length (all formats)");
    let trials = 3;
    let mut t = Table::new(
        "relative RMS error vs f64 (moderate operands)",
        &["n", "HRFNA", "FP32", "BFP", "Fixed", "LNS"],
    );
    let mut series: Vec<(usize, f64, f64)> = Vec::new();
    let mut n = 1024usize;
    while n <= 65536 {
        let hctx = HrfnaContext::paper_default();
        let h = dot::dot_rms_error::<Hrfna>(trials, n, Dist::moderate(), 1, &hctx);
        let f = dot::dot_rms_error::<f32>(trials, n, Dist::moderate(), 1, &());
        let b = dot::dot_rms_error::<Bfp>(trials, n, Dist::moderate(), 1, &BfpConfig::default());
        let fx = dot::dot_rms_error::<Fixed>(trials, n, Dist::moderate(), 1, &FixedConfig::q16_16());
        let l = dot::dot_rms_error::<Lns>(trials, n, Dist::moderate(), 1, &LnsConfig::default());
        t.rowv(&[
            n.to_string(),
            format!("{h:.2e}"),
            format!("{f:.2e}"),
            format!("{b:.2e}"),
            format!("{fx:.2e}"),
            format!("{l:.2e}"),
        ]);
        series.push((n, h, b));
        n *= 2;
    }
    t.print();

    // Shape assertions: HRFNA flat (< 10x from first to last), BFP grows.
    let (first_h, last_h) = (series[0].1, series.last().unwrap().1);
    let (first_b, last_b) = (series[0].2, series.last().unwrap().2);
    assert!(
        last_h < first_h * 20.0,
        "HRFNA error must stay ~flat: {first_h:.2e} -> {last_h:.2e}"
    );
    assert!(
        last_b > first_b * 2.0,
        "BFP error must grow with N: {first_b:.2e} -> {last_b:.2e}"
    );
    println!("shape check OK: HRFNA flat in N, BFP grows (paper Fig/§VII-B)");
}
