//! Table III (RK4 ODE Solver rows): bounded error over long horizons —
//! HRFNA stable and FP32-class, BFP drifts (paper runs 1e6 steps; default
//! here 200k for bench runtime; pass --full via env HRFNA_RK4_FULL=1).

mod common;

use hrfna::baselines::{Bfp, BfpConfig};
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::util::table::{eng, Table};
use hrfna::workloads::rk4::{rk4_integrate, Ode};

fn main() {
    common::banner("Table III / §VII-D", "iterative RK4 ODE solver");
    let steps: u64 = if std::env::var("HRFNA_RK4_FULL").is_ok() {
        1_000_000
    } else {
        200_000
    };
    let dt = 0.002;
    let every = steps / 10;

    for (name, ode) in [
        ("Van der Pol (mu=1)", Ode::VanDerPol { mu: 1.0 }),
        (
            "damped oscillator",
            Ode::DampedOscillator { omega: 1.0, zeta: 0.05 },
        ),
    ] {
        let ctx = HrfnaContext::paper_default();
        let y0 = ode.default_y0();
        let h = rk4_integrate::<Hrfna>(&ode, &y0, dt, steps, every, &ctx);
        let f = rk4_integrate::<f32>(&ode, &y0, dt, steps, every, &());
        let b = rk4_integrate::<Bfp>(&ode, &y0, dt, steps, every, &BfpConfig::default());
        let snap = ctx.snapshot();

        let mut t = Table::new(
            &format!("{name}: {steps} steps, dt={dt}"),
            &["format", "max err vs f64", "drift ratio", "norm/op"],
        );
        t.rowv(&[
            "HRFNA".to_string(),
            eng(h.max_error()),
            format!("{:.2}", h.drift_ratio()),
            format!("{:.1e}", snap.norm_rate()),
        ]);
        t.rowv(&[
            "FP32".to_string(),
            eng(f.max_error()),
            format!("{:.2}", f.drift_ratio()),
            "-".to_string(),
        ]);
        t.rowv(&[
            "BFP".to_string(),
            eng(b.max_error()),
            format!("{:.2}", b.drift_ratio()),
            "-".to_string(),
        ]);
        t.print();

        // Paper claims: bounded (finite, no blowup), FP32-class.
        assert!(h.final_state.iter().all(|v| v.is_finite()));
        assert!(
            h.max_error() <= f.max_error() * 2.0 + 1e-9,
            "{name}: HRFNA {} vs FP32 {}",
            h.max_error(),
            f.max_error()
        );
    }
    println!("paper: HRFNA bounded over 1e6 steps, matches FP32; BFP error increases");
}
