//! Shared helpers for the benchmark harness (each bench regenerates one of
//! the paper's tables or figure series; `cargo bench` runs them all).

#![allow(dead_code)]

use hrfna::fpga::pipeline::{model_workload, WorkloadKind, WorkloadTiming};
use hrfna::fpga::resources::FormatArch;
use hrfna::config::HrfnaConfig;

/// Pretty-print a bench banner so `cargo bench` output is navigable.
pub fn banner(paper_ref: &str, what: &str) {
    println!("\n================================================================");
    println!("### {paper_ref}: {what}");
    println!("================================================================");
}

/// Modeled timing for all four formats on one workload.
pub fn timings_for(
    cfg: &HrfnaConfig,
    kind: WorkloadKind,
    hrfna_norm_events: u64,
) -> Vec<WorkloadTiming> {
    [
        FormatArch::Hrfna,
        FormatArch::Fp32,
        FormatArch::Bfp,
        FormatArch::Fixed,
    ]
    .iter()
    .map(|&f| {
        let events = if f == FormatArch::Hrfna { hrfna_norm_events } else { 0 };
        model_workload(f, kind, cfg, events)
    })
    .collect()
}
