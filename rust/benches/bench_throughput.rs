//! §VII headline: up to 2.4× throughput vs FP32. Modeled workload timing
//! for every format × workload, plus *measured* wall-clock of the real
//! PJRT kernels and the software MAC loop for the record (absolute numbers
//! are host-CPU, not FPGA — the model carries the FPGA claim; see
//! DESIGN.md substitution table).

mod common;

use hrfna::config::HrfnaConfig;
use hrfna::fpga::pipeline::{speedup, WorkloadKind};
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::util::bench::bench;
use hrfna::util::prng::Rng;
use hrfna::util::table::Table;
use hrfna::workloads::dot::dot_product_encoded;
use hrfna::workloads::generators::Dist;
use hrfna::workloads::traits::Numeric;

fn main() {
    common::banner("§VII", "throughput: modeled FPGA + measured host");
    let cfg = HrfnaConfig::paper_default();

    // --- FPGA model ------------------------------------------------------
    let mut t = Table::new(
        "modeled FPGA throughput (Mops = MAC-equivalents/s)",
        &["workload", "HRFNA", "FP32", "BFP", "Fixed", "HRFNA/FP32"],
    );
    for kind in [
        WorkloadKind::Dot { n: 65536 },
        WorkloadKind::Matmul { m: 64, k: 64, n: 64 },
        WorkloadKind::Matmul { m: 128, k: 128, n: 128 },
        WorkloadKind::Rk4 { steps: 100_000 },
    ] {
        let tm = common::timings_for(&cfg, kind, 16);
        let s = speedup(&tm[0], &tm[1]);
        t.rowv(&[
            kind.label(),
            format!("{:.0}", tm[0].throughput_mops),
            format!("{:.0}", tm[1].throughput_mops),
            format!("{:.0}", tm[2].throughput_mops),
            format!("{:.0}", tm[3].throughput_mops),
            format!("{s:.2}x"),
        ]);
        if matches!(kind, WorkloadKind::Dot { .. }) {
            assert!((2.0..=2.6).contains(&s), "dot speedup {s} out of band");
        }
    }
    t.print();

    // --- measured host wall-clock (software model + PJRT kernels) --------
    let ctx = HrfnaContext::paper_default();
    let mut rng = Rng::new(4);
    let n = 4096;
    let xs: Vec<Hrfna> = Dist::moderate()
        .sample_vec(&mut rng, n)
        .iter()
        .map(|&v| Hrfna::encode(v, &ctx))
        .collect();
    let ys: Vec<Hrfna> = Dist::moderate()
        .sample_vec(&mut rng, n)
        .iter()
        .map(|&v| Hrfna::encode(v, &ctx))
        .collect();
    let xf: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let yf: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();

    let r1 = bench("host: HRFNA software dot n=4096", || {
        dot_product_encoded::<Hrfna>(&xs, &ys, &ctx)
    });
    let r2 = bench("host: f32 dot n=4096", || {
        let mut acc = 0f32;
        for i in 0..n {
            acc += xf[i] * yf[i];
        }
        acc
    });
    println!("{}", r1.line());
    println!("{}", r2.line());

    match hrfna::runtime::Engine::load_default() {
        Ok(engine) => {
            use hrfna::coordinator::hybrid_exec::encode_block;
            use hrfna::runtime::pjrt::Tensor;
            let xs = Dist::moderate().sample_vec(&mut rng, 4096);
            let ysv = Dist::moderate().sample_vec(&mut rng, 4096);
            let ex = encode_block(&xs, &ctx);
            let ey = encode_block(&ysv, &ctx);
            let m: Vec<i64> = ctx.cfg.moduli.iter().map(|&v| v as i64).collect();
            let k = ctx.k();
            let r = bench("pjrt: hybrid_dot kernel n=4096", || {
                engine
                    .execute(
                        "hybrid_dot",
                        &[
                            Tensor::I64(ex.residues.clone(), vec![k, 4096]),
                            Tensor::I64(ey.residues.clone(), vec![k, 4096]),
                            Tensor::I64(m.clone(), vec![k]),
                        ],
                    )
                    .unwrap()
            });
            println!("{}", r.line());
            let xf: Vec<f32> = xs.iter().map(|&v| v as f32).collect();
            let yf: Vec<f32> = ysv.iter().map(|&v| v as f32).collect();
            let r = bench("pjrt: fp32_dot kernel n=4096", || {
                engine
                    .execute(
                        "fp32_dot",
                        &[
                            Tensor::F32(xf.clone(), vec![4096]),
                            Tensor::F32(yf.clone(), vec![4096]),
                        ],
                    )
                    .unwrap()
            });
            println!("{}", r.line());
        }
        Err(e) => println!("(PJRT kernels skipped: {e})"),
    }
    println!("paper: up to 2.4x dot, 1.8-2.2x matmul vs FP32 (modeled above)");
}
