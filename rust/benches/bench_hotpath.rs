//! Hot-path microbenchmarks — the §Perf instrument panel. Times every
//! layer's critical operation; before/after numbers live in
//! EXPERIMENTS.md §Perf, and the scalar-vs-planar dot comparison is
//! written to `BENCH_hotpath.json` for perf-trajectory tracking.

mod common;

use hrfna::bigint::BigUint;
use hrfna::hybrid::{Hrfna, HrfnaBatch, HrfnaContext};
use hrfna::rns::{Barrett, CrtContext, ResidueVec};
use hrfna::util::bench::{bench, write_json, BenchRecord};
use hrfna::util::prng::Rng;
use hrfna::workloads::dot::dot_product_encoded_scalar;
use hrfna::workloads::generators::Dist;

fn main() {
    common::banner("§Perf", "hot-path microbenchmarks");
    let ctx = HrfnaContext::paper_default();
    let mut rng = Rng::new(1);
    let mut records: Vec<BenchRecord> = Vec::new();

    // --- L3 primitive ops -------------------------------------------------
    let bar = Barrett::new(65521);
    let a = rng.below(65521);
    let b = rng.below(65521);
    let r = bench("barrett mul (1 channel)", || bar.mul(a, b));
    records.push(BenchRecord::from_result("barrett_mul", 1, &r));
    println!("{}", r.line());

    let crt = CrtContext::new(&ctx.cfg.moduli);
    let x = ResidueVec::encode_u64(0xDEAD_BEEF_CAFE, &ctx.cfg.moduli);
    let y = ResidueVec::encode_u64(0x1234_5678_9ABC, &ctx.cfg.moduli);
    println!(
        "{}",
        bench("residue mul (k=8 channels)", || x.mul(&y, &crt.barrett)).line()
    );
    let mut acc = ResidueVec::zero(8);
    println!(
        "{}",
        bench("residue MAC (k=8)", || acc.mac_assign(&x, &y, &crt.barrett)).line()
    );
    let r = bench("CRT reconstruction (k=8)", || crt.reconstruct(&x));
    records.push(BenchRecord::from_result("crt_reconstruct", 1, &r));
    println!("{}", r.line());
    println!(
        "{}",
        bench("mixed-radix digits (k=8)", || crt.mixed_radix(&x)).line()
    );
    let big = BigUint::from_u128(0x1234_5678_9ABC_DEF0_1122_3344_5566_7788u128);
    println!(
        "{}",
        bench("BigUint mul 128x64", || big.mul_u64(0xFFFF_FFFF)).line()
    );

    // --- Hrfna value ops ---------------------------------------------------
    let ha = Hrfna::encode(1234.5678, &ctx);
    let hb = Hrfna::encode(-0.000987, &ctx);
    println!("{}", bench("Hrfna encode", || Hrfna::encode(3.75, &ctx)).line());
    println!("{}", bench("Hrfna mul", || ha.mul(&hb, &ctx)).line());
    println!("{}", bench("Hrfna add (sync)", || ha.add(&hb, &ctx)).line());
    println!("{}", bench("Hrfna decode", || ha.decode(&ctx)).line());
    let mut v = Hrfna::from_signed_int(0x7FFF_FFFF_FFFF, -20, &ctx);
    println!(
        "{}",
        bench("Hrfna normalize s=16", || {
            let mut w = v.clone();
            w.normalize(16, &ctx, false);
            w
        })
        .line()
    );
    v.normalize(1, &ctx, false);

    // --- workload loop: scalar reference vs planar engine ----------------
    for n in [1024usize, 4096] {
        let xs: Vec<Hrfna> = Dist::moderate()
            .sample_vec(&mut rng, n)
            .iter()
            .map(|&q| Hrfna::encode(q, &ctx))
            .collect();
        let ys: Vec<Hrfna> = Dist::moderate()
            .sample_vec(&mut rng, n)
            .iter()
            .map(|&q| Hrfna::encode(q, &ctx))
            .collect();
        let r_scalar = bench(&format!("Hrfna dot n={n} (scalar ref)"), || {
            dot_product_encoded_scalar::<Hrfna>(&xs, &ys, &ctx)
        });
        println!(
            "{} ({:.1} ns/MAC)",
            r_scalar.line(),
            r_scalar.ns_per_iter / n as f64
        );
        let bx = HrfnaBatch::from_items(&xs, ctx.k());
        let by = HrfnaBatch::from_items(&ys, ctx.k());
        let r_planar = bench(&format!("Hrfna dot n={n} (planar)"), || bx.dot(&by, &ctx));
        println!(
            "{} ({:.1} ns/MAC)",
            r_planar.line(),
            r_planar.ns_per_iter / n as f64
        );
        println!(
            "  -> planar speedup over scalar at n={n}: {:.2}x",
            r_scalar.ns_per_iter / r_planar.ns_per_iter
        );
        records.push(BenchRecord::from_result(
            &format!("dot_scalar_n{n}"),
            n as u64,
            &r_scalar,
        ));
        records.push(BenchRecord::from_result(
            &format!("dot_planar_n{n}"),
            n as u64,
            &r_planar,
        ));
    }

    // --- engine layer (PJRT with --features xla; software otherwise) ------
    match hrfna::runtime::Engine::load_default() {
        Ok(engine) => {
            use hrfna::coordinator::hybrid_exec::encode_block;
            use hrfna::runtime::pjrt::Tensor;
            let xsf = Dist::moderate().sample_vec(&mut rng, 4096);
            let ysf = Dist::moderate().sample_vec(&mut rng, 4096);
            let ex = encode_block(&xsf, &ctx);
            let ey = encode_block(&ysf, &ctx);
            let m: Vec<i64> = ctx.cfg.moduli.iter().map(|&q| q as i64).collect();
            let k = ctx.k();
            let r = bench("encode_block n=4096", || encode_block(&xsf, &ctx));
            records.push(BenchRecord::from_result("encode_block_n4096", 4096, &r));
            println!("{}", r.line());
            let r = bench("engine hybrid_dot n=4096", || {
                engine
                    .execute(
                        "hybrid_dot",
                        &[
                            Tensor::I64(ex.residues.clone(), vec![k, 4096]),
                            Tensor::I64(ey.residues.clone(), vec![k, 4096]),
                            Tensor::I64(m.clone(), vec![k]),
                        ],
                    )
                    .unwrap()
            });
            println!("{} ({:.1} ns/MAC)", r.line(), r.ns_per_iter / 4096.0);
            records.push(BenchRecord::from_result("engine_hybrid_dot_n4096", 4096, &r));
        }
        Err(e) => println!("(engine skipped: {e})"),
    }

    match write_json("BENCH_hotpath.json", &records) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json ({} records)", records.len()),
        Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
    }
}
