//! Serving-path benchmark: scalar-reference vs planar datapath jobs/sec
//! through the full coordinator (admission → sharded queues → batch
//! execution → decode → reply), closed-loop at batch ≥ 8, plus an
//! open-loop backpressure probe, a mixed-lane smoke and a **mixed-tier**
//! closed-loop scenario (lo/paper/wide requests interleaved over one
//! coordinator, per-tier jobs/sec recorded). Drives the coordinator
//! through the [`Backend`] seam ([`InProcess`]) — the same API the RPC
//! edge and the cluster router serve. A weight-stationary matmul A/B
//! additionally measures the encoded-operand cache (cached vs
//! cold-encode jobs/sec and the cache hit ratio). Writes
//! `BENCH_serve.json`; the CI gate (`tools/bench_gate.rs`) holds the
//! recorded planar speedup, the tiered records and the cache records
//! within tolerance.
//!
//! Quick mode for CI: `BENCH_QUICK=1 cargo bench --bench bench_serve`
//! (or `--quick`).

mod common;

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::router::ShapeBuckets;
use hrfna::coordinator::{
    closed_loop, open_loop, Backend, ContextRegistry, Coordinator, CoordinatorConfig, ExecMode,
    InProcess, JobKind, JobSpec, Tier,
};
use hrfna::util::bench::{write_json, BenchRecord};
use hrfna::util::cli::Args;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::{Dist, ServeMix};
use std::sync::Arc;
use std::time::Duration;

const DOT_N: usize = 4096;
const CLIENTS: usize = 4;
const BURST: usize = 16;

fn backend_tiered(mode: ExecMode, capacity: usize, tiers: Vec<Tier>) -> InProcess {
    backend_with_cache(mode, capacity, tiers, CoordinatorConfig::default().op_cache_bytes)
}

fn backend_with_cache(
    mode: ExecMode,
    capacity: usize,
    tiers: Vec<Tier>,
    op_cache_bytes: usize,
) -> InProcess {
    let engine = hrfna::runtime::EngineHandle::spawn(None).expect("engine");
    InProcess::new(Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
                capacity,
            },
            buckets: ShapeBuckets { tiers, ..ShapeBuckets::default() },
            exec: mode,
            op_cache_bytes,
        },
    ))
}

/// Paper-tier-only backend: the historical scalar-vs-planar A/B
/// (one lane per kind/bucket, exactly the pre-registry shape).
fn backend(mode: ExecMode, capacity: usize) -> InProcess {
    backend_tiered(mode, capacity, vec![Tier::Paper])
}

fn main() {
    common::banner("§Serve", "coordinator scalar-path vs planar-path jobs/sec");
    let args = Args::from_env();
    let quick = args.flag("quick") || std::env::var("BENCH_QUICK").is_ok();
    let jobs_per_client = if quick { 64 } else { 256 };

    // Shared operand pool so generation stays out of the measured loop.
    let mut rng = Rng::new(2026);
    let pool: Vec<(Vec<f64>, Vec<f64>)> = (0..16)
        .map(|_| {
            (
                Dist::moderate().sample_vec(&mut rng, DOT_N),
                Dist::moderate().sample_vec(&mut rng, DOT_N),
            )
        })
        .collect();
    let make_dot = |c: u64, i: usize| -> JobSpec {
        let (x, y) = &pool[(c as usize * 7 + i) % pool.len()];
        JobSpec::dot(x.clone(), y.clone())
    };

    let mut records: Vec<BenchRecord> = Vec::new();
    let mut by_mode = [0.0f64; 2];
    for (m, mode) in [ExecMode::Scalar, ExecMode::Planar].into_iter().enumerate() {
        let be = backend(mode, 4096);
        // Warmup (threadpool spin-up, first allocations).
        for _ in 0..4 {
            be.call(make_dot(0, 0)).expect("warmup job");
        }
        let report = closed_loop(&be, CLIENTS, jobs_per_client, BURST, &make_dot);
        assert_eq!(report.accepted, report.offered, "{mode:?}: capacity too small");
        assert_eq!(report.completed, report.accepted, "{mode:?}: lost jobs");
        let mean_batch = be
            .with_coordinator(|c| c.metrics.mean_batch_size(JobKind::DotHybrid))
            .expect("live coordinator");
        let lat = report.latency_us.as_ref().expect("latencies");
        println!(
            "dot n={DOT_N} {}: {:.0} jobs/s  (mean batch {:.1}, p50 {:.0} us, p99 {:.0} us)",
            mode.label(),
            report.jobs_per_s,
            mean_batch,
            lat.p50,
            lat.p99
        );
        let drain = be.shutdown().expect("first shutdown");
        assert!(drain.is_clean(), "unclean drain: {drain}");
        by_mode[m] = report.jobs_per_s;
        records.push(BenchRecord {
            name: format!("serve_dot_{}_n{DOT_N}_b8_jobs", mode.label()),
            n: report.completed as u64,
            ns_per_op: report.wall.as_nanos() as f64 / report.completed.max(1) as f64,
            throughput_per_s: report.jobs_per_s,
        });
    }
    let speedup = by_mode[1] / by_mode[0].max(1e-9);
    println!("-> planar serving speedup over scalar path: {speedup:.2}x");
    // Machine-independent gate record: planar cost relative to the scalar
    // path *measured in the same run* (ns_per_op = planar/scalar per-job
    // cost, lower is better; throughput_per_s holds the speedup). Shared
    // CI runners drift on absolute ns/op but not on this ratio.
    records.push(BenchRecord {
        name: "serve_dot_planar_cost_ratio".to_string(),
        n: 1,
        ns_per_op: 1.0 / speedup.max(1e-9),
        throughput_per_s: speedup,
    });
    if !quick {
        assert!(
            speedup >= 2.0,
            "planar serving path must be >= 2x scalar jobs/sec (got {speedup:.2}x)"
        );
    }

    // Open-loop backpressure probe: offer ~2x the measured planar
    // capacity into small queues; the bounded lanes must shed load with
    // `Overloaded` instead of queueing without bound.
    let be = backend(ExecMode::Planar, 16);
    let probe_jobs = if quick { 200 } else { 800 };
    let report = open_loop(&be, probe_jobs, (by_mode[1] * 2.0).max(100.0), &make_dot);
    println!(
        "open-loop at 2x capacity: offered {} accepted {} shed {} ({:.0} jobs/s served)",
        report.offered, report.accepted, report.rejected, report.jobs_per_s
    );
    let drain = be.shutdown().expect("shutdown after open loop");
    assert!(drain.is_clean(), "unclean drain after open loop: {drain}");

    // Mixed-tier closed loop: lo/paper/wide dot requests interleaved
    // 3:5:2 over one coordinator with every tier lane enabled — the
    // multi-scenario shape the registry serves. The mixed record tracks
    // total wall clock for the fixed mix; per-tier *cost* is measured
    // separately below by isolated single-tier runs (inside a mixed run
    // the per-tier jobs/sec is fixed by the mix ratio, so it cannot
    // expose a per-tier kernel regression on its own).
    let mix = ServeMix::default_mix();
    let make_tiered = |c: u64, i: usize| -> JobSpec {
        let (x, y) = &pool[(c as usize * 5 + i) % pool.len()];
        JobSpec::dot(x.clone(), y.clone()).tier(mix.tier_for(i))
    };
    let be = backend_tiered(ExecMode::Planar, 4096, Tier::ALL.to_vec());
    let tiered = closed_loop(
        &be,
        CLIENTS,
        if quick { 40 } else { 160 },
        10,
        &make_tiered,
    );
    assert_eq!(tiered.completed, tiered.offered, "tiered run lost jobs");
    assert_eq!(
        be.with_coordinator(|c| c.metrics.total_escalations()).expect("live coordinator"),
        0,
        "moderate-range traffic must not escalate"
    );
    println!(
        "mixed tiers: {} jobs in {:.2?} ({:.0} jobs/s)",
        tiered.completed, tiered.wall, tiered.jobs_per_s
    );
    for tier in Tier::ALL {
        let (jobs, p50) = be
            .with_coordinator(|c| {
                (
                    c.metrics.jobs_tier(JobKind::DotHybrid, tier),
                    c.metrics.latency_percentile_us_tier(JobKind::DotHybrid, tier, 50.0),
                )
            })
            .expect("live coordinator");
        assert!(jobs > 0, "{tier:?} lane saw no traffic in the mix");
        println!("  tier {:<5} {jobs} jobs (p50 {p50:.0} us)", tier.label());
    }
    records.push(BenchRecord {
        name: "serve_mixed_tier_dot_jobs".to_string(),
        n: tiered.completed as u64,
        ns_per_op: tiered.wall.as_nanos() as f64 / tiered.completed.max(1) as f64,
        throughput_per_s: tiered.jobs_per_s,
    });

    // Per-tier cost: one isolated closed loop per tier on the same
    // coordinator — each record's jobs/sec reflects that tier's lane
    // cost alone (fewer/narrower residue lanes are cheaper, so expect
    // lo ≥ paper ≥ wide throughput).
    for tier in Tier::ALL {
        let make_tier = |c: u64, i: usize| -> JobSpec {
            let (x, y) = &pool[(c as usize * 3 + i) % pool.len()];
            JobSpec::dot(x.clone(), y.clone()).tier(tier)
        };
        let rep = closed_loop(&be, CLIENTS, if quick { 32 } else { 96 }, 8, &make_tier);
        assert_eq!(rep.completed, rep.offered, "{tier:?} run lost jobs");
        println!(
            "  tier {:<5} isolated: {:.0} jobs/s ({} jobs in {:.2?})",
            tier.label(),
            rep.jobs_per_s,
            rep.completed,
            rep.wall
        );
        records.push(BenchRecord {
            name: format!("serve_tier_{}_dot_jobs", tier.label()),
            n: rep.completed as u64,
            ns_per_op: rep.wall.as_nanos() as f64 / rep.completed.max(1) as f64,
            throughput_per_s: rep.jobs_per_s,
        });
    }
    println!("{}", be.metrics_text());
    let drain = be.shutdown().expect("shutdown after tiered load");
    assert!(drain.is_clean(), "unclean drain after tiered load: {drain}");

    // Mixed-lane smoke: every lane (both dot buckets, matmuls, RK4)
    // under one coordinator, planar path, paper tier.
    let make_mixed = |c: u64, i: usize| -> JobSpec {
        let (slot, mut rng) = mix.request_rng(c + 100, i);
        match slot {
            0..=3 => {
                let x = mix.dist.sample_vec(&mut rng, mix.dot_n);
                let y = mix.dist.sample_vec(&mut rng, mix.dot_n);
                JobSpec::dot(x, y)
            }
            4..=6 => {
                let x = mix.dist.sample_vec(&mut rng, mix.dot_n);
                let y = mix.dist.sample_vec(&mut rng, mix.dot_n);
                JobSpec::dot_f32(x, y)
            }
            7 => {
                let a = mix.dist.sample_vec(&mut rng, mix.matmul_dim * mix.matmul_dim);
                let b = mix.dist.sample_vec(&mut rng, mix.matmul_dim * mix.matmul_dim);
                JobSpec::matmul(a, b, mix.matmul_dim)
            }
            8 => {
                let a = mix.dist.sample_vec(&mut rng, mix.matmul_dim * mix.matmul_dim);
                let b = mix.dist.sample_vec(&mut rng, mix.matmul_dim * mix.matmul_dim);
                JobSpec::matmul_f32(a, b, mix.matmul_dim)
            }
            _ => JobSpec::rk4(
                vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
                1.0,
                0.005,
                mix.rk4_steps,
            ),
        }
    };
    let be = backend(ExecMode::Planar, 4096);
    let mixed = closed_loop(&be, 2, if quick { 20 } else { 60 }, 10, &make_mixed);
    println!(
        "mixed lanes: {} jobs in {:.2?} ({:.0} jobs/s)",
        mixed.completed, mixed.wall, mixed.jobs_per_s
    );
    println!("{}", be.metrics_text());
    let drain = be.shutdown().expect("shutdown after mixed load");
    assert!(drain.is_clean(), "unclean drain after mixed load: {drain}");
    records.push(BenchRecord {
        name: "serve_mixed_planar_jobs".to_string(),
        n: mixed.completed as u64,
        ns_per_op: mixed.wall.as_nanos() as f64 / mixed.completed.max(1) as f64,
        throughput_per_s: mixed.jobs_per_s,
    });

    // Cached-weights matmul: a weight-stationary stream (one RHS reused
    // by every job, activations varying) through a cache-enabled
    // coordinator vs the same stream with the cache disabled, so the
    // cold leg re-encodes the weight plane per job. Three records:
    //
    //  * `serve_cached_matmul_jobs` — absolute cached-leg jobs/sec,
    //  * `serve_matmul_cache_cost_ratio` — cached-over-cold per-job cost
    //    measured in the same run (machine-independent; gated so the
    //    cache must keep ≥ 1.3x the cold-encode jobs/sec),
    //  * `op_cache_hit_ratio` — hits/lookups on the cached leg (gated
    //    ≥ 0.9: the stream must actually serve from cache).
    const MATMUL_DIM: usize = 64;
    let weights = Dist::moderate().sample_vec(&mut rng, MATMUL_DIM * MATMUL_DIM);
    let act_pool: Vec<Vec<f64>> = (0..16)
        .map(|_| Dist::moderate().sample_vec(&mut rng, MATMUL_DIM * MATMUL_DIM))
        .collect();
    let make_weighted = |c: u64, i: usize| -> JobSpec {
        let a = &act_pool[(c as usize * 7 + i) % act_pool.len()];
        JobSpec::matmul(a.clone(), weights.clone(), MATMUL_DIM)
    };
    let mm_jobs = if quick { 16 } else { 48 };

    let be = backend_with_cache(ExecMode::Planar, 4096, vec![Tier::Paper], 0);
    for _ in 0..4 {
        be.call(make_weighted(0, 0)).expect("warmup job");
    }
    let cold = closed_loop(&be, CLIENTS, mm_jobs, 8, &make_weighted);
    assert_eq!(cold.completed, cold.offered, "cold-encode leg lost jobs");
    let cold_lookups = be
        .with_coordinator(|c| {
            c.metrics.cache_hits(JobKind::MatmulHybrid)
                + c.metrics.cache_misses(JobKind::MatmulHybrid)
        })
        .expect("live coordinator");
    assert_eq!(cold_lookups, 0, "op_cache_bytes: 0 must disable cache lookups");
    println!("matmul dim={MATMUL_DIM} cold-encode: {:.0} jobs/s", cold.jobs_per_s);
    let drain = be.shutdown().expect("shutdown after cold leg");
    assert!(drain.is_clean(), "unclean drain after cold leg: {drain}");

    let be = backend_with_cache(
        ExecMode::Planar,
        4096,
        vec![Tier::Paper],
        CoordinatorConfig::default().op_cache_bytes,
    );
    for _ in 0..4 {
        be.call(make_weighted(0, 0)).expect("warmup job");
    }
    let hot = closed_loop(&be, CLIENTS, mm_jobs, 8, &make_weighted);
    assert_eq!(hot.completed, hot.offered, "cached leg lost jobs");
    let (hits, misses) = be
        .with_coordinator(|c| {
            (
                c.metrics.cache_hits(JobKind::MatmulHybrid),
                c.metrics.cache_misses(JobKind::MatmulHybrid),
            )
        })
        .expect("live coordinator");
    let hit_ratio = hits as f64 / (hits + misses).max(1) as f64;
    println!(
        "matmul dim={MATMUL_DIM} cached:      {:.0} jobs/s ({hits} hits / {misses} misses, ratio {hit_ratio:.3})",
        hot.jobs_per_s
    );
    let drain = be.shutdown().expect("shutdown after cached leg");
    assert!(drain.is_clean(), "unclean drain after cached leg: {drain}");

    records.push(BenchRecord {
        name: "serve_cached_matmul_jobs".to_string(),
        n: hot.completed as u64,
        ns_per_op: hot.wall.as_nanos() as f64 / hot.completed.max(1) as f64,
        throughput_per_s: hot.jobs_per_s,
    });
    let cache_speedup = hot.jobs_per_s / cold.jobs_per_s.max(1e-9);
    println!("-> operand cache serving speedup over cold encode: {cache_speedup:.2}x");
    records.push(BenchRecord {
        name: "serve_matmul_cache_cost_ratio".to_string(),
        n: 1,
        ns_per_op: 1.0 / cache_speedup.max(1e-9),
        throughput_per_s: cache_speedup,
    });
    records.push(BenchRecord {
        name: "op_cache_hit_ratio".to_string(),
        n: (hits + misses).max(1),
        ns_per_op: 1.0 / hit_ratio.max(1e-9),
        throughput_per_s: hit_ratio,
    });
    if !quick {
        assert!(
            cache_speedup >= 1.3,
            "cache-served matmul must keep >= 1.3x cold-encode jobs/sec (got {cache_speedup:.2}x)"
        );
        assert!(
            hit_ratio >= 0.9,
            "weight-stationary stream must hit the cache >= 90% (got {hit_ratio:.3})"
        );
    }

    match write_json("BENCH_serve.json", &records) {
        Ok(()) => println!("\nwrote BENCH_serve.json ({} records)", records.len()),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
