//! Table II: RTL configuration and implementation setup, rendered from the
//! live config (plus the derived quantities the paper lists).

mod common;

use hrfna::config::HrfnaConfig;
use hrfna::fpga::report::table2;
use hrfna::fpga::resources::{mac_unit, FormatArch};
use hrfna::fpga::timing;
use hrfna::util::table::Table;

fn main() {
    common::banner("Table II", "RTL configuration and FPGA implementation setup");
    for preset in ["paper", "low-precision", "stress-norm", "wide"] {
        let cfg = HrfnaConfig::preset(preset).unwrap();
        println!("--- preset: {preset} ---");
        table2(&cfg).print();
    }

    // Derived implementation summary for the paper preset.
    let cfg = HrfnaConfig::paper_default();
    let mut t = Table::new(
        "derived implementation parameters (paper preset)",
        &["quantity", "value"],
    );
    let r = mac_unit(FormatArch::Hrfna, &cfg, 16);
    t.rowv(&["MAC unit LUT".to_string(), format!("{:.0}", r.lut)]);
    t.rowv(&["MAC unit FF".to_string(), format!("{:.0}", r.ff)]);
    t.rowv(&["MAC unit DSP".to_string(), format!("{:.0}", r.dsp)]);
    t.rowv(&[
        "residue pipe latency".to_string(),
        format!("{} cycles", timing::mac_latency_cycles(FormatArch::Hrfna)),
    ]);
    t.rowv(&[
        "normalization engine latency".to_string(),
        format!("{} cycles", timing::normalization_latency_cycles(&cfg)),
    ]);
    t.rowv(&[
        "achieved Fmax (model)".to_string(),
        format!("{:.0} MHz", timing::fmax_mhz(FormatArch::Hrfna, &cfg)),
    ]);
    t.print();

    assert!(timing::fmax_mhz(FormatArch::Hrfna, &cfg) >= cfg.clock_mhz);
    println!("Table II reproduced; 300 MHz target met by the modeled Fmax");
}
