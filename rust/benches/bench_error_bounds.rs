//! §III-D (Lemmas 1–2): measured normalization error vs the formal bounds
//! over thousands of randomized events — the bounds must never be
//! violated, and the measured/bound ratio shows their tightness.

mod common;

use hrfna::hybrid::{error, Hrfna, HrfnaContext};
use hrfna::util::prng::Rng;
use hrfna::util::table::Table;

fn main() {
    common::banner("§III-D", "formal error bounds: measured vs Lemma 1/2");
    let ctx = HrfnaContext::paper_default();
    let mut rng = Rng::new(314159);

    let cases = 5000;
    let mut abs_ratio_max: f64 = 0.0;
    let mut rel_ratio_max: f64 = 0.0;
    let mut abs_ratios = Vec::with_capacity(cases);
    let mut violations = 0u64;

    for _ in 0..cases {
        let bits = 16 + rng.below(44) as u32;
        let n = (rng.next_u64() >> (64 - bits)).max(1) as i64;
        let f = rng.range_i64(-80, 80) as i32;
        let s = 1 + rng.below(30) as u32;
        let mut v = Hrfna::from_signed_int(if rng.bool() { n } else { -n }, f, &ctx);
        let sample = error::measure_normalization(&mut v, s, &ctx);
        if !sample.within_bounds() {
            violations += 1;
            continue;
        }
        // Tightness statistics only over measurements where the bound is
        // well above the f64 probe noise (~1e-14·|Φ|) — below that the
        // ratio measures decode rounding, not normalization error.
        let noise = sample.before.abs() * 1e-14;
        if sample.abs_bound > 100.0 * noise {
            let r = sample.abs_err / sample.abs_bound;
            abs_ratio_max = abs_ratio_max.max(r);
            abs_ratios.push(r);
            if sample.rel_bound > 0.0 && sample.before != 0.0 {
                rel_ratio_max = rel_ratio_max.max(sample.rel_err / sample.rel_bound);
            }
        }
    }

    let mean_ratio = abs_ratios.iter().sum::<f64>() / abs_ratios.len() as f64;
    let mut t = Table::new(
        &format!("{cases} randomized normalization events"),
        &["metric", "value"],
    );
    t.rowv(&["bound violations".to_string(), violations.to_string()]);
    t.rowv(&["max |err|/Lemma1-bound".to_string(), format!("{abs_ratio_max:.4}")]);
    t.rowv(&["mean |err|/Lemma1-bound".to_string(), format!("{mean_ratio:.4}")]);
    t.rowv(&["max rel-err/tight-bound".to_string(), format!("{rel_ratio_max:.4}")]);
    t.print();

    assert_eq!(violations, 0, "Lemma bounds must never be violated");
    assert!(abs_ratio_max <= 1.0 + 1e-9);

    // Composed bound over a workload (§III-D interpretation): total error
    // after E events ≤ E × per-event bound.
    let cfg = hrfna::config::HrfnaConfig {
        tau_bits: 72,
        ..hrfna::config::HrfnaConfig::paper_default()
    };
    let ctx2 = HrfnaContext::new(cfg);
    let xs = hrfna::workloads::generators::Dist::moderate().sample_vec(&mut rng, 8192);
    let ys = hrfna::workloads::generators::Dist::moderate().sample_vec(&mut rng, 8192);
    let want = hrfna::workloads::dot::dot_product::<f64>(&xs, &ys, &());
    let got = hrfna::workloads::dot::dot_product::<Hrfna>(&xs, &ys, &ctx2);
    let events = ctx2.snapshot().norms + ctx2.snapshot().guard_norms;
    let per_event = error::lemma2_rel_bound_tight(ctx2.cfg.scale_step, ctx2.cfg.tau_bits);
    let composed = error::composed_rel_bound(events, ctx2.cfg.scale_step, ctx2.cfg.tau_bits)
        // encode rounding of 2·8192 operands at 2^-sig each:
        + 2.0 * 8192.0 * 2f64.powi(-(ctx2.cfg.sig_bits as i32));
    let measured = ((got - want) / want).abs();
    println!(
        "composed-bound check: {events} events, per-event {per_event:.2e}, \
         budget {composed:.2e}, measured {measured:.2e}"
    );
    assert!(measured <= composed, "composed bound violated");
    println!("bounds verified: 0 violations across {cases} events + composed workload");
}
