//! §VII-E: normalization frequency and overhead. Measures events per
//! arithmetic op across workloads and a τ sweep, then feeds the measured
//! rates into the pipeline model to confirm steady-state Π ≈ 1.

mod common;

use hrfna::config::HrfnaConfig;
use hrfna::fpga::pipeline::{model_workload, WorkloadKind};
use hrfna::fpga::resources::FormatArch;
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::util::table::Table;
use hrfna::workloads::rk4::{rk4_integrate, Ode};
use hrfna::workloads::{dot, generators::Dist, matmul};

fn main() {
    common::banner("§VII-E", "normalization frequency and overhead");

    // --- Per-workload event rates (paper default config) ----------------
    let mut t = Table::new(
        "normalization events per arithmetic op (paper config)",
        &["workload", "ops", "norm events", "rate", "ops per event"],
    );
    let cfg = HrfnaConfig::paper_default();

    let row = |t: &mut Table, name: &str, ctx: &HrfnaContext| {
        let s = ctx.snapshot();
        let events = s.norms + s.guard_norms;
        let per = if events == 0 {
            "inf".to_string()
        } else {
            format!("{:.0}", s.arithmetic_ops() as f64 / events as f64)
        };
        t.rowv(&[
            name.to_string(),
            s.arithmetic_ops().to_string(),
            events.to_string(),
            format!("{:.2e}", s.norm_rate()),
            per,
        ]);
    };

    let ctx = HrfnaContext::new(cfg.clone());
    let _ = dot::dot_rms_error::<Hrfna>(2, 16384, Dist::moderate(), 3, &ctx);
    row(&mut t, "dot 16k moderate", &ctx);

    let ctx = HrfnaContext::new(cfg.clone());
    let _ = dot::dot_rms_error::<Hrfna>(2, 16384, Dist::high_dynamic_range(), 3, &ctx);
    row(&mut t, "dot 16k high-dyn-range", &ctx);

    let ctx = HrfnaContext::new(cfg.clone());
    let _ = matmul::matmul_rms_error::<Hrfna>(64, Dist::high_dynamic_range(), 3, &ctx);
    row(&mut t, "matmul 64 high-dyn-range", &ctx);

    let ctx = HrfnaContext::new(cfg.clone());
    let _ = rk4_integrate::<Hrfna>(
        &Ode::VanDerPol { mu: 1.0 },
        &[2.0, 0.0],
        0.002,
        20_000,
        20_000,
        &ctx,
    );
    row(&mut t, "rk4 20k steps", &ctx);
    t.print();

    // --- τ ablation: tighter thresholds → more events, still bounded ----
    let mut t = Table::new(
        "tau ablation (dot 8192, high-dynamic-range)",
        &["tau bits", "rms", "rate", "modeled stall cycles", "Pi (eff. II)"],
    );
    for tau_bits in [112u32, 96, 80, 72] {
        let cfg = HrfnaConfig {
            tau_bits,
            ..HrfnaConfig::paper_default()
        };
        let ctx = HrfnaContext::new(cfg.clone());
        let rms = dot::dot_rms_error::<Hrfna>(2, 8192, Dist::high_dynamic_range(), 3, &ctx);
        let s = ctx.snapshot();
        let events = (s.norms + s.guard_norms) / 2;
        let timing = model_workload(
            FormatArch::Hrfna,
            WorkloadKind::Dot { n: 8192 },
            &cfg,
            events,
        );
        t.rowv(&[
            tau_bits.to_string(),
            format!("{rms:.2e}"),
            format!("{:.2e}", s.norm_rate()),
            format!("{:.1}", timing.norm_stall_cycles),
            format!("{:.4}", timing.cycles / 8192.0),
        ]);
        assert!(rms < 1e-6, "accuracy must hold under tau={tau_bits}");
    }
    t.print();
    println!("paper: events orders of magnitude rarer than ops; Pi stays ~1");
}
