//! Table III "All Workloads" energy rows: HRFNA ≈ 0.52× FP32 energy/op
//! (≈1.9× efficiency), BFP ≈ 0.7×. Energy = modeled power / modeled
//! throughput; the ratio emerges from resources × activity × clock.

mod common;

use hrfna::config::HrfnaConfig;
use hrfna::fpga::pipeline::WorkloadKind;
use hrfna::fpga::power::{dynamic_power_mw, energy_per_mac_nj};
use hrfna::fpga::resources::{mac_unit, FormatArch};
use hrfna::util::table::Table;

fn main() {
    common::banner("Table III energy", "energy per MAC and efficiency ratios");
    let cfg = HrfnaConfig::paper_default();
    let formats = [
        FormatArch::Hrfna,
        FormatArch::Fp32,
        FormatArch::Bfp,
        FormatArch::Fixed,
    ];

    for kind in [
        WorkloadKind::Dot { n: 65536 },
        WorkloadKind::Matmul { m: 128, k: 128, n: 128 },
        WorkloadKind::Rk4 { steps: 100_000 },
    ] {
        let timings = common::timings_for(&cfg, kind, 16);
        let mut t = Table::new(
            &format!("energy model — {}", kind.label()),
            &["format", "P_dyn mW", "Mops", "nJ/MAC", "vs FP32"],
        );
        let fp32_e = {
            let res = mac_unit(FormatArch::Fp32, &cfg, 16);
            energy_per_mac_nj(&res, FormatArch::Fp32, &timings[1])
        };
        for (i, &f) in formats.iter().enumerate() {
            let res = mac_unit(f, &cfg, 16);
            let p = dynamic_power_mw(&res, f, timings[i].fmax_mhz);
            let e = energy_per_mac_nj(&res, f, &timings[i]);
            t.rowv(&[
                f.name().to_string(),
                format!("{p:.2}"),
                format!("{:.0}", timings[i].throughput_mops),
                format!("{e:.4}"),
                format!("{:.2}x", e / fp32_e),
            ]);
        }
        t.print();

        // Paper band check on the dot workload.
        if matches!(kind, WorkloadKind::Dot { .. }) {
            let h = energy_per_mac_nj(
                &mac_unit(FormatArch::Hrfna, &cfg, 16),
                FormatArch::Hrfna,
                &timings[0],
            );
            let ratio = h / fp32_e;
            assert!(
                (0.35..=0.75).contains(&ratio),
                "HRFNA energy ratio {ratio} outside band"
            );
            println!(
                "  -> HRFNA energy efficiency vs FP32: {:.2}x (paper: up to 1.9x)\n",
                1.0 / ratio
            );
        }
    }
    println!("note: model shows BFP energy below the paper's ~0.7x — see EXPERIMENTS.md");
}
