//! Table III (Matrix Multiplication rows): RMS < 2e-6 at 64×64 and
//! 128×128, error preserved under composition, 1.8–2.2× throughput.

mod common;

use hrfna::baselines::{Bfp, BfpConfig};
use hrfna::fpga::pipeline::{speedup, WorkloadKind};
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::util::table::Table;
use hrfna::workloads::{generators::Dist, matmul};

fn main() {
    common::banner("Table III / §VII-C", "dense matrix multiplication");
    let cfg = hrfna::config::HrfnaConfig::paper_default();

    let mut t = Table::new(
        "Matmul: accuracy + modeled throughput",
        &["dim", "HRFNA rms", "FP32 rms", "BFP rms", "norm/op", "HRFNA vs FP32 thr"],
    );
    for dim in [64usize, 128] {
        let ctx = HrfnaContext::new(cfg.clone());
        let h = matmul::matmul_rms_error::<Hrfna>(dim, Dist::moderate(), 42, &ctx);
        let snap = ctx.snapshot();
        let f = matmul::matmul_rms_error::<f32>(dim, Dist::moderate(), 42, &());
        let b = matmul::matmul_rms_error::<Bfp>(dim, Dist::moderate(), 42, &BfpConfig::default());
        let kind = WorkloadKind::Matmul {
            m: dim as u64,
            k: dim as u64,
            n: dim as u64,
        };
        let tm = common::timings_for(&cfg, kind, snap.norms + snap.guard_norms);
        let s = speedup(&tm[0], &tm[1]);
        t.rowv(&[
            format!("{dim}x{dim}"),
            format!("{h:.2e}"),
            format!("{f:.2e}"),
            format!("{b:.2e}"),
            format!("{:.2e}", snap.norm_rate()),
            format!("{s:.2}x"),
        ]);
        assert!(h < 2e-6, "paper claim: matmul rms < 2e-6 (dim={dim}, rms={h})");
    }
    t.print();
    println!("paper: RMS < 2e-6 at both sizes, no degradation with size, 1.8-2.2x");
}
