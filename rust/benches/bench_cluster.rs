//! Cluster-serving benchmark (`--features rpc`): jobs/sec through the
//! full sharded topology — client socket → router `RpcServer` →
//! `ShardRouter` consistent-hash placement → worker `RpcServer` →
//! `InProcess` coordinator — at fleet sizes 1, 2 and 4, all in one
//! process on ephemeral ports. Traffic interleaves tiers (lo/paper/wide)
//! and both dot buckets so six `(kind, tier, bucket)` lanes spread over
//! the ring; placement is lane-coherent, so each worker's batcher still
//! sees shape-coherent streams. Records `BENCH_cluster.json`; CI gates
//! it `--strict` against `ci/baselines/BENCH_cluster.json`.
//!
//! Machine-independent gate records, measured within one run:
//!
//! * `cluster_scale_2w_ratio` / `cluster_scale_4w_ratio` — routed
//!   jobs/sec at 2 (4) workers over 1 worker (the scaling claim; the
//!   full run asserts ≥ 1.7x at 2 workers),
//! * `cluster_router_overhead_ratio` — per-job cost through the router
//!   hop over direct-to-worker socket cost at fleet size 1 (what the
//!   extra hop costs).
//!
//! Quick mode for CI: `BENCH_QUICK=1 cargo bench --features rpc --bench
//! bench_cluster` (or `--quick`).

mod common;

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::cluster::{RouterConfig, ShardRouter, WorkerSpec};
use hrfna::coordinator::router::ShapeBuckets;
use hrfna::coordinator::rpc::{socket_closed_loop, ConnMode, RpcServer, RpcServerConfig};
use hrfna::coordinator::{
    Backend, ContextRegistry, Coordinator, CoordinatorConfig, ExecMode, InProcess, JobSpec, Tier,
};
use hrfna::util::bench::{write_json, BenchRecord};
use hrfna::util::cli::Args;
use hrfna::util::prng::Rng;
use hrfna::workloads::generators::Dist;
use std::sync::Arc;
use std::time::Duration;

const CLIENTS: usize = 4;
const BURST: usize = 8;
/// Both admission buckets, so traffic spans two shapes per tier.
const DOT_SMALL: usize = 512;
const DOT_BIG: usize = 4096;

/// One in-process "worker process": an `InProcess` coordinator behind
/// its own `RpcServer` on an ephemeral port.
struct Worker {
    backend: Arc<InProcess>,
    server: RpcServer,
    spec: WorkerSpec,
}

fn spawn_worker(id: usize) -> Worker {
    let engine = hrfna::runtime::EngineHandle::spawn(None).expect("engine");
    let backend = Arc::new(InProcess::new(Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 1,
            batch: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
                capacity: 4096,
            },
            buckets: ShapeBuckets::default(),
            exec: ExecMode::Planar,
            ..CoordinatorConfig::default()
        },
    )));
    let server = RpcServer::bind(
        Arc::clone(&backend) as Arc<dyn Backend>,
        "127.0.0.1:0",
        RpcServerConfig::default(),
    )
    .expect("bind worker rpc server");
    let spec = WorkerSpec {
        id: format!("w{id}"),
        addr: server.local_addr().to_string(),
    };
    Worker { backend, server, spec }
}

/// Routed jobs/sec at fleet size `n`, plus (at n = 1) the direct-to-
/// worker comparator for the router-overhead record.
fn run_fleet(
    n: usize,
    jobs_per_client: usize,
    cfg: RouterConfig,
    make: &(dyn Fn(u64, usize) -> JobSpec + Sync),
) -> (hrfna::coordinator::LoadReport, Option<hrfna::coordinator::LoadReport>) {
    let workers: Vec<Worker> = (0..n).map(spawn_worker).collect();
    let specs: Vec<WorkerSpec> = workers.iter().map(|w| w.spec.clone()).collect();

    // Direct comparator first: same worker, no router hop.
    let direct = (n == 1).then(|| {
        let warm = socket_closed_loop(
            &workers[0].spec.addr,
            CLIENTS,
            2,
            BURST,
            ConnMode::Persistent,
            make,
        );
        assert_eq!(warm.completed, warm.offered, "direct warmup lost jobs");
        let rep = socket_closed_loop(
            &workers[0].spec.addr,
            CLIENTS,
            jobs_per_client,
            BURST,
            ConnMode::Persistent,
            make,
        );
        assert_eq!(rep.completed, rep.offered, "direct run lost jobs");
        rep
    });

    let router = Arc::new(
        ShardRouter::start(
            specs,
            RouterConfig {
                health_interval: Duration::from_millis(200),
                connect_wait: Duration::from_secs(2),
                ..cfg
            },
        )
        .expect("start shard router"),
    );
    assert_eq!(router.up_count(), n, "all workers must come up");
    let front = RpcServer::bind(
        Arc::clone(&router) as Arc<dyn Backend>,
        "127.0.0.1:0",
        RpcServerConfig::default(),
    )
    .expect("bind router rpc server");
    let addr = front.local_addr().to_string();

    let warm = socket_closed_loop(&addr, CLIENTS, 2, BURST, ConnMode::Persistent, make);
    assert_eq!(warm.completed, warm.offered, "routed warmup lost jobs");
    let routed = socket_closed_loop(&addr, CLIENTS, jobs_per_client, BURST, ConnMode::Persistent, make);
    assert_eq!(routed.completed, routed.offered, "routed run lost jobs ({n} workers)");

    // Teardown front to back; the router's shutdown asks every shard to
    // drain, so the workers' own shutdown may already be done.
    front.stop();
    let drain = router.shutdown().expect("router shutdown");
    assert!(drain.is_clean(), "unclean router drain at {n} workers: {drain}");
    for w in workers {
        w.server.stop();
        // Err means the router's shutdown RPC already drained it.
        if let Ok(d) = w.backend.shutdown() {
            assert_eq!(d.dropped, 0, "worker {} dropped jobs: {d}", w.spec.id);
        }
    }
    (routed, direct)
}

fn main() {
    common::banner("§Cluster", "routed jobs/sec scaling over worker fleet size");
    let args = Args::from_env();
    let quick = args.flag("quick") || std::env::var("BENCH_QUICK").is_ok();
    let jobs_per_client = if quick { 48 } else { 192 };

    // Operand pools for both dot buckets; traffic cycles tier and shape
    // so six hybrid lanes spread over the ring.
    let mut rng = Rng::new(2026);
    let small: Vec<(Vec<f64>, Vec<f64>)> = (0..8)
        .map(|_| {
            (
                Dist::moderate().sample_vec(&mut rng, DOT_SMALL),
                Dist::moderate().sample_vec(&mut rng, DOT_SMALL),
            )
        })
        .collect();
    let big: Vec<(Vec<f64>, Vec<f64>)> = (0..8)
        .map(|_| {
            (
                Dist::moderate().sample_vec(&mut rng, DOT_BIG),
                Dist::moderate().sample_vec(&mut rng, DOT_BIG),
            )
        })
        .collect();
    let make = |c: u64, i: usize| -> JobSpec {
        let slot = c as usize * 7 + i;
        let (x, y) = if slot % 2 == 0 {
            &small[slot % small.len()]
        } else {
            &big[slot % big.len()]
        };
        JobSpec::dot(x.clone(), y.clone()).tier(Tier::ALL[slot % Tier::ALL.len()])
    };

    let mut records: Vec<BenchRecord> = Vec::new();
    let mut by_fleet: Vec<(usize, f64)> = Vec::new();
    let mut direct_jps = 0.0f64;
    for n in [1usize, 2, 4] {
        let (routed, direct) = run_fleet(n, jobs_per_client, RouterConfig::default(), &make);
        if let Some(d) = direct {
            direct_jps = d.jobs_per_s;
            println!("direct to 1 worker: {:.0} jobs/s", d.jobs_per_s);
        }
        let lat = routed.latency_us.as_ref().expect("latencies");
        println!(
            "routed {n}w: {:.0} jobs/s ({} jobs in {:.2?}, p50 {:.0} us, p99 {:.0} us)",
            routed.jobs_per_s, routed.completed, routed.wall, lat.p50, lat.p99
        );
        records.push(BenchRecord {
            name: format!("cluster_route_{n}w_jobs"),
            n: routed.completed as u64,
            ns_per_op: routed.wall.as_nanos() as f64 / routed.completed.max(1) as f64,
            throughput_per_s: routed.jobs_per_s,
        });
        by_fleet.push((n, routed.jobs_per_s));
    }

    let one = by_fleet[0].1.max(1e-9);
    for &(n, jps) in &by_fleet[1..] {
        let ratio = jps / one;
        println!("-> {n}-worker scaling: {ratio:.2}x single-worker routed throughput");
        records.push(BenchRecord {
            name: format!("cluster_scale_{n}w_ratio"),
            n: 1,
            ns_per_op: 1.0 / ratio.max(1e-9),
            throughput_per_s: ratio,
        });
        if !quick && n == 2 {
            assert!(
                ratio >= 1.7,
                "2 workers must yield >= 1.7x single-worker routed jobs/sec (got {ratio:.2}x)"
            );
        }
    }

    // Coalesced router edge: the same 2-worker fleet with the Nagle
    // window on — submissions from the 4 closed-loop clients share
    // `submit_batch` frames per (worker, lane) instead of one frame
    // each. Ratio over the plain 2-worker run above (higher is better).
    let (coalesced, _) = run_fleet(
        2,
        jobs_per_client,
        RouterConfig {
            coalesce_window: Duration::from_micros(200),
            coalesce_max: 8,
            ..RouterConfig::default()
        },
        &make,
    );
    let plain_2w = by_fleet
        .iter()
        .find(|&&(n, _)| n == 2)
        .map(|&(_, jps)| jps)
        .expect("2-worker run recorded")
        .max(1e-9);
    let coalesce_ratio = coalesced.jobs_per_s / plain_2w;
    println!(
        "routed 2w coalesced: {:.0} jobs/s -> {coalesce_ratio:.2}x plain routed throughput",
        coalesced.jobs_per_s
    );
    records.push(BenchRecord {
        name: "cluster_coalesced_submit_ratio".to_string(),
        n: 1,
        ns_per_op: 1.0 / coalesce_ratio.max(1e-9),
        throughput_per_s: coalesce_ratio,
    });
    if !quick {
        assert!(
            coalesce_ratio >= 1.3,
            "coalescing must yield >= 1.3x routed jobs/sec at {CLIENTS} closed-loop clients \
             (got {coalesce_ratio:.2}x)"
        );
    }

    // Router hop cost at fleet size 1: routed per-job cost over direct
    // per-job cost (lower is better; throughput_per_s = fraction of
    // direct throughput the router retains).
    let overhead = direct_jps / one;
    println!("-> router hop overhead: {overhead:.2}x direct per-job cost");
    records.push(BenchRecord {
        name: "cluster_router_overhead_ratio".to_string(),
        n: 1,
        ns_per_op: overhead,
        throughput_per_s: 1.0 / overhead.max(1e-9),
    });

    match write_json("BENCH_cluster.json", &records) {
        Ok(()) => println!("\nwrote BENCH_cluster.json ({} records)", records.len()),
        Err(e) => eprintln!("could not write BENCH_cluster.json: {e}"),
    }
}
