//! Authenticated-serving overhead benchmark: MAC-verified dot, verified
//! FIR and Freivalds-checked matmul jobs/sec through the full coordinator
//! vs the same traffic unauthenticated, closed-loop at batch ≥ 8 over the
//! [`Backend`] seam ([`InProcess`]). Writes `BENCH_auth.json`; the CI gate
//! (`tools/bench_gate.rs`) holds the machine-independent overhead ratios
//! within tolerance — the headline `serve_auth_overhead_ratio` baseline is
//! set so the gate caps authenticated dot serving at ≤ 1.35× the
//! unauthenticated per-job cost (asserted outright in full mode too).
//!
//! Quick mode for CI: `BENCH_QUICK=1 cargo bench --bench bench_auth`
//! (or `--quick`).

mod common;

use hrfna::coordinator::batcher::BatchPolicy;
use hrfna::coordinator::router::ShapeBuckets;
use hrfna::coordinator::{
    closed_loop, Backend, ContextRegistry, Coordinator, CoordinatorConfig, ExecMode, InProcess,
    JobSpec, Tier,
};
use hrfna::hybrid::auth::values_checksum;
use hrfna::util::bench::{write_json, BenchRecord};
use hrfna::util::cli::Args;
use hrfna::util::prng::Rng;
use hrfna::workloads::fir::lowpass_taps;
use hrfna::workloads::generators::Dist;
use std::sync::Arc;
use std::time::Duration;

const DOT_N: usize = 4096;
const MATMUL_DIM: usize = 64;
const FIR_N: usize = 256;
const FIR_TAPS: usize = 16;
const CLIENTS: usize = 4;
const BURST: usize = 16;

/// The authenticated-serving overhead cap the CI gate enforces (the
/// committed `serve_auth_overhead_ratio` baseline × the 20% tolerance
/// lands exactly here; full mode asserts it outright as well).
const AUTH_OVERHEAD_CAP: f64 = 1.35;

fn backend() -> InProcess {
    let engine = hrfna::runtime::EngineHandle::spawn(None).expect("engine");
    InProcess::new(Coordinator::start(
        engine,
        Arc::new(ContextRegistry::new()),
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
                capacity: 4096,
            },
            buckets: ShapeBuckets { tiers: vec![Tier::Paper], ..ShapeBuckets::default() },
            exec: ExecMode::Planar,
            ..CoordinatorConfig::default()
        },
    ))
}

/// One closed-loop A/B leg: fresh backend, warmup (with the check-field
/// contract asserted), measured run, clean-drain. Returns jobs/sec and
/// pushes the absolute record.
fn run_leg(
    records: &mut Vec<BenchRecord>,
    name: &str,
    label: &str,
    jobs_per_client: usize,
    burst: usize,
    authed: bool,
    make: &(dyn Fn(u64, usize) -> JobSpec + Sync),
) -> f64 {
    let be = backend();
    for _ in 0..4 {
        let r = be.call(make(0, 0)).expect("warmup job");
        if authed {
            assert_eq!(
                r.check,
                Some(values_checksum(&r.values)),
                "{label}: authenticated results must carry the values checksum"
            );
        } else {
            assert_eq!(r.check, None, "{label}: plain results carry no checksum");
        }
    }
    let report = closed_loop(&be, CLIENTS, jobs_per_client, burst, make);
    assert_eq!(report.accepted, report.offered, "{label}: capacity too small");
    assert_eq!(report.completed, report.accepted, "{label}: lost jobs");
    assert_eq!(
        be.integrity_detections(),
        0,
        "{label}: a clean run must record zero integrity detections"
    );
    let lat = report.latency_us.as_ref().expect("latencies");
    println!(
        "{label}: {:.0} jobs/s  (p50 {:.0} us, p99 {:.0} us)",
        report.jobs_per_s, lat.p50, lat.p99
    );
    let drain = be.shutdown().expect("shutdown");
    assert!(drain.is_clean(), "{label}: unclean drain: {drain}");
    records.push(BenchRecord {
        name: name.to_string(),
        n: report.completed as u64,
        ns_per_op: report.wall.as_nanos() as f64 / report.completed.max(1) as f64,
        throughput_per_s: report.jobs_per_s,
    });
    report.jobs_per_s
}

/// Machine-independent same-run ratio record: authenticated per-job cost
/// over unauthenticated (`ns_per_op` = overhead, lower is better;
/// `throughput_per_s` = fraction of plain throughput retained).
fn ratio_record(name: &str, unauth_jps: f64, auth_jps: f64) -> (BenchRecord, f64) {
    let overhead = unauth_jps / auth_jps.max(1e-9);
    let rec = BenchRecord {
        name: name.to_string(),
        n: 1,
        ns_per_op: overhead,
        throughput_per_s: 1.0 / overhead.max(1e-9),
    };
    (rec, overhead)
}

fn main() {
    common::banner("§Auth", "authenticated (MAC/Freivalds) vs plain serving cost");
    let args = Args::from_env();
    let quick = args.flag("quick") || std::env::var("BENCH_QUICK").is_ok();

    let mut rng = Rng::new(2027);
    let dot_pool: Vec<(Vec<f64>, Vec<f64>)> = (0..16)
        .map(|_| {
            (
                Dist::moderate().sample_vec(&mut rng, DOT_N),
                Dist::moderate().sample_vec(&mut rng, DOT_N),
            )
        })
        .collect();
    let mm_pool: Vec<(Vec<f64>, Vec<f64>)> = (0..8)
        .map(|_| {
            (
                Dist::moderate().sample_vec(&mut rng, MATMUL_DIM * MATMUL_DIM),
                Dist::moderate().sample_vec(&mut rng, MATMUL_DIM * MATMUL_DIM),
            )
        })
        .collect();
    let taps = lowpass_taps(FIR_TAPS, 0.2);
    let fir_pool: Vec<Vec<f64>> = (0..8)
        .map(|_| Dist::moderate().sample_vec(&mut rng, FIR_N))
        .collect();

    let mut records: Vec<BenchRecord> = Vec::new();

    // Dot A/B — the headline overhead: MAC-lane batch authentication +
    // dual-MAC verified window dot + wire checksum vs the plain planar
    // path on identical operands.
    let dot_jobs = if quick { 64 } else { 256 };
    let make_dot = |c: u64, i: usize| -> JobSpec {
        let (x, y) = &dot_pool[(c as usize * 7 + i) % dot_pool.len()];
        JobSpec::dot(x.clone(), y.clone())
    };
    let make_dot_auth = |c: u64, i: usize| -> JobSpec { make_dot(c, i).authenticated() };
    let plain_jps = run_leg(
        &mut records,
        "serve_dot_unauth_jobs",
        &format!("dot n={DOT_N} plain"),
        dot_jobs,
        BURST,
        false,
        &make_dot,
    );
    let auth_jps = run_leg(
        &mut records,
        "serve_dot_auth_jobs",
        &format!("dot n={DOT_N} auth "),
        dot_jobs,
        BURST,
        true,
        &make_dot_auth,
    );
    let (rec, overhead) = ratio_record("serve_auth_overhead_ratio", plain_jps, auth_jps);
    records.push(rec);
    println!("-> authenticated dot serving overhead: {overhead:.2}x plain cost");
    if !quick {
        assert!(
            overhead <= AUTH_OVERHEAD_CAP,
            "authenticated dot serving must stay <= {AUTH_OVERHEAD_CAP}x the \
             unauthenticated per-job cost (got {overhead:.2}x)"
        );
    }

    // Matmul A/B — Freivalds verification rides on the unchanged product
    // datapath, so its overhead is the A·(B·r) probe alone.
    let mm_jobs = if quick { 16 } else { 48 };
    let make_mm = |c: u64, i: usize| -> JobSpec {
        let (a, b) = &mm_pool[(c as usize * 5 + i) % mm_pool.len()];
        JobSpec::matmul(a.clone(), b.clone(), MATMUL_DIM)
    };
    let make_mm_auth = |c: u64, i: usize| -> JobSpec { make_mm(c, i).authenticated() };
    let mm_plain_jps = run_leg(
        &mut records,
        "serve_matmul_unauth_jobs",
        &format!("matmul dim={MATMUL_DIM} plain"),
        mm_jobs,
        8,
        false,
        &make_mm,
    );
    let mm_auth_jps = run_leg(
        &mut records,
        "serve_matmul_auth_jobs",
        &format!("matmul dim={MATMUL_DIM} auth "),
        mm_jobs,
        8,
        true,
        &make_mm_auth,
    );
    let (rec, mm_overhead) = ratio_record("serve_matmul_freivalds_ratio", mm_plain_jps, mm_auth_jps);
    records.push(rec);
    println!("-> Freivalds matmul verification overhead: {mm_overhead:.2}x plain cost");

    // Authenticated FIR: per-output verified window dots — the most
    // verification-heavy lane; tracked as an absolute record so a
    // regression in the windowed verifier shows up in exactly this case.
    let fir_jobs = if quick { 16 } else { 48 };
    let make_fir = |c: u64, i: usize| -> JobSpec {
        let x = &fir_pool[(c as usize * 3 + i) % fir_pool.len()];
        JobSpec::fir(taps.clone(), x.clone()).authenticated()
    };
    run_leg(
        &mut records,
        "serve_fir_auth_jobs",
        &format!("fir taps={FIR_TAPS} n={FIR_N} auth"),
        fir_jobs,
        8,
        true,
        &make_fir,
    );

    match write_json("BENCH_auth.json", &records) {
        Ok(()) => println!("\nwrote BENCH_auth.json ({} records)", records.len()),
        Err(e) => eprintln!("could not write BENCH_auth.json: {e}"),
    }
}
