//! Table III (Vector Dot Product rows): RMS error, stability vs length,
//! throughput ratio, normalization rate — HRFNA vs FP32 vs BFP.
//!
//! Paper claims reproduced: RMS < 1e-6 at all lengths; error does not grow
//! with N (BFP's does); 2.4× throughput over FP32; threshold-driven,
//! rare normalization.

mod common;

use hrfna::baselines::{Bfp, BfpConfig};
use hrfna::fpga::pipeline::{speedup, WorkloadKind};
use hrfna::hybrid::{Hrfna, HrfnaBatch, HrfnaContext};
use hrfna::util::bench::{bench, write_json, BenchRecord};
use hrfna::util::prng::Rng;
use hrfna::util::table::Table;
use hrfna::workloads::{dot, generators::Dist};

fn main() {
    common::banner("Table III / §VII-B", "vector dot product");
    let cfg = hrfna::config::HrfnaConfig::paper_default();
    // Quick mode (CI): fewer accuracy trials and no 65536-length row; the
    // measured-host section below is untouched so every BENCH_dot.json
    // record name still exists for the regression gate.
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let trials = if quick { 1 } else { 3 };
    let accuracy_lengths: &[usize] = if quick {
        &[1024, 4096, 16384]
    } else {
        &[1024, 4096, 16384, 65536]
    };

    let mut t = Table::new(
        "Dot product: accuracy + modeled throughput (moderate operands)",
        &[
            "n", "HRFNA rms", "FP32 rms", "BFP rms", "norm/op", "HRFNA vs FP32 thr",
        ],
    );
    for &n in accuracy_lengths {
        let ctx = HrfnaContext::new(cfg.clone());
        let h = dot::dot_rms_error::<Hrfna>(trials, n, Dist::moderate(), 42, &ctx);
        let snap = ctx.snapshot();
        let f = dot::dot_rms_error::<f32>(trials, n, Dist::moderate(), 42, &());
        let b = dot::dot_rms_error::<Bfp>(trials, n, Dist::moderate(), 42, &BfpConfig::default());
        let norm_events = (snap.norms + snap.guard_norms) / trials as u64;
        let kind = WorkloadKind::Dot { n: n as u64 };
        let tm = common::timings_for(&cfg, kind, norm_events);
        let s = speedup(&tm[0], &tm[1]);
        t.rowv(&[
            n.to_string(),
            format!("{h:.2e}"),
            format!("{f:.2e}"),
            format!("{b:.2e}"),
            format!("{:.2e}", snap.norm_rate()),
            format!("{s:.2}x"),
        ]);
        assert!(h < 1e-6, "paper claim: HRFNA rms < 1e-6 (n={n}, rms={h})");
    }
    t.print();

    // High-dynamic-range variant (normalization active).
    let mut t = Table::new(
        "Dot product: high-dynamic-range operands",
        &["n", "HRFNA rms", "FP32 rms", "BFP rms", "norm/op"],
    );
    for n in [4096usize, 16384] {
        let ctx = HrfnaContext::new(cfg.clone());
        let h = dot::dot_rms_error::<Hrfna>(trials, n, Dist::high_dynamic_range(), 7, &ctx);
        let f = dot::dot_rms_error::<f32>(trials, n, Dist::high_dynamic_range(), 7, &());
        let b = dot::dot_rms_error::<Bfp>(
            trials,
            n,
            Dist::high_dynamic_range(),
            7,
            &BfpConfig::default(),
        );
        t.rowv(&[
            n.to_string(),
            format!("{h:.2e}"),
            format!("{f:.2e}"),
            format!("{b:.2e}"),
            format!("{:.2e}", ctx.snapshot().norm_rate()),
        ]);
    }
    t.print();
    println!("paper: HRFNA <1e-6 & stable vs length; BFP degrades; 2.4x throughput");

    // --- measured host wall-clock: scalar reference vs planar engine ------
    let ctx = HrfnaContext::new(cfg);
    let mut rng = Rng::new(99);
    let mut t = Table::new(
        "measured host dot (pre-encoded operands)",
        &["n", "scalar ns/MAC", "planar ns/MAC", "speedup"],
    );
    let mut records = Vec::new();
    for n in [1024usize, 4096, 16384] {
        let xs: Vec<Hrfna> = Dist::moderate()
            .sample_vec(&mut rng, n)
            .iter()
            .map(|&q| Hrfna::encode(q, &ctx))
            .collect();
        let ys: Vec<Hrfna> = Dist::moderate()
            .sample_vec(&mut rng, n)
            .iter()
            .map(|&q| Hrfna::encode(q, &ctx))
            .collect();
        let r_scalar = bench(&format!("dot scalar n={n}"), || {
            dot::dot_product_encoded_scalar::<Hrfna>(&xs, &ys, &ctx)
        });
        let bx = HrfnaBatch::from_items(&xs, ctx.k());
        let by = HrfnaBatch::from_items(&ys, ctx.k());
        let r_planar = bench(&format!("dot planar n={n}"), || bx.dot(&by, &ctx));
        t.rowv(&[
            n.to_string(),
            format!("{:.1}", r_scalar.ns_per_iter / n as f64),
            format!("{:.1}", r_planar.ns_per_iter / n as f64),
            format!("{:.2}x", r_scalar.ns_per_iter / r_planar.ns_per_iter),
        ]);
        records.push(BenchRecord::from_result(
            &format!("dot_scalar_n{n}"),
            n as u64,
            &r_scalar,
        ));
        records.push(BenchRecord::from_result(
            &format!("dot_planar_n{n}"),
            n as u64,
            &r_planar,
        ));
    }
    t.print();
    match write_json("BENCH_dot.json", &records) {
        Ok(()) => println!("wrote BENCH_dot.json ({} records)", records.len()),
        Err(e) => eprintln!("could not write BENCH_dot.json: {e}"),
    }
}
