//! Table III (Vector Dot Product rows): RMS error, stability vs length,
//! throughput ratio, normalization rate — HRFNA vs FP32 vs BFP.
//!
//! Paper claims reproduced: RMS < 1e-6 at all lengths; error does not grow
//! with N (BFP's does); 2.4× throughput over FP32; threshold-driven,
//! rare normalization.

mod common;

use hrfna::baselines::{Bfp, BfpConfig};
use hrfna::fpga::pipeline::{speedup, WorkloadKind};
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::util::table::Table;
use hrfna::workloads::{dot, generators::Dist};

fn main() {
    common::banner("Table III / §VII-B", "vector dot product");
    let cfg = hrfna::config::HrfnaConfig::paper_default();
    let trials = 3;

    let mut t = Table::new(
        "Dot product: accuracy + modeled throughput (moderate operands)",
        &[
            "n", "HRFNA rms", "FP32 rms", "BFP rms", "norm/op", "HRFNA vs FP32 thr",
        ],
    );
    for n in [1024usize, 4096, 16384, 65536] {
        let ctx = HrfnaContext::new(cfg.clone());
        let h = dot::dot_rms_error::<Hrfna>(trials, n, Dist::moderate(), 42, &ctx);
        let snap = ctx.snapshot();
        let f = dot::dot_rms_error::<f32>(trials, n, Dist::moderate(), 42, &());
        let b = dot::dot_rms_error::<Bfp>(trials, n, Dist::moderate(), 42, &BfpConfig::default());
        let norm_events = (snap.norms + snap.guard_norms) / trials as u64;
        let kind = WorkloadKind::Dot { n: n as u64 };
        let tm = common::timings_for(&cfg, kind, norm_events);
        let s = speedup(&tm[0], &tm[1]);
        t.rowv(&[
            n.to_string(),
            format!("{h:.2e}"),
            format!("{f:.2e}"),
            format!("{b:.2e}"),
            format!("{:.2e}", snap.norm_rate()),
            format!("{s:.2}x"),
        ]);
        assert!(h < 1e-6, "paper claim: HRFNA rms < 1e-6 (n={n}, rms={h})");
    }
    t.print();

    // High-dynamic-range variant (normalization active).
    let mut t = Table::new(
        "Dot product: high-dynamic-range operands",
        &["n", "HRFNA rms", "FP32 rms", "BFP rms", "norm/op"],
    );
    for n in [4096usize, 16384] {
        let ctx = HrfnaContext::new(cfg.clone());
        let h = dot::dot_rms_error::<Hrfna>(trials, n, Dist::high_dynamic_range(), 7, &ctx);
        let f = dot::dot_rms_error::<f32>(trials, n, Dist::high_dynamic_range(), 7, &());
        let b = dot::dot_rms_error::<Bfp>(
            trials,
            n,
            Dist::high_dynamic_range(),
            7,
            &BfpConfig::default(),
        );
        t.rowv(&[
            n.to_string(),
            format!("{h:.2e}"),
            format!("{f:.2e}"),
            format!("{b:.2e}"),
            format!("{:.2e}", ctx.snapshot().norm_rate()),
        ]);
    }
    t.print();
    println!("paper: HRFNA <1e-6 & stable vs length; BFP degrades; 2.4x throughput");
}
