//! Tables I and IV: the qualitative format-comparison matrices — but
//! *derived from measured runs*, not asserted: each ✓/×/partial cell is
//! computed by a small experiment on this codebase.

mod common;

use hrfna::baselines::{Bfp, BfpConfig, Fixed, FixedConfig, Lns, LnsConfig, PureRns, PureRnsContext};
use hrfna::hybrid::{Hrfna, HrfnaContext};
use hrfna::util::table::{Align, Table};
use hrfna::workloads::rk4::{rk4_integrate, Ode};
use hrfna::workloads::traits::Numeric;
use hrfna::workloads::{dot, generators::Dist};

/// Verdict for one property cell.
fn v(b: bool) -> &'static str {
    if b {
        "yes"
    } else {
        "no"
    }
}

/// Dynamic-range probe: can the format represent 1e30 and 1e-30 with
/// < 1e-3 relative error after a multiply round-trip?
fn dynamic_range_ok<N: Numeric>(ctx: &N::Ctx) -> bool {
    let big = N::from_f64(1e30, ctx);
    let small = N::from_f64(1e-30, ctx);
    let p = big.mul(&small, ctx).to_f64(ctx);
    (p - 1.0).abs() < 1e-3
}

/// Accuracy probe: 4096-dot relative RMS below 1e-4?
fn dot_accurate<N: Numeric>(ctx: &N::Ctx) -> f64 {
    dot::dot_rms_error::<N>(2, 4096, Dist::moderate(), 5, ctx)
}

/// Stability probe: 20k-step damped-oscillator max error.
fn rk4_err<N: Numeric>(ctx: &N::Ctx) -> f64 {
    let ode = Ode::DampedOscillator { omega: 1.0, zeta: 0.05 };
    rk4_integrate::<N>(&ode, &[1.0, 0.0], 0.005, 20_000, 5_000, ctx).max_error()
}

fn main() {
    common::banner("Tables I & IV", "qualitative comparison, measured");

    let hctx = HrfnaContext::paper_default();
    let bctx = BfpConfig::default();
    let fxctx = FixedConfig::q16_16();
    let lctx = LnsConfig::default();
    let pctx = PureRnsContext::paper_default();

    // Measured probes.
    let probes = [
        (
            "Fixed-Point",
            false, // carry-free
            dynamic_range_ok::<Fixed>(&fxctx),
            dot_accurate::<Fixed>(&fxctx),
            rk4_err::<Fixed>(&fxctx),
        ),
        (
            "IEEE-754 FP32",
            false,
            dynamic_range_ok::<f32>(&()),
            dot_accurate::<f32>(&()),
            rk4_err::<f32>(&()),
        ),
        (
            "Block FP",
            false,
            dynamic_range_ok::<Bfp>(&bctx),
            dot_accurate::<Bfp>(&bctx),
            rk4_err::<Bfp>(&bctx),
        ),
        (
            "LNS",
            false,
            dynamic_range_ok::<Lns>(&lctx),
            dot_accurate::<Lns>(&lctx),
            rk4_err::<Lns>(&lctx),
        ),
        (
            "Pure RNS",
            true,
            dynamic_range_ok::<PureRns>(&pctx),
            dot_accurate::<PureRns>(&pctx),
            rk4_err::<PureRns>(&pctx),
        ),
        (
            "HRFNA",
            true,
            dynamic_range_ok::<Hrfna>(&hctx),
            dot_accurate::<Hrfna>(&hctx),
            rk4_err::<Hrfna>(&hctx),
        ),
    ];

    let mut t = Table::new(
        "Table I / IV — measured property matrix",
        &[
            "Representation",
            "Carry-free",
            "Dyn. range",
            "dot RMS (4k)",
            "RK4 err (20k)",
            "Formal bounds",
            "Long-term stable",
        ],
    )
    .aligns(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);

    let fp32_rk4 = probes[1].4;
    for (name, carry_free, dr, rms, rk4) in &probes {
        // "Formal bounds": HRFNA (Lemmas 1-2, verified in
        // bench_error_bounds) and IEEE-754 (standard semantics) qualify.
        let formal = matches!(*name, "HRFNA" | "IEEE-754 FP32" | "Fixed-Point");
        let stable = *rk4 <= fp32_rk4 * 10.0;
        t.rowv(&[
            name.to_string(),
            v(*carry_free).to_string(),
            v(*dr).to_string(),
            format!("{rms:.1e}"),
            format!("{rk4:.1e}"),
            v(formal).to_string(),
            v(stable).to_string(),
        ]);
    }
    t.print();

    // The paper's Table I/IV claim: only HRFNA has yes across the board.
    let h = probes.last().unwrap();
    assert!(h.1 && h.2, "HRFNA must be carry-free with wide range");
    assert!(h.3 < 1e-6, "HRFNA dot accuracy");
    assert!(h.4 <= fp32_rk4 * 2.0, "HRFNA stability must be FP32-class");
    let rns = &probes[4];
    assert!(!rns.2, "pure RNS must fail the dynamic-range probe");
    println!("paper: HRFNA is the only row satisfying every property");
}
