//! Engine service thread: the `xla` crate's PJRT client is `Rc`-based and
//! not `Send`, so a dedicated thread owns the [`Engine`] and worker lanes
//! talk to it through a cloneable, `Send` [`EngineHandle`]. PJRT's CPU
//! backend parallelizes internally, so a single dispatch thread is not the
//! throughput bottleneck (measured in the serve bench).

use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;

use super::pjrt::{Engine, Output, Tensor};

enum Call {
    Execute {
        name: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<Result<Output>>,
    },
    Info {
        reply: mpsc::Sender<(String, Vec<String>)>,
    },
    Shutdown,
}

/// Cloneable, thread-safe handle to the engine service.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Call>,
}

impl EngineHandle {
    /// Spawn the engine thread, loading artifacts from `dir` (or the
    /// default location when `None`). Fails fast if loading fails.
    pub fn spawn(dir: Option<PathBuf>) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Call>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        thread::Builder::new()
            .name("hrfna-engine".to_string())
            .spawn(move || {
                let engine = match dir {
                    Some(d) => Engine::load(&d),
                    None => Engine::load_default(),
                };
                let engine = match engine {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(call) = rx.recv() {
                    match call {
                        Call::Execute {
                            name,
                            inputs,
                            reply,
                        } => {
                            let _ = reply.send(engine.execute(&name, &inputs));
                        }
                        Call::Info { reply } => {
                            let _ = reply.send((engine.platform(), engine.names()));
                        }
                        Call::Shutdown => break,
                    }
                }
            })
            .expect("spawn engine thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during load"))??;
        Ok(EngineHandle { tx })
    }

    /// Execute an artifact synchronously.
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<Output> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Call::Execute {
                name: name.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine reply dropped"))?
    }

    /// Platform description + loaded artifact names.
    pub fn info(&self) -> Result<(String, Vec<String>)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Call::Info { reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine reply dropped"))
    }

    /// Stop the engine thread (best-effort).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Call::Shutdown);
    }
}
