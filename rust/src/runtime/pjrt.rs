//! PJRT execution engine: compile HLO text once at startup, execute many
//! times from the request path (one compiled executable per model variant,
//! as in the vLLM-router-style architecture).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use super::artifacts::{ArgSpec, DType, Manifest};

/// Typed input tensor for an execution call.
#[derive(Clone, Debug)]
pub enum Tensor {
    I64(Vec<i64>, Vec<usize>),
    F32(Vec<f32>, Vec<usize>),
    /// Scalar f32 (rank-0).
    ScalarF32(f32),
}

impl Tensor {
    fn matches(&self, spec: &ArgSpec) -> bool {
        match self {
            Tensor::I64(data, shape) => {
                spec.dtype == DType::I64 && *shape == spec.shape && data.len() == spec.numel()
            }
            Tensor::F32(data, shape) => {
                spec.dtype == DType::F32 && *shape == spec.shape && data.len() == spec.numel()
            }
            Tensor::ScalarF32(_) => spec.dtype == DType::F32 && spec.shape.is_empty(),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Tensor::I64(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Tensor::F32(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Tensor::ScalarF32(x) => xla::Literal::scalar(*x),
        })
    }
}

/// Typed output tensor.
#[derive(Clone, Debug)]
pub enum Output {
    I64(Vec<i64>),
    F32(Vec<f32>),
}

impl Output {
    /// Unwrap i64 data.
    pub fn into_i64(self) -> Result<Vec<i64>> {
        match self {
            Output::I64(v) => Ok(v),
            _ => bail!("output is not i64"),
        }
    }

    /// Unwrap f32 data.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Output::F32(v) => Ok(v),
            _ => bail!("output is not f32"),
        }
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    args: Vec<ArgSpec>,
    out_dtype: DType,
}

/// The runtime engine: a PJRT CPU client plus one compiled executable per
/// artifact. `execute` is `&self` and internally serialized per executable.
pub struct Engine {
    client: xla::PjRtClient,
    compiled: BTreeMap<String, Mutex<Compiled>>,
    pub manifest: Manifest,
}

impl Engine {
    /// Load every artifact in the manifest directory and compile it.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut compiled = BTreeMap::new();
        for (name, entry) in &manifest.entries {
            let proto = xla::HloModuleProto::from_text_file(
                entry
                    .path
                    .to_str()
                    .context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text for {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            // Output dtype convention: hybrid_* artifacts return i64,
            // fp32_*/rk4_* return f32 (matches compile/model.py).
            let out_dtype = if name.starts_with("hybrid") {
                DType::I64
            } else {
                DType::F32
            };
            compiled.insert(
                name.clone(),
                Mutex::new(Compiled {
                    exe,
                    args: entry.args.clone(),
                    out_dtype,
                }),
            );
        }
        Ok(Engine {
            client,
            compiled,
            manifest,
        })
    }

    /// Load from the default artifact location.
    pub fn load_default() -> Result<Engine> {
        Engine::load(&Manifest::default_dir())
    }

    /// Names of the loaded executables.
    pub fn names(&self) -> Vec<String> {
        self.compiled.keys().cloned().collect()
    }

    /// Device/platform description.
    pub fn platform(&self) -> String {
        format!(
            "{} ({} device(s))",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Execute artifact `name` with `inputs`; returns the first (tupled)
    /// output flattened.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Output> {
        let slot = self
            .compiled
            .get(name)
            .with_context(|| format!("unknown executable {name}"))?;
        let guard = slot.lock().expect("engine poisoned");
        if inputs.len() != guard.args.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                guard.args.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&guard.args).enumerate() {
            if !t.matches(spec) {
                bail!("{name}: input {i} does not match {spec:?}");
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = guard.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // Graphs are lowered with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(match guard.out_dtype {
            DType::I64 => Output::I64(out.to_vec::<i64>()?),
            DType::F32 => Output::F32(out.to_vec::<f32>()?),
        })
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in rust/tests/ (they
    // require `make artifacts` to have run). Here: pure-logic tests.
    use super::*;

    #[test]
    fn tensor_shape_matching() {
        let spec = ArgSpec {
            dtype: DType::I64,
            shape: vec![2, 3],
        };
        let good = Tensor::I64(vec![0; 6], vec![2, 3]);
        let bad_len = Tensor::I64(vec![0; 5], vec![2, 3]);
        let bad_ty = Tensor::F32(vec![0.0; 6], vec![2, 3]);
        assert!(good.matches(&spec));
        assert!(!bad_len.matches(&spec));
        assert!(!bad_ty.matches(&spec));
    }

    #[test]
    fn scalar_matches_rank0_only() {
        let s = Tensor::ScalarF32(1.0);
        assert!(s.matches(&ArgSpec { dtype: DType::F32, shape: vec![] }));
        assert!(!s.matches(&ArgSpec { dtype: DType::F32, shape: vec![1] }));
    }

    #[test]
    fn output_unwrap() {
        assert_eq!(Output::I64(vec![1]).into_i64().unwrap(), vec![1]);
        assert!(Output::I64(vec![1]).into_f32().is_err());
    }
}
