//! PJRT runtime: loads the AOT-compiled HLO artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` → `python -m compile.aot`) and executes
//! them from the Rust request path. Python never runs at request time.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProto with 64-bit
//! instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md and /opt/xla-example/README.md).

pub mod artifacts;
pub mod pjrt;
pub mod service;

pub use artifacts::{ArgSpec, DType, Manifest};
pub use pjrt::Engine;
pub use service::EngineHandle;
