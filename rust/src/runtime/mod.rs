//! Execution runtime behind the coordinator. Two interchangeable
//! backends expose the same `pjrt::{Engine, Tensor, Output}` surface:
//!
//! * **`xla` feature on** — the real PJRT backend: loads the
//!   AOT-compiled HLO artifacts (`artifacts/*.hlo.txt`, produced once by
//!   `make artifacts` → `python -m compile.aot`) and executes them with
//!   the PJRT CPU client. Python never runs at request time. Interchange
//!   is HLO *text*: jax ≥ 0.5 emits HloModuleProto with 64-bit
//!   instruction ids which xla_extension 0.5.1 rejects; the text parser
//!   reassigns ids (see DESIGN.md). Requires the vendored `xla` crate.
//! * **default (offline)** — the pure-Rust software executor
//!   (`swexec.rs`): the same graphs computed with host loops, bit-exact
//!   on the residue kernels, with no artifacts or XLA needed.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(not(feature = "xla"))]
#[path = "swexec.rs"]
pub mod pjrt;
pub mod service;

pub use artifacts::{ArgSpec, DType, Manifest};
pub use pjrt::Engine;
pub use service::EngineHandle;
