//! Artifact manifest: which graphs exist, where their HLO text lives, and
//! the argument shapes/dtypes they were lowered with (fixed at AOT time;
//! the coordinator's batcher buckets requests into these shapes).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Element type of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    I64,
    F32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "int64" => Ok(DType::I64),
            "float32" => Ok(DType::F32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// Shape + dtype of one argument.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArgSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    /// Parse `float32[4096]` / `int64[8, 4096]` / `float32[]`.
    fn parse(s: &str) -> Result<ArgSpec> {
        let (dt, rest) = s
            .split_once('[')
            .ok_or_else(|| anyhow!("bad arg descriptor {s}"))?;
        let dims = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("bad arg descriptor {s}"))?;
        let shape = if dims.trim().is_empty() {
            Vec::new()
        } else {
            dims.split(',')
                .map(|d| d.trim().parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(ArgSpec {
            dtype: DType::parse(dt)?,
            shape,
        })
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub path: PathBuf,
    pub args: Vec<ArgSpec>,
}

/// Parsed `manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, Entry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Locate the artifact directory: `$HRFNA_ARTIFACTS` or `./artifacts`
    /// (walking up from the current dir so tests work from target dirs).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("HRFNA_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let candidate = dir.join("artifacts");
            if candidate.join("manifest.txt").exists() {
                return candidate;
            }
            if !dir.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// Load the manifest from a directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        Manifest::parse(&text, dir)
    }

    /// Parse manifest text (`name file argdesc;argdesc;...` per line).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut entries = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.splitn(3, ' ');
            let name = parts.next().ok_or_else(|| anyhow!("empty line"))?;
            let file = parts
                .next()
                .ok_or_else(|| anyhow!("missing file in {line}"))?;
            let argdesc = parts.next().unwrap_or("");
            let args = if argdesc.is_empty() {
                Vec::new()
            } else {
                argdesc
                    .split(';')
                    .map(ArgSpec::parse)
                    .collect::<Result<Vec<_>>>()?
            };
            entries.insert(
                name.to_string(),
                Entry {
                    name: name.to_string(),
                    path: dir.join(file),
                    args,
                },
            );
        }
        Ok(Manifest {
            entries,
            dir: dir.to_path_buf(),
        })
    }

    /// Entry lookup.
    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_arg_specs() {
        let a = ArgSpec::parse("int64[8, 4096]").unwrap();
        assert_eq!(a.dtype, DType::I64);
        assert_eq!(a.shape, vec![8, 4096]);
        assert_eq!(a.numel(), 8 * 4096);
        let s = ArgSpec::parse("float32[]").unwrap();
        assert_eq!(s.shape.len(), 0);
        assert_eq!(s.numel(), 1);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(ArgSpec::parse("int64").is_err());
        assert!(ArgSpec::parse("complex64[2]").is_err());
        assert!(ArgSpec::parse("int64[a]").is_err());
    }

    #[test]
    fn parses_manifest_lines() {
        let text = "hybrid_dot hybrid_dot.hlo.txt int64[8, 4096];int64[8, 4096];int64[8]\nfp32_dot fp32_dot.hlo.txt float32[4096];float32[4096]\n";
        let m = Manifest::parse(text, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.get("hybrid_dot").unwrap();
        assert_eq!(e.args.len(), 3);
        assert_eq!(e.path, PathBuf::from("/tmp/a/hybrid_dot.hlo.txt"));
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Integration-lite: if the repo's artifacts are built, load them.
        let dir = Manifest::default_dir();
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.entries.contains_key("hybrid_dot"));
            assert!(m.entries.contains_key("fp32_dot"));
        }
    }
}
