//! Pure-Rust software executor — the offline stand-in for the PJRT
//! backend, compiled when the `xla` feature is off (the default).
//!
//! It exposes the exact `Engine`/`Tensor`/`Output` surface of
//! `runtime/pjrt.rs`, validates inputs against the same `ArgSpec` shapes,
//! and executes the known AOT graphs (see `python/compile/model.py`) with
//! straightforward host loops: channelwise `i128` modular arithmetic for
//! the `hybrid_*` residue kernels (bit-exact against the Rust residue
//! model) and `f32` loops for the FP32/RK4 baselines. When the artifact
//! manifest is absent (no `make artifacts`), the canonical shapes are
//! synthesized, so the full L3 serving stack runs offline with no Python
//! and no XLA.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use super::artifacts::{ArgSpec, DType, Manifest};

/// Canonical AOT shapes — keep in sync with `python/compile/model.py`.
const K_CHANNELS: usize = 8;
const DOT_N: usize = 4096;
const MM_DIM: usize = 64;
const RK4_BATCH: usize = 256;

/// Typed input tensor for an execution call.
#[derive(Clone, Debug)]
pub enum Tensor {
    I64(Vec<i64>, Vec<usize>),
    F32(Vec<f32>, Vec<usize>),
    /// Scalar f32 (rank-0).
    ScalarF32(f32),
}

impl Tensor {
    fn matches(&self, spec: &ArgSpec) -> bool {
        match self {
            Tensor::I64(data, shape) => {
                spec.dtype == DType::I64 && *shape == spec.shape && data.len() == spec.numel()
            }
            Tensor::F32(data, shape) => {
                spec.dtype == DType::F32 && *shape == spec.shape && data.len() == spec.numel()
            }
            Tensor::ScalarF32(_) => spec.dtype == DType::F32 && spec.shape.is_empty(),
        }
    }

    fn i64_data(&self) -> Result<(&[i64], &[usize])> {
        match self {
            Tensor::I64(d, s) => Ok((d, s)),
            _ => bail!("expected an i64 tensor"),
        }
    }

    fn f32_data(&self) -> Result<(&[f32], &[usize])> {
        match self {
            Tensor::F32(d, s) => Ok((d, s)),
            _ => bail!("expected an f32 tensor"),
        }
    }

    fn scalar_f32(&self) -> Result<f32> {
        match self {
            Tensor::ScalarF32(x) => Ok(*x),
            Tensor::F32(d, s) if s.is_empty() && d.len() == 1 => Ok(d[0]),
            _ => bail!("expected a scalar f32"),
        }
    }
}

/// Typed output tensor.
#[derive(Clone, Debug)]
pub enum Output {
    I64(Vec<i64>),
    F32(Vec<f32>),
}

impl Output {
    /// Unwrap i64 data.
    pub fn into_i64(self) -> Result<Vec<i64>> {
        match self {
            Output::I64(v) => Ok(v),
            _ => bail!("output is not i64"),
        }
    }

    /// Unwrap f32 data.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Output::F32(v) => Ok(v),
            _ => bail!("output is not f32"),
        }
    }
}

/// The software engine: one validated argument list per known graph.
pub struct Engine {
    compiled: BTreeMap<String, Vec<ArgSpec>>,
    pub manifest: Manifest,
}

/// Argument shapes for one synthesized graph (manifest-free load).
fn default_args(name: &str) -> Option<Vec<ArgSpec>> {
    let spec = |dtype, shape: &[usize]| ArgSpec {
        dtype,
        shape: shape.to_vec(),
    };
    let k = K_CHANNELS;
    Some(match name {
        "hybrid_dot" | "hybrid_modmul" | "hybrid_modadd" => vec![
            spec(DType::I64, &[k, DOT_N]),
            spec(DType::I64, &[k, DOT_N]),
            spec(DType::I64, &[k]),
        ],
        "hybrid_matmul" => vec![
            spec(DType::I64, &[k, MM_DIM, MM_DIM]),
            spec(DType::I64, &[k, MM_DIM, MM_DIM]),
            spec(DType::I64, &[k]),
        ],
        "fp32_dot" => vec![spec(DType::F32, &[DOT_N]), spec(DType::F32, &[DOT_N])],
        "fp32_matmul" => vec![
            spec(DType::F32, &[MM_DIM, MM_DIM]),
            spec(DType::F32, &[MM_DIM, MM_DIM]),
        ],
        "rk4_vdp_step" => vec![
            spec(DType::F32, &[RK4_BATCH, 2]),
            spec(DType::F32, &[]),
            spec(DType::F32, &[]),
        ],
        _ => return None,
    })
}

/// The graph names every deployment serves (model.py's GRAPHS table).
const GRAPH_NAMES: [&str; 7] = [
    "hybrid_dot",
    "hybrid_matmul",
    "hybrid_modmul",
    "hybrid_modadd",
    "fp32_dot",
    "fp32_matmul",
    "rk4_vdp_step",
];

impl Engine {
    /// Load argument specs from the artifact manifest when present, or
    /// synthesize the canonical set so the software path needs no
    /// artifacts at all.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir).unwrap_or_default();
        let mut compiled = BTreeMap::new();
        for (name, entry) in &manifest.entries {
            let args = if entry.args.is_empty() {
                default_args(name)
                    .with_context(|| format!("no arg specs for artifact {name}"))?
            } else {
                entry.args.clone()
            };
            compiled.insert(name.clone(), args);
        }
        for name in GRAPH_NAMES {
            if !compiled.contains_key(name) {
                compiled.insert(
                    name.to_string(),
                    default_args(name).expect("known graph"),
                );
            }
        }
        Ok(Engine { compiled, manifest })
    }

    /// Load from the default artifact location (or synthesized shapes).
    pub fn load_default() -> Result<Engine> {
        Engine::load(&Manifest::default_dir())
    }

    /// Names of the loaded executables (including the dynamic-batch
    /// serving graphs only the software backend provides).
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.compiled.keys().cloned().collect();
        names.push("fp32_dot_batch".to_string());
        names
    }

    /// Device/platform description.
    pub fn platform(&self) -> String {
        "software (pure-Rust reference backend, 1 device)".to_string()
    }

    /// Execute graph `name` with `inputs`; returns the output flattened.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Output> {
        if name == "fp32_dot_batch" {
            // The one graph with a dynamic leading dimension: the serving
            // batcher amortizes a single engine round trip over the whole
            // admitted batch. Validated inside (no frozen ArgSpec).
            return exec_fp32_dot_batch(inputs);
        }
        let args = self
            .compiled
            .get(name)
            .with_context(|| format!("unknown executable {name}"))?;
        if inputs.len() != args.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                args.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(args).enumerate() {
            if !t.matches(spec) {
                bail!("{name}: input {i} does not match {spec:?}");
            }
        }
        match name {
            "hybrid_dot" => exec_hybrid_dot(inputs),
            "hybrid_matmul" => exec_hybrid_matmul(inputs),
            "hybrid_modmul" => exec_elementwise(inputs, |a, b, m| a * b % m),
            "hybrid_modadd" => exec_elementwise(inputs, |a, b, m| (a + b) % m),
            "fp32_dot" => exec_fp32_dot(inputs),
            "fp32_matmul" => exec_fp32_matmul(inputs),
            "rk4_vdp_step" => exec_rk4_vdp_step(inputs),
            other => bail!("no software kernel for {other}"),
        }
    }
}

/// `int64[k,n] × int64[k,n] × int64[k] -> int64[k]`: channelwise modular
/// MAC (the residue half of Algorithm 1; bit-exact vs the Rust model).
fn exec_hybrid_dot(inputs: &[Tensor]) -> Result<Output> {
    let (x, shape) = inputs[0].i64_data()?;
    let (y, _) = inputs[1].i64_data()?;
    let (m, _) = inputs[2].i64_data()?;
    let (k, n) = (shape[0], shape[1]);
    let mut out = Vec::with_capacity(k);
    for c in 0..k {
        let modulus = m[c] as i128;
        let mut acc = 0i128;
        for j in 0..n {
            acc = (acc + x[c * n + j] as i128 * y[c * n + j] as i128) % modulus;
        }
        out.push(acc as i64);
    }
    Ok(Output::I64(out))
}

/// `int64[k,d,d] × int64[k,d,d] × int64[k] -> int64[k·d·d]`: per-channel
/// modular matmul.
fn exec_hybrid_matmul(inputs: &[Tensor]) -> Result<Output> {
    let (a, shape) = inputs[0].i64_data()?;
    let (b, _) = inputs[1].i64_data()?;
    let (m, _) = inputs[2].i64_data()?;
    let (k, d) = (shape[0], shape[1]);
    let mut out = vec![0i64; k * d * d];
    for c in 0..k {
        let modulus = m[c] as i128;
        let ac = &a[c * d * d..(c + 1) * d * d];
        let bc = &b[c * d * d..(c + 1) * d * d];
        let oc = &mut out[c * d * d..(c + 1) * d * d];
        for i in 0..d {
            for j in 0..d {
                let mut acc = 0i128;
                for p in 0..d {
                    acc = (acc + ac[i * d + p] as i128 * bc[p * d + j] as i128) % modulus;
                }
                oc[i * d + j] = acc as i64;
            }
        }
    }
    Ok(Output::I64(out))
}

/// Elementwise channelwise modular op over `int64[k,n]` operands.
fn exec_elementwise(inputs: &[Tensor], op: fn(i128, i128, i128) -> i128) -> Result<Output> {
    let (x, shape) = inputs[0].i64_data()?;
    let (y, _) = inputs[1].i64_data()?;
    let (m, _) = inputs[2].i64_data()?;
    let (k, n) = (shape[0], shape[1]);
    let mut out = vec![0i64; k * n];
    for c in 0..k {
        let modulus = m[c] as i128;
        for j in 0..n {
            let idx = c * n + j;
            out[idx] = op(x[idx] as i128, y[idx] as i128, modulus) as i64;
        }
    }
    Ok(Output::I64(out))
}

/// `f32[n] × f32[n] -> f32[]`.
fn exec_fp32_dot(inputs: &[Tensor]) -> Result<Output> {
    let (x, _) = inputs[0].f32_data()?;
    let (y, _) = inputs[1].f32_data()?;
    let mut acc = 0.0f32;
    for (a, b) in x.iter().zip(y) {
        acc += a * b;
    }
    Ok(Output::F32(vec![acc]))
}

/// `f32[b,n] × f32[b,n] -> f32[b]`: one dot product per batch row. The
/// leading dimension is dynamic — the software stand-in for a batched AOT
/// graph family.
fn exec_fp32_dot_batch(inputs: &[Tensor]) -> Result<Output> {
    if inputs.len() != 2 {
        bail!("fp32_dot_batch: expected 2 inputs, got {}", inputs.len());
    }
    let (x, xs) = inputs[0].f32_data()?;
    let (y, ys) = inputs[1].f32_data()?;
    if xs.len() != 2 || xs != ys || xs[0] == 0 || x.len() != xs[0] * xs[1] || y.len() != x.len()
    {
        bail!("fp32_dot_batch: bad shapes {xs:?} vs {ys:?}");
    }
    let (b, n) = (xs[0], xs[1]);
    let mut out = Vec::with_capacity(b);
    for row in 0..b {
        let mut acc = 0.0f32;
        for j in 0..n {
            acc += x[row * n + j] * y[row * n + j];
        }
        out.push(acc);
    }
    Ok(Output::F32(out))
}

/// `f32[d,d] × f32[d,d] -> f32[d·d]`.
fn exec_fp32_matmul(inputs: &[Tensor]) -> Result<Output> {
    let (a, shape) = inputs[0].f32_data()?;
    let (b, _) = inputs[1].f32_data()?;
    let d = shape[0];
    let mut out = vec![0.0f32; d * d];
    for i in 0..d {
        for j in 0..d {
            let mut acc = 0.0f32;
            for p in 0..d {
                acc += a[i * d + p] * b[p * d + j];
            }
            out[i * d + j] = acc;
        }
    }
    Ok(Output::F32(out))
}

/// One classical RK4 step for a batch of Van der Pol states (`f32[b,2]`),
/// mirroring `python/compile/model.py::rk4_vdp_step`.
fn exec_rk4_vdp_step(inputs: &[Tensor]) -> Result<Output> {
    let (state, shape) = inputs[0].f32_data()?;
    let dt = inputs[1].scalar_f32()?;
    let mu = inputs[2].scalar_f32()?;
    let b = shape[0];
    let f = |s: &[f32; 2]| -> [f32; 2] { [s[1], mu * (1.0 - s[0] * s[0]) * s[1] - s[0]] };
    let mut out = vec![0.0f32; b * 2];
    for i in 0..b {
        let s = [state[i * 2], state[i * 2 + 1]];
        let k1 = f(&s);
        let s2 = [s[0] + 0.5 * dt * k1[0], s[1] + 0.5 * dt * k1[1]];
        let k2 = f(&s2);
        let s3 = [s[0] + 0.5 * dt * k2[0], s[1] + 0.5 * dt * k2[1]];
        let k3 = f(&s3);
        let s4 = [s[0] + dt * k3[0], s[1] + dt * k3[1]];
        let k4 = f(&s4);
        for d in 0..2 {
            out[i * 2 + d] = s[d] + dt / 6.0 * (k1[d] + 2.0 * k2[d] + 2.0 * k3[d] + k4[d]);
        }
    }
    Ok(Output::F32(out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::hybrid_exec::{decode_scalar, encode_block};
    use crate::hybrid::HrfnaContext;
    use crate::util::prng::Rng;
    use crate::workloads::generators::Dist;

    fn engine() -> Engine {
        Engine::load_default().expect("software engine always loads")
    }

    #[test]
    fn loads_all_graphs_without_artifacts() {
        let e = engine();
        let names = e.names();
        for want in GRAPH_NAMES {
            assert!(names.iter().any(|n| n == want), "missing {want}");
        }
        assert!(e.platform().contains("software"));
    }

    #[test]
    fn tensor_shape_matching() {
        let spec = ArgSpec {
            dtype: DType::I64,
            shape: vec![2, 3],
        };
        let good = Tensor::I64(vec![0; 6], vec![2, 3]);
        let bad_len = Tensor::I64(vec![0; 5], vec![2, 3]);
        let bad_ty = Tensor::F32(vec![0.0; 6], vec![2, 3]);
        assert!(good.matches(&spec));
        assert!(!bad_len.matches(&spec));
        assert!(!bad_ty.matches(&spec));
    }

    #[test]
    fn scalar_matches_rank0_only() {
        let s = Tensor::ScalarF32(1.0);
        assert!(s.matches(&ArgSpec { dtype: DType::F32, shape: vec![] }));
        assert!(!s.matches(&ArgSpec { dtype: DType::F32, shape: vec![1] }));
    }

    #[test]
    fn output_unwrap() {
        assert_eq!(Output::I64(vec![1]).into_i64().unwrap(), vec![1]);
        assert!(Output::I64(vec![1]).into_f32().is_err());
    }

    #[test]
    fn rejects_wrong_shapes_and_arity() {
        let e = engine();
        let bad = e.execute(
            "fp32_dot",
            &[
                Tensor::F32(vec![0.0; 8], vec![8]),
                Tensor::F32(vec![0.0; 8], vec![8]),
            ],
        );
        assert!(bad.is_err());
        let bad = e.execute("fp32_dot", &[Tensor::F32(vec![0.0; DOT_N], vec![DOT_N])]);
        assert!(bad.is_err());
        assert!(e.execute("nonexistent", &[]).is_err());
    }

    #[test]
    fn fp32_dot_batch_dynamic_leading_dim() {
        let e = engine();
        for b in [1usize, 3, 8] {
            let n = 16;
            let x: Vec<f32> = (0..b * n).map(|i| (i % 7) as f32 - 3.0).collect();
            let y: Vec<f32> = (0..b * n).map(|i| (i % 5) as f32 - 2.0).collect();
            let out = e
                .execute(
                    "fp32_dot_batch",
                    &[
                        Tensor::F32(x.clone(), vec![b, n]),
                        Tensor::F32(y.clone(), vec![b, n]),
                    ],
                )
                .unwrap()
                .into_f32()
                .unwrap();
            assert_eq!(out.len(), b);
            for row in 0..b {
                let want: f32 = (0..n).map(|j| x[row * n + j] * y[row * n + j]).sum();
                assert!((out[row] - want).abs() < 1e-4, "b={b} row={row}");
            }
        }
        // Mismatched shapes are rejected.
        assert!(e
            .execute(
                "fp32_dot_batch",
                &[
                    Tensor::F32(vec![0.0; 4], vec![2, 2]),
                    Tensor::F32(vec![0.0; 6], vec![2, 3]),
                ],
            )
            .is_err());
        assert!(e.names().iter().any(|n| n == "fp32_dot_batch"));
    }

    #[test]
    fn hybrid_dot_matches_decoded_f64() {
        let e = engine();
        let ctx = HrfnaContext::paper_default();
        let mut rng = Rng::new(3);
        let xs = Dist::moderate().sample_vec(&mut rng, DOT_N);
        let ys = Dist::moderate().sample_vec(&mut rng, DOT_N);
        let ex = encode_block(&xs, &ctx);
        let ey = encode_block(&ys, &ctx);
        let m: Vec<i64> = ctx.cfg.moduli.iter().map(|&v| v as i64).collect();
        let k = ctx.k();
        let got = e
            .execute(
                "hybrid_dot",
                &[
                    Tensor::I64(ex.residues, vec![k, DOT_N]),
                    Tensor::I64(ey.residues, vec![k, DOT_N]),
                    Tensor::I64(m, vec![k]),
                ],
            )
            .unwrap()
            .into_i64()
            .unwrap();
        let value = decode_scalar(&got, ex.f + ey.f, &ctx);
        let truth: f64 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
        let scale: f64 = xs.iter().zip(&ys).map(|(a, b)| (a * b).abs()).sum();
        assert!(
            (value - truth).abs() < 1e-7 * scale,
            "value={value} truth={truth}"
        );
    }
}
