//! Micro-benchmark harness (the offline registry has no criterion).
//!
//! Warms up, then runs timed iterations until both a minimum iteration count
//! and a minimum wall-clock budget are met; reports ns/iter with deviation.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub ns_per_iter: f64,
    pub stddev_ns: f64,
    pub throughput_per_s: f64,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12.1} ns/iter (±{:>8.1})  {:>14.0} it/s",
            self.name, self.ns_per_iter, self.stddev_ns, self.throughput_per_s
        )
    }
}

/// Benchmark a closure. `f` should return something observable to keep the
/// optimizer honest (its value is black-boxed here).
pub fn bench<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench_with(name, Duration::from_millis(300), 10, &mut f)
}

/// Benchmark with an explicit time budget and minimum sample count.
pub fn bench_with<T, F: FnMut() -> T>(
    name: &str,
    budget: Duration,
    min_samples: usize,
    f: &mut F,
) -> BenchResult {
    // Warm-up + calibration: find an inner-loop count so one sample takes
    // roughly budget/20.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(20));
    let target_sample = budget / 20;
    let inner = ((target_sample.as_nanos() / once.as_nanos().max(1)).max(1)) as u64;

    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_samples || start.elapsed() < budget {
        let t = Instant::now();
        for _ in 0..inner {
            std::hint::black_box(f());
        }
        samples.push(t.elapsed().as_nanos() as f64 / inner as f64);
        if samples.len() > 10_000 {
            break;
        }
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        iters: inner * samples.len() as u64,
        ns_per_iter: mean,
        stddev_ns: var.sqrt(),
        throughput_per_s: 1e9 / mean,
    }
}

/// Print a bench-suite header (keeps `cargo bench` output structured).
pub fn suite(title: &str) {
    println!("\n##### {title} #####");
}

/// Machine-readable benchmark record for the perf-trajectory tracking
/// (`BENCH_*.json` files): one timed case, normalized to per-op cost.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Stable case name (e.g. `dot_planar_n4096`).
    pub name: String,
    /// Problem size (elements per iteration; 1 for single-op cases).
    pub n: u64,
    /// Nanoseconds per op (ns/iter divided by `n`).
    pub ns_per_op: f64,
    /// Ops per second (1e9 / ns_per_op).
    pub throughput_per_s: f64,
}

impl BenchRecord {
    /// Build a record from a timed result, renamed to `name` and
    /// normalized by `n` ops per iteration.
    pub fn from_result(name: &str, n: u64, r: &BenchResult) -> BenchRecord {
        let ns_per_op = r.ns_per_iter / n.max(1) as f64;
        BenchRecord {
            name: name.to_string(),
            n,
            ns_per_op,
            throughput_per_s: if ns_per_op > 0.0 { 1e9 / ns_per_op } else { 0.0 },
        }
    }

    fn json(&self) -> String {
        // Names are code-controlled; escape the two JSON-breaking chars.
        let name = self.name.replace('\\', "\\\\").replace('"', "\\\"");
        format!(
            "{{\"name\":\"{name}\",\"n\":{},\"ns_per_op\":{:.3},\"throughput_per_s\":{:.1}}}",
            self.n, self.ns_per_op, self.throughput_per_s
        )
    }
}

/// Write records as a JSON array (one record per line) — the
/// `BENCH_hotpath.json` / `BENCH_dot.json` trajectory files.
pub fn write_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&r.json());
        out.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    out.push_str("]\n");
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let r = bench_with(
            "noop-ish",
            Duration::from_millis(20),
            3,
            &mut || std::hint::black_box(1u64 + 1),
        );
        assert!(r.ns_per_iter > 0.0);
        assert!(r.iters > 0);
        assert!(r.throughput_per_s > 0.0);
    }

    #[test]
    fn line_contains_name() {
        let r = bench_with("xyz", Duration::from_millis(5), 2, &mut || 0u8);
        assert!(r.line().contains("xyz"));
    }

    #[test]
    fn record_normalizes_per_op() {
        let r = BenchResult {
            name: "raw".into(),
            iters: 10,
            ns_per_iter: 4096.0,
            stddev_ns: 0.0,
            throughput_per_s: 1e9 / 4096.0,
        };
        let rec = BenchRecord::from_result("dot_planar_n4096", 4096, &r);
        assert_eq!(rec.n, 4096);
        assert!((rec.ns_per_op - 1.0).abs() < 1e-12);
        assert!((rec.throughput_per_s - 1e9).abs() < 1.0);
    }

    #[test]
    fn write_json_roundtrippable_shape() {
        let recs = vec![
            BenchRecord {
                name: "a\"b".into(),
                n: 1,
                ns_per_op: 2.5,
                throughput_per_s: 4e8,
            },
            BenchRecord {
                name: "c".into(),
                n: 7,
                ns_per_op: 1.0,
                throughput_per_s: 1e9,
            },
        ];
        let path = std::env::temp_dir().join("hrfna_bench_test.json");
        let path = path.to_str().unwrap();
        write_json(path, &recs).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.starts_with("[\n"));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\"name\":\"a\\\"b\""));
        assert!(text.contains("\"n\":7"));
        assert_eq!(text.matches('{').count(), 2);
        let _ = std::fs::remove_file(path);
    }
}
