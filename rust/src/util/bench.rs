//! Micro-benchmark harness (the offline registry has no criterion).
//!
//! Warms up, then runs timed iterations until both a minimum iteration count
//! and a minimum wall-clock budget are met; reports ns/iter with deviation.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub ns_per_iter: f64,
    pub stddev_ns: f64,
    pub throughput_per_s: f64,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12.1} ns/iter (±{:>8.1})  {:>14.0} it/s",
            self.name, self.ns_per_iter, self.stddev_ns, self.throughput_per_s
        )
    }
}

/// Benchmark a closure. `f` should return something observable to keep the
/// optimizer honest (its value is black-boxed here).
pub fn bench<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench_with(name, Duration::from_millis(300), 10, &mut f)
}

/// Benchmark with an explicit time budget and minimum sample count.
pub fn bench_with<T, F: FnMut() -> T>(
    name: &str,
    budget: Duration,
    min_samples: usize,
    f: &mut F,
) -> BenchResult {
    // Warm-up + calibration: find an inner-loop count so one sample takes
    // roughly budget/20.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(20));
    let target_sample = budget / 20;
    let inner = ((target_sample.as_nanos() / once.as_nanos().max(1)).max(1)) as u64;

    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_samples || start.elapsed() < budget {
        let t = Instant::now();
        for _ in 0..inner {
            std::hint::black_box(f());
        }
        samples.push(t.elapsed().as_nanos() as f64 / inner as f64);
        if samples.len() > 10_000 {
            break;
        }
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        iters: inner * samples.len() as u64,
        ns_per_iter: mean,
        stddev_ns: var.sqrt(),
        throughput_per_s: 1e9 / mean,
    }
}

/// Print a bench-suite header (keeps `cargo bench` output structured).
pub fn suite(title: &str) {
    println!("\n##### {title} #####");
}

/// Machine-readable benchmark record for the perf-trajectory tracking
/// (`BENCH_*.json` files): one timed case, normalized to per-op cost.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Stable case name (e.g. `dot_planar_n4096`).
    pub name: String,
    /// Problem size (elements per iteration; 1 for single-op cases).
    pub n: u64,
    /// Nanoseconds per op (ns/iter divided by `n`).
    pub ns_per_op: f64,
    /// Ops per second (1e9 / ns_per_op).
    pub throughput_per_s: f64,
}

impl BenchRecord {
    /// Build a record from a timed result, renamed to `name` and
    /// normalized by `n` ops per iteration.
    pub fn from_result(name: &str, n: u64, r: &BenchResult) -> BenchRecord {
        let ns_per_op = r.ns_per_iter / n.max(1) as f64;
        BenchRecord {
            name: name.to_string(),
            n,
            ns_per_op,
            throughput_per_s: if ns_per_op > 0.0 { 1e9 / ns_per_op } else { 0.0 },
        }
    }

    fn json(&self) -> String {
        // Names are code-controlled; escape the two JSON-breaking chars.
        let name = self.name.replace('\\', "\\\\").replace('"', "\\\"");
        format!(
            "{{\"name\":\"{name}\",\"n\":{},\"ns_per_op\":{:.3},\"throughput_per_s\":{:.1}}}",
            self.n, self.ns_per_op, self.throughput_per_s
        )
    }
}

/// Parse the `BENCH_*.json` record format back out of its text (the
/// registry has no serde; this reads exactly what [`write_json`] emits:
/// an array of flat objects with one string field and numeric fields).
pub fn parse_records(text: &str) -> Vec<BenchRecord> {
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(start) = rest.find('{') {
        let Some(end) = rest[start..].find('}') else { break };
        let obj = &rest[start + 1..start + end];
        rest = &rest[start + end + 1..];
        let Some(name) = parse_string_field(obj, "name") else { continue };
        let n = parse_number_field(obj, "n").unwrap_or(0.0) as u64;
        let ns_per_op = parse_number_field(obj, "ns_per_op").unwrap_or(0.0);
        let throughput_per_s = parse_number_field(obj, "throughput_per_s")
            .unwrap_or(if ns_per_op > 0.0 { 1e9 / ns_per_op } else { 0.0 });
        out.push(BenchRecord { name, n, ns_per_op, throughput_per_s });
    }
    out
}

/// Extract `"key":"value"` from a flat JSON object body, unescaping the
/// two characters [`write_json`] escapes (char-aware, so non-ASCII names
/// round-trip intact).
fn parse_string_field(obj: &str, key: &str) -> Option<String> {
    let tag = format!("\"{key}\":\"");
    let start = obj.find(&tag)? + tag.len();
    let mut value = String::new();
    let mut chars = obj[start..].chars();
    while let Some(c) = chars.next() {
        match c {
            '\\' => value.push(chars.next()?),
            '"' => return Some(value),
            other => value.push(other),
        }
    }
    None
}

/// Extract `"key":number` from a flat JSON object body.
fn parse_number_field(obj: &str, key: &str) -> Option<f64> {
    let tag = format!("\"{key}\":");
    let start = obj.find(&tag)? + tag.len();
    let rest = &obj[start..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Read a `BENCH_*.json` trajectory file.
pub fn read_json(path: &str) -> std::io::Result<Vec<BenchRecord>> {
    Ok(parse_records(&std::fs::read_to_string(path)?))
}

/// One bench-gate regression: a record whose per-op cost exceeded the
/// committed baseline by more than the tolerance.
#[derive(Clone, Debug)]
pub struct GateViolation {
    pub name: String,
    pub baseline_ns: f64,
    /// `f64::INFINITY` when the record vanished from the current run.
    pub current_ns: f64,
    /// current/baseline per-op cost.
    pub ratio: f64,
}

impl GateViolation {
    /// Gate report line.
    pub fn line(&self) -> String {
        if self.current_ns.is_finite() {
            format!(
                "REGRESSION {:<40} baseline {:>12.2} ns/op -> current {:>12.2} ns/op ({:.2}x)",
                self.name, self.baseline_ns, self.current_ns, self.ratio
            )
        } else {
            format!("MISSING    {:<40} (in baseline, absent from current run)", self.name)
        }
    }
}

/// Compare a current bench run against a committed baseline: a record
/// regresses when its ns/op exceeds the baseline by more than
/// `tolerance` (0.20 = 20%, the CI gate's default). Records present in
/// the baseline but missing from the current run fail too — a silently
/// deleted bench case must not pass the gate. New records (current-only)
/// are allowed; they become protected once the baseline is refreshed.
pub fn gate_records(
    baseline: &[BenchRecord],
    current: &[BenchRecord],
    tolerance: f64,
) -> Vec<GateViolation> {
    let mut violations = Vec::new();
    for base in baseline {
        match current.iter().find(|r| r.name == base.name) {
            None => violations.push(GateViolation {
                name: base.name.clone(),
                baseline_ns: base.ns_per_op,
                current_ns: f64::INFINITY,
                ratio: f64::INFINITY,
            }),
            Some(cur) => {
                if base.ns_per_op > 0.0 && cur.ns_per_op > base.ns_per_op * (1.0 + tolerance) {
                    violations.push(GateViolation {
                        name: base.name.clone(),
                        baseline_ns: base.ns_per_op,
                        current_ns: cur.ns_per_op,
                        ratio: cur.ns_per_op / base.ns_per_op,
                    });
                }
            }
        }
    }
    violations
}

/// Names present in `current` but absent from `baseline`: new bench
/// records. The gate accepts them with a warning (they become protected
/// once the baseline is refreshed); this is the complement of the
/// missing-record failure in [`gate_records`].
pub fn new_record_names(baseline: &[BenchRecord], current: &[BenchRecord]) -> Vec<String> {
    current
        .iter()
        .filter(|c| !baseline.iter().any(|b| b.name == c.name))
        .map(|c| c.name.clone())
        .collect()
}

/// Write records as a JSON array (one record per line) — the
/// `BENCH_hotpath.json` / `BENCH_dot.json` trajectory files.
pub fn write_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&r.json());
        out.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    out.push_str("]\n");
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let r = bench_with(
            "noop-ish",
            Duration::from_millis(20),
            3,
            &mut || std::hint::black_box(1u64 + 1),
        );
        assert!(r.ns_per_iter > 0.0);
        assert!(r.iters > 0);
        assert!(r.throughput_per_s > 0.0);
    }

    #[test]
    fn line_contains_name() {
        let r = bench_with("xyz", Duration::from_millis(5), 2, &mut || 0u8);
        assert!(r.line().contains("xyz"));
    }

    #[test]
    fn record_normalizes_per_op() {
        let r = BenchResult {
            name: "raw".into(),
            iters: 10,
            ns_per_iter: 4096.0,
            stddev_ns: 0.0,
            throughput_per_s: 1e9 / 4096.0,
        };
        let rec = BenchRecord::from_result("dot_planar_n4096", 4096, &r);
        assert_eq!(rec.n, 4096);
        assert!((rec.ns_per_op - 1.0).abs() < 1e-12);
        assert!((rec.throughput_per_s - 1e9).abs() < 1.0);
    }

    #[test]
    fn parse_roundtrips_written_records() {
        let recs = vec![
            BenchRecord {
                name: "dot_planar_n4096".into(),
                n: 4096,
                ns_per_op: 7.25,
                throughput_per_s: 1e9 / 7.25,
            },
            BenchRecord {
                name: "serve \"q\"".into(),
                n: 1,
                ns_per_op: 120000.0,
                throughput_per_s: 8333.3,
            },
            BenchRecord {
                name: "lat_p50_µs".into(),
                n: 1,
                ns_per_op: 3.5,
                throughput_per_s: 1e9 / 3.5,
            },
        ];
        let path = std::env::temp_dir().join("hrfna_bench_parse_test.json");
        let path = path.to_str().unwrap();
        write_json(path, &recs).unwrap();
        let back = read_json(path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back[0].name, "dot_planar_n4096");
        assert_eq!(back[0].n, 4096);
        assert!((back[0].ns_per_op - 7.25).abs() < 1e-9);
        assert_eq!(back[1].name, "serve \"q\"");
        assert!((back[1].throughput_per_s - 8333.3).abs() < 0.1);
        assert_eq!(back[2].name, "lat_p50_µs", "non-ASCII names round-trip");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn gate_flags_regressions_missing_and_passes_improvements() {
        let rec = |name: &str, ns: f64| BenchRecord {
            name: name.into(),
            n: 1,
            ns_per_op: ns,
            throughput_per_s: if ns > 0.0 { 1e9 / ns } else { 0.0 },
        };
        let baseline = vec![rec("a", 100.0), rec("b", 100.0), rec("gone", 50.0)];
        let current = vec![rec("a", 115.0), rec("b", 125.0), rec("new", 1.0)];
        let v = gate_records(&baseline, &current, 0.20);
        // "a" is within 20%, "b" regressed 25%, "gone" is missing; "new"
        // (current-only) is allowed.
        assert_eq!(v.len(), 2);
        assert!(v.iter().any(|x| x.name == "b" && (x.ratio - 1.25).abs() < 1e-9));
        assert!(v.iter().any(|x| x.name == "gone" && !x.current_ns.is_finite()));
        assert!(v.iter().all(|x| !x.line().is_empty()));
        // Improvements never trip the gate.
        assert!(gate_records(&baseline, &[rec("a", 1.0), rec("b", 1.0), rec("gone", 1.0)], 0.2)
            .is_empty());
    }

    #[test]
    fn new_records_are_listed_not_gated() {
        let rec = |name: &str, ns: f64| BenchRecord {
            name: name.into(),
            n: 1,
            ns_per_op: ns,
            throughput_per_s: 1e9 / ns,
        };
        let baseline = vec![rec("a", 100.0)];
        let current = vec![rec("a", 90.0), rec("fresh", 5.0), rec("also_new", 7.0)];
        let new = new_record_names(&baseline, &current);
        assert_eq!(new, vec!["fresh".to_string(), "also_new".to_string()]);
        // New records never appear as gate violations.
        assert!(gate_records(&baseline, &current, 0.2).is_empty());
        // And an empty baseline marks everything as new.
        assert_eq!(new_record_names(&[], &current).len(), 3);
    }

    #[test]
    fn write_json_roundtrippable_shape() {
        let recs = vec![
            BenchRecord {
                name: "a\"b".into(),
                n: 1,
                ns_per_op: 2.5,
                throughput_per_s: 4e8,
            },
            BenchRecord {
                name: "c".into(),
                n: 7,
                ns_per_op: 1.0,
                throughput_per_s: 1e9,
            },
        ];
        let path = std::env::temp_dir().join("hrfna_bench_test.json");
        let path = path.to_str().unwrap();
        write_json(path, &recs).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.starts_with("[\n"));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\"name\":\"a\\\"b\""));
        assert!(text.contains("\"n\":7"));
        assert_eq!(text.matches('{').count(), 2);
        let _ = std::fs::remove_file(path);
    }
}
