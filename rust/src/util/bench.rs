//! Micro-benchmark harness (the offline registry has no criterion).
//!
//! Warms up, then runs timed iterations until both a minimum iteration count
//! and a minimum wall-clock budget are met; reports ns/iter with deviation.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub ns_per_iter: f64,
    pub stddev_ns: f64,
    pub throughput_per_s: f64,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12.1} ns/iter (±{:>8.1})  {:>14.0} it/s",
            self.name, self.ns_per_iter, self.stddev_ns, self.throughput_per_s
        )
    }
}

/// Benchmark a closure. `f` should return something observable to keep the
/// optimizer honest (its value is black-boxed here).
pub fn bench<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench_with(name, Duration::from_millis(300), 10, &mut f)
}

/// Benchmark with an explicit time budget and minimum sample count.
pub fn bench_with<T, F: FnMut() -> T>(
    name: &str,
    budget: Duration,
    min_samples: usize,
    f: &mut F,
) -> BenchResult {
    // Warm-up + calibration: find an inner-loop count so one sample takes
    // roughly budget/20.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(20));
    let target_sample = budget / 20;
    let inner = ((target_sample.as_nanos() / once.as_nanos().max(1)).max(1)) as u64;

    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_samples || start.elapsed() < budget {
        let t = Instant::now();
        for _ in 0..inner {
            std::hint::black_box(f());
        }
        samples.push(t.elapsed().as_nanos() as f64 / inner as f64);
        if samples.len() > 10_000 {
            break;
        }
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        iters: inner * samples.len() as u64,
        ns_per_iter: mean,
        stddev_ns: var.sqrt(),
        throughput_per_s: 1e9 / mean,
    }
}

/// Print a bench-suite header (keeps `cargo bench` output structured).
pub fn suite(title: &str) {
    println!("\n##### {title} #####");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let r = bench_with(
            "noop-ish",
            Duration::from_millis(20),
            3,
            &mut || std::hint::black_box(1u64 + 1),
        );
        assert!(r.ns_per_iter > 0.0);
        assert!(r.iters > 0);
        assert!(r.throughput_per_s > 0.0);
    }

    #[test]
    fn line_contains_name() {
        let r = bench_with("xyz", Duration::from_millis(5), 2, &mut || 0u8);
        assert!(r.line().contains("xyz"));
    }
}
