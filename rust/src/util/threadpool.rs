//! Fixed-size worker thread pool (no tokio in the offline registry; the
//! coordinator's workers and the benchmark sweeps run on this).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    executed: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let executed = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                let executed = Arc::clone(&executed);
                thread::Builder::new()
                    .name(format!("hrfna-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                executed.fetch_add(1, Ordering::Relaxed);
                                let (lock, cvar) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cvar.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            pending,
            executed,
        }
    }

    /// Pool sized to the machine (cores, at least 2).
    pub fn for_host() -> ThreadPool {
        let n = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2);
        ThreadPool::new(n)
    }

    /// Submit a job for asynchronous execution.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has completed.
    pub fn wait(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }

    /// Total jobs executed so far.
    pub fn executed(&self) -> usize {
        self.executed.load(Ordering::Relaxed)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait();
        drop(self.tx.take()); // close the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The process-wide shared pool (host-sized, lazily spawned). Callers
/// lock it for the duration of a parallel section; concurrent sections
/// serialize on the mutex instead of oversubscribing the machine.
pub fn global() -> &'static Mutex<ThreadPool> {
    static GLOBAL: OnceLock<Mutex<ThreadPool>> = OnceLock::new();
    GLOBAL.get_or_init(|| Mutex::new(ThreadPool::for_host()))
}

/// Map `f` over `items` in parallel, preserving order, *without* the
/// `'static` closure bound of [`par_map`]: `f` may borrow locals (the
/// planar matmul borrows encoded planes and the HRFNA context).
///
/// Panics in a job are caught per job and re-raised here after all jobs
/// drain, so the pool's pending count stays consistent.
pub fn par_map_scoped<T, R, F>(pool: &ThreadPool, items: Vec<T>, f: &F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let out: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    let panicked = AtomicBool::new(false);
    {
        let f_dyn: &(dyn Fn(T) -> R + Sync) = f;
        // SAFETY: `pool.wait()` below blocks until every job submitted
        // here has completed, so the erased borrows of `f`, `out` and
        // `panicked` never outlive this stack frame.
        let f_st: &'static (dyn Fn(T) -> R + Sync) =
            unsafe { std::mem::transmute::<&(dyn Fn(T) -> R + Sync), _>(f_dyn) };
        let out_st: &'static Mutex<Vec<Option<R>>> =
            unsafe { std::mem::transmute::<&Mutex<Vec<Option<R>>>, _>(&out) };
        let pk_st: &'static AtomicBool =
            unsafe { std::mem::transmute::<&AtomicBool, _>(&panicked) };
        for (i, item) in items.into_iter().enumerate() {
            pool.submit(move || {
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f_st(item))) {
                    Ok(r) => out_st.lock().unwrap()[i] = Some(r),
                    Err(_) => pk_st.store(true, Ordering::Relaxed),
                }
            });
        }
        pool.wait();
    }
    assert!(
        !panicked.load(Ordering::Relaxed),
        "par_map_scoped: a parallel job panicked"
    );
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("job dropped"))
        .collect()
}

/// Map `f` over `items` in parallel, preserving order, using `pool`.
pub fn par_map<T, R, F>(pool: &ThreadPool, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let out: Arc<Mutex<Vec<Option<R>>>> =
        Arc::new(Mutex::new((0..items.len()).map(|_| None).collect()));
    for (i, item) in items.into_iter().enumerate() {
        let f = Arc::clone(&f);
        let out = Arc::clone(&out);
        pool.submit(move || {
            let r = f(item);
            out.lock().unwrap()[i] = Some(r);
        });
    }
    pool.wait();
    Arc::try_unwrap(out)
        .ok()
        .expect("pending refs")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("job dropped"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(pool.executed(), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = par_map(&pool, (0..50).collect::<Vec<u64>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn wait_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.wait();
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {});
        drop(pool);
    }

    #[test]
    fn par_map_scoped_borrows_locals() {
        let pool = ThreadPool::new(3);
        let base = vec![10u64, 20, 30];
        let f = |i: usize| base[i] + i as u64;
        let out = par_map_scoped(&pool, vec![0usize, 1, 2], &f);
        assert_eq!(out, vec![10, 21, 32]);
    }

    #[test]
    #[should_panic(expected = "parallel job panicked")]
    fn par_map_scoped_propagates_panics() {
        let pool = ThreadPool::new(2);
        let f = |i: usize| {
            if i == 1 {
                panic!("boom");
            }
            i
        };
        let _ = par_map_scoped(&pool, vec![0usize, 1], &f);
    }

    #[test]
    fn global_pool_is_usable() {
        let pool = global().lock().unwrap_or_else(|p| p.into_inner());
        let f = |x: u64| x * 3;
        let out = par_map_scoped(&pool, vec![1u64, 2, 3], &f);
        assert_eq!(out, vec![3, 6, 9]);
    }
}
