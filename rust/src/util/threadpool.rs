//! Fixed-size worker thread pool (no tokio in the offline registry; the
//! coordinator's workers and the benchmark sweeps run on this).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    executed: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let executed = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                let executed = Arc::clone(&executed);
                thread::Builder::new()
                    .name(format!("hrfna-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                executed.fetch_add(1, Ordering::Relaxed);
                                let (lock, cvar) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cvar.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            pending,
            executed,
        }
    }

    /// Pool sized to the machine (cores, at least 2).
    pub fn for_host() -> ThreadPool {
        let n = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2);
        ThreadPool::new(n)
    }

    /// Submit a job for asynchronous execution.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has completed.
    pub fn wait(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }

    /// Total jobs executed so far.
    pub fn executed(&self) -> usize {
        self.executed.load(Ordering::Relaxed)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait();
        drop(self.tx.take()); // close the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Map `f` over `items` in parallel, preserving order, using `pool`.
pub fn par_map<T, R, F>(pool: &ThreadPool, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let out: Arc<Mutex<Vec<Option<R>>>> =
        Arc::new(Mutex::new((0..items.len()).map(|_| None).collect()));
    for (i, item) in items.into_iter().enumerate() {
        let f = Arc::clone(&f);
        let out = Arc::clone(&out);
        pool.submit(move || {
            let r = f(item);
            out.lock().unwrap()[i] = Some(r);
        });
    }
    pool.wait();
    Arc::try_unwrap(out)
        .ok()
        .expect("pending refs")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("job dropped"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(pool.executed(), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = par_map(&pool, (0..50).collect::<Vec<u64>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn wait_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.wait();
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {});
        drop(pool);
    }
}
