//! Hand-rolled utility substrates.
//!
//! The offline crate registry carries only `xla`/`anyhow`/`thiserror`, so the
//! usual ecosystem crates (rand, clap, criterion, proptest, serde) are
//! re-implemented here at the scale this project needs.

pub mod backoff;
pub mod faults;
pub mod prng;
pub mod stats;
pub mod table;
pub mod cli;
pub mod proptest;
pub mod threadpool;
pub mod bench;
pub mod log;

pub use prng::Rng;
pub use stats::Summary;
pub use table::Table;
