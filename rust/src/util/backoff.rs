//! Jittered exponential backoff for reconnect loops.
//!
//! The schedule doubles from `base` up to `cap`, and every delay is
//! scaled by a uniform factor in [0.5, 1.0) drawn from the crate's own
//! PRNG. The jitter is the point: N router replicas that all watched the
//! same worker die would otherwise wake on identical fixed ticks and
//! stampede the restarted listener — desynchronized delays spread the
//! reconnects across the whole window.

use std::time::Duration;

use super::prng::Rng;

/// Exponential backoff state for one retry loop.
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    /// Schedule doubling from `base` to at most `cap`; `seed` decorrelates
    /// concurrent loops (hash the peer address, mix in the process time —
    /// see [`Backoff::seed_for`]).
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        Backoff { base: base.max(Duration::from_micros(1)), cap, attempt: 0, rng: Rng::new(seed) }
    }

    /// The reconnect default: 10 ms doubling to a 1 s cap.
    pub fn for_reconnect(seed: u64) -> Backoff {
        Backoff::new(Duration::from_millis(10), Duration::from_secs(1), seed)
    }

    /// A per-loop seed: FNV over `label`, mixed with wall-clock nanos so
    /// two processes retrying the same address still diverge.
    pub fn seed_for(label: &str) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64)
            .unwrap_or(0);
        h ^ nanos.wrapping_mul(0x9e37_79b9_7f4a_7c15)
    }

    /// Next delay: `min(cap, base · 2^attempt)` jittered by a uniform
    /// factor in [0.5, 1.0).
    pub fn next_delay(&mut self) -> Duration {
        let exp = self.attempt.min(20);
        self.attempt = self.attempt.saturating_add(1);
        let full = self
            .base
            .checked_mul(1u32 << exp)
            .map(|d| d.min(self.cap))
            .unwrap_or(self.cap);
        full.mul_f64(self.rng.uniform(0.5, 1.0))
    }

    /// Reset the exponent after a successful attempt.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_grow_and_cap() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_secs(1);
        let mut b = Backoff::new(base, cap, 7);
        let delays: Vec<Duration> = (0..12).map(|_| b.next_delay()).collect();
        for (i, d) in delays.iter().enumerate() {
            // Jitter floor is half the unjittered delay; ceiling is cap.
            let unjittered = base.checked_mul(1 << i.min(20)).unwrap_or(cap).min(cap);
            assert!(*d >= unjittered.mul_f64(0.5), "delay {i} below jitter floor");
            assert!(*d <= cap, "delay {i} above cap");
        }
        // By attempt 7 (10ms * 128 > 1s) the schedule is cap-bound.
        assert!(delays[8] >= cap.mul_f64(0.5));
    }

    #[test]
    fn jitter_desynchronizes_two_loops() {
        let mut a = Backoff::new(Duration::from_millis(50), Duration::from_secs(1), 1);
        let mut b = Backoff::new(Duration::from_millis(50), Duration::from_secs(1), 2);
        // Two loops on the same schedule but different seeds must not
        // tick in lockstep — at least one of the first 8 delays differs.
        let differ = (0..8).any(|_| a.next_delay() != b.next_delay());
        assert!(differ, "seeded jitter produced identical schedules");
    }

    #[test]
    fn reset_restarts_the_exponent() {
        let mut b = Backoff::new(Duration::from_millis(100), Duration::from_secs(10), 3);
        for _ in 0..6 {
            b.next_delay();
        }
        b.reset();
        assert!(b.next_delay() <= Duration::from_millis(100));
    }
}
