//! ASCII table rendering for benchmark output — every bench regenerates one
//! of the paper's tables/figure series as rows printed through this.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple ASCII table with a title, headers and string rows.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given title and column headers (all left-aligned
    /// headers, right-aligned data by default for numeric readability).
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: vec![Align::Right; headers.len()],
            rows: Vec::new(),
        }
    }

    /// Set per-column alignment (length must match headers).
    pub fn aligns(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append a row; panics if the arity doesn't match the header.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity != header arity"
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: append a row of displayable items.
    pub fn rowv<T: std::fmt::Display>(&mut self, cells: &[T]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    /// Number of data rows so far.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a bordered ASCII string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let cell = &cells[i];
                let pad = widths[i] - cell.len();
                match self.aligns[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(cell);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(cell);
                        s.push(' ');
                    }
                }
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float in compact engineering style (for table cells).
pub fn eng(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if (1e-3..1e5).contains(&a) {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_with_padding() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.rowv(&["1", "2"]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| a | bbbb |") || s.contains("|  a | bbbb |") || s.contains("| a |"));
        assert_eq!(s.matches('+').count() % 3, 0); // 3 separator lines
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("T", &["a", "b"]);
        t.rowv(&["1"]);
    }

    #[test]
    fn eng_formats() {
        assert_eq!(eng(0.0), "0");
        assert!(eng(1234.5).starts_with("1234.5"));
        assert!(eng(1.5e-9).contains('e'));
    }

    #[test]
    fn alignment_left() {
        let mut t = Table::new("", &["x"]).aligns(&[Align::Left]);
        t.rowv(&["ab"]);
        let s = t.render();
        assert!(s.contains("| ab |"));
    }
}
