//! Deterministic pseudo-random number generation (xoshiro256** seeded by
//! SplitMix64) plus the distributions the workload generators need.
//!
//! Not cryptographic; used only for reproducible synthetic workloads and
//! property tests.

/// SplitMix64 step — used to expand a 64-bit seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = std::f64::consts::TAU * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Log-normal deviate: exp(mu + sigma * N(0,1)) — the paper's
    /// "high dynamic range" operand distribution.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Random sign: ±1.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 }
    }

    /// Random boolean.
    #[inline]
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 0
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 65521, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn range_i64_inclusive() {
        let mut r = Rng::new(11);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..2000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            hit_lo |= v == -3;
            hit_hi |= v == 3;
        }
        assert!(hit_lo && hit_hi);
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 4.0) > 0.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
