//! Deterministic fault injection for the robustness stack.
//!
//! The flip helpers and the seeded [`FaultInjector`] are always compiled
//! (property tests corrupt authenticated batches with them directly); the
//! *serving-path call sites* — worker-side lane/exponent flips in
//! `coordinator::hybrid_exec` and wire-frame flips in the RPC server —
//! are gated behind the `fault-inject` cargo feature, so a default build
//! cannot corrupt anything no matter what flags it is handed.
//!
//! Decisions are a pure function of `(seed, opportunity_counter)` via a
//! splitmix64 hash: a given seed and rate reproduce the exact same fault
//! pattern across runs, which is what lets the `fault-smoke` CI tier
//! assert "detections > 0, zero corrupted results delivered" instead of
//! hoping the dice cooperate.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Finalizer of splitmix64 — the decision hash.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Flip one bit of a raw word.
#[inline]
pub fn flip_bit(v: u64, bit: u32) -> u64 {
    v ^ (1u64 << (bit % 64))
}

/// Flip one high mantissa/exponent bit (52..=63) of an f64 — the model of
/// a residue-lane corruption surviving decode: a huge, non-subtle error,
/// which is exactly what an undetected RNS lane flip produces after CRT.
#[inline]
pub fn flip_f64_high_bit(v: f64, pick: u64) -> f64 {
    f64::from_bits(flip_bit(v.to_bits(), 52 + (pick % 12) as u32))
}

/// Parsed `--inject-faults` configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Probability that any single fault opportunity fires.
    pub rate: f64,
    /// Seed for the deterministic decision stream.
    pub seed: u64,
}

impl FaultPlan {
    /// Parse the CLI form `rate=1e-3[,seed=N]`.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut rate: Option<f64> = None;
        let mut seed: u64 = 0x5EED;
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec `{part}`: expected key=value"))?;
            match key.trim() {
                "rate" => {
                    let r: f64 = val
                        .trim()
                        .parse()
                        .map_err(|e| format!("fault rate `{val}`: {e}"))?;
                    if !(0.0..=1.0).contains(&r) {
                        return Err(format!("fault rate {r} outside [0, 1]"));
                    }
                    rate = Some(r);
                }
                "seed" => {
                    seed = val
                        .trim()
                        .parse()
                        .map_err(|e| format!("fault seed `{val}`: {e}"))?;
                }
                other => return Err(format!("unknown fault key `{other}`")),
            }
        }
        Ok(FaultPlan {
            rate: rate.ok_or("fault spec needs rate=<p>")?,
            seed,
        })
    }
}

/// Seeded, counted fault source. Each corruption opportunity calls
/// [`FaultInjector::draw`]; `Some(payload)` means "fire", and the payload
/// is a deterministic 64-bit value the call site uses to choose *what* to
/// corrupt (which lane, which bit).
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    threshold: u64,
    opportunities: AtomicU64,
    injected: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let threshold = if plan.rate >= 1.0 {
            u64::MAX
        } else {
            (plan.rate * u64::MAX as f64) as u64
        };
        FaultInjector {
            plan,
            threshold,
            opportunities: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        }
    }

    /// One corruption opportunity: deterministically decide whether to
    /// fire, and if so return the payload driving the corruption choice.
    pub fn draw(&self) -> Option<u64> {
        let t = self.opportunities.fetch_add(1, Ordering::Relaxed);
        let h = mix(self.plan.seed ^ mix(t.wrapping_add(0x9e37_79b9_7f4a_7c15)));
        if h <= self.threshold && self.plan.rate > 0.0 {
            self.injected.fetch_add(1, Ordering::Relaxed);
            Some(mix(h ^ 0xd1b5_4a32_d192_ed03))
        } else {
            None
        }
    }

    /// Opportunities seen so far.
    pub fn opportunities(&self) -> u64 {
        self.opportunities.load(Ordering::Relaxed)
    }

    /// Faults actually injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// The configured plan.
    pub fn plan(&self) -> FaultPlan {
        self.plan
    }
}

static GLOBAL: OnceLock<FaultInjector> = OnceLock::new();

/// Install the process-wide injector (worker CLI; first call wins).
/// Returns false if one was already installed.
pub fn install(plan: FaultPlan) -> bool {
    GLOBAL.set(FaultInjector::new(plan)).is_ok()
}

/// The process-wide injector, if `--inject-faults` installed one.
pub fn global() -> Option<&'static FaultInjector> {
    GLOBAL.get()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_rate_and_seed() {
        assert_eq!(
            FaultPlan::parse("rate=1e-3"),
            Ok(FaultPlan { rate: 1e-3, seed: 0x5EED })
        );
        assert_eq!(
            FaultPlan::parse("rate=0.5,seed=42"),
            Ok(FaultPlan { rate: 0.5, seed: 42 })
        );
        assert!(FaultPlan::parse("seed=42").is_err(), "rate is required");
        assert!(FaultPlan::parse("rate=2.0").is_err(), "rate outside [0,1]");
        assert!(FaultPlan::parse("rate=0.1,bogus=1").is_err());
        assert!(FaultPlan::parse("nonsense").is_err());
    }

    #[test]
    fn same_seed_reproduces_the_exact_decision_stream() {
        let plan = FaultPlan { rate: 0.05, seed: 99 };
        let a = FaultInjector::new(plan);
        let b = FaultInjector::new(plan);
        let da: Vec<Option<u64>> = (0..4096).map(|_| a.draw()).collect();
        let db: Vec<Option<u64>> = (0..4096).map(|_| b.draw()).collect();
        assert_eq!(da, db);
        assert_eq!(a.injected(), b.injected());
        assert!(a.injected() > 0, "5% over 4096 draws must fire");
    }

    #[test]
    fn rate_is_respected_statistically() {
        let inj = FaultInjector::new(FaultPlan { rate: 0.01, seed: 7 });
        let n = 200_000u64;
        for _ in 0..n {
            inj.draw();
        }
        assert_eq!(inj.opportunities(), n);
        let got = inj.injected() as f64 / n as f64;
        assert!(
            (got - 0.01).abs() < 0.003,
            "empirical rate {got} far from 0.01"
        );
    }

    #[test]
    fn zero_rate_never_fires_and_full_rate_always_fires() {
        let never = FaultInjector::new(FaultPlan { rate: 0.0, seed: 1 });
        assert!((0..1000).all(|_| never.draw().is_none()));
        let always = FaultInjector::new(FaultPlan { rate: 1.0, seed: 1 });
        assert!((0..1000).all(|_| always.draw().is_some()));
    }

    #[test]
    fn flip_helpers_toggle_exactly_one_bit() {
        assert_eq!(flip_bit(0, 3), 8);
        assert_eq!(flip_bit(flip_bit(0xABCD, 17), 17), 0xABCD);
        let x = 1234.5678f64;
        let y = flip_f64_high_bit(x, 5);
        assert_ne!(x, y);
        assert_eq!((x.to_bits() ^ y.to_bits()).count_ones(), 1);
        let bit = (x.to_bits() ^ y.to_bits()).trailing_zeros();
        assert!((52..=63).contains(&bit), "flip must hit a high bit");
    }
}
