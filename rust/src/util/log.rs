//! Tiny leveled logger controlled by the `HRFNA_LOG` environment variable
//! (`error|warn|info|debug|trace`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let parsed = match std::env::var("HRFNA_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the level programmatically (tests, CLI --verbose).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// True if `l` would currently be emitted.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

/// Emit a log line (used by the macros).
pub fn emit(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)+) => { $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($t)+)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)+) => { $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($t)+)) };
}
#[macro_export]
macro_rules! log_error {
    ($($t:tt)+) => { $crate::util::log::emit($crate::util::log::Level::Error, format_args!($($t)+)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)+) => { $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($t)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn emit_does_not_panic() {
        set_level(Level::Trace);
        emit(Level::Debug, format_args!("hello {}", 1));
        set_level(Level::Info);
    }
}
