//! Descriptive statistics and error metrics (RMS error is the paper's
//! accuracy metric, §VII-A.2).

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute summary statistics; panics on an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Root-mean-square of a sample.
pub fn rms(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64).sqrt()
}

/// RMS error between a measurement and a (double-precision) reference —
/// the paper's aggregate accuracy metric.
pub fn rms_error(got: &[f64], want: &[f64]) -> f64 {
    assert_eq!(got.len(), want.len());
    assert!(!got.is_empty());
    let sum: f64 = got
        .iter()
        .zip(want)
        .map(|(g, w)| {
            let d = g - w;
            d * d
        })
        .sum();
    (sum / got.len() as f64).sqrt()
}

/// Relative RMS error: RMS(got-want) / RMS(want). Guards a zero reference.
pub fn relative_rms_error(got: &[f64], want: &[f64]) -> f64 {
    let denom = rms(want);
    if denom == 0.0 {
        return rms_error(got, want);
    }
    rms_error(got, want) / denom
}

/// Maximum absolute elementwise error.
pub fn max_abs_error(got: &[f64], want: &[f64]) -> f64 {
    assert_eq!(got.len(), want.len());
    got.iter()
        .zip(want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.p99, 2.0);
    }

    #[test]
    fn summary_linear() {
        let xs: Vec<f64> = (1..=5).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn rms_error_basics() {
        assert_eq!(rms_error(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rms_error(&[1.0, 1.0], &[0.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn relative_rms_scale_invariant() {
        let want = [100.0, 200.0, 300.0];
        let got = [101.0, 202.0, 303.0];
        let r = relative_rms_error(&got, &want);
        assert!((r - 0.01).abs() < 1e-3, "r={r}");
    }

    #[test]
    fn max_abs() {
        assert_eq!(max_abs_error(&[1.0, 5.0], &[1.0, 2.0]), 3.0);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        Summary::of(&[]);
    }
}
