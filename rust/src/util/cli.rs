//! Minimal command-line argument parser (the offline registry has no clap).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [--key=value] [pos...]`.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args and `--key value` opts.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw string option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; panics with a clear message on parse error.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key}={v}: {e}")),
        }
    }

    /// Boolean flag (present without value) or explicit `--key=true/false`.
    pub fn flag(&self, key: &str) -> bool {
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("dot 123 abc");
        assert_eq!(a.subcommand.as_deref(), Some("dot"));
        assert_eq!(a.positional, vec!["123", "abc"]);
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse("run --n 64 --tau=0.5");
        assert_eq!(a.parse_or("n", 0usize), 64);
        assert_eq!(a.parse_or("tau", 0.0f64), 0.5);
    }

    #[test]
    fn flags() {
        let a = parse("run --verbose --check=true --quiet");
        assert!(a.flag("verbose"));
        assert!(a.flag("check"));
        assert!(a.flag("quiet"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.parse_or("n", 7usize), 7);
        assert_eq!(a.str_or("mode", "x"), "x");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("run --a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
