//! Mini property-testing harness (the offline registry has no proptest).
//!
//! Runs a property over many seeded cases; on failure reports the seed and
//! case index so the exact input is reproducible with `Rng::new(seed)`.

use super::prng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` over `cases` deterministic cases. The closure receives a
/// per-case RNG and returns `Err(reason)` on violation. Panics (test
/// failure) with the reproducing seed on the first violation.
pub fn check_with<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Derive per-case seeds from the property name so adding properties
    // doesn't shift the cases of the others.
    let base = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        });
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(reason) = prop(&mut rng) {
            panic!(
                "property `{name}` failed at case {case}/{cases} (seed={seed:#x}): {reason}"
            );
        }
    }
}

/// Run with the default number of cases.
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_with(name, DEFAULT_CASES, prop);
}

/// Assert-like helper producing the Err the harness expects.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_with("always-true", 100, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 100);
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_panics_with_seed() {
        check_with("always-false", 10, |_rng| Err("nope".into()));
    }

    #[test]
    fn prop_assert_macro() {
        check_with("macro", 10, |rng| {
            let x = rng.below(100);
            prop_assert!(x < 100, "x={x} out of range");
            Ok(())
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check_with("det", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check_with("det", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
