//! Dense matrix multiplication (paper §VII-C): hybrid dot products composed
//! across rows/columns — the composability stress test. Row-major flat
//! storage; identical blocking across formats.

use super::traits::Numeric;
use crate::util::stats;

/// `C = A·B` with `A: m×k`, `B: k×n` (row-major f64 in, f64 out), computed
/// in format `N`: each output element is one exponent-coherent dot product
/// (paper §IV-E: "each output element invokes one Hybrid Dot Product").
/// Formats with a planar engine (HRFNA) dispatch to their batched kernel.
pub fn matmul<N: Numeric>(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    ctx: &N::Ctx,
) -> Vec<f64> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    if let Some(out) = N::matmul_block(a, b, m, k, n, ctx) {
        return out;
    }
    // Encode operands once (data reuse, §VII-C.1).
    let ea: Vec<N> = a.iter().map(|&x| N::from_f64(x, ctx)).collect();
    let eb: Vec<N> = b.iter().map(|&x| N::from_f64(x, ctx)).collect();
    let mut out = Vec::with_capacity(m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = N::zero(ctx);
            for p in 0..k {
                acc.mac_assign(&ea[i * k + p], &eb[p * n + j], ctx);
            }
            out.push(acc.to_f64(ctx));
        }
    }
    out
}

/// Column-tile width of the cache-blocked planar matmul: the `Bᵀ` lane
/// windows of one tile (`TILE_COLS · k` elements × 8 channels) stay
/// resident while a whole row block streams over them.
const TILE_COLS: usize = 64;

/// Row cap per tile, so a tile's accumulator batch (and its `A` row
/// windows) stays cache-sized even on machines with few workers.
const TILE_ROWS_MAX: usize = 64;

/// The HRFNA planar matmul kernel: encode `A` and `Bᵀ` into channel-major
/// planes once, then compute each output element with one batched
/// single-fold [`crate::hybrid::HrfnaBatch::dot_range`] over contiguous
/// row/column lane windows — no per-MAC allocation. The output is
/// **cache-blocked** into row×column tiles scheduled on the shared
/// [`crate::util::threadpool`]; each tile accumulates its dots into a
/// per-thread [`crate::hybrid::HrfnaBatch`] accumulator plane and decodes
/// them with one batched CRT pass.
pub fn matmul_hrfna_planar(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<f64> {
    matmul_hrfna_planar_tiled(a, b, m, k, n, TILE_COLS, ctx)
}

/// [`matmul_hrfna_planar`] with an explicit column-tile width (tests
/// shrink it to force the multi-tile scatter paths on small matrices).
pub fn matmul_hrfna_planar_tiled(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    tile_cols: usize,
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<f64> {
    assert_eq!(b.len(), k * n);
    if m == 0 || n == 0 {
        assert_eq!(a.len(), m * k);
        return Vec::new();
    }
    let eb = encode_matmul_rhs(b, k, n, ctx);
    matmul_hrfna_planar_encoded_tiled(a, &eb, m, k, n, tile_cols, ctx)
}

/// Transpose and block-encode the matmul right-hand side: the reusable
/// half of the planar matmul, split out so the serving layer's operand
/// cache (`coordinator::op_cache`) can keep the encoded `Bᵀ` plane
/// across jobs that share a weight matrix. Feeding the result to
/// [`matmul_hrfna_planar_encoded`] is bit-identical to
/// [`matmul_hrfna_planar`] on the raw `b` — the plane below is the very
/// value the one-shot path constructs internally.
pub fn encode_matmul_rhs(
    b: &[f64],
    k: usize,
    n: usize,
    ctx: &crate::hybrid::HrfnaContext,
) -> crate::hybrid::HrfnaBatch {
    assert_eq!(b.len(), k * n);
    // Bᵀ so each output column is a contiguous lane window too.
    let mut bt = vec![0.0f64; k * n];
    for p in 0..k {
        for j in 0..n {
            bt[j * k + p] = b[p * n + j];
        }
    }
    crate::hybrid::HrfnaBatch::encode(&bt, ctx)
}

/// Planar matmul against a pre-encoded (transposed) right-hand side
/// from [`encode_matmul_rhs`], at the default column-tile width.
pub fn matmul_hrfna_planar_encoded(
    a: &[f64],
    eb: &crate::hybrid::HrfnaBatch,
    m: usize,
    k: usize,
    n: usize,
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<f64> {
    matmul_hrfna_planar_encoded_tiled(a, eb, m, k, n, TILE_COLS, ctx)
}

/// [`matmul_hrfna_planar_encoded`] with an explicit column-tile width.
pub fn matmul_hrfna_planar_encoded_tiled(
    a: &[f64],
    eb: &crate::hybrid::HrfnaBatch,
    m: usize,
    k: usize,
    n: usize,
    tile_cols: usize,
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<f64> {
    use crate::hybrid::number::signed_mag_to_f64;
    use crate::hybrid::HrfnaBatch;
    use crate::util::threadpool;
    use std::sync::atomic::Ordering;

    assert_eq!(a.len(), m * k);
    assert_eq!(eb.len(), k * n);
    if m == 0 || n == 0 {
        return Vec::new();
    }
    let ea = HrfnaBatch::encode(a, ctx);
    let tile_cols = tile_cols.max(1);

    type Tile = (usize, usize, usize, usize);
    let body = |(i0, i1, j0, j1): Tile| -> (Tile, Vec<f64>) {
        // Per-thread accumulators: the tile's output dots are decoded by
        // one batched CRT pass reading them in place (no intermediate
        // plane copy).
        let mut accs = Vec::with_capacity((i1 - i0) * (j1 - j0));
        for i in i0..i1 {
            for j in j0..j1 {
                accs.push(ea.dot_range(i * k, eb, j * k, k, ctx));
            }
        }
        ctx.counters
            .reconstructions
            .fetch_add(accs.len() as u64, Ordering::Relaxed);
        let vals = ctx
            .crt
            .reconstruct_signed_batch_with(accs.len(), |c, j| accs[j].r.r[c])
            .into_iter()
            .zip(&accs)
            .map(|((neg, mag), h)| signed_mag_to_f64(neg, &mag, h.f))
            .collect();
        ((i0, i1, j0, j1), vals)
    };
    let tiles_for = |workers: usize| -> Vec<Tile> {
        let row_block = m.div_ceil((2 * workers).max(1)).clamp(1, TILE_ROWS_MAX);
        let mut tiles = Vec::new();
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + row_block).min(m);
            let mut j0 = 0;
            while j0 < n {
                let j1 = (j0 + tile_cols).min(n);
                tiles.push((i0, i1, j0, j1));
                j0 = j1;
            }
            i0 = i1;
        }
        tiles
    };
    // `try_lock`, not `lock`: if the shared pool is already busy (another
    // parallel section, possibly one we are nested inside), waiting could
    // deadlock a worker on its own section — compute inline instead.
    let parts: Vec<(Tile, Vec<f64>)> = match threadpool::global().try_lock() {
        Ok(pool) => threadpool::par_map_scoped(&pool, tiles_for(pool.size()), &body),
        Err(std::sync::TryLockError::Poisoned(p)) => {
            let pool = p.into_inner();
            threadpool::par_map_scoped(&pool, tiles_for(pool.size()), &body)
        }
        Err(std::sync::TryLockError::WouldBlock) => {
            tiles_for(1).into_iter().map(&body).collect()
        }
    };
    let mut out = vec![0.0f64; m * n];
    for ((i0, _i1, j0, j1), vals) in parts {
        let w = j1 - j0;
        for (t, v) in vals.into_iter().enumerate() {
            out[(i0 + t / w) * n + (j0 + t % w)] = v;
        }
    }
    out
}

/// RMS of relative elementwise error vs the f64 reference for a random
/// square matmul (§VII-C metric).
pub fn matmul_rms_error<N: Numeric>(
    dim: usize,
    dist: super::generators::Dist,
    seed: u64,
    ctx: &N::Ctx,
) -> f64 {
    let mut rng = crate::util::prng::Rng::new(seed);
    let a = dist.sample_vec(&mut rng, dim * dim);
    let b = dist.sample_vec(&mut rng, dim * dim);
    let want = matmul::<f64>(&a, &b, dim, dim, dim, &());
    let got = matmul::<N>(&a, &b, dim, dim, dim, ctx);
    let rel: Vec<f64> = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w) / w.abs().max(1e-300))
        .collect();
    stats::rms(&rel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hybrid::{Hrfna, HrfnaContext};
    use crate::workloads::generators::Dist;

    #[test]
    fn identity_matmul() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        let c = matmul::<f64>(&a, &eye, 2, 2, 2, &());
        assert_eq!(c, a);
    }

    #[test]
    fn rectangular_shapes() {
        // (1x3)·(3x2)
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0];
        let c = matmul::<f64>(&a, &b, 1, 3, 2, &());
        assert_eq!(c, vec![14.0, 32.0]);
    }

    #[test]
    fn hrfna_matmul_matches_f64_8x8() {
        let ctx = HrfnaContext::paper_default();
        let rms = matmul_rms_error::<Hrfna>(8, Dist::moderate(), 3, &ctx);
        assert!(rms < 1e-6, "rms={rms}");
    }

    #[test]
    fn hrfna_matmul_rms_paper_threshold_32() {
        // Paper §VII-C.3: RMS below 2e-6 for all tested sizes.
        let ctx = HrfnaContext::paper_default();
        let rms = matmul_rms_error::<Hrfna>(32, Dist::moderate(), 11, &ctx);
        assert!(rms < 2e-6, "rms={rms}");
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        matmul::<f64>(&[1.0], &[1.0, 2.0], 1, 2, 1, &());
    }

    #[test]
    fn planar_matmul_matches_f64_rectangular() {
        let ctx = HrfnaContext::paper_default();
        let mut rng = crate::util::prng::Rng::new(17);
        let (m, k, n) = (5, 7, 3);
        let a = Dist::moderate().sample_vec(&mut rng, m * k);
        let b = Dist::moderate().sample_vec(&mut rng, k * n);
        let want = matmul::<f64>(&a, &b, m, k, n, &());
        let got = matmul_hrfna_planar(&a, &b, m, k, n, &ctx);
        assert_eq!(got.len(), m * n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-7 * w.abs().max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn tiled_matmul_bit_identical_across_tile_widths() {
        // Tiling only reorders which outputs a task computes; every output
        // is still one full-inner-dim dot_range, so results must be bit
        // identical for every tile width (including widths that leave
        // ragged last tiles).
        let ctx = HrfnaContext::paper_default();
        let mut rng = crate::util::prng::Rng::new(29);
        let (m, k, n) = (9, 5, 11);
        let a = Dist::moderate().sample_vec(&mut rng, m * k);
        let b = Dist::moderate().sample_vec(&mut rng, k * n);
        let want = matmul_hrfna_planar(&a, &b, m, k, n, &ctx);
        for tile in [1usize, 2, 3, 4, 7, 11, 64] {
            let got = matmul_hrfna_planar_tiled(&a, &b, m, k, n, tile, &ctx);
            assert_eq!(got.len(), want.len(), "tile={tile}");
            for (idx, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "tile={tile} idx={idx}");
            }
        }
    }

    #[test]
    fn pre_encoded_rhs_bit_identical_to_one_shot_planar() {
        // The cache-consulting executor path encodes the RHS once via
        // encode_matmul_rhs and replays it across activations; every
        // replay must be bit-identical to the one-shot path that
        // encodes b inline.
        let ctx = HrfnaContext::paper_default();
        let mut rng = crate::util::prng::Rng::new(31);
        let (m, k, n) = (6, 10, 7);
        let b = Dist::moderate().sample_vec(&mut rng, k * n);
        let eb = encode_matmul_rhs(&b, k, n, &ctx);
        for trial in 0..3 {
            let a = Dist::moderate().sample_vec(&mut rng, m * k);
            let want = matmul_hrfna_planar(&a, &b, m, k, n, &ctx);
            let got = matmul_hrfna_planar_encoded(&a, &eb, m, k, n, &ctx);
            for (idx, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "trial={trial} idx={idx}");
            }
        }
    }

    #[test]
    fn planar_matmul_handles_wide_range_and_zeros() {
        let ctx = HrfnaContext::paper_default();
        let mut rng = crate::util::prng::Rng::new(19);
        let dim = 12;
        let mut a = Dist::high_dynamic_range().sample_vec(&mut rng, dim * dim);
        let b = Dist::moderate().sample_vec(&mut rng, dim * dim);
        a[0] = 0.0;
        a[dim + 1] = 0.0;
        let want = matmul::<f64>(&a, &b, dim, dim, dim, &());
        let got = matmul_hrfna_planar(&a, &b, dim, dim, dim, &ctx);
        for i in 0..dim {
            for j in 0..dim {
                // Tolerance against the non-cancelling magnitude: encode
                // quantization is relative to Σ|a·b|, not to the sum.
                let scale: f64 = (0..dim)
                    .map(|p| (a[i * dim + p] * b[p * dim + j]).abs())
                    .sum();
                let (g, w) = (got[i * dim + j], want[i * dim + j]);
                assert!(
                    (g - w).abs() <= 1e-6 * scale + 1e-12,
                    "({i},{j}): {g} vs {w}"
                );
            }
        }
    }
}
