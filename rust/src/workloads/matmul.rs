//! Dense matrix multiplication (paper §VII-C): hybrid dot products composed
//! across rows/columns — the composability stress test. Row-major flat
//! storage; identical blocking across formats.

use super::traits::Numeric;
use crate::util::stats;

/// `C = A·B` with `A: m×k`, `B: k×n` (row-major f64 in, f64 out), computed
/// in format `N`: each output element is one exponent-coherent dot product
/// (paper §IV-E: "each output element invokes one Hybrid Dot Product").
pub fn matmul<N: Numeric>(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    ctx: &N::Ctx,
) -> Vec<f64> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    // Encode operands once (data reuse, §VII-C.1).
    let ea: Vec<N> = a.iter().map(|&x| N::from_f64(x, ctx)).collect();
    let eb: Vec<N> = b.iter().map(|&x| N::from_f64(x, ctx)).collect();
    let mut out = Vec::with_capacity(m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = N::zero(ctx);
            for p in 0..k {
                acc.mac_assign(&ea[i * k + p], &eb[p * n + j], ctx);
            }
            out.push(acc.to_f64(ctx));
        }
    }
    out
}

/// RMS of relative elementwise error vs the f64 reference for a random
/// square matmul (§VII-C metric).
pub fn matmul_rms_error<N: Numeric>(
    dim: usize,
    dist: super::generators::Dist,
    seed: u64,
    ctx: &N::Ctx,
) -> f64 {
    let mut rng = crate::util::prng::Rng::new(seed);
    let a = dist.sample_vec(&mut rng, dim * dim);
    let b = dist.sample_vec(&mut rng, dim * dim);
    let want = matmul::<f64>(&a, &b, dim, dim, dim, &());
    let got = matmul::<N>(&a, &b, dim, dim, dim, ctx);
    let rel: Vec<f64> = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w) / w.abs().max(1e-300))
        .collect();
    stats::rms(&rel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hybrid::{Hrfna, HrfnaContext};
    use crate::workloads::generators::Dist;

    #[test]
    fn identity_matmul() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        let c = matmul::<f64>(&a, &eye, 2, 2, 2, &());
        assert_eq!(c, a);
    }

    #[test]
    fn rectangular_shapes() {
        // (1x3)·(3x2)
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0];
        let c = matmul::<f64>(&a, &b, 1, 3, 2, &());
        assert_eq!(c, vec![14.0, 32.0]);
    }

    #[test]
    fn hrfna_matmul_matches_f64_8x8() {
        let ctx = HrfnaContext::paper_default();
        let rms = matmul_rms_error::<Hrfna>(8, Dist::moderate(), 3, &ctx);
        assert!(rms < 1e-6, "rms={rms}");
    }

    #[test]
    fn hrfna_matmul_rms_paper_threshold_32() {
        // Paper §VII-C.3: RMS below 2e-6 for all tested sizes.
        let ctx = HrfnaContext::paper_default();
        let rms = matmul_rms_error::<Hrfna>(32, Dist::moderate(), 11, &ctx);
        assert!(rms < 2e-6, "rms={rms}");
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        matmul::<f64>(&[1.0], &[1.0, 2.0], 1, 2, 1, &());
    }
}
