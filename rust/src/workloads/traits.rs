//! The `Numeric` abstraction: every numeric format under evaluation
//! (HRFNA, FP32, BFP, fixed-point, pure RNS, LNS) implements this trait,
//! so the workload kernels (§VII: dot product, matmul, RK4) are written
//! once and run unchanged across formats — the paper's "identical loop
//! structures" methodology (§VII-C.2).

/// A numeric format with an explicit shared context (HRFNA needs CRT
/// state; plain floats use `()`).
pub trait Numeric: Clone {
    /// Per-format shared context (precomputed tables, config).
    type Ctx;

    /// Human-readable format name (table row label).
    fn name() -> &'static str;

    /// Encode a real.
    fn from_f64(x: f64, ctx: &Self::Ctx) -> Self;

    /// Decode to a real.
    fn to_f64(&self, ctx: &Self::Ctx) -> f64;

    /// Additive identity.
    fn zero(ctx: &Self::Ctx) -> Self;

    /// Addition.
    fn add(&self, other: &Self, ctx: &Self::Ctx) -> Self;

    /// Subtraction.
    fn sub(&self, other: &Self, ctx: &Self::Ctx) -> Self;

    /// Multiplication.
    fn mul(&self, other: &Self, ctx: &Self::Ctx) -> Self;

    /// Negation.
    fn neg(&self, ctx: &Self::Ctx) -> Self;

    /// Fused multiply-accumulate `self += a·b`. Formats with deferred
    /// normalization (HRFNA) override this with their accumulator path.
    fn mac_assign(&mut self, a: &Self, b: &Self, ctx: &Self::Ctx) {
        *self = self.add(&a.mul(b, ctx), ctx);
    }

    /// Multiply by a real constant (RK4 coefficients).
    fn scale(&self, k: f64, ctx: &Self::Ctx) -> Self {
        self.mul(&Self::from_f64(k, ctx), ctx)
    }

    /// Batched dot product over pre-encoded operands. Formats with a
    /// planar engine (HRFNA) override this with their lane kernels; the
    /// default is the scalar reference MAC loop.
    fn dot_encoded(xs: &[Self], ys: &[Self], ctx: &Self::Ctx) -> Self {
        let mut acc = Self::zero(ctx);
        for (x, y) in xs.iter().zip(ys) {
            acc.mac_assign(x, y, ctx);
        }
        acc
    }

    /// Planar matmul fast path: `Some(C)` when the format provides a
    /// batched kernel for `C = A·B` (`A: m×k`, `B: k×n`, row-major f64 in,
    /// f64 out), `None` to use the generic scalar kernel.
    fn matmul_block(
        _a: &[f64],
        _b: &[f64],
        _m: usize,
        _k: usize,
        _n: usize,
        _ctx: &Self::Ctx,
    ) -> Option<Vec<f64>> {
        None
    }
}

/// FP64 — the double-precision software reference (§VII-A.2).
impl Numeric for f64 {
    type Ctx = ();

    fn name() -> &'static str {
        "FP64(ref)"
    }
    fn from_f64(x: f64, _: &()) -> f64 {
        x
    }
    fn to_f64(&self, _: &()) -> f64 {
        *self
    }
    fn zero(_: &()) -> f64 {
        0.0
    }
    fn add(&self, o: &f64, _: &()) -> f64 {
        self + o
    }
    fn sub(&self, o: &f64, _: &()) -> f64 {
        self - o
    }
    fn mul(&self, o: &f64, _: &()) -> f64 {
        self * o
    }
    fn neg(&self, _: &()) -> f64 {
        -self
    }
}

/// FP32 — the IEEE-754 single-precision baseline (vendor FP32 IP stand-in).
impl Numeric for f32 {
    type Ctx = ();

    fn name() -> &'static str {
        "FP32"
    }
    fn from_f64(x: f64, _: &()) -> f32 {
        x as f32
    }
    fn to_f64(&self, _: &()) -> f64 {
        *self as f64
    }
    fn zero(_: &()) -> f32 {
        0.0
    }
    fn add(&self, o: &f32, _: &()) -> f32 {
        self + o
    }
    fn sub(&self, o: &f32, _: &()) -> f32 {
        self - o
    }
    fn mul(&self, o: &f32, _: &()) -> f32 {
        self * o
    }
    fn neg(&self, _: &()) -> f32 {
        -self
    }
}

/// HRFNA as a `Numeric` (delegates to the hybrid module).
impl Numeric for crate::hybrid::Hrfna {
    type Ctx = crate::hybrid::HrfnaContext;

    fn name() -> &'static str {
        "HRFNA"
    }
    fn from_f64(x: f64, ctx: &Self::Ctx) -> Self {
        crate::hybrid::Hrfna::encode(x, ctx)
    }
    fn to_f64(&self, ctx: &Self::Ctx) -> f64 {
        self.decode(ctx)
    }
    fn zero(ctx: &Self::Ctx) -> Self {
        crate::hybrid::Hrfna::zero(ctx, 0)
    }
    fn add(&self, o: &Self, ctx: &Self::Ctx) -> Self {
        crate::hybrid::Hrfna::add(self, o, ctx)
    }
    fn sub(&self, o: &Self, ctx: &Self::Ctx) -> Self {
        crate::hybrid::Hrfna::sub(self, o, ctx)
    }
    fn mul(&self, o: &Self, ctx: &Self::Ctx) -> Self {
        crate::hybrid::Hrfna::mul(self, o, ctx)
    }
    fn neg(&self, ctx: &Self::Ctx) -> Self {
        crate::hybrid::Hrfna::neg(self, ctx)
    }
    fn mac_assign(&mut self, a: &Self, b: &Self, ctx: &Self::Ctx) {
        crate::hybrid::Hrfna::mac_assign(self, a, b, ctx)
    }

    /// §Perf planar fast path: pack into channel-major lanes and run the
    /// exact batched Algorithm 1 kernel (falls back to the scalar MAC
    /// loop internally when interval headroom cannot prove exactness).
    fn dot_encoded(xs: &[Self], ys: &[Self], ctx: &Self::Ctx) -> Self {
        let bx = crate::hybrid::HrfnaBatch::from_items(xs, ctx.k());
        let by = crate::hybrid::HrfnaBatch::from_items(ys, ctx.k());
        bx.dot(&by, ctx)
    }

    /// §Perf planar matmul: one batched dot per output element over
    /// row/column lane windows, parallelized across row blocks on the
    /// shared thread pool.
    fn matmul_block(
        a: &[f64],
        b: &[f64],
        m: usize,
        k: usize,
        n: usize,
        ctx: &Self::Ctx,
    ) -> Option<Vec<f64>> {
        Some(crate::workloads::matmul::matmul_hrfna_planar(a, b, m, k, n, ctx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hybrid::{Hrfna, HrfnaContext};

    fn roundtrip<N: Numeric>(ctx: &N::Ctx, xs: &[f64], tol: f64) {
        for &x in xs {
            let n = N::from_f64(x, ctx);
            let back = n.to_f64(ctx);
            assert!(
                ((back - x) / x.abs().max(1e-30)).abs() <= tol,
                "{}: x={x} back={back}",
                N::name()
            );
        }
    }

    #[test]
    fn f64_roundtrip_exact() {
        roundtrip::<f64>(&(), &[1.5, -2.25e10, 3.33e-7], 0.0);
    }

    #[test]
    fn f32_roundtrip_quantizes() {
        roundtrip::<f32>(&(), &[1.5, -2.25e10, 3.33e-7], 1e-7);
    }

    #[test]
    fn hrfna_roundtrip_within_sig() {
        let ctx = HrfnaContext::paper_default();
        roundtrip::<Hrfna>(&ctx, &[1.5, -2.25e10, 3.33e-7], 1e-8);
    }

    #[test]
    fn generic_mac_matches_manual() {
        let ctx = HrfnaContext::paper_default();
        let mut acc = Hrfna::zero(&ctx, 0);
        let a = Hrfna::from_f64(2.5, &ctx);
        let b = Hrfna::from_f64(-4.0, &ctx);
        Numeric::mac_assign(&mut acc, &a, &b, &ctx);
        assert!((acc.to_f64(&ctx) + 10.0).abs() < 1e-7);
    }

    #[test]
    fn scale_default_impl() {
        let x = 3.0f64;
        assert_eq!(x.scale(0.5, &()), 1.5);
    }
}
