//! FIR filtering (the signal-processing workload class the paper's
//! introduction motivates; cf. ref. [16] RNS FIR filters): direct-form
//! convolution is a sliding dot product — multiplication-dominated with
//! exponent-coherent taps, the HRFNA sweet spot (§IX-A).

use super::traits::Numeric;
use crate::util::stats;

/// Direct-form FIR: `y[n] = Σ_i h[i] · x[n-i]` in format `N`.
///
/// Taps and signal are encoded **once**, outside the output loop; the
/// signal is additionally staged in reverse so every output is one
/// *contiguous* sliding window, routed through the format's batched
/// [`Numeric::dot_encoded`] fast path (HRFNA: the planar lane kernels —
/// one exact residue accumulation and one CRT per output instead of a
/// per-output scalar MAC chain). [`fir_filter_scalar`] keeps the
/// per-output MAC loop as the bit-identity reference.
pub fn fir_filter<N: Numeric>(taps: &[f64], signal: &[f64], ctx: &N::Ctx) -> Vec<f64> {
    assert!(!taps.is_empty());
    let eh: Vec<N> = taps.iter().map(|&t| N::from_f64(t, ctx)).collect();
    fir_filter_encoded_taps(&eh, signal, ctx)
}

/// [`fir_filter`] against pre-encoded taps — the reusable half of the
/// convolution, split out so the serving layer's operand cache
/// (`coordinator::op_cache`) can keep the encoded tap vector across
/// jobs that share a filter. The `eh` produced by encoding each tap
/// with [`Numeric::from_f64`] makes this bit-identical to
/// [`fir_filter`] on the raw taps.
pub fn fir_filter_encoded_taps<N: Numeric>(
    eh: &[N],
    signal: &[f64],
    ctx: &N::Ctx,
) -> Vec<f64> {
    assert!(!eh.is_empty());
    let len = signal.len();
    // exr[j] = encode(x[len-1-j]): the window for output n is then the
    // contiguous slice exr[len-1-n ..][..w] paired with eh[..w].
    let exr: Vec<N> = signal
        .iter()
        .rev()
        .map(|&s| N::from_f64(s, ctx))
        .collect();
    (0..len)
        .map(|n| {
            let w = eh.len().min(n + 1);
            let start = len - 1 - n;
            N::dot_encoded(&eh[..w], &exr[start..start + w], ctx).to_f64(ctx)
        })
        .collect()
}

/// The pre-planar reference: encode once, then one scalar MAC chain per
/// output. Kept as the datapath [`fir_filter`] is bit-identity-tested
/// against (same term set, same order).
pub fn fir_filter_scalar<N: Numeric>(taps: &[f64], signal: &[f64], ctx: &N::Ctx) -> Vec<f64> {
    assert!(!taps.is_empty());
    let eh: Vec<N> = taps.iter().map(|&t| N::from_f64(t, ctx)).collect();
    let ex: Vec<N> = signal.iter().map(|&s| N::from_f64(s, ctx)).collect();
    (0..signal.len())
        .map(|n| {
            let mut acc = N::zero(ctx);
            for (i, h) in eh.iter().enumerate() {
                if n >= i {
                    acc.mac_assign(h, &ex[n - i], ctx);
                }
            }
            acc.to_f64(ctx)
        })
        .collect()
}

/// Windowed-sinc low-pass taps (Hamming window), normalized cutoff
/// `fc ∈ (0, 0.5)`.
pub fn lowpass_taps(order: usize, fc: f64) -> Vec<f64> {
    assert!(order >= 2 && (0.0..0.5).contains(&fc));
    let m = order as f64;
    (0..=order)
        .map(|i| {
            let x = i as f64 - m / 2.0;
            let sinc = if x == 0.0 {
                2.0 * fc
            } else {
                (std::f64::consts::TAU * fc * x).sin() / (std::f64::consts::PI * x)
            };
            let window =
                0.54 - 0.46 * (std::f64::consts::TAU * i as f64 / m).cos();
            sinc * window
        })
        .collect()
}

/// RMS error of a format's FIR output vs the f64 reference on a noisy
/// two-tone test signal.
pub fn fir_rms_error<N: Numeric>(
    order: usize,
    signal_len: usize,
    seed: u64,
    ctx: &N::Ctx,
) -> f64 {
    let taps = lowpass_taps(order, 0.1);
    let mut rng = crate::util::prng::Rng::new(seed);
    let signal: Vec<f64> = (0..signal_len)
        .map(|i| {
            let t = i as f64;
            (0.05 * t).sin() + 0.5 * (0.8 * t).sin() + 0.1 * rng.normal()
        })
        .collect();
    let want = fir_filter::<f64>(&taps, &signal, &());
    let got = fir_filter::<N>(&taps, &signal, ctx);
    stats::rms_error(&got, &want) / stats::rms(&want).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{Bfp, BfpConfig};
    use crate::hybrid::{Hrfna, HrfnaContext};

    #[test]
    fn impulse_response_recovers_taps() {
        let taps = lowpass_taps(16, 0.2);
        let mut impulse = vec![0.0; 32];
        impulse[0] = 1.0;
        let y = fir_filter::<f64>(&taps, &impulse, &());
        for (i, &t) in taps.iter().enumerate() {
            assert!((y[i] - t).abs() < 1e-12, "tap {i}");
        }
    }

    #[test]
    fn lowpass_attenuates_high_tone() {
        // Filter a high-frequency tone: output power must drop sharply.
        let taps = lowpass_taps(64, 0.05);
        let signal: Vec<f64> = (0..512).map(|i| (2.5 * i as f64).sin()).collect();
        let y = fir_filter::<f64>(&taps, &signal, &());
        let in_rms = crate::util::stats::rms(&signal);
        let out_rms = crate::util::stats::rms(&y[64..]);
        assert!(out_rms < in_rms * 0.05, "attenuation {out_rms}/{in_rms}");
    }

    #[test]
    fn hrfna_fir_matches_f64() {
        let ctx = HrfnaContext::paper_default();
        let rel = fir_rms_error::<Hrfna>(32, 256, 9, &ctx);
        assert!(rel < 1e-6, "rel={rel}");
    }

    #[test]
    fn planar_fir_bit_identical_to_scalar_mac_loop() {
        // The windowed `dot_encoded` path must reproduce the per-output
        // scalar MAC chain bit for bit: same term set in the same order,
        // exact residue accumulation on both paths (no normalization at
        // these magnitudes), one decode each. Covers the partial windows
        // at the signal head, f64 and HRFNA.
        let ctx = HrfnaContext::paper_default();
        let taps = lowpass_taps(16, 0.2);
        let mut rng = crate::util::prng::Rng::new(77);
        for len in [1usize, 5, 16, 17, 64] {
            let signal: Vec<f64> = (0..len)
                .map(|_| rng.uniform(-2.0, 2.0))
                .collect();
            let fast = fir_filter::<Hrfna>(&taps, &signal, &ctx);
            let slow = fir_filter_scalar::<Hrfna>(&taps, &signal, &ctx);
            assert_eq!(fast.len(), slow.len());
            for (n, (a, b)) in fast.iter().zip(&slow).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "len={len} output {n}: {a} vs {b}"
                );
            }
            let fast64 = fir_filter::<f64>(&taps, &signal, &());
            let slow64 = fir_filter_scalar::<f64>(&taps, &signal, &());
            assert_eq!(fast64, slow64);
        }
    }

    #[test]
    fn pre_encoded_taps_bit_identical_to_raw_taps() {
        // The cache-consulting executor path encodes taps once and
        // replays them across signals; every replay must match the
        // one-shot fir_filter bit for bit.
        let ctx = HrfnaContext::paper_default();
        let taps = lowpass_taps(12, 0.12);
        let eh: Vec<Hrfna> = taps
            .iter()
            .map(|&t| Hrfna::encode(t, &ctx))
            .collect();
        let mut rng = crate::util::prng::Rng::new(41);
        for len in [3usize, 13, 40] {
            let signal: Vec<f64> = (0..len).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let want = fir_filter::<Hrfna>(&taps, &signal, &ctx);
            let got = fir_filter_encoded_taps::<Hrfna>(&eh, &signal, &ctx);
            for (n, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "len={len} output {n}");
            }
        }
    }

    #[test]
    fn hrfna_beats_bfp_on_fir() {
        let hctx = HrfnaContext::paper_default();
        let bctx = BfpConfig::default();
        let h = fir_rms_error::<Hrfna>(32, 256, 9, &hctx);
        let b = fir_rms_error::<Bfp>(32, 256, 9, &bctx);
        assert!(b > h * 10.0, "BFP {b} vs HRFNA {h}");
    }

    #[test]
    fn taps_symmetric_linear_phase() {
        let taps = lowpass_taps(20, 0.15);
        for i in 0..taps.len() / 2 {
            assert!(
                (taps[i] - taps[taps.len() - 1 - i]).abs() < 1e-12,
                "tap symmetry at {i}"
            );
        }
    }
}
