//! Fourth-order Runge–Kutta integration of nonlinear ODEs (paper §VII-D):
//! the long-horizon stability workload. The vector field is evaluated *in
//! the format under test*, so per-step rounding/normalization error feeds
//! back through the dynamics exactly as it would in a deployed solver.

use super::traits::Numeric;

/// MAC-equivalent cost of one RK4 step of a 2-D nonlinear field (§V
/// timing model and the serving throughput metric): four vector-field
/// evaluations of ~7 format ops each plus the 4-term weighted state
/// update, ≈ 40 scalar MAC-equivalents. Shared by
/// [`crate::fpga::pipeline::WorkloadKind::Rk4`] and
/// [`crate::coordinator::Payload::macs`] so the hardware model and the
/// served workload price a step identically and cannot drift.
pub const RK4_MACS_PER_STEP: u64 = 40;

/// Test ODEs (paper: "a nonlinear ordinary differential equation").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Ode {
    /// Van der Pol oscillator: x' = v, v' = μ(1 - x²)v - x (limit cycle —
    /// errors neither explode nor vanish, ideal for drift measurement).
    VanDerPol { mu: f64 },
    /// Damped harmonic oscillator: x' = v, v' = -ω²x - 2ζωv.
    DampedOscillator { omega: f64, zeta: f64 },
    /// Exponential decay toward a forced equilibrium: y' = λ(c - y).
    Relaxation { lambda: f64, c: f64 },
}

impl Ode {
    /// State dimension.
    pub fn dim(&self) -> usize {
        match self {
            Ode::VanDerPol { .. } | Ode::DampedOscillator { .. } => 2,
            Ode::Relaxation { .. } => 1,
        }
    }

    /// Default initial state.
    pub fn default_y0(&self) -> Vec<f64> {
        match self {
            Ode::VanDerPol { .. } => vec![2.0, 0.0],
            Ode::DampedOscillator { .. } => vec![1.0, 0.0],
            Ode::Relaxation { .. } => vec![0.0],
        }
    }

    /// Evaluate the vector field in format `N`.
    pub fn field<N: Numeric>(&self, y: &[N], ctx: &N::Ctx) -> Vec<N> {
        match *self {
            Ode::VanDerPol { mu } => {
                let x = &y[0];
                let v = &y[1];
                // v' = mu*(1 - x^2)*v - x
                let one = N::from_f64(1.0, ctx);
                let x2 = x.mul(x, ctx);
                let damp = one.sub(&x2, ctx).scale(mu, ctx);
                let vprime = damp.mul(v, ctx).sub(x, ctx);
                vec![v.clone(), vprime]
            }
            Ode::DampedOscillator { omega, zeta } => {
                let x = &y[0];
                let v = &y[1];
                let vprime = x
                    .scale(-omega * omega, ctx)
                    .sub(&v.scale(2.0 * zeta * omega, ctx), ctx);
                vec![v.clone(), vprime]
            }
            Ode::Relaxation { lambda, c } => {
                let target = N::from_f64(c, ctx);
                vec![target.sub(&y[0], ctx).scale(lambda, ctx)]
            }
        }
    }
}

/// Classical RK4 step in format `N`.
pub fn rk4_step<N: Numeric>(ode: &Ode, y: &[N], dt: f64, ctx: &N::Ctx) -> Vec<N> {
    let k1 = ode.field(y, ctx);
    let y2: Vec<N> = y
        .iter()
        .zip(&k1)
        .map(|(yi, ki)| yi.add(&ki.scale(dt / 2.0, ctx), ctx))
        .collect();
    let k2 = ode.field(&y2, ctx);
    let y3: Vec<N> = y
        .iter()
        .zip(&k2)
        .map(|(yi, ki)| yi.add(&ki.scale(dt / 2.0, ctx), ctx))
        .collect();
    let k3 = ode.field(&y3, ctx);
    let y4: Vec<N> = y
        .iter()
        .zip(&k3)
        .map(|(yi, ki)| yi.add(&ki.scale(dt, ctx), ctx))
        .collect();
    let k4 = ode.field(&y4, ctx);
    (0..y.len())
        .map(|i| {
            // y + dt/6 (k1 + 2k2 + 2k3 + k4)
            let sum = k1[i]
                .add(&k2[i].scale(2.0, ctx), ctx)
                .add(&k3[i].scale(2.0, ctx), ctx)
                .add(&k4[i], ctx);
            y[i].add(&sum.scale(dt / 6.0, ctx), ctx)
        })
        .collect()
}

/// Pre-encoded scalar constants of an [`Ode`]'s batched vector field —
/// the residue encodings the per-step broadcast would otherwise redo on
/// every field evaluation (4 per RK4 step). [`Rk4Coeffs::encode`] is
/// deterministic, so a cached table is bit-identical to a cold encode
/// and integrating with either produces the same residues; the
/// coordinator's operand cache stores these per `(ODE constants, tier)`
/// digest (`coordinator::op_cache`).
#[derive(Clone, Debug)]
pub struct Rk4Coeffs {
    /// Encoded constants, in the fixed order [`field_batch_with`]
    /// consumes them: VanDerPol `[1.0]`, Relaxation `[c]`,
    /// DampedOscillator `[]` (its field is pure scaling).
    pub consts: Vec<crate::hybrid::Hrfna>,
}

impl Rk4Coeffs {
    /// Encode the constants of `ode` under `ctx`'s format.
    pub fn encode(ode: &Ode, ctx: &crate::hybrid::HrfnaContext) -> Rk4Coeffs {
        use crate::hybrid::Hrfna;
        let consts = match *ode {
            Ode::VanDerPol { .. } => vec![Hrfna::encode(1.0, ctx)],
            Ode::DampedOscillator { .. } => Vec::new(),
            Ode::Relaxation { c, .. } => vec![Hrfna::encode(c, ctx)],
        };
        Rk4Coeffs { consts }
    }

    /// Rewrap a cached constant table (as stored by the operand cache).
    pub fn from_consts(consts: Vec<crate::hybrid::Hrfna>) -> Rk4Coeffs {
        Rk4Coeffs { consts }
    }
}

/// Batched vector-field evaluation on the planar engine: one
/// [`HrfnaBatch`] per state dimension, each holding every instance —
/// elementwise kernels advance all instances at once, mirroring the
/// scalar [`Ode::field`] op-for-op (so results are bit-identical to
/// integrating each instance with the scalar reference).
fn field_batch(
    ode: &Ode,
    y: &[crate::hybrid::HrfnaBatch],
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<crate::hybrid::HrfnaBatch> {
    field_batch_with(ode, y, &Rk4Coeffs::encode(ode, ctx), ctx)
}

/// [`field_batch`] over pre-encoded constants: the per-call broadcast
/// reads `coeffs` instead of re-encoding, everything else is identical
/// (and so are the residues — encoding is deterministic).
fn field_batch_with(
    ode: &Ode,
    y: &[crate::hybrid::HrfnaBatch],
    coeffs: &Rk4Coeffs,
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<crate::hybrid::HrfnaBatch> {
    use crate::hybrid::HrfnaBatch;
    let b = y[0].len();
    match *ode {
        Ode::VanDerPol { mu } => {
            let x = &y[0];
            let v = &y[1];
            let one = HrfnaBatch::broadcast(&coeffs.consts[0], b);
            let x2 = x.mul(x, ctx);
            let damp = one.sub(&x2, ctx).scale(mu, ctx);
            let vprime = damp.mul(v, ctx).sub(x, ctx);
            vec![v.clone(), vprime]
        }
        Ode::DampedOscillator { omega, zeta } => {
            let x = &y[0];
            let v = &y[1];
            let vprime = x
                .scale(-omega * omega, ctx)
                .sub(&v.scale(2.0 * zeta * omega, ctx), ctx);
            vec![v.clone(), vprime]
        }
        Ode::Relaxation { lambda, .. } => {
            let target = HrfnaBatch::broadcast(&coeffs.consts[0], b);
            vec![target.sub(&y[0], ctx).scale(lambda, ctx)]
        }
    }
}

/// One classical RK4 step for a batch of instances (planar HRFNA).
pub fn rk4_step_batch(
    ode: &Ode,
    y: &[crate::hybrid::HrfnaBatch],
    dt: f64,
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<crate::hybrid::HrfnaBatch> {
    rk4_step_batch_with(ode, y, dt, &Rk4Coeffs::encode(ode, ctx), ctx)
}

/// [`rk4_step_batch`] over pre-encoded constants — four field
/// evaluations per step share one constant table instead of encoding
/// four times.
pub fn rk4_step_batch_with(
    ode: &Ode,
    y: &[crate::hybrid::HrfnaBatch],
    dt: f64,
    coeffs: &Rk4Coeffs,
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<crate::hybrid::HrfnaBatch> {
    let k1 = field_batch_with(ode, y, coeffs, ctx);
    let y2: Vec<_> = y
        .iter()
        .zip(&k1)
        .map(|(yi, ki)| yi.add(&ki.scale(dt / 2.0, ctx), ctx))
        .collect();
    let k2 = field_batch_with(ode, &y2, coeffs, ctx);
    let y3: Vec<_> = y
        .iter()
        .zip(&k2)
        .map(|(yi, ki)| yi.add(&ki.scale(dt / 2.0, ctx), ctx))
        .collect();
    let k3 = field_batch_with(ode, &y3, coeffs, ctx);
    let y4: Vec<_> = y
        .iter()
        .zip(&k3)
        .map(|(yi, ki)| yi.add(&ki.scale(dt, ctx), ctx))
        .collect();
    let k4 = field_batch_with(ode, &y4, coeffs, ctx);
    (0..y.len())
        .map(|i| {
            let sum = k1[i]
                .add(&k2[i].scale(2.0, ctx), ctx)
                .add(&k3[i].scale(2.0, ctx), ctx)
                .add(&k4[i], ctx);
            y[i].add(&sum.scale(dt / 6.0, ctx), ctx)
        })
        .collect()
}

/// Serving entry, scalar reference: integrate one instance `steps` steps
/// and return only the decoded final state (no reference trace).
pub fn rk4_final_state<N: Numeric>(
    ode: &Ode,
    y0: &[f64],
    dt: f64,
    steps: u64,
    ctx: &N::Ctx,
) -> Vec<f64> {
    let mut y: Vec<N> = y0.iter().map(|&v| N::from_f64(v, ctx)).collect();
    for _ in 0..steps {
        y = rk4_step(ode, &y, dt, ctx);
    }
    y.iter().map(|v| v.to_f64(ctx)).collect()
}

/// Serving entry, planar: integrate a batch of instances lock-step on the
/// planar engine and decode *only* the final states (one bulk decode at
/// the end — the coordinator's "reconstruct requested outputs" contract).
/// Per-instance results are bit-identical to [`rk4_final_state`] over
/// [`crate::hybrid::Hrfna`].
pub fn rk4_final_states_batch(
    ode: &Ode,
    y0s: &[Vec<f64>],
    dt: f64,
    steps: u64,
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<Vec<f64>> {
    rk4_final_states_batch_with(ode, y0s, dt, steps, &Rk4Coeffs::encode(ode, ctx), ctx)
}

/// [`rk4_final_states_batch`] over pre-encoded constants: `steps × 4`
/// field evaluations share one constant table. Bit-identical to the
/// cold-encoding entry — the serving path's operand-cache contract.
pub fn rk4_final_states_batch_with(
    ode: &Ode,
    y0s: &[Vec<f64>],
    dt: f64,
    steps: u64,
    coeffs: &Rk4Coeffs,
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<Vec<f64>> {
    use crate::hybrid::HrfnaBatch;
    let dim = ode.dim();
    let b = y0s.len();
    assert!(y0s.iter().all(|y0| y0.len() == dim));
    let mut y: Vec<HrfnaBatch> = (0..dim)
        .map(|d| {
            let xs: Vec<f64> = y0s.iter().map(|y0| y0[d]).collect();
            HrfnaBatch::encode(&xs, ctx)
        })
        .collect();
    for _ in 0..steps {
        y = rk4_step_batch_with(ode, &y, dt, coeffs, ctx);
    }
    let decoded: Vec<Vec<f64>> = y.iter().map(|bd| bd.decode(ctx)).collect();
    (0..b)
        .map(|i| (0..dim).map(|d| decoded[d][i]).collect())
        .collect()
}

/// Integrate a *batch* of instances of `ode` (one initial state per
/// instance) in lock-step on the planar engine, sampling each instance's
/// error against its own f64 reference. Serving many independent ODE
/// instances is the batched form of the §VII-D workload; per-instance
/// results are bit-identical to the scalar [`rk4_integrate`] run.
pub fn rk4_integrate_batch(
    ode: &Ode,
    y0s: &[Vec<f64>],
    dt: f64,
    steps: u64,
    sample_every: u64,
    ctx: &crate::hybrid::HrfnaContext,
) -> Vec<Rk4Trace> {
    use crate::hybrid::HrfnaBatch;
    let dim = ode.dim();
    let b = y0s.len();
    assert!(y0s.iter().all(|y0| y0.len() == dim));
    // One batch per state dimension, instances as elements.
    let mut y: Vec<HrfnaBatch> = (0..dim)
        .map(|d| {
            let xs: Vec<f64> = y0s.iter().map(|y0| y0[d]).collect();
            HrfnaBatch::encode(&xs, ctx)
        })
        .collect();
    let mut yref: Vec<Vec<f64>> = y0s.to_vec();
    let mut samples: Vec<Vec<(u64, f64)>> = vec![Vec::new(); b];
    for step in 1..=steps {
        y = rk4_step_batch(ode, &y, dt, ctx);
        for r in yref.iter_mut() {
            *r = rk4_step::<f64>(ode, r, dt, &());
        }
        if step % sample_every == 0 || step == steps {
            let decoded: Vec<Vec<f64>> = y.iter().map(|bd| bd.decode(ctx)).collect();
            for (i, r) in yref.iter().enumerate() {
                let err = (0..dim)
                    .map(|d| (decoded[d][i] - r[d]).abs())
                    .fold(0.0, f64::max);
                samples[i].push((step, err));
            }
        }
    }
    let decoded: Vec<Vec<f64>> = y.iter().map(|bd| bd.decode(ctx)).collect();
    (0..b)
        .map(|i| Rk4Trace {
            samples: samples[i].clone(),
            final_state: (0..dim).map(|d| decoded[d][i]).collect(),
            final_ref: yref[i].clone(),
        })
        .collect()
}

/// Integration trace: error vs the f64 reference sampled along the run.
#[derive(Clone, Debug)]
pub struct Rk4Trace {
    /// (step index, max-abs state error vs f64 reference).
    pub samples: Vec<(u64, f64)>,
    /// Final state decoded to f64.
    pub final_state: Vec<f64>,
    /// Final reference state (f64 integration).
    pub final_ref: Vec<f64>,
}

impl Rk4Trace {
    /// Max error observed across all samples.
    pub fn max_error(&self) -> f64 {
        self.samples.iter().map(|&(_, e)| e).fold(0.0, f64::max)
    }

    /// Error slope between the first and second half of the run — a drift
    /// detector: stable formats stay flat, drifting formats grow.
    pub fn drift_ratio(&self) -> f64 {
        if self.samples.len() < 4 {
            return 1.0;
        }
        let mid = self.samples.len() / 2;
        let first: f64 = self.samples[..mid].iter().map(|&(_, e)| e).sum::<f64>()
            / mid as f64;
        let second: f64 = self.samples[mid..].iter().map(|&(_, e)| e).sum::<f64>()
            / (self.samples.len() - mid) as f64;
        if first == 0.0 {
            return if second == 0.0 { 1.0 } else { f64::INFINITY };
        }
        second / first
    }
}

/// Integrate `steps` RK4 steps in format `N`, sampling the error against a
/// lock-step f64 reference every `sample_every` steps.
pub fn rk4_integrate<N: Numeric>(
    ode: &Ode,
    y0: &[f64],
    dt: f64,
    steps: u64,
    sample_every: u64,
    ctx: &N::Ctx,
) -> Rk4Trace {
    let mut y: Vec<N> = y0.iter().map(|&v| N::from_f64(v, ctx)).collect();
    let mut yref: Vec<f64> = y0.to_vec();
    let mut samples = Vec::new();
    for step in 1..=steps {
        y = rk4_step(ode, &y, dt, ctx);
        yref = rk4_step::<f64>(ode, &yref, dt, &());
        if step % sample_every == 0 || step == steps {
            let err = y
                .iter()
                .zip(&yref)
                .map(|(a, b)| (a.to_f64(ctx) - b).abs())
                .fold(0.0, f64::max);
            samples.push((step, err));
        }
    }
    Rk4Trace {
        samples,
        final_state: y.iter().map(|v| v.to_f64(ctx)).collect(),
        final_ref: yref,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hybrid::{Hrfna, HrfnaContext};

    #[test]
    fn relaxation_converges_to_c() {
        let ode = Ode::Relaxation { lambda: 2.0, c: 5.0 };
        let tr = rk4_integrate::<f64>(&ode, &[0.0], 0.01, 1000, 100, &());
        assert!((tr.final_ref[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn damped_oscillator_decays() {
        let ode = Ode::DampedOscillator { omega: 1.0, zeta: 0.2 };
        let tr = rk4_integrate::<f64>(&ode, &[1.0, 0.0], 0.01, 5000, 1000, &());
        assert!(tr.final_ref[0].abs() < 0.1);
    }

    #[test]
    fn vdp_reaches_limit_cycle_amplitude() {
        // Van der Pol limit cycle amplitude ≈ 2 for small mu.
        let ode = Ode::VanDerPol { mu: 0.5 };
        let tr = rk4_integrate::<f64>(&ode, &[0.5, 0.0], 0.01, 10_000, 2000, &());
        let amp = tr.final_ref[0].hypot(tr.final_ref[1]);
        assert!(amp > 1.0 && amp < 3.0, "amp={amp}");
    }

    #[test]
    fn hrfna_tracks_f64_comparably_to_fp32() {
        // Paper §VII-D.3: HRFNA error "closely matching FP32 behavior" —
        // on a limit cycle, per-op rounding turns into phase drift for any
        // finite format; the claim is parity with FP32, not with f64.
        let ctx = HrfnaContext::paper_default();
        let ode = Ode::VanDerPol { mu: 1.0 };
        let steps = 10_000;
        let tr_h = rk4_integrate::<Hrfna>(&ode, &[2.0, 0.0], 0.005, steps, 1000, &ctx);
        let tr_f = rk4_integrate::<f32>(&ode, &[2.0, 0.0], 0.005, steps, 1000, &());
        assert!(tr_h.final_state.iter().all(|v| v.is_finite()));
        assert!(
            tr_h.max_error() <= tr_f.max_error() * 2.0 + 1e-9,
            "HRFNA err={} vs FP32 err={}",
            tr_h.max_error(),
            tr_f.max_error()
        );
    }

    #[test]
    fn hrfna_stable_on_non_chaotic_ode() {
        // On a contracting ODE (no phase amplification) HRFNA should stay
        // near f64 over long horizons — the bounded-error story in pure form.
        let ctx = HrfnaContext::paper_default();
        let ode = Ode::Relaxation { lambda: 1.0, c: 3.0 };
        let tr = rk4_integrate::<Hrfna>(&ode, &[0.0], 0.01, 20_000, 2000, &ctx);
        assert!(tr.max_error() < 1e-6, "max_error={}", tr.max_error());
    }

    #[test]
    fn batched_integration_bit_identical_to_scalar() {
        // The batched kernels mirror the scalar ops exactly, so every
        // instance of a batched run must reproduce its scalar run bit for
        // bit — across ODEs with different op mixes.
        let ctx = HrfnaContext::paper_default();
        let mut rng = crate::util::prng::Rng::new(23);
        for (ode, steps) in [
            (Ode::VanDerPol { mu: 1.0 }, 400u64),
            (Ode::DampedOscillator { omega: 1.0, zeta: 0.1 }, 400),
            (Ode::Relaxation { lambda: 1.5, c: 2.0 }, 400),
        ] {
            let dim = ode.dim();
            let y0s: Vec<Vec<f64>> = (0..5)
                .map(|_| (0..dim).map(|_| rng.uniform(-1.5, 1.5)).collect())
                .collect();
            let traces = rk4_integrate_batch(&ode, &y0s, 0.01, steps, 100, &ctx);
            assert_eq!(traces.len(), y0s.len());
            for (i, y0) in y0s.iter().enumerate() {
                let scalar = rk4_integrate::<Hrfna>(&ode, y0, 0.01, steps, 100, &ctx);
                assert_eq!(
                    traces[i].final_state, scalar.final_state,
                    "{ode:?} instance {i} diverged from the scalar reference"
                );
                assert_eq!(traces[i].final_ref, scalar.final_ref);
            }
        }
    }

    #[test]
    fn batched_integration_tracks_f64() {
        let ctx = HrfnaContext::paper_default();
        let ode = Ode::Relaxation { lambda: 1.0, c: 3.0 };
        let y0s = vec![vec![0.0], vec![1.0], vec![-2.0]];
        let traces = rk4_integrate_batch(&ode, &y0s, 0.01, 2000, 500, &ctx);
        for tr in &traces {
            assert!(tr.max_error() < 1e-6, "max_error={}", tr.max_error());
            assert!((tr.final_state[0] - tr.final_ref[0]).abs() < 1e-6);
        }
    }

    #[test]
    fn final_state_serving_entries_bit_identical() {
        // The serving entries must agree exactly: the planar batch mirrors
        // the scalar ops op-for-op, and both decode the same residues.
        let ctx = HrfnaContext::paper_default();
        let ode = Ode::VanDerPol { mu: 1.0 };
        let y0s = vec![vec![2.0, 0.0], vec![-1.0, 0.5], vec![0.25, -0.75]];
        let batch = rk4_final_states_batch(&ode, &y0s, 0.01, 150, &ctx);
        for (i, y0) in y0s.iter().enumerate() {
            let scalar = rk4_final_state::<Hrfna>(&ode, y0, 0.01, 150, &ctx);
            assert_eq!(batch[i], scalar, "instance {i}");
            assert!(scalar.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn precomputed_coeffs_bit_identical_to_cold_encode() {
        // The `_with` entries must reproduce the plain entries exactly:
        // encoding is deterministic, so a constant table encoded once
        // and reused across steps yields the same residues as
        // re-encoding per field evaluation — across all three ODEs
        // (including DampedOscillator's empty table).
        let ctx = HrfnaContext::paper_default();
        for ode in [
            Ode::VanDerPol { mu: 1.0 },
            Ode::DampedOscillator { omega: 1.0, zeta: 0.1 },
            Ode::Relaxation { lambda: 1.5, c: 2.0 },
        ] {
            let dim = ode.dim();
            let y0s: Vec<Vec<f64>> =
                vec![vec![0.5; dim], vec![-0.25; dim], vec![1.5; dim]];
            let cold = rk4_final_states_batch(&ode, &y0s, 0.01, 200, &ctx);
            let coeffs = Rk4Coeffs::encode(&ode, &ctx);
            let warm = rk4_final_states_batch_with(&ode, &y0s, 0.01, 200, &coeffs, &ctx);
            assert_eq!(cold, warm, "{ode:?}");
            // And a rewrapped table (the cache round trip) as well.
            let rewrapped = Rk4Coeffs::from_consts(coeffs.consts.clone());
            let cached =
                rk4_final_states_batch_with(&ode, &y0s, 0.01, 200, &rewrapped, &ctx);
            assert_eq!(cold, cached, "{ode:?} via rewrapped table");
        }
    }

    #[test]
    fn long_run_drift_within_composed_norm_budget() {
        // §III-D composition over a long horizon (ISSUE 4 satellite): a
        // ≥10k-step integration with a tight τ (so normalization events
        // actually fire) must keep the decoded drift vs the f64
        // reference within `composed_rel_bound(events, s, tau_bits)`
        // computed from the engine's *measured* event count — on both
        // the scalar and the planar path. The contracting Relaxation ODE
        // is the pure bounded-error setting (no phase amplification);
        // scale_step = 24 keeps the per-event budget 2^{s-1-τ} above the
        // worst per-event rounding 2^{-sig} the engine actually takes.
        use crate::config::HrfnaConfig;
        use crate::hybrid::error::composed_rel_bound;

        let cfg = HrfnaConfig {
            tau_bits: 40,
            scale_step: 24,
            ..HrfnaConfig::paper_default()
        };
        let ode = Ode::Relaxation { lambda: 1.0, c: 3.0 };
        let (dt, steps) = (0.01, 12_000u64);
        // f64 reference trajectory (shared by both paths).
        let mut yref = vec![0.5f64];
        for _ in 0..steps {
            yref = rk4_step::<f64>(&ode, &yref, dt, &());
        }
        // Encode-quantization noise floor: the composed bound covers
        // normalization rounding only, not the per-op 2^{-sig} encode
        // quantization (tiny next to any nonzero event budget).
        let noise_floor = 1e-7;

        // Scalar path, with its own counter window.
        let ctx = HrfnaContext::new(cfg.clone());
        let before = ctx.snapshot();
        let scalar = rk4_final_state::<Hrfna>(&ode, &[0.5], dt, steps, &ctx);
        let d = ctx.snapshot().since(&before);
        let events = d.norms + d.guard_norms;
        assert!(events > 0, "tight τ must trigger events ({events})");
        let budget =
            composed_rel_bound(events, ctx.cfg.scale_step, ctx.cfg.tau_bits) + noise_floor;
        let rel = (scalar[0] - yref[0]).abs() / yref[0].abs();
        assert!(
            rel <= budget,
            "scalar drift {rel:.3e} exceeds composed budget {budget:.3e} ({events} events)"
        );

        // Planar path (a 3-instance lock-step batch), fresh window.
        let ctx = HrfnaContext::new(cfg);
        let before = ctx.snapshot();
        let finals = rk4_final_states_batch(
            &ode,
            &[vec![0.5], vec![0.5], vec![0.5]],
            dt,
            steps,
            &ctx,
        );
        let d = ctx.snapshot().since(&before);
        let events = d.norms + d.guard_norms;
        assert!(events > 0, "planar path must also take events");
        let budget =
            composed_rel_bound(events, ctx.cfg.scale_step, ctx.cfg.tau_bits) + noise_floor;
        for (i, state) in finals.iter().enumerate() {
            let rel = (state[0] - yref[0]).abs() / yref[0].abs();
            assert!(
                rel <= budget,
                "planar instance {i} drift {rel:.3e} exceeds {budget:.3e} ({events} events)"
            );
        }
    }

    #[test]
    fn drift_ratio_flat_for_equal_errors() {
        let tr = Rk4Trace {
            samples: (1..=10u64).map(|i| (i, 1.0)).collect(),
            final_state: vec![],
            final_ref: vec![],
        };
        assert!((tr.drift_ratio() - 1.0).abs() < 1e-12);
    }
}
