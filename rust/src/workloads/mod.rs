//! Application-level workloads (paper §VII): vector dot products, dense
//! matrix multiplication and an RK4 ODE integrator, written once against
//! the [`traits::Numeric`] abstraction and executed across every format
//! under evaluation with identical loop structure (§VII-C.2 methodology).

pub mod traits;
pub mod generators;
pub mod dot;
pub mod fir;
pub mod matmul;
pub mod rk4;

pub use dot::dot_product;
pub use generators::Dist;
pub use matmul::matmul;
pub use rk4::{rk4_integrate, Ode};
pub use traits::Numeric;
