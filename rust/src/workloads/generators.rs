//! Synthetic operand generators (paper §VII-B.2: "input values are drawn
//! from distributions designed to exercise both moderate and high dynamic
//! range, ensuring that normalization is triggered but not excessively").

use crate::hybrid::registry::Tier;
use crate::util::prng::Rng;

/// Operand distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Dist {
    /// Uniform in [lo, hi].
    Uniform { lo: f64, hi: f64 },
    /// Signed log-normal: ±exp(N(mu, sigma²)·ln2-ish) — wide dynamic range.
    LogNormal { mu: f64, sigma: f64 },
    /// 90% moderate uniform + 10% log-normal outliers (the "mixed
    /// magnitude" stress in §VII-B).
    Mixed,
}

impl Dist {
    /// The paper's "moderate dynamic range" setting.
    pub fn moderate() -> Dist {
        Dist::Uniform { lo: -1.0, hi: 1.0 }
    }

    /// The paper's "high dynamic range" setting: σ = 4 gives ~±17 bits of
    /// per-operand magnitude spread — wide enough that normalization is
    /// "triggered but not excessively" (§VII-B.2) under the default k=8
    /// modulus set. (Wider spreads exceed M ≈ 2^128's exact-accumulation
    /// budget and shift the system into the §IX-B frequent-rescaling
    /// regime; the design-space example explores larger k for those.)
    pub fn high_dynamic_range() -> Dist {
        Dist::LogNormal { mu: 0.0, sigma: 4.0 }
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            Dist::Uniform { lo, hi } => rng.uniform(lo, hi),
            Dist::LogNormal { mu, sigma } => rng.sign() * rng.lognormal(mu, sigma),
            Dist::Mixed => {
                if rng.below(10) == 0 {
                    rng.sign() * rng.lognormal(0.0, 10.0)
                } else {
                    rng.uniform(-1.0, 1.0)
                }
            }
        }
    }

    /// Draw a vector of samples.
    pub fn sample_vec(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }

    /// Short label for tables.
    pub fn label(&self) -> &'static str {
        match self {
            Dist::Uniform { .. } => "uniform",
            Dist::LogNormal { .. } => "lognormal",
            Dist::Mixed => "mixed",
        }
    }
}

/// Serving request mix: deterministic per (seed, index) so every client
/// thread of a load generator can build its own stream without sharing a
/// PRNG. Weights follow the serve example: 40% hybrid dot, 30% FP32 dot,
/// 10% each matmul lane, 10% RK4.
pub struct ServeMix {
    pub dist: Dist,
    /// Dot operand length before padding.
    pub dot_n: usize,
    pub matmul_dim: usize,
    pub rk4_steps: u64,
}

impl ServeMix {
    /// Default mix sized for the default shape buckets.
    pub fn default_mix() -> ServeMix {
        ServeMix {
            dist: Dist::moderate(),
            dot_n: 512,
            matmul_dim: 64,
            rk4_steps: 200,
        }
    }

    /// Draw request `i` of stream `seed` as a (slot, operands) pair where
    /// `slot` in 0..10 selects the lane per the mix weights. Returns the
    /// slot and a fresh RNG positioned for this request's operands.
    pub fn request_rng(&self, seed: u64, i: usize) -> (usize, Rng) {
        let rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ i as u64);
        (i % 10, rng)
    }

    /// Requested precision tier for request `i` of a mixed-tier stream:
    /// 30% `lo`, 50% `paper`, 20% `wide` — deterministic, and phased
    /// against the 10-slot kind cycle (the decade term of the historical
    /// `(i % 10 + i / 10) % 10` phase advances the tier residue between
    /// same-slot requests) so every lane kind sees every tier over a
    /// stream. The phase-with-drift pattern repeats every 100 requests,
    /// so the whole thing collapses to one precomputed 100-slot
    /// expansion of [`ServeMix::TIER_CYCLE`]: a single `% 100` + table
    /// load per submit, no per-job div or match chain on the generator
    /// hot path.
    #[inline]
    pub fn tier_for(&self, i: usize) -> Tier {
        const TABLE: [Tier; 100] = ServeMix::tier_table();
        TABLE[i % 100]
    }

    /// The 10-slot 3:5:2 tier cycle (`lo lo lo paper ×5 wide wide`) that
    /// [`ServeMix::tier_for`] walks with a per-decade phase shift.
    pub const TIER_CYCLE: [Tier; 10] = [
        Tier::Lo,
        Tier::Lo,
        Tier::Lo,
        Tier::Paper,
        Tier::Paper,
        Tier::Paper,
        Tier::Paper,
        Tier::Paper,
        Tier::Wide,
        Tier::Wide,
    ];

    /// Expand [`ServeMix::TIER_CYCLE`] through the per-decade phase shift
    /// into the full 100-request period: entry `i` is
    /// `TIER_CYCLE[(i % 10 + i / 10) % 10]`, the exact sequence the
    /// per-request modulo used to emit (pinned by unit test).
    const fn tier_table() -> [Tier; 100] {
        let mut t = [Tier::Lo; 100];
        let mut i = 0;
        while i < 100 {
            t[i] = ServeMix::TIER_CYCLE[(i % 10 + i / 10) % 10];
            i += 1;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_mix_streams_are_deterministic_and_distinct() {
        let mix = ServeMix::default_mix();
        let (slot_a, mut rng_a) = mix.request_rng(1, 3);
        let (slot_b, mut rng_b) = mix.request_rng(1, 3);
        assert_eq!(slot_a, slot_b);
        assert_eq!(
            mix.dist.sample_vec(&mut rng_a, 8),
            mix.dist.sample_vec(&mut rng_b, 8)
        );
        let (_, mut rng_c) = mix.request_rng(2, 3);
        assert_ne!(
            mix.dist.sample_vec(&mut rng_a, 8),
            mix.dist.sample_vec(&mut rng_c, 8)
        );
    }

    #[test]
    fn tier_mix_hits_every_tier_and_is_deterministic() {
        let mix = ServeMix::default_mix();
        let mut counts = [0usize; 3];
        for i in 0..100 {
            assert_eq!(mix.tier_for(i), mix.tier_for(i));
            counts[mix.tier_for(i).index()] += 1;
        }
        assert_eq!(counts, [30, 50, 20], "3:5:2 lo/paper/wide mix");
        // Phased against the 10-slot kind cycle: one kind slot must see
        // more than one tier across a stream.
        let tiers: std::collections::BTreeSet<_> =
            (0..100).step_by(10).map(|i| mix.tier_for(i)).collect();
        assert!(tiers.len() > 1);
    }

    #[test]
    fn tier_table_pins_the_historical_per_request_sequence() {
        // The precomputed 100-slot table must emit exactly the sequence
        // the per-request `(i % 10 + i / 10) % 10` modulo chain used to
        // produce — including past the first period, where the decade
        // drift wraps.
        let mix = ServeMix::default_mix();
        let legacy = |i: usize| match (i % 10 + i / 10) % 10 {
            0..=2 => Tier::Lo,
            3..=7 => Tier::Paper,
            _ => Tier::Wide,
        };
        for i in 0..1000 {
            assert_eq!(mix.tier_for(i), legacy(i), "i={i}");
        }
        // And pin the literal head of the stream: slot 0 starts on the
        // raw 3:5:2 cycle, decade 1 starts one phase in.
        use Tier::{Lo, Paper, Wide};
        let head: Vec<Tier> = (0..30).map(|i| mix.tier_for(i)).collect();
        assert_eq!(
            head,
            vec![
                Lo, Lo, Lo, Paper, Paper, Paper, Paper, Paper, Wide, Wide, // i/10 = 0
                Lo, Lo, Paper, Paper, Paper, Paper, Paper, Wide, Wide, Lo, // i/10 = 1
                Lo, Paper, Paper, Paper, Paper, Paper, Wide, Wide, Lo, Lo, // i/10 = 2
            ]
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Rng::new(1);
        let d = Dist::Uniform { lo: -2.0, hi: 3.0 };
        for _ in 0..1000 {
            let x = d.sample(&mut rng);
            assert!((-2.0..=3.0).contains(&x));
        }
    }

    #[test]
    fn lognormal_has_wide_range() {
        let mut rng = Rng::new(2);
        let d = Dist::high_dynamic_range();
        let xs = d.sample_vec(&mut rng, 10_000);
        let max = xs.iter().cloned().fold(0.0f64, |a, x| a.max(x.abs()));
        let min = xs
            .iter()
            .cloned()
            .fold(f64::INFINITY, |a, x| a.min(x.abs()));
        assert!(max / min > 1e6, "dynamic range too small: {max}/{min}");
    }

    #[test]
    fn mixed_has_outliers_and_bulk() {
        let mut rng = Rng::new(3);
        let xs = Dist::Mixed.sample_vec(&mut rng, 10_000);
        let outliers = xs.iter().filter(|x| x.abs() > 10.0).count();
        assert!(outliers > 100, "expected outliers, got {outliers}");
        let bulk = xs.iter().filter(|x| x.abs() <= 1.0).count();
        assert!(bulk > 7000, "expected bulk, got {bulk}");
    }

    #[test]
    fn deterministic_given_seed() {
        let d = Dist::Mixed;
        let a = d.sample_vec(&mut Rng::new(7), 100);
        let b = d.sample_vec(&mut Rng::new(7), 100);
        assert_eq!(a, b);
    }
}
