//! Vector dot product (paper §VII-B, Algorithm 1): the long-accumulation
//! workload. One generic kernel runs every format with the identical loop.

use super::traits::Numeric;
use crate::util::stats;

/// Dot product of two real vectors evaluated in format `N`:
/// encode once, MAC with format-native accumulation, decode once
/// (Algorithm 1: exponent-coherent accumulation, one final reconstruction).
pub fn dot_product<N: Numeric>(xs: &[f64], ys: &[f64], ctx: &N::Ctx) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mut acc = N::zero(ctx);
    for (x, y) in xs.iter().zip(ys) {
        let nx = N::from_f64(*x, ctx);
        let ny = N::from_f64(*y, ctx);
        acc.mac_assign(&nx, &ny, ctx);
    }
    acc.to_f64(ctx)
}

/// Dot product over pre-encoded operands (separates encode cost from the
/// accumulation loop — the timing-path variant). Routes through the
/// format's batched fast path when it has one (HRFNA: the planar engine);
/// [`dot_product_encoded_scalar`] is the always-scalar reference.
pub fn dot_product_encoded<N: Numeric>(xs: &[N], ys: &[N], ctx: &N::Ctx) -> N {
    assert_eq!(xs.len(), ys.len());
    N::dot_encoded(xs, ys, ctx)
}

/// The scalar reference MAC loop over pre-encoded operands — kept as the
/// baseline the planar engine is benchmarked and property-tested against.
pub fn dot_product_encoded_scalar<N: Numeric>(xs: &[N], ys: &[N], ctx: &N::Ctx) -> N {
    assert_eq!(xs.len(), ys.len());
    let mut acc = N::zero(ctx);
    for (x, y) in xs.iter().zip(ys) {
        acc.mac_assign(x, y, ctx);
    }
    acc
}

/// Accuracy experiment: many random dot products at length `n`; returns
/// the RMS of relative errors vs the f64 reference (§VII-A.2 metric).
pub fn dot_rms_error<N: Numeric>(
    trials: usize,
    n: usize,
    dist: super::generators::Dist,
    seed: u64,
    ctx: &N::Ctx,
) -> f64 {
    let mut rng = crate::util::prng::Rng::new(seed);
    let mut rel_errors = Vec::with_capacity(trials);
    for _ in 0..trials {
        let xs = dist.sample_vec(&mut rng, n);
        let ys = dist.sample_vec(&mut rng, n);
        let want = dot_product::<f64>(&xs, &ys, &());
        let got = dot_product::<N>(&xs, &ys, ctx);
        let denom = want.abs().max(1e-300);
        rel_errors.push((got - want) / denom);
    }
    stats::rms(&rel_errors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{Bfp, BfpConfig};
    use crate::hybrid::{Hrfna, HrfnaContext};
    use crate::workloads::generators::Dist;

    #[test]
    fn f64_dot_is_exactish() {
        let xs = vec![1.0, 2.0, 3.0];
        let ys = vec![4.0, 5.0, 6.0];
        assert_eq!(dot_product::<f64>(&xs, &ys, &()), 32.0);
    }

    #[test]
    fn hrfna_dot_matches_reference_small() {
        let ctx = HrfnaContext::paper_default();
        let xs = vec![1.5, -2.0, 3.25, 0.0, 10.0];
        let ys = vec![2.0, 1.0, -4.0, 9.0, 0.5];
        let want = dot_product::<f64>(&xs, &ys, &());
        let got = dot_product::<Hrfna>(&xs, &ys, &ctx);
        assert!((got - want).abs() < 1e-6 * want.abs().max(1.0));
    }

    #[test]
    fn hrfna_dot_rms_below_paper_threshold_1k() {
        // Paper §VII-B.3: RMS error below 1e-6 across lengths.
        let ctx = HrfnaContext::paper_default();
        let rms = dot_rms_error::<Hrfna>(5, 1024, Dist::moderate(), 42, &ctx);
        assert!(rms < 1e-6, "rms={rms}");
    }

    #[test]
    fn bfp_dot_worse_than_hrfna() {
        let hctx = HrfnaContext::paper_default();
        let bctx = BfpConfig::default();
        let h = dot_rms_error::<Hrfna>(3, 2048, Dist::moderate(), 7, &hctx);
        let b = dot_rms_error::<Bfp>(3, 2048, Dist::moderate(), 7, &bctx);
        assert!(b > h * 10.0, "BFP rms={b} should exceed HRFNA rms={h}");
    }

    #[test]
    fn encoded_variant_matches() {
        let ctx = HrfnaContext::paper_default();
        let xs = vec![1.0, 2.0, -3.0];
        let ys = vec![4.0, -5.0, 6.0];
        let ex: Vec<Hrfna> = xs.iter().map(|&x| Hrfna::encode(x, &ctx)).collect();
        let ey: Vec<Hrfna> = ys.iter().map(|&y| Hrfna::encode(y, &ctx)).collect();
        let got = dot_product_encoded::<Hrfna>(&ex, &ey, &ctx).decode(&ctx);
        let want = dot_product::<f64>(&xs, &ys, &());
        assert!((got - want).abs() < 1e-6 * want.abs());
    }

    #[test]
    fn planar_and_scalar_encoded_paths_agree() {
        let ctx = HrfnaContext::paper_default();
        let mut rng = crate::util::prng::Rng::new(71);
        let xs = Dist::moderate().sample_vec(&mut rng, 777);
        let ys = Dist::moderate().sample_vec(&mut rng, 777);
        let ex: Vec<Hrfna> = xs.iter().map(|&x| Hrfna::encode(x, &ctx)).collect();
        let ey: Vec<Hrfna> = ys.iter().map(|&y| Hrfna::encode(y, &ctx)).collect();
        let planar = dot_product_encoded::<Hrfna>(&ex, &ey, &ctx).decode(&ctx);
        let scalar = dot_product_encoded_scalar::<Hrfna>(&ex, &ey, &ctx).decode(&ctx);
        let tol = 1e-9 * scalar.abs().max(1e-12);
        assert!((planar - scalar).abs() <= tol, "planar={planar} scalar={scalar}");
    }
}
