//! Arithmetic on `BigUint`: add/sub/mul, shifts, division and modular
//! reduction. Schoolbook algorithms — operands here are ~128–160 bits
//! (CRT terms), far below the sizes where Karatsuba pays off.

use super::BigUint;

impl BigUint {
    /// `self + other`.
    pub fn add(&self, other: &BigUint) -> BigUint {
        let n = self.limbs.len().max(other.limbs.len());
        let mut out = Vec::with_capacity(n + 1);
        let mut carry = 0u64;
        for i in 0..n {
            let a = *self.limbs.get(i).unwrap_or(&0);
            let b = *other.limbs.get(i).unwrap_or(&0);
            let (s1, c1) = a.overflowing_add(b);
            let (s2, c2) = s1.overflowing_add(carry);
            out.push(s2);
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry != 0 {
            out.push(carry);
        }
        BigUint::from_limbs(out)
    }

    /// Add a u64 in place.
    pub fn add_u64(&self, x: u64) -> BigUint {
        self.add(&BigUint::from_u64(x))
    }

    /// `self - other`; panics on underflow (callers compare first).
    pub fn sub(&self, other: &BigUint) -> BigUint {
        assert!(self >= other, "BigUint subtraction underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let a = self.limbs[i];
            let b = *other.limbs.get(i).unwrap_or(&0);
            let (d1, b1) = a.overflowing_sub(b);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out.push(d2);
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        BigUint::from_limbs(out)
    }

    /// `self * other` (schoolbook).
    pub fn mul(&self, other: &BigUint) -> BigUint {
        if self.is_zero() || other.is_zero() {
            return BigUint::zero();
        }
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let t = out[i + j] as u128 + (a as u128) * (b as u128) + carry;
                out[i + j] = t as u64;
                carry = t >> 64;
            }
            let mut k = i + other.limbs.len();
            while carry != 0 {
                let t = out[k] as u128 + carry;
                out[k] = t as u64;
                carry = t >> 64;
                k += 1;
            }
        }
        BigUint::from_limbs(out)
    }

    /// `self * x` for a u64 scalar.
    pub fn mul_u64(&self, x: u64) -> BigUint {
        if x == 0 || self.is_zero() {
            return BigUint::zero();
        }
        let mut out = Vec::with_capacity(self.limbs.len() + 1);
        let mut carry = 0u128;
        for &a in &self.limbs {
            let t = (a as u128) * (x as u128) + carry;
            out.push(t as u64);
            carry = t >> 64;
        }
        if carry != 0 {
            out.push(carry as u64);
        }
        BigUint::from_limbs(out)
    }

    /// Left shift by `s` bits.
    pub fn shl(&self, s: u32) -> BigUint {
        if self.is_zero() || s == 0 {
            return self.clone();
        }
        let limb_shift = (s / 64) as usize;
        let bit_shift = s % 64;
        let mut out = vec![0u64; limb_shift];
        if bit_shift == 0 {
            out.extend_from_slice(&self.limbs);
        } else {
            let mut carry = 0u64;
            for &a in &self.limbs {
                out.push((a << bit_shift) | carry);
                carry = a >> (64 - bit_shift);
            }
            if carry != 0 {
                out.push(carry);
            }
        }
        BigUint::from_limbs(out)
    }

    /// Right shift by `s` bits (⌊self / 2^s⌋ — the paper's normalization
    /// scaling, Definition 4).
    pub fn shr(&self, s: u32) -> BigUint {
        let limb_shift = (s / 64) as usize;
        if limb_shift >= self.limbs.len() {
            return BigUint::zero();
        }
        let bit_shift = s % 64;
        let src = &self.limbs[limb_shift..];
        let mut out = Vec::with_capacity(src.len());
        if bit_shift == 0 {
            out.extend_from_slice(src);
        } else {
            for i in 0..src.len() {
                let lo = src[i] >> bit_shift;
                let hi = if i + 1 < src.len() {
                    src[i + 1] << (64 - bit_shift)
                } else {
                    0
                };
                out.push(lo | hi);
            }
        }
        BigUint::from_limbs(out)
    }

    /// Divide by a u64, returning (quotient, remainder).
    pub fn div_rem_u64(&self, d: u64) -> (BigUint, u64) {
        assert!(d != 0, "division by zero");
        let mut out = vec![0u64; self.limbs.len()];
        let mut rem = 0u128;
        for i in (0..self.limbs.len()).rev() {
            let cur = (rem << 64) | self.limbs[i] as u128;
            out[i] = (cur / d as u128) as u64;
            rem = cur % d as u128;
        }
        (BigUint::from_limbs(out), rem as u64)
    }

    /// Remainder mod a u64 (residue re-encoding path).
    pub fn rem_u64(&self, d: u64) -> u64 {
        self.div_rem_u64(d).1
    }

    /// General division: (⌊self/div⌋, self mod div). Binary long division —
    /// O(bits · limbs); operands are ≤ ~3 limbs here.
    pub fn div_rem(&self, div: &BigUint) -> (BigUint, BigUint) {
        assert!(!div.is_zero(), "division by zero");
        if self < div {
            return (BigUint::zero(), self.clone());
        }
        if let (Some(a), Some(b)) = (self.to_u128(), div.to_u128()) {
            return (BigUint::from_u128(a / b), BigUint::from_u128(a % b));
        }
        let shift = self.bit_length() - div.bit_length();
        let mut rem = self.clone();
        let mut quot = BigUint::zero();
        for s in (0..=shift).rev() {
            let d = div.shl(s);
            if rem >= d {
                rem = rem.sub(&d);
                quot = quot.add(&BigUint::one().shl(s));
            }
        }
        (quot, rem)
    }

    /// `self mod m`.
    pub fn rem_big(&self, m: &BigUint) -> BigUint {
        self.div_rem(m).1
    }

    /// `(self + other) mod m`, assuming both inputs are already < m.
    pub fn add_mod(&self, other: &BigUint, m: &BigUint) -> BigUint {
        let s = self.add(other);
        if &s >= m {
            s.sub(m)
        } else {
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn big(x: u128) -> BigUint {
        BigUint::from_u128(x)
    }

    #[test]
    fn add_carries_across_limbs() {
        let a = big(u128::MAX);
        let b = BigUint::one();
        let s = a.add(&b);
        assert_eq!(s.bit_length(), 129);
        assert_eq!(s.shr(128).to_u64(), Some(1));
    }

    #[test]
    fn sub_borrows() {
        let a = big(1u128 << 64);
        let b = BigUint::one();
        assert_eq!(a.sub(&b).to_u128(), Some((1u128 << 64) - 1));
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        BigUint::one().sub(&big(2));
    }

    #[test]
    fn mul_known() {
        assert_eq!(
            big(u64::MAX as u128).mul(&big(u64::MAX as u128)).to_u128(),
            Some((u64::MAX as u128) * (u64::MAX as u128))
        );
        assert_eq!(big(0).mul(&big(5)), BigUint::zero());
    }

    #[test]
    fn mul_u64_matches_mul() {
        let a = big(0x1234_5678_9abc_def0_1111_2222u128);
        assert_eq!(a.mul_u64(65521), a.mul(&big(65521)));
    }

    #[test]
    fn shifts_roundtrip() {
        let a = big(0xdead_beef_cafe_babe_u128);
        for s in [0u32, 1, 17, 63, 64, 65, 100] {
            assert_eq!(a.shl(s).shr(s), a, "s={s}");
        }
    }

    #[test]
    fn shr_floors() {
        assert_eq!(big(7).shr(1).to_u64(), Some(3));
        assert_eq!(big(7).shr(3).to_u64(), Some(0));
    }

    #[test]
    fn div_rem_u64_known() {
        let (q, r) = big(1_000_000_007).div_rem_u64(13);
        assert_eq!(q.to_u64(), Some(1_000_000_007 / 13));
        assert_eq!(r, 1_000_000_007 % 13);
    }

    #[test]
    fn div_rem_big_cases() {
        let a = big(12345678901234567890u128);
        let b = big(987654321u128);
        let (q, r) = a.div_rem(&b);
        assert_eq!(q.to_u128(), Some(12345678901234567890u128 / 987654321));
        assert_eq!(r.to_u128(), Some(12345678901234567890u128 % 987654321));
    }

    #[test]
    fn div_rem_multi_limb() {
        // 3-limb dividend, 2-limb divisor: exercises binary long division.
        let a = BigUint::from_limbs(vec![0x1111, 0x2222, 0x3333]);
        let b = BigUint::from_limbs(vec![0xffff_ffff_ffff_fff1, 0x7]);
        let (q, r) = a.div_rem(&b);
        assert_eq!(q.mul(&b).add(&r), a);
        assert!(r < b);
    }

    #[test]
    fn prop_add_sub_roundtrip() {
        check("bigint-add-sub", |rng| {
            let a = big(((rng.next_u64() as u128) << 64) | rng.next_u64() as u128);
            let b = big(rng.next_u64() as u128);
            let s = a.add(&b);
            crate::prop_assert!(s.sub(&b) == a, "roundtrip failed");
            Ok(())
        });
    }

    #[test]
    fn prop_div_rem_invariant() {
        check("bigint-divrem", |rng| {
            let a = BigUint::from_limbs(vec![
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64() % 4,
            ]);
            let b = BigUint::from_limbs(vec![rng.next_u64(), rng.next_u64() % 8 + 1]);
            let (q, r) = a.div_rem(&b);
            crate::prop_assert!(q.mul(&b).add(&r) == a, "q*b+r != a");
            crate::prop_assert!(r < b, "r >= b");
            Ok(())
        });
    }

    #[test]
    fn prop_mul_commutative() {
        check("bigint-mul-comm", |rng| {
            let a = big(rng.next_u64() as u128);
            let b = big(((rng.next_u64() as u128) << 32) | 1);
            crate::prop_assert!(a.mul(&b) == b.mul(&a), "commutativity");
            Ok(())
        });
    }

    #[test]
    fn prop_rem_u64_matches_div_rem() {
        check("bigint-rem-u64", |rng| {
            let a = BigUint::from_limbs(vec![rng.next_u64(), rng.next_u64()]);
            let d = rng.next_u64() % 65521 + 2;
            let (q, r) = a.div_rem_u64(d);
            crate::prop_assert!(
                q.mul_u64(d).add_u64(r) == a,
                "q*d+r != a for d={d}"
            );
            Ok(())
        });
    }

    #[test]
    fn add_mod_wraps() {
        let m = big(100);
        assert_eq!(big(60).add_mod(&big(50), &m).to_u64(), Some(10));
        assert_eq!(big(30).add_mod(&big(50), &m).to_u64(), Some(80));
    }
}
