//! `BigUint` representation, construction and conversions.

use std::cmp::Ordering;
use std::fmt;

/// Unsigned big integer: little-endian base-2^64 limbs, normalized so the
/// most significant limb is nonzero (zero is the empty limb vector).
#[derive(Clone, Debug, PartialEq, Eq, Hash, Default)]
pub struct BigUint {
    pub(crate) limbs: Vec<u64>,
}

impl BigUint {
    /// Zero.
    pub fn zero() -> BigUint {
        BigUint { limbs: Vec::new() }
    }

    /// One.
    pub fn one() -> BigUint {
        BigUint { limbs: vec![1] }
    }

    /// From a u64.
    pub fn from_u64(x: u64) -> BigUint {
        if x == 0 {
            BigUint::zero()
        } else {
            BigUint { limbs: vec![x] }
        }
    }

    /// From a u128.
    pub fn from_u128(x: u128) -> BigUint {
        let lo = x as u64;
        let hi = (x >> 64) as u64;
        let mut b = BigUint { limbs: vec![lo, hi] };
        b.normalize();
        b
    }

    /// From little-endian limbs (normalizing).
    pub fn from_limbs(limbs: Vec<u64>) -> BigUint {
        let mut b = BigUint { limbs };
        b.normalize();
        b
    }

    /// Strip trailing zero limbs.
    pub(crate) fn normalize(&mut self) {
        while let Some(&0) = self.limbs.last() {
            self.limbs.pop();
        }
    }

    /// True iff zero.
    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    /// Number of significant bits (0 for zero).
    pub fn bit_length(&self) -> u32 {
        match self.limbs.last() {
            None => 0,
            Some(&top) => {
                (self.limbs.len() as u32 - 1) * 64 + (64 - top.leading_zeros())
            }
        }
    }

    /// Value of bit `i` (false beyond the top).
    pub fn bit(&self, i: u32) -> bool {
        let limb = (i / 64) as usize;
        if limb >= self.limbs.len() {
            return false;
        }
        (self.limbs[limb] >> (i % 64)) & 1 == 1
    }

    /// Lossy conversion to f64 (rounds the 53-bit prefix, tracks scale).
    pub fn to_f64(&self) -> f64 {
        limbs_to_f64(&self.limbs)
    }

    /// Exact conversion to u64 if it fits.
    pub fn to_u64(&self) -> Option<u64> {
        match self.limbs.len() {
            0 => Some(0),
            1 => Some(self.limbs[0]),
            _ => None,
        }
    }

    /// Exact conversion to u128 if it fits.
    pub fn to_u128(&self) -> Option<u128> {
        match self.limbs.len() {
            0 => Some(0),
            1 => Some(self.limbs[0] as u128),
            2 => Some(self.limbs[0] as u128 | (self.limbs[1] as u128) << 64),
            _ => None,
        }
    }

    /// Comparison.
    pub fn cmp_big(&self, other: &BigUint) -> Ordering {
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => continue,
                o => return o,
            }
        }
        Ordering::Equal
    }
}

/// Lossy limbs→f64 conversion (top two significant limbs, scaled): the
/// single definition shared by [`BigUint::to_f64`] and the fixed-width
/// CRT scratch (`rns::crt`), so interval reseeds from the batched
/// normalization engine can never diverge bit-wise from the BigUint
/// decode paths. `limbs` must be normalized (no trailing zero limbs).
pub fn limbs_to_f64(limbs: &[u64]) -> f64 {
    match limbs.len() {
        0 => 0.0,
        1 => limbs[0] as f64,
        2 => limbs[0] as f64 + limbs[1] as f64 * 2f64.powi(64),
        n => {
            // Take the top two limbs and scale.
            let hi = limbs[n - 1] as f64;
            let lo = limbs[n - 2] as f64;
            (hi * 2f64.powi(64) + lo) * 2f64.powi(64 * (n as i32 - 2))
        }
    }
}

impl PartialOrd for BigUint {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp_big(other))
    }
}

impl Ord for BigUint {
    fn cmp(&self, other: &Self) -> Ordering {
        self.cmp_big(other)
    }
}

impl fmt::Display for BigUint {
    /// Decimal rendering (repeated division by 10^19; fine at our sizes).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_zero() {
            return write!(f, "0");
        }
        let mut chunks: Vec<u64> = Vec::new();
        let mut cur = self.clone();
        const TEN19: u64 = 10_000_000_000_000_000_000;
        while !cur.is_zero() {
            let (q, r) = cur.div_rem_u64(TEN19);
            chunks.push(r);
            cur = q;
        }
        let mut s = format!("{}", chunks.pop().unwrap());
        while let Some(c) = chunks.pop() {
            s.push_str(&format!("{c:019}"));
        }
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_normalization() {
        assert!(BigUint::zero().is_zero());
        assert_eq!(BigUint::from_limbs(vec![5, 0, 0]), BigUint::from_u64(5));
        assert_eq!(BigUint::from_u128(0), BigUint::zero());
    }

    #[test]
    fn bit_length() {
        assert_eq!(BigUint::zero().bit_length(), 0);
        assert_eq!(BigUint::one().bit_length(), 1);
        assert_eq!(BigUint::from_u64(u64::MAX).bit_length(), 64);
        assert_eq!(BigUint::from_u128(1u128 << 64).bit_length(), 65);
    }

    #[test]
    fn bits() {
        let b = BigUint::from_u128(0b1010);
        assert!(!b.bit(0));
        assert!(b.bit(1));
        assert!(!b.bit(2));
        assert!(b.bit(3));
        assert!(!b.bit(400));
    }

    #[test]
    fn to_f64_roundtrip_small() {
        for x in [0u64, 1, 12345, u64::MAX] {
            assert_eq!(BigUint::from_u64(x).to_f64(), x as f64);
        }
    }

    #[test]
    fn to_f64_large() {
        let b = BigUint::from_u128(1u128 << 100);
        assert_eq!(b.to_f64(), 2f64.powi(100));
    }

    #[test]
    fn conversions() {
        assert_eq!(BigUint::from_u64(7).to_u64(), Some(7));
        assert_eq!(BigUint::from_u128(u128::MAX).to_u128(), Some(u128::MAX));
        assert_eq!(BigUint::from_u128(u128::MAX).to_u64(), None);
    }

    #[test]
    fn ordering() {
        let a = BigUint::from_u128(1u128 << 80);
        let b = BigUint::from_u64(u64::MAX);
        assert!(a > b);
        assert_eq!(a.cmp_big(&a), Ordering::Equal);
    }

    #[test]
    fn display_decimal() {
        assert_eq!(BigUint::zero().to_string(), "0");
        assert_eq!(BigUint::from_u64(123456789).to_string(), "123456789");
        // 2^64 = 18446744073709551616
        let b = BigUint::from_u128(1u128 << 64);
        assert_eq!(b.to_string(), "18446744073709551616");
        // 10^19 boundary padding
        let c = BigUint::from_u128(10_000_000_000_000_000_000u128 * 3 + 7);
        assert_eq!(c.to_string(), "30000000000000000007");
    }
}
