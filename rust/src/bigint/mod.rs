//! Arbitrary-precision unsigned integers (u64 limbs, little-endian).
//!
//! Substrate for CRT reconstruction: with the default k=8 sixteen-bit
//! moduli the composite modulus `M ≈ 2^128`, and intermediate CRT terms
//! `r_i · M_i · inv_i` reach ~`M · m_i ≈ 2^144`, so fixed-width integers do
//! not suffice and the offline registry carries no num-bigint.

mod biguint;
mod ops;

pub use biguint::{limbs_to_f64, BigUint};
