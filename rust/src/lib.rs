//! # HRFNA — Hybrid Residue–Floating Numerical Architecture
//!
//! Reproduction of *"A Hybrid Residue–Floating Numerical Architecture with
//! Formal Error Bounds for High-Throughput FPGA Computation"* (M. Darvishi,
//! CS.AR 2026) as a three-layer Rust + JAX + Pallas system.
//!
//! An HRFNA value is a pair `(r, f)`: a residue vector `r` over pairwise
//! coprime moduli `{m_i}` plus a global power-of-two exponent `f`, with
//! semantics `Φ(r, f) = CRT(r) · 2^f` (paper Definition 1). Multiplication
//! and (exponent-synchronized) addition are exact, carry-free, per-channel
//! modular operations (Theorem 1); rounding happens *only* at explicit,
//! threshold-driven normalization events `N → ⌊N/2^s⌋, f → f+s`, whose error
//! is bounded by `|ε| ≤ 2^{f+s-1}` (Lemma 1) and `|ε|/|Φ| ≤ 2^{-s}`-style
//! relative bounds (Lemma 2).
//!
//! ## Crate layout
//!
//! * [`util`] — hand-rolled substrates (PRNG, stats, tables, CLI, property
//!   testing, thread pool): the offline build has no external crates beyond
//!   `xla`/`anyhow`/`thiserror`.
//! * [`bigint`] — unsigned big integers (CRT reconstruction substrate).
//! * [`rns`] — residue number system: moduli, Barrett reduction, CRT, and
//!   the planar (structure-of-arrays) residue lanes ([`rns::plane`]).
//! * [`hybrid`] — the HRFNA number system itself (paper §III–IV): the
//!   scalar [`hybrid::Hrfna`] reference plus the batched planar engine
//!   ([`hybrid::batch`]) that the hot paths run on.
//! * [`baselines`] — FP32, block floating-point, fixed-point, pure RNS and
//!   LNS comparators (paper Tables I/IV).
//! * [`fpga`] — ZCU104-class microarchitecture model: pipeline timing,
//!   LUT/FF/DSP resources, power (paper §V–VI substitution; see DESIGN.md).
//! * [`workloads`] — dot product / matmul / RK4 generic over [`workloads::Numeric`].
//! * [`runtime`] — execution engine: PJRT loader/executor for the AOT HLO
//!   artifacts (`--features xla`) or the pure-Rust software backend
//!   (default, offline).
//! * [`coordinator`] — request router with precision-tier resolution
//!   over the [`hybrid::ContextRegistry`], fixed-shape batcher,
//!   scheduler, per-tier metrics, server loop (Layer 3). With
//!   `--features rpc`, `coordinator::rpc` adds the network serving
//!   edge: length-prefix-framed JSON-RPC over TCP with per-client
//!   quotas and typed backpressure error codes.
//! * [`config`] — typed configuration + TOML-subset parser + presets.

pub mod util;
pub mod config;
pub mod bigint;
pub mod rns;
pub mod hybrid;
pub mod baselines;
pub mod fpga;
pub mod workloads;
pub mod runtime;
pub mod coordinator;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
