//! Typed configuration for the HRFNA system (paper Table II parameters)
//! plus a small TOML-subset parser and named presets.

mod toml;

pub use toml::TomlDoc;

use crate::rns::moduli::{
    default_moduli, dynamic_range_bits, fits_lane_width, generate_prime_moduli,
    is_pairwise_coprime,
};

/// HRFNA numeric + microarchitecture configuration (paper Table II).
#[derive(Clone, Debug, PartialEq)]
pub struct HrfnaConfig {
    /// Pairwise coprime modulus set {m_1..m_k}.
    pub moduli: Vec<u64>,
    /// Exponent width ω_f in bits (exponent range is ±(2^{ω_f-1} - 1)).
    pub exponent_width: u32,
    /// Normalization threshold τ expressed as `log2 τ` (τ = 2^tau_bits);
    /// normalization triggers when the magnitude estimate reaches τ.
    pub tau_bits: u32,
    /// Power-of-two scaling step s (Definition 4): N → ⌊N/2^s⌋, f → f+s.
    pub scale_step: u32,
    /// Significand target: encode reals with |N| ∈ [2^{sig_bits-1}, 2^{sig_bits}).
    pub sig_bits: u32,
    /// Target clock for the FPGA model, MHz (Table II: 300 MHz).
    pub clock_mhz: f64,
}

impl HrfnaConfig {
    /// The paper's default configuration (§VII-A: parameters fixed across
    /// all workloads).
    pub fn paper_default() -> HrfnaConfig {
        HrfnaConfig {
            moduli: default_moduli(),
            exponent_width: 16,
            // M ≈ 2^127.9; trigger normalization with 16 bits of headroom.
            tau_bits: 112,
            scale_step: 32,
            sig_bits: 30,
            clock_mhz: 300.0,
        }
    }

    /// A reduced-precision preset (design-space exploration).
    pub fn low_precision() -> HrfnaConfig {
        HrfnaConfig {
            moduli: generate_prime_moduli(4, 16),
            exponent_width: 12,
            tau_bits: 48,
            scale_step: 24,
            sig_bits: 18,
            clock_mhz: 300.0,
        }
    }

    /// A stress preset: tight threshold so normalization is frequent
    /// (used by ablation benches).
    pub fn stress_normalization() -> HrfnaConfig {
        HrfnaConfig {
            tau_bits: 72,
            ..HrfnaConfig::paper_default()
        }
    }

    /// An extended-precision preset: twelve 24-bit prime moduli give
    /// M ≈ 2^287 — roughly 2.25× the paper's dynamic range — with a
    /// 48-bit significand target. The `wide` tier of the serving
    /// registry: jobs whose tolerance or magnitude envelope the paper
    /// set cannot cover escalate here (cf. Sentieys & Menard, per-
    /// workload precision customization).
    pub fn wide() -> HrfnaConfig {
        HrfnaConfig {
            moduli: generate_prime_moduli(12, 24),
            exponent_width: 20,
            tau_bits: 240,
            scale_step: 64,
            sig_bits: 48,
            clock_mhz: 300.0,
        }
    }

    /// Look up a preset by name.
    pub fn preset(name: &str) -> Option<HrfnaConfig> {
        match name {
            "paper" | "default" => Some(HrfnaConfig::paper_default()),
            "low-precision" => Some(HrfnaConfig::low_precision()),
            "stress-norm" => Some(HrfnaConfig::stress_normalization()),
            "wide" => Some(HrfnaConfig::wide()),
            _ => None,
        }
    }

    /// Number of residue channels k.
    pub fn k(&self) -> usize {
        self.moduli.len()
    }

    /// log2(M): residue-domain dynamic range in bits.
    pub fn m_bits(&self) -> f64 {
        dynamic_range_bits(&self.moduli)
    }

    /// Validate the invariants Table II implies. Returns a reason on failure.
    pub fn validate(&self) -> Result<(), String> {
        if self.moduli.is_empty() {
            return Err("empty modulus set".into());
        }
        if !is_pairwise_coprime(&self.moduli) {
            return Err("moduli not pairwise coprime".into());
        }
        if self.moduli.iter().any(|&m| !fits_lane_width(m)) {
            return Err(
                "moduli must be in [2, 2^31): the deferred lane kernels form raw 62-bit \
                 residue products (rns::moduli::MAX_LANE_MODULUS_BITS)"
                    .into(),
            );
        }
        let m_bits = self.m_bits();
        if (self.tau_bits as f64) >= m_bits {
            return Err(format!(
                "tau (2^{}) must be < M (2^{m_bits:.1})",
                self.tau_bits
            ));
        }
        if self.scale_step == 0 || self.scale_step as f64 >= m_bits {
            return Err("scale_step must be in (0, log2 M)".into());
        }
        if self.sig_bits + 2 > self.tau_bits {
            return Err("sig_bits must leave headroom below tau".into());
        }
        if !(2..=32).contains(&self.exponent_width) {
            return Err("exponent_width must be in [2, 32]".into());
        }
        Ok(())
    }

    /// Parse overrides from a TOML-subset document (see `TomlDoc`).
    pub fn from_toml(doc: &TomlDoc) -> Result<HrfnaConfig, String> {
        let mut cfg = match doc.get_str("preset") {
            Some(p) => HrfnaConfig::preset(p).ok_or(format!("unknown preset {p}"))?,
            None => HrfnaConfig::paper_default(),
        };
        if let Some(ms) = doc.get_u64_array("moduli") {
            cfg.moduli = ms;
        }
        if let Some(x) = doc.get_u64("exponent_width") {
            cfg.exponent_width = x as u32;
        }
        if let Some(x) = doc.get_u64("tau_bits") {
            cfg.tau_bits = x as u32;
        }
        if let Some(x) = doc.get_u64("scale_step") {
            cfg.scale_step = x as u32;
        }
        if let Some(x) = doc.get_u64("sig_bits") {
            cfg.sig_bits = x as u32;
        }
        if let Some(x) = doc.get_f64("clock_mhz") {
            cfg.clock_mhz = x;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a config file path.
    pub fn from_file(path: &str) -> Result<HrfnaConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let doc = TomlDoc::parse(&text)?;
        HrfnaConfig::from_toml(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_is_valid() {
        let c = HrfnaConfig::paper_default();
        c.validate().unwrap();
        assert_eq!(c.k(), 8);
        assert!(c.m_bits() > 127.0);
    }

    #[test]
    fn all_presets_valid() {
        for name in ["paper", "default", "low-precision", "stress-norm", "wide"] {
            HrfnaConfig::preset(name).unwrap().validate().unwrap();
        }
        assert!(HrfnaConfig::preset("nope").is_none());
    }

    #[test]
    fn wide_preset_extends_dynamic_range_and_significand() {
        let w = HrfnaConfig::wide();
        let p = HrfnaConfig::paper_default();
        assert!(w.m_bits() > 2.0 * p.m_bits(), "wide M must dwarf paper M");
        assert!(w.sig_bits > p.sig_bits);
        assert!(w.tau_bits > p.tau_bits);
        assert_eq!(w.k(), 12);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = HrfnaConfig::paper_default();
        c.moduli = vec![6, 9];
        assert!(c.validate().is_err());

        let mut c = HrfnaConfig::paper_default();
        c.tau_bits = 200;
        assert!(c.validate().is_err());

        let mut c = HrfnaConfig::paper_default();
        c.scale_step = 0;
        assert!(c.validate().is_err());

        let mut c = HrfnaConfig::paper_default();
        c.sig_bits = c.tau_bits;
        assert!(c.validate().is_err());

        // 32-bit moduli break the deferred lane kernels' 62-bit product
        // invariant and must be rejected at config time.
        let mut c = HrfnaConfig::paper_default();
        c.moduli = vec![65521, 4_294_967_291];
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_toml_overrides() {
        let doc = TomlDoc::parse(
            "preset = \"paper\"\ntau_bits = 100\nclock_mhz = 250.0\n",
        )
        .unwrap();
        let c = HrfnaConfig::from_toml(&doc).unwrap();
        assert_eq!(c.tau_bits, 100);
        assert_eq!(c.clock_mhz, 250.0);
        assert_eq!(c.moduli, default_moduli());
    }

    #[test]
    fn from_toml_moduli_array() {
        let doc = TomlDoc::parse("moduli = [3, 5, 7]\ntau_bits = 6\nscale_step = 2\nsig_bits = 4\nexponent_width = 8\n").unwrap();
        let c = HrfnaConfig::from_toml(&doc).unwrap();
        assert_eq!(c.moduli, vec![3, 5, 7]);
    }
}
