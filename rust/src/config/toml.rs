//! Minimal TOML-subset parser: `key = value` lines, `#` comments, string /
//! integer / float / boolean scalars and flat integer arrays. No tables,
//! no nesting — enough for HRFNA config files without a serde dependency.

use std::collections::BTreeMap;

/// A parsed flat TOML-subset document.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

/// Scalar or integer-array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    IntArray(Vec<i64>),
}

impl TomlDoc {
    /// Parse a document; returns a line-tagged error message on failure.
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or(format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() || !key.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '-') {
                return Err(format!("line {}: bad key `{key}`", lineno + 1));
            }
            let val = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            values.insert(key.to_string(), val);
        }
        Ok(TomlDoc { values })
    }

    /// Raw value lookup.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    /// String value.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.values.get(key) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Unsigned integer value (rejects negatives).
    pub fn get_u64(&self, key: &str) -> Option<u64> {
        match self.values.get(key) {
            Some(TomlValue::Int(i)) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    /// Float value (integer values coerce).
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        match self.values.get(key) {
            Some(TomlValue::Float(f)) => Some(*f),
            Some(TomlValue::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    /// Boolean value.
    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.values.get(key) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// Array of non-negative integers.
    pub fn get_u64_array(&self, key: &str) -> Option<Vec<u64>> {
        match self.values.get(key) {
            Some(TomlValue::IntArray(xs)) if xs.iter().all(|&x| x >= 0) => {
                Some(xs.iter().map(|&x| x as u64).collect())
            }
            _ => None,
        }
    }

    /// Number of keys.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True if no keys parsed.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// Remove a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or("unterminated string".to_string())?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or("unterminated array".to_string())?;
        let mut xs = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            xs.push(
                part.parse::<i64>()
                    .map_err(|_| format!("bad array int `{part}`"))?,
            );
        }
        return Ok(TomlValue::IntArray(xs));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let d = TomlDoc::parse(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = false\n",
        )
        .unwrap();
        assert_eq!(d.get_u64("a"), Some(1));
        assert_eq!(d.get_f64("b"), Some(2.5));
        assert_eq!(d.get_str("c"), Some("hi"));
        assert_eq!(d.get_bool("d"), Some(true));
        assert_eq!(d.get_bool("e"), Some(false));
        assert_eq!(d.len(), 5);
    }

    #[test]
    fn parses_arrays_and_comments() {
        let d = TomlDoc::parse(
            "# header\nmoduli = [3, 5, 7] # trailing\nname = \"x # not comment\"\n",
        )
        .unwrap();
        assert_eq!(d.get_u64_array("moduli"), Some(vec![3, 5, 7]));
        assert_eq!(d.get_str("name"), Some("x # not comment"));
    }

    #[test]
    fn int_coerces_to_float() {
        let d = TomlDoc::parse("x = 3\n").unwrap();
        assert_eq!(d.get_f64("x"), Some(3.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("x = \n").is_err());
        assert!(TomlDoc::parse("x = [1, oops]\n").is_err());
        assert!(TomlDoc::parse("bad key = 1\n").is_err());
        assert!(TomlDoc::parse("s = \"unterminated\n").is_err());
    }

    #[test]
    fn negative_ints_rejected_by_u64_getters() {
        let d = TomlDoc::parse("x = -5\narr = [-1, 2]\n").unwrap();
        assert_eq!(d.get_u64("x"), None);
        assert_eq!(d.get_u64_array("arr"), None);
    }
}
