//! The `Hrfna` value type: paper Definitions 1–4, Theorem 1 multiplication,
//! exponent-synchronized addition (§IV-B), MAC (§IV-C) and threshold-driven
//! normalization (§III-C) with the Lemma 1/2 error discipline.
//!
//! Representation invariants:
//! * `r` encodes the signed integer `N` in M-complement (values ≥ M/2 are
//!   negative: `N = CRT(r) - M`).
//! * `iv` conservatively brackets the signed `N` at all times — it is the
//!   paper's interval-evaluation control word (§III-E) and the *only* input
//!   to normalization / overflow-guard decisions. CRT reconstruction happens
//!   exclusively inside normalization events.
//! * `Φ(self) = N · 2^f`.

use super::context::HrfnaContext;
use super::interval::Interval;
use crate::bigint::BigUint;
use crate::rns::residue::ResidueVec;

/// A hybrid residue–floating number `(r, f)` with its magnitude interval.
#[derive(Clone, Debug)]
pub struct Hrfna {
    /// Residue vector for the signed integer N (M-complement).
    pub r: ResidueVec,
    /// Global power-of-two exponent f (Definition 1).
    pub f: i32,
    /// Conservative interval bracketing the signed N.
    pub iv: Interval,
}

impl Hrfna {
    // ------------------------------------------------------------------
    // Construction / conversion
    // ------------------------------------------------------------------

    /// The value 0 (with exponent `f`, relevant for accumulators).
    pub fn zero(ctx: &HrfnaContext, f: i32) -> Hrfna {
        Hrfna {
            r: ResidueVec::zero(ctx.k()),
            f,
            iv: Interval::zero(),
        }
    }

    /// Encode a signed integer at exponent `f` (exact).
    pub fn from_signed_int(n: i64, f: i32, ctx: &HrfnaContext) -> Hrfna {
        let mag = BigUint::from_u64(n.unsigned_abs());
        let mut r = ctx.crt.encode(&mag);
        if n < 0 {
            r = negate_residues(&r, ctx);
        }
        Hrfna {
            r,
            f,
            iv: Interval::point(n as f64),
        }
    }

    /// Encode a real: choose `f` so `|N| ∈ [2^{sig-1}, 2^{sig}]`, then
    /// `N = round(x / 2^f)` (one rounding, relative error ≤ 2^{-sig_bits}).
    pub fn encode(x: f64, ctx: &HrfnaContext) -> Hrfna {
        assert!(x.is_finite(), "cannot encode {x}");
        if x == 0.0 {
            return Hrfna::zero(ctx, 0);
        }
        let sig = ctx.cfg.sig_bits as i32;
        let e = x.abs().log2().floor() as i32;
        let f = e - sig + 1;
        // Staged power-of-two scaling: a single pow2(-f) can overflow for
        // subnormal inputs (|f| > 1023) even though the product is finite.
        let mut scaled = x;
        let mut rem = -f;
        while rem != 0 {
            let step = rem.clamp(-1000, 1000);
            scaled *= pow2(step);
            rem -= step;
        }
        let n = scaled.round() as i64;
        debug_assert!(n.unsigned_abs() <= 1u64 << (sig + 1));
        Hrfna::from_signed_int(n, f, ctx)
    }

    /// Decode to f64: `Φ(r, f) = N · 2^f` via one CRT reconstruction.
    pub fn decode(&self, ctx: &HrfnaContext) -> f64 {
        HrfnaContext::count(&ctx.counters.reconstructions);
        let (neg, mag) = ctx.crt.reconstruct_signed(&self.r);
        signed_mag_to_f64(neg, &mag, self.f)
    }

    /// True iff the value is exactly zero (all residues zero).
    pub fn is_zero(&self) -> bool {
        self.r.is_zero()
    }

    // ------------------------------------------------------------------
    // Arithmetic (Definitions 2, §IV-A/B/C)
    // ------------------------------------------------------------------

    /// Hybrid multiplication (Definition 2 / Theorem 1):
    /// `r_Z = r_X ⊙ r_Y`, `f_Z = f_X + f_Y` — exact, carry-free. Operands
    /// are overflow-guarded via intervals; the result is normalized only if
    /// it crosses the τ threshold.
    pub fn mul(&self, other: &Hrfna, ctx: &HrfnaContext) -> Hrfna {
        let mut z = self.mul_raw(other, ctx);
        z.maybe_normalize(ctx);
        z
    }

    /// Multiplication without the trailing threshold check (used inside
    /// MAC loops that defer normalization to the accumulator, §IV-C).
    ///
    /// §Perf: the common no-guard case multiplies straight from the
    /// borrowed operands — no clones, one output allocation.
    pub fn mul_raw(&self, other: &Hrfna, ctx: &HrfnaContext) -> Hrfna {
        HrfnaContext::count(&ctx.counters.muls);
        let budget = ctx.signed_budget_bits(); // signed headroom below M/2
        if self.iv.bits_hi() + other.iv.bits_hi() < budget {
            return Hrfna {
                r: self.r.mul(&other.r, ctx.barrett()),
                f: self.f + other.f,
                iv: self.iv.mul(&other.iv),
            };
        }
        // Rare: interval says the product could reach M/2 — pre-normalize
        // the oversized operand(s) to the significand target.
        let mut a = self.clone();
        let mut b = other.clone();
        if a.iv.bits_hi() + b.iv.bits_hi() >= budget {
            if a.iv.bits_hi() >= b.iv.bits_hi() {
                a.normalize_to_sig(ctx, true);
            } else {
                b.normalize_to_sig(ctx, true);
            }
        }
        if a.iv.bits_hi() + b.iv.bits_hi() >= budget {
            // Both huge: shrink the other one too.
            if a.iv.bits_hi() >= b.iv.bits_hi() {
                a.normalize_to_sig(ctx, true);
            } else {
                b.normalize_to_sig(ctx, true);
            }
        }
        Hrfna {
            r: a.r.mul(&b.r, ctx.barrett()),
            f: a.f + b.f,
            iv: a.iv.mul(&b.iv),
        }
    }

    /// Hybrid addition with explicit exponent synchronization (§IV-B).
    pub fn add(&self, other: &Hrfna, ctx: &HrfnaContext) -> Hrfna {
        let (a, b) = sync_exponents(self, other, ctx);
        HrfnaContext::count(&ctx.counters.adds);
        let mut z = Hrfna {
            r: a.r.add(&b.r, ctx.barrett()),
            f: a.f,
            iv: a.iv.add(&b.iv),
        };
        z.maybe_normalize(ctx);
        z
    }

    /// Negation: channelwise M-complement (exact, carry-free).
    pub fn neg(&self, ctx: &HrfnaContext) -> Hrfna {
        Hrfna {
            r: negate_residues(&self.r, ctx),
            f: self.f,
            iv: self.iv.neg(),
        }
    }

    /// Subtraction: `self + (-other)`.
    pub fn sub(&self, other: &Hrfna, ctx: &HrfnaContext) -> Hrfna {
        self.add(&other.neg(ctx), ctx)
    }

    /// Fused multiply-accumulate into `self` (Alg. 1 inner loop /
    /// §VI-C "accumulator modes"): the accumulator's exponent is *held*
    /// and each incoming product is aligned to it, so the accumulator
    /// grows only through carry-free residue adds; threshold
    /// normalization advances the exponent by the fixed scale step
    /// (Definition 4), never re-expanding — the exponent-coherent
    /// accumulation that keeps normalization rare (§VII-E).
    pub fn mac_assign(&mut self, x: &Hrfna, y: &Hrfna, ctx: &HrfnaContext) {
        let p = x.mul_raw(y, ctx);
        if p.is_zero() {
            return;
        }
        if self.is_zero() {
            // Alg. 1 step 1: f0 matches the initial operands.
            *self = p;
            self.maybe_normalize_acc(ctx);
            return;
        }
        HrfnaContext::count(&ctx.counters.adds);
        let budget = ctx.signed_budget_bits();
        let bars = ctx.barrett();
        if p.f == self.f {
            // §Perf fast path: exponent-coherent product — accumulate in
            // place, zero clones (the common case under Alg. 1).
            for i in 0..self.r.r.len() {
                self.r.r[i] = bars[i].add(self.r.r[i], p.r.r[i]);
            }
            self.iv = self.iv.add(&p.iv);
        } else if p.f > self.f && p.iv.bits_hi() + (p.f - self.f) as u32 + 1 < budget {
            // §Perf fused sync: scale the product by 2^Δ (exact, table
            // lookup) *inside* the accumulate loop — no temporaries.
            HrfnaContext::count(&ctx.counters.syncs);
            let delta = (p.f - self.f) as u32;
            for i in 0..self.r.r.len() {
                let scaled = bars[i].mul(p.r.r[i], ctx.pow2_mod(i, delta));
                self.r.r[i] = bars[i].add(self.r.r[i], scaled);
            }
            self.iv = self.iv.add(&p.iv.shl(delta));
        } else if p.f < self.f && self.iv.bits_hi() + (self.f - p.f) as u32 + 1 < budget {
            // Fused expansion of the accumulator down to the product's
            // exponent (exact; §III-D exactness between normalizations).
            HrfnaContext::count(&ctx.counters.syncs);
            let delta = (self.f - p.f) as u32;
            for i in 0..self.r.r.len() {
                let scaled = bars[i].mul(self.r.r[i], ctx.pow2_mod(i, delta));
                self.r.r[i] = bars[i].add(scaled, p.r.r[i]);
            }
            self.f = p.f;
            self.iv = self.iv.shl(delta).add(&p.iv);
        } else {
            // Rare: headroom exhausted — general synchronization (lossy
            // Lemma-1-bounded path inside).
            let (a, b) = sync_exponents(self, &p, ctx);
            *self = Hrfna {
                r: a.r.add(&b.r, ctx.barrett()),
                f: a.f,
                iv: a.iv.add(&b.iv),
            };
        }
        self.maybe_normalize_acc(ctx);
    }

    /// Accumulator-mode threshold check: fixed-step normalization
    /// (Definition 4 with s = scale_step), repeated if necessary.
    fn maybe_normalize_acc(&mut self, ctx: &HrfnaContext) {
        let tau = ctx.tau_f64();
        while self.iv.abs_hi() >= tau {
            self.normalize(ctx.cfg.scale_step, ctx, false);
        }
    }

    /// Re-express this value at exponent `target` (value-preserving up to
    /// the Lemma-1 rounding of a downward alignment):
    /// * `f > target` — exact residue-domain scaling by 2^Δ (guarded);
    /// * `f < target` — controlled normalization by Δ (rounds low bits).
    pub fn align_to(&self, target: i32, ctx: &HrfnaContext) -> Hrfna {
        if self.f == target {
            return self.clone();
        }
        HrfnaContext::count(&ctx.counters.syncs);
        if self.f > target {
            let mut v = self.clone();
            let budget = ctx.signed_budget_bits();
            if v.iv.bits_hi() + (v.f - target) as u32 + 1 >= budget {
                // Cannot expand exactly: reduce significance first (the
                // guard raises v.f, shrinking the required expansion).
                v.normalize_to_sig(ctx, true);
            }
            if v.f < target {
                let mut w = v;
                w.normalize((target - w.f) as u32, ctx, false);
                return w;
            }
            let delta = (v.f - target) as u32;
            Hrfna {
                r: scale_residues_pow2(&v.r, delta, ctx),
                f: target,
                iv: v.iv.shl(delta),
            }
        } else {
            let mut v = self.clone();
            v.normalize((target - v.f) as u32, ctx, false);
            v
        }
    }

    // ------------------------------------------------------------------
    // Normalization (Definitions 3–4, §VI-E engine)
    // ------------------------------------------------------------------

    /// Threshold check (Definition 3): normalize when the conservative
    /// magnitude bound reaches τ = 2^tau_bits.
    pub fn maybe_normalize(&mut self, ctx: &HrfnaContext) {
        if self.iv.abs_hi() >= ctx.tau_f64() {
            self.normalize_to_sig(ctx, false);
        }
    }

    /// Normalize with an explicit scale step `s` (Definition 4):
    /// `N → round(N / 2^s)` (round-half-away-from-zero, so the Lemma 1
    /// half-unit bound holds), `f → f + s`, re-encode residues. Delegates
    /// to the engine's single rescale primitive ([`super::norm::rescale`])
    /// — the one place in the system that performs
    /// reconstruct → shift → re-encode → interval update.
    pub fn normalize(&mut self, s: u32, ctx: &HrfnaContext, guard: bool) {
        super::norm::rescale(self, s, ctx, guard);
    }

    /// Normalize so the magnitude returns to the significand target:
    /// `s = bits − sig_bits` exactly. (Rounding `s` up to a multiple of
    /// `scale_step` would leave as little as `sig − step + 1` bits of
    /// significance after the event and destroy precision; the paper's
    /// fixed-step Definition 4 is available via [`Hrfna::normalize`], and
    /// `scale_step` parameterizes the hardware shifter granularity in the
    /// FPGA model.)
    pub fn normalize_to_sig(&mut self, ctx: &HrfnaContext, guard: bool) {
        let bits = self.iv.bits_hi();
        let sig = ctx.cfg.sig_bits;
        if bits <= sig {
            return;
        }
        self.normalize(bits - sig, ctx, guard);
    }

    // ------------------------------------------------------------------
    // Introspection
    // ------------------------------------------------------------------

    /// Conservative magnitude-bits estimate from the interval.
    pub fn magnitude_bits(&self) -> u32 {
        self.iv.bits_hi()
    }

    /// Exact signed reconstruction (costly; test/verification path).
    pub fn reconstruct_signed(&self, ctx: &HrfnaContext) -> (bool, BigUint) {
        ctx.crt.reconstruct_signed(&self.r)
    }

    /// Verify the interval invariant against an exact reconstruction.
    /// Test helper: returns false if the interval fails to bracket N.
    pub fn interval_is_sound(&self, ctx: &HrfnaContext) -> bool {
        let (neg, mag) = ctx.crt.reconstruct_signed(&self.r);
        let v = mag.to_f64();
        let n = if neg { -v } else { v };
        // Allow the to_f64 truncation slack on the exact value itself.
        let slack = n.abs() * 1e-12 + 1e-9;
        self.iv.lo - slack <= n && n <= self.iv.hi + slack
    }
}

/// The shared decode tail: apply the M-complement sign and the exponent
/// to a reconstructed magnitude, `±mag · 2^f`. Every decode path — the
/// scalar [`Hrfna::decode`] and the batched-CRT consumers — goes through
/// this one function so the conventions can never desynchronize.
#[inline]
pub fn signed_mag_to_f64(neg: bool, mag: &BigUint, f: i32) -> f64 {
    let v = ldexp_staged(mag.to_f64(), f);
    if neg {
        -v
    } else {
        v
    }
}

/// `v · 2^e` with staged scaling so intermediate powers never saturate
/// even when `v`'s own exponent compensates for an extreme `e`.
#[inline]
pub fn ldexp_staged(v: f64, e: i32) -> f64 {
    let mut out = v;
    let mut rem = e;
    while rem != 0 && out != 0.0 && out.is_finite() {
        let step = rem.clamp(-500, 500);
        out *= pow2(step);
        rem -= step;
    }
    out
}

/// `2^e` as f64 (exact for normal range; clamps to 0/∞ beyond f64's range).
#[inline]
pub fn pow2(e: i32) -> f64 {
    if e >= 1024 {
        f64::INFINITY
    } else if e <= -1074 {
        0.0
    } else {
        2f64.powi(e)
    }
}

/// Channelwise M-complement negation: `r_i → (m_i - r_i) mod m_i`.
fn negate_residues(r: &ResidueVec, ctx: &HrfnaContext) -> ResidueVec {
    ResidueVec {
        r: r.r
            .iter()
            .zip(&ctx.cfg.moduli)
            .map(|(&ri, &mi)| if ri == 0 { 0 } else { mi - ri })
            .collect(),
    }
}

/// Exponent synchronization (§IV-B). Returns value-equal operands with a
/// common exponent. Prefers the *exact* direction (scaling the
/// higher-exponent operand's residues up by 2^Δ, which is carry-free and
/// lossless) when interval headroom allows; falls back to controlled
/// normalization of the lower-exponent operand otherwise.
fn sync_exponents(x: &Hrfna, y: &Hrfna, ctx: &HrfnaContext) -> (Hrfna, Hrfna) {
    if x.f == y.f {
        return (x.clone(), y.clone());
    }
    HrfnaContext::count(&ctx.counters.syncs);
    // Identify hi = operand with larger exponent.
    let (hi, lo) = if x.f > y.f { (x, y) } else { (y, x) };
    let delta = (hi.f - lo.f) as u32;
    let budget = ctx.signed_budget_bits();

    // Exact path: N_hi · 2^Δ at exponent f_lo.
    if hi.iv.bits_hi() + delta + 1 < budget {
        let scaled = scale_residues_pow2(&hi.r, delta, ctx);
        let hi2 = Hrfna {
            r: scaled,
            f: lo.f,
            iv: hi.iv.shl(delta),
        };
        return if x.f > y.f {
            (hi2, lo.clone())
        } else {
            (lo.clone(), hi2)
        };
    }

    // Lossy path: controlled normalization of the lower-exponent operand
    // by exactly Δ (rounds to zero when Δ exceeds its magnitude — the hi
    // operand cannot resolve it anyway). Error bounded by Lemma 1.
    let mut lo2 = lo.clone();
    lo2.normalize(delta, ctx, false);
    debug_assert_eq!(lo2.f, hi.f);
    if x.f > y.f {
        (hi.clone(), lo2)
    } else {
        (lo2, hi.clone())
    }
}

/// Residue-domain multiplication by 2^Δ (per channel: `r_i · 2^Δ mod m_i`;
/// §Perf: 2^Δ mod m comes from the context's precomputed table).
fn scale_residues_pow2(r: &ResidueVec, delta: u32, ctx: &HrfnaContext) -> ResidueVec {
    ResidueVec {
        r: r.r
            .iter()
            .zip(ctx.barrett())
            .enumerate()
            .map(|(ch, (&ri, bar))| bar.mul(ri, ctx.pow2_mod(ch, delta)))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, check_with};

    fn ctx() -> HrfnaContext {
        HrfnaContext::paper_default()
    }

    #[test]
    fn encode_decode_roundtrip_precision() {
        let c = ctx();
        for x in [1.0, -1.0, 3.14159, -2.5e10, 7.7e-12, 65521.5, 1e30, -1e-30] {
            let h = Hrfna::encode(x, &c);
            let back = h.decode(&c);
            let rel = ((back - x) / x).abs();
            assert!(rel <= 2f64.powi(-(c.cfg.sig_bits as i32) + 1), "x={x} rel={rel}");
            assert!(h.interval_is_sound(&c));
        }
    }

    #[test]
    fn zero_encoding() {
        let c = ctx();
        let z = Hrfna::encode(0.0, &c);
        assert!(z.is_zero());
        assert_eq!(z.decode(&c), 0.0);
    }

    #[test]
    fn theorem1_multiplication_exact() {
        // Φ(X⊗Y) == Φ(X)·Φ(Y) exactly (integer-exact, checked via BigUint).
        let c = ctx();
        let x = Hrfna::from_signed_int(123_456_789, -10, &c);
        let y = Hrfna::from_signed_int(-987_654_321, 4, &c);
        let z = x.mul(&y, &c);
        let (neg, mag) = z.reconstruct_signed(&c);
        assert!(neg);
        assert_eq!(
            mag.to_u128(),
            Some(123_456_789u128 * 987_654_321u128)
        );
        assert_eq!(z.f, -6);
    }

    #[test]
    fn multiplication_signs() {
        let c = ctx();
        for (a, b) in [(3.0, 4.0), (-3.0, 4.0), (3.0, -4.0), (-3.0, -4.0)] {
            let z = Hrfna::encode(a, &c).mul(&Hrfna::encode(b, &c), &c);
            let got = z.decode(&c);
            assert!(((got - a * b) / (a * b)).abs() < 1e-8, "a={a} b={b} got={got}");
        }
    }

    #[test]
    fn addition_same_exponent_exact() {
        let c = ctx();
        let x = Hrfna::from_signed_int(1000, 3, &c);
        let y = Hrfna::from_signed_int(-400, 3, &c);
        let z = x.add(&y, &c);
        assert_eq!(z.f, 3);
        let (neg, mag) = z.reconstruct_signed(&c);
        assert!(!neg);
        assert_eq!(mag.to_u64(), Some(600));
    }

    #[test]
    fn addition_exponent_sync_exact_path() {
        let c = ctx();
        // 3·2^5 + 5·2^0 = 101: exact because sync multiplies 3 by 2^5.
        let x = Hrfna::from_signed_int(3, 5, &c);
        let y = Hrfna::from_signed_int(5, 0, &c);
        let z = x.add(&y, &c);
        assert_eq!(z.decode(&c), 101.0);
        assert_eq!(c.snapshot().syncs, 1);
    }

    #[test]
    fn subtraction_and_negation() {
        let c = ctx();
        let x = Hrfna::encode(10.5, &c);
        let y = Hrfna::encode(4.25, &c);
        let d = x.sub(&y, &c).decode(&c);
        assert!((d - 6.25).abs() < 1e-7, "d={d}");
        let n = x.neg(&c).decode(&c);
        assert!((n + 10.5).abs() < 1e-7);
    }

    #[test]
    fn normalization_triggers_at_threshold() {
        let c = HrfnaContext::new(crate::config::HrfnaConfig {
            tau_bits: 40,
            ..crate::config::HrfnaConfig::paper_default()
        });
        // Build a value with ~60 bits by repeated multiplication.
        let mut v = Hrfna::from_signed_int(1 << 20, 0, &c);
        let m = Hrfna::from_signed_int(1 << 20, 0, &c);
        let before = c.snapshot().norms;
        let v2 = v.mul(&m, &c); // 40 bits -> hits tau
        v = v2.mul(&m, &c);
        assert!(c.snapshot().norms > before, "normalization should trigger");
        assert!(v.magnitude_bits() <= c.cfg.sig_bits + c.cfg.scale_step);
        assert!(v.interval_is_sound(&c));
    }

    #[test]
    fn normalization_error_within_lemma1() {
        let c = ctx();
        let mut v = Hrfna::from_signed_int(0x7FFF_FFFF_FFFF, -20, &c); // 47 bits
        let before = v.decode(&c);
        let s = 16;
        v.normalize(s, &c, false);
        let after = v.decode(&c);
        // Lemma 1: |ε| ≤ 2^{f_old + s - 1}; f_old = -20.
        let bound = pow2(-20 + s as i32 - 1);
        assert!((after - before).abs() <= bound, "err={} bound={bound}", (after - before).abs());
    }

    #[test]
    fn mac_long_chain_matches_f64() {
        let c = ctx();
        let mut rng = crate::util::prng::Rng::new(99);
        let mut acc = Hrfna::zero(&c, 0);
        let mut truth = 0.0f64;
        for _ in 0..2000 {
            let a = rng.uniform(-100.0, 100.0);
            let b = rng.uniform(-100.0, 100.0);
            let ha = Hrfna::encode(a, &c);
            let hb = Hrfna::encode(b, &c);
            acc.mac_assign(&ha, &hb, &c);
            truth += (ha.decode(&c)) * (hb.decode(&c));
        }
        let got = acc.decode(&c);
        let rel = ((got - truth) / truth.abs().max(1e-30)).abs();
        assert!(rel < 1e-6, "got={got} truth={truth} rel={rel}");
        assert!(acc.interval_is_sound(&c));
    }

    #[test]
    fn overflow_guard_keeps_values_in_range() {
        let c = ctx();
        // Build two ~90-bit operands via raw (unnormalized) products; their
        // product would exceed the signed range, so the mul guard must fire.
        let a = Hrfna::encode(1e9, &c); // ~30 bits
        let b = a.mul_raw(&a, &c); // ~60 bits
        let big = b.mul_raw(&a, &c); // ~90 bits, below tau? (tau=112) yes
        assert!(big.magnitude_bits() > 80);
        let before = c.snapshot().guard_norms;
        let z = big.mul(&big.clone(), &c);
        assert!(c.snapshot().guard_norms > before, "guard should fire");
        assert!(z.interval_is_sound(&c));
        let (_, mag) = z.reconstruct_signed(&c);
        assert!(mag < c.half_m, "magnitude escaped signed range");
        // And the value is still numerically right: (1e9^3)^2 = 1e54.
        let got = z.decode(&c);
        let want = 1e54;
        assert!(((got - want) / want).abs() < 1e-6, "got={got}");
    }

    #[test]
    fn repeated_squaring_stays_sound() {
        let c = ctx();
        let mut v = Hrfna::encode(1.5e20, &c);
        for _ in 0..12 {
            v = v.mul(&v.clone(), &c);
            assert!(v.interval_is_sound(&c), "interval unsound");
            let (_, mag) = v.reconstruct_signed(&c);
            assert!(mag < c.half_m, "magnitude escaped signed range");
        }
    }

    #[test]
    fn prop_mul_matches_f64_reference() {
        let c = ctx();
        check("hrfna-mul-f64", |rng| {
            let a = rng.sign() * rng.lognormal(0.0, 20.0);
            let b = rng.sign() * rng.lognormal(0.0, 20.0);
            let z = Hrfna::encode(a, &c).mul(&Hrfna::encode(b, &c), &c);
            let got = z.decode(&c);
            let want = a * b;
            let rel = ((got - want) / want).abs();
            crate::prop_assert!(rel < 1e-7, "a={a} b={b} got={got} rel={rel}");
            Ok(())
        });
    }

    #[test]
    fn prop_add_matches_f64_reference() {
        let c = ctx();
        check("hrfna-add-f64", |rng| {
            let a = rng.sign() * rng.lognormal(0.0, 8.0);
            let b = rng.sign() * rng.lognormal(0.0, 8.0);
            let z = Hrfna::encode(a, &c).add(&Hrfna::encode(b, &c), &c);
            let got = z.decode(&c);
            let want = a + b;
            let tol = 1e-7 * (a.abs() + b.abs()).max(1e-300);
            crate::prop_assert!(
                (got - want).abs() <= tol,
                "a={a} b={b} got={got} want={want}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_interval_always_sound_under_random_ops() {
        let c = ctx();
        check_with("hrfna-interval-sound", 64, |rng| {
            let mut v = Hrfna::encode(rng.uniform(-1e6, 1e6), &c);
            for _ in 0..30 {
                let w = Hrfna::encode(rng.sign() * rng.lognormal(0.0, 10.0), &c);
                v = match rng.below(3) {
                    0 => v.mul(&w, &c),
                    1 => v.add(&w, &c),
                    _ => v.sub(&w, &c),
                };
                crate::prop_assert!(v.interval_is_sound(&c), "unsound interval");
            }
            Ok(())
        });
    }

    #[test]
    fn pow2_values() {
        assert_eq!(pow2(0), 1.0);
        assert_eq!(pow2(10), 1024.0);
        assert_eq!(pow2(-1), 0.5);
        assert_eq!(pow2(1024), f64::INFINITY);
        assert_eq!(pow2(-1074), 0.0);
        assert!((pow2(-1030) - 2f64.powi(-1030)).abs() < 1e-320);
    }
}
