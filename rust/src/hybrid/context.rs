//! Shared HRFNA context: configuration, precomputed CRT state, the
//! normalization threshold, and lock-free operation counters.
//!
//! The context is the software analogue of the synthesized parameter set in
//! paper Table II: modulus set, exponent width, threshold τ, scaling step s.
//! Counters mirror the event monitors a real deployment would expose
//! (§VII-E measures normalization frequency with exactly these).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::bigint::BigUint;
use crate::config::HrfnaConfig;
use crate::rns::{Barrett, CrtContext};

/// Lock-free event counters (relaxed; they are metrics, not synchronization).
#[derive(Debug, Default)]
pub struct OpCounters {
    /// Hybrid multiplications (Definition 2).
    pub muls: AtomicU64,
    /// Residue-domain additions (post-synchronization).
    pub adds: AtomicU64,
    /// Exponent synchronization events (§IV-B) that required scaling.
    pub syncs: AtomicU64,
    /// Threshold-triggered normalization events (Definition 4).
    pub norms: AtomicU64,
    /// Full CRT reconstructions (each normalization plus explicit decodes).
    pub reconstructions: AtomicU64,
    /// Pre-multiplication guard normalizations (overflow headroom, §III-C).
    pub guard_norms: AtomicU64,
}

/// A plain-data snapshot of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpSnapshot {
    pub muls: u64,
    pub adds: u64,
    pub syncs: u64,
    pub norms: u64,
    pub reconstructions: u64,
    pub guard_norms: u64,
}

impl OpSnapshot {
    /// Total arithmetic operations (muls + adds).
    pub fn arithmetic_ops(&self) -> u64 {
        self.muls + self.adds
    }

    /// Normalization events per arithmetic operation (§VII-E metric).
    pub fn norm_rate(&self) -> f64 {
        let ops = self.arithmetic_ops();
        if ops == 0 {
            0.0
        } else {
            (self.norms + self.guard_norms) as f64 / ops as f64
        }
    }

    /// Difference of two snapshots (self - earlier).
    pub fn since(&self, earlier: &OpSnapshot) -> OpSnapshot {
        OpSnapshot {
            muls: self.muls - earlier.muls,
            adds: self.adds - earlier.adds,
            syncs: self.syncs - earlier.syncs,
            norms: self.norms - earlier.norms,
            reconstructions: self.reconstructions - earlier.reconstructions,
            guard_norms: self.guard_norms - earlier.guard_norms,
        }
    }
}

/// Shared immutable HRFNA state + counters. Create once, pass by reference.
#[derive(Debug)]
pub struct HrfnaContext {
    pub cfg: HrfnaConfig,
    pub crt: CrtContext,
    /// Normalization threshold τ = 2^tau_bits (Definition 3: τ < M).
    pub tau: BigUint,
    /// M/2 — boundary of the signed (M-complement) value range.
    pub half_m: BigUint,
    /// log2(M), cached.
    pub m_bits: f64,
    /// §Perf: per-channel table of `2^d mod m_i` for d < POW2_TABLE_LEN —
    /// exponent synchronization scales residues by 2^Δ on every mismatch,
    /// and a table lookup replaces a per-channel pow_mod ladder.
    pow2_table: Vec<Vec<u64>>,
    pub counters: OpCounters,
}

/// Table depth: Δ beyond this falls back to pow_mod (Δ is bounded by the
/// exponent spread, ~2·1100 for f64-ranged encodes; 4096 covers all of it).
const POW2_TABLE_LEN: usize = 4096;

impl HrfnaContext {
    /// Build a context from a validated config (panics on invalid config —
    /// construction is setup-time, not request-path).
    pub fn new(cfg: HrfnaConfig) -> HrfnaContext {
        cfg.validate().expect("invalid HrfnaConfig");
        let crt = CrtContext::new(&cfg.moduli);
        let tau = BigUint::one().shl(cfg.tau_bits);
        let half_m = crt.big_m.shr(1);
        assert!(tau < crt.big_m, "Definition 3 requires tau < M");
        let m_bits = cfg.m_bits();
        let pow2_table = cfg
            .moduli
            .iter()
            .map(|&m| {
                let mut row = Vec::with_capacity(POW2_TABLE_LEN);
                let mut v = 1u64 % m;
                for _ in 0..POW2_TABLE_LEN {
                    row.push(v);
                    v = (v * 2) % m;
                }
                row
            })
            .collect();
        HrfnaContext {
            cfg,
            crt,
            tau,
            half_m,
            m_bits,
            pow2_table,
            counters: OpCounters::default(),
        }
    }

    /// `2^delta mod m_i` (table lookup; pow_mod fallback beyond the table).
    #[inline]
    pub fn pow2_mod(&self, channel: usize, delta: u32) -> u64 {
        match self.pow2_table[channel].get(delta as usize) {
            Some(&v) => v,
            None => crate::rns::moduli::pow_mod(2, delta as u64, self.cfg.moduli[channel]),
        }
    }

    /// Context with the paper's default parameters.
    pub fn paper_default() -> HrfnaContext {
        HrfnaContext::new(HrfnaConfig::paper_default())
    }

    /// Barrett contexts for the channelwise ops.
    #[inline]
    pub fn barrett(&self) -> &[Barrett] {
        &self.crt.barrett
    }

    /// Signed headroom budget in bits for the overflow guards: operands
    /// are kept below `2^budget < M/2`. The scalar ops and the batched
    /// planar engine share this single definition — the batch fast paths'
    /// bit-identity with the scalar reference depends on it.
    #[inline]
    pub fn signed_budget_bits(&self) -> u32 {
        (self.m_bits - 2.0) as u32
    }

    /// Normalization threshold τ as f64 (the Definition 3 comparison
    /// value used by `maybe_normalize` and the batched threshold scans).
    #[inline]
    pub fn tau_f64(&self) -> f64 {
        super::number::pow2(self.cfg.tau_bits as i32)
    }

    /// Number of residue channels.
    #[inline]
    pub fn k(&self) -> usize {
        self.cfg.moduli.len()
    }

    /// Snapshot the counters.
    pub fn snapshot(&self) -> OpSnapshot {
        let c = &self.counters;
        OpSnapshot {
            muls: c.muls.load(Ordering::Relaxed),
            adds: c.adds.load(Ordering::Relaxed),
            syncs: c.syncs.load(Ordering::Relaxed),
            norms: c.norms.load(Ordering::Relaxed),
            reconstructions: c.reconstructions.load(Ordering::Relaxed),
            guard_norms: c.guard_norms.load(Ordering::Relaxed),
        }
    }

    /// Reset all counters (benchmark setup).
    pub fn reset_counters(&self) {
        let c = &self.counters;
        for a in [
            &c.muls,
            &c.adds,
            &c.syncs,
            &c.norms,
            &c.reconstructions,
            &c.guard_norms,
        ] {
            a.store(0, Ordering::Relaxed);
        }
    }

    #[inline]
    pub(crate) fn count(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_context() {
        let ctx = HrfnaContext::paper_default();
        assert_eq!(ctx.k(), 8);
        assert!(ctx.tau < ctx.crt.big_m);
        assert!(ctx.half_m < ctx.crt.big_m);
        assert!(ctx.m_bits > 127.0);
    }

    #[test]
    fn counters_snapshot_and_reset() {
        let ctx = HrfnaContext::paper_default();
        HrfnaContext::count(&ctx.counters.muls);
        HrfnaContext::count(&ctx.counters.muls);
        HrfnaContext::count(&ctx.counters.norms);
        let s = ctx.snapshot();
        assert_eq!(s.muls, 2);
        assert_eq!(s.norms, 1);
        assert_eq!(s.arithmetic_ops(), 2);
        assert!(s.norm_rate() > 0.0);
        ctx.reset_counters();
        assert_eq!(ctx.snapshot(), OpSnapshot::default());
    }

    #[test]
    fn snapshot_since() {
        let ctx = HrfnaContext::paper_default();
        let before = ctx.snapshot();
        HrfnaContext::count(&ctx.counters.adds);
        let after = ctx.snapshot();
        assert_eq!(after.since(&before).adds, 1);
    }

    #[test]
    #[should_panic(expected = "invalid HrfnaConfig")]
    fn invalid_config_panics() {
        let mut cfg = HrfnaConfig::paper_default();
        cfg.moduli = vec![4, 6];
        HrfnaContext::new(cfg);
    }
}
