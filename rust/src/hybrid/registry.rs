//! Precision-tier context registry: the serving stack's named, lazily
//! constructed `HrfnaContext` instances plus the bound-driven escalation
//! policy that picks the cheapest tier whose formal error budget covers a
//! request.
//!
//! The paper defines HRFNA over a *parameterized* hybrid space (Table II:
//! modulus set, exponent width ω_f, threshold τ, scaling step s) and
//! proves its error bounds per parameter set — nothing in the format
//! forces one global configuration. Related work makes precision a
//! per-workload knob (Sentieys & Menard; de Fine Licht et al.), and a
//! multi-tenant deployment needs the same: this module exposes a fixed
//! set of **tiers** ([`Tier::Lo`] = `low_precision`, [`Tier::Paper`] =
//! `paper_default`, [`Tier::Wide`] = the extended `wide` preset), each
//! backed by one immutable [`HrfnaContext`] built exactly once on first
//! use (`OnceLock` per slot) with its own [`super::context::OpCounters`].
//!
//! ## Escalation (§III-D bounds, applied at admission)
//!
//! Before any encoding happens, [`ContextRegistry::resolve`] checks a
//! job's [`MagnitudeEnvelope`] and optional relative-error tolerance
//! against each tier's *static* configuration (no context construction
//! on this path):
//!
//! 1. **Legal-interval overflow** — the block-encoded exponent of the
//!    job's extreme magnitude (and its products) must fit ±(2^{ω_f−1}−1),
//!    and the exact residue accumulation `terms · 2^{2·sig}` must stay
//!    inside the tier's signed budget `2^{m_bits−2} < M/2`.
//! 2. **Bound above tolerance** — the tier's a-priori relative budget
//!    (encode quantization plus [`composed_rel_bound`] over the
//!    envelope's normalization-event estimate) must not exceed the job's
//!    tolerance.
//!
//! A tier that fails either check is skipped and the next tier is tried
//! (`lo → paper → wide`); the coordinator counts every bump in its
//! per-tier metrics. The `paper` tier is bit-identical to the historical
//! single-context serving path (pinned by test below).

use std::sync::{Arc, OnceLock};

use super::context::HrfnaContext;
use super::error::composed_rel_bound;
use super::number::pow2;
use crate::config::HrfnaConfig;

/// A named precision tier of the serving registry, cheapest first.
/// The derived order (`Lo < Paper < Wide`) is the escalation order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tier {
    /// `HrfnaConfig::low_precision`: k=4 16-bit lanes, 18-bit significand.
    Lo,
    /// `HrfnaConfig::paper_default`: the Table II parameter set.
    Paper,
    /// `HrfnaConfig::wide`: k=12 24-bit lanes, 48-bit significand.
    Wide,
}

impl Tier {
    /// Every tier, escalation order.
    pub const ALL: [Tier; 3] = [Tier::Lo, Tier::Paper, Tier::Wide];

    /// Stable slot index.
    #[inline]
    pub fn index(self) -> usize {
        match self {
            Tier::Lo => 0,
            Tier::Paper => 1,
            Tier::Wide => 2,
        }
    }

    /// Table/record label.
    pub fn label(self) -> &'static str {
        match self {
            Tier::Lo => "lo",
            Tier::Paper => "paper",
            Tier::Wide => "wide",
        }
    }

    /// Parse a label produced by [`Tier::label`].
    pub fn from_label(s: &str) -> Option<Tier> {
        Tier::ALL.iter().copied().find(|t| t.label() == s)
    }

    /// The next tier up, `None` at the top.
    pub fn next(self) -> Option<Tier> {
        match self {
            Tier::Lo => Some(Tier::Paper),
            Tier::Paper => Some(Tier::Wide),
            Tier::Wide => None,
        }
    }

    /// The tier's preset configuration.
    pub fn config(self) -> HrfnaConfig {
        match self {
            Tier::Lo => HrfnaConfig::low_precision(),
            Tier::Paper => HrfnaConfig::paper_default(),
            Tier::Wide => HrfnaConfig::wide(),
        }
    }
}

/// Magnitude envelope of one request — everything the escalation policy
/// needs to know about the payload *before* encoding it.
#[derive(Clone, Copy, Debug)]
pub struct MagnitudeEnvelope {
    /// Largest operand magnitude (0.0 for an all-zero payload).
    pub max_abs: f64,
    /// Longest exact residue accumulation the job performs (dot length,
    /// matmul inner dimension, field-evaluation chain for ODE steps).
    pub terms: u64,
    /// A-priori estimate of threshold/guard normalization events the job
    /// may take (0 for the zero-mid-loop-rounding planar kernels; one
    /// per step for iterative workloads — coarse by design, it prices
    /// the Lemma 2 budget, it does not predict the measured count).
    pub norm_events: u64,
}

impl MagnitudeEnvelope {
    /// Envelope over a set of operand slices.
    pub fn of_slices(slices: &[&[f64]], terms: u64, norm_events: u64) -> MagnitudeEnvelope {
        let max_abs = slices
            .iter()
            .flat_map(|s| s.iter())
            .fold(0.0f64, |a, &x| a.max(x.abs()));
        MagnitudeEnvelope { max_abs, terms, norm_events }
    }
}

/// Why a tier was skipped during resolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EscalateReason {
    /// Block/product exponents fall outside ±(2^{ω_f−1}−1).
    ExponentRange,
    /// `terms · 2^{2·sig}` exceeds the signed budget `2^{m_bits−2}`.
    AccumulatorOverflow,
    /// The tier's composed relative budget exceeds the job's tolerance.
    BoundAboveTolerance,
    /// Authenticated (MAC-carrying) jobs need the odd-moduli fast path
    /// and one extra guard bit over the plain accumulator budget: a MAC
    /// only misses a corruption that is an exact multiple of M, so the
    /// admission bound must keep authenticated accumulations one bit
    /// further from the mod-M wraparound blind spot.
    MacBudget,
}

/// Outcome of tier resolution for one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Resolution {
    /// The tier the job will run on.
    pub tier: Tier,
    /// How many tiers the request was bumped past its requested tier.
    pub escalations: u32,
    /// False iff even the top tier failed a coverage check (the job
    /// still runs there, best effort — the caller decides whether a
    /// saturated resolution is acceptable).
    pub covered: bool,
    /// The check the *requested* tier failed (None when it covered).
    pub reason: Option<EscalateReason>,
}

/// `ceil(log2(n))` for `n ≥ 1` (0 for 0 and 1).
fn ceil_log2(n: u64) -> u32 {
    n.max(1).next_power_of_two().trailing_zeros()
}

/// A tier's a-priori relative-error budget for an envelope: RMS-model
/// encode quantization (`√terms · 2^{1−sig}`) plus the composed Lemma 2
/// budget over the envelope's normalization-event estimate.
pub fn tier_rel_bound(cfg: &HrfnaConfig, env: &MagnitudeEnvelope) -> f64 {
    let quant = (env.terms.max(1) as f64).sqrt() * pow2(1 - cfg.sig_bits as i32);
    quant + composed_rel_bound(env.norm_events, cfg.scale_step, cfg.tau_bits)
}

/// Check one tier configuration against an envelope and tolerance.
///
/// `authenticated` jobs additionally require the MAC budget: every
/// modulus odd (the SPDZ-style MAC lanes rescale through the
/// residue-domain fast path, which needs 2 invertible mod every m_i)
/// and one guard bit of extra accumulator headroom, because a residue
/// corruption the MAC cannot see must be an exact multiple of M — the
/// extra bit keeps authenticated sums a factor of two away from that
/// wraparound blind spot.
pub fn tier_covers(
    cfg: &HrfnaConfig,
    env: &MagnitudeEnvelope,
    tolerance: Option<f64>,
    authenticated: bool,
) -> Result<(), EscalateReason> {
    if authenticated && cfg.moduli.iter().any(|&m| m % 2 == 0) {
        return Err(EscalateReason::MacBudget);
    }
    // Exponent legality: f = ⌊log2 max|x|⌋ − sig + 1; operands and their
    // pairwise products (exponent 2f) must stay inside ±(2^{ω_f−1}−1).
    if env.max_abs > 0.0 {
        let e = env.max_abs.log2().floor() as i64;
        let f = e - cfg.sig_bits as i64 + 1;
        let limit = (1i64 << (cfg.exponent_width - 1)) - 1;
        if f.abs() > limit || (2 * f).abs() > limit {
            return Err(EscalateReason::ExponentRange);
        }
    }
    // Accumulator legality: the planar kernels add `terms` products of
    // two sig-bit mantissas carry-free; the exact signed sum must stay
    // below 2^{m_bits−2} < M/2 (the shared signed budget).
    let acc_bits = 2 * cfg.sig_bits + ceil_log2(env.terms) + 1;
    if f64::from(acc_bits) >= cfg.m_bits() - 2.0 {
        return Err(EscalateReason::AccumulatorOverflow);
    }
    if authenticated && f64::from(acc_bits + 1) >= cfg.m_bits() - 2.0 {
        return Err(EscalateReason::MacBudget);
    }
    if let Some(tol) = tolerance {
        if tier_rel_bound(cfg, env) > tol {
            return Err(EscalateReason::BoundAboveTolerance);
        }
    }
    Ok(())
}

/// The registry: one lazily-built immutable context per tier. Shared
/// `Arc` so every lane worker of a tier sees the same counters.
#[derive(Debug)]
pub struct ContextRegistry {
    cfgs: [HrfnaConfig; 3],
    slots: [OnceLock<Arc<HrfnaContext>>; 3],
}

impl Default for ContextRegistry {
    fn default() -> ContextRegistry {
        ContextRegistry::new()
    }
}

impl ContextRegistry {
    /// Registry over the three preset tiers.
    pub fn new() -> ContextRegistry {
        ContextRegistry {
            cfgs: [Tier::Lo.config(), Tier::Paper.config(), Tier::Wide.config()],
            slots: [OnceLock::new(), OnceLock::new(), OnceLock::new()],
        }
    }

    /// Registry whose *base* ([`Tier::Paper`]) slot serves a caller
    /// configuration (the `hrfna serve --config` path); `lo`/`wide`
    /// keep their presets. The config must validate.
    pub fn with_base(cfg: HrfnaConfig) -> ContextRegistry {
        assert!(cfg.validate().is_ok(), "invalid base config for registry");
        ContextRegistry {
            cfgs: [Tier::Lo.config(), cfg, Tier::Wide.config()],
            slots: [OnceLock::new(), OnceLock::new(), OnceLock::new()],
        }
    }

    /// The tier's static configuration (never constructs the context).
    #[inline]
    pub fn cfg(&self, tier: Tier) -> &HrfnaConfig {
        &self.cfgs[tier.index()]
    }

    /// The tier's context, built exactly once on first use.
    pub fn get(&self, tier: Tier) -> Arc<HrfnaContext> {
        Arc::clone(self.slots[tier.index()].get_or_init(|| {
            Arc::new(HrfnaContext::new(self.cfgs[tier.index()].clone()))
        }))
    }

    /// The tier's context if it has been constructed (metrics seeding
    /// and accounting must not force a tier into existence).
    pub fn peek(&self, tier: Tier) -> Option<Arc<HrfnaContext>> {
        self.slots[tier.index()].get().map(Arc::clone)
    }

    /// Resolve the cheapest tier at or above `requested` whose bounds
    /// cover the envelope/tolerance. Saturates at [`Tier::Wide`] (best
    /// effort) with `covered = false` when even it fails a check.
    pub fn resolve(
        &self,
        requested: Tier,
        env: &MagnitudeEnvelope,
        tolerance: Option<f64>,
        authenticated: bool,
    ) -> Resolution {
        let mut tier = requested;
        let mut escalations = 0u32;
        let mut first_reason = None;
        loop {
            match tier_covers(self.cfg(tier), env, tolerance, authenticated) {
                Ok(()) => {
                    return Resolution { tier, escalations, covered: true, reason: first_reason }
                }
                Err(reason) => {
                    if first_reason.is_none() {
                        first_reason = Some(reason);
                    }
                    match tier.next() {
                        Some(up) => {
                            tier = up;
                            escalations += 1;
                        }
                        None => {
                            return Resolution {
                                tier,
                                escalations,
                                covered: false,
                                reason: first_reason,
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hybrid::Hrfna;
    use crate::util::prng::Rng;
    use crate::workloads::generators::Dist;

    fn env(max_abs: f64, terms: u64, events: u64) -> MagnitudeEnvelope {
        MagnitudeEnvelope { max_abs, terms, norm_events: events }
    }

    #[test]
    fn tiers_enumerate_in_escalation_order() {
        assert!(Tier::Lo < Tier::Paper && Tier::Paper < Tier::Wide);
        assert_eq!(Tier::Lo.next(), Some(Tier::Paper));
        assert_eq!(Tier::Wide.next(), None);
        for (i, t) in Tier::ALL.iter().enumerate() {
            assert_eq!(t.index(), i);
            assert_eq!(Tier::from_label(t.label()), Some(*t));
        }
        assert_eq!(Tier::from_label("nope"), None);
    }

    #[test]
    fn contexts_are_lazy_and_shared() {
        let reg = ContextRegistry::new();
        assert!(reg.peek(Tier::Wide).is_none(), "no context before first get");
        let a = reg.get(Tier::Paper);
        let b = reg.get(Tier::Paper);
        assert!(Arc::ptr_eq(&a, &b), "one context per tier");
        assert!(reg.peek(Tier::Paper).is_some());
        assert!(reg.peek(Tier::Lo).is_none(), "get(paper) must not build lo");
        assert_eq!(a.cfg, Tier::Paper.config());
    }

    #[test]
    fn concurrent_get_initializes_each_tier_exactly_once() {
        // Thread-race the first construction of every tier: all racers
        // must observe the *same* Arc (OnceLock admits one winner; the
        // losers' closures are discarded, never stored).
        let reg = std::sync::Arc::new(ContextRegistry::new());
        let handles: Vec<_> = (0..12)
            .map(|i| {
                let reg = std::sync::Arc::clone(&reg);
                std::thread::spawn(move || {
                    let tier = Tier::ALL[i % 3];
                    (tier, reg.get(tier))
                })
            })
            .collect();
        let got: Vec<(Tier, Arc<HrfnaContext>)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for tier in Tier::ALL {
            let canonical = reg.get(tier);
            for (t, ctx) in got.iter().filter(|(t, _)| *t == tier) {
                assert!(Arc::ptr_eq(ctx, &canonical), "{t:?} racer saw a second context");
            }
            assert_eq!(canonical.cfg, *reg.cfg(tier));
        }
    }

    #[test]
    fn per_tier_counters_are_independent() {
        let reg = ContextRegistry::new();
        let lo = reg.get(Tier::Lo);
        let paper = reg.get(Tier::Paper);
        HrfnaContext::count(&lo.counters.muls);
        assert_eq!(lo.snapshot().muls, 1);
        assert_eq!(paper.snapshot().muls, 0, "tiers share no counters");
    }

    #[test]
    fn paper_tier_bit_identical_to_standalone_context() {
        // Regression pin (pre-refactor single-context path): encoding
        // through the registry's paper tier must reproduce the residues,
        // exponent and interval of a standalone paper context bit for
        // bit — including through a multiply and a dot.
        let reg = ContextRegistry::new();
        let via_reg = reg.get(Tier::Paper);
        let standalone = HrfnaContext::new(HrfnaConfig::paper_default());
        assert_eq!(via_reg.cfg, standalone.cfg);
        let mut rng = Rng::new(314);
        let xs = Dist::high_dynamic_range().sample_vec(&mut rng, 64);
        let ys = Dist::moderate().sample_vec(&mut rng, 64);
        for (&x, &y) in xs.iter().zip(&ys) {
            let a = Hrfna::encode(x, &via_reg);
            let b = Hrfna::encode(x, &standalone);
            assert_eq!(a.r.r, b.r.r, "residues diverged for {x}");
            assert_eq!(a.f, b.f);
            assert_eq!(a.iv.lo.to_bits(), b.iv.lo.to_bits());
            assert_eq!(a.iv.hi.to_bits(), b.iv.hi.to_bits());
            let pa = a.mul(&Hrfna::encode(y, &via_reg), &via_reg);
            let pb = b.mul(&Hrfna::encode(y, &standalone), &standalone);
            assert_eq!(pa.r.r, pb.r.r);
            assert_eq!(pa.f, pb.f);
            assert_eq!(pa.decode(&via_reg).to_bits(), pb.decode(&standalone).to_bits());
        }
        let ea: Vec<Hrfna> = xs.iter().map(|&v| Hrfna::encode(v, &via_reg)).collect();
        let eb: Vec<Hrfna> = ys.iter().map(|&v| Hrfna::encode(v, &via_reg)).collect();
        let sa: Vec<Hrfna> = xs.iter().map(|&v| Hrfna::encode(v, &standalone)).collect();
        let sb: Vec<Hrfna> = ys.iter().map(|&v| Hrfna::encode(v, &standalone)).collect();
        let d_reg = crate::workloads::dot::dot_product_encoded::<Hrfna>(&ea, &eb, &via_reg);
        let d_std = crate::workloads::dot::dot_product_encoded::<Hrfna>(&sa, &sb, &standalone);
        assert_eq!(d_reg.r.r, d_std.r.r);
        assert_eq!(d_reg.f, d_std.f);
        assert_eq!(
            d_reg.decode(&via_reg).to_bits(),
            d_std.decode(&standalone).to_bits()
        );
    }

    #[test]
    fn cross_tier_decodes_stay_within_each_tiers_bound() {
        // Identical inputs run under every tier must each stay within
        // that tier's composed relative budget (quantization + measured
        // Lemma 2 events) against the f64 reference.
        let reg = ContextRegistry::new();
        let mut rng = Rng::new(99);
        for trial in 0..8 {
            let n = 32 + rng.below(200) as usize;
            let xs = Dist::moderate().sample_vec(&mut rng, n);
            let ys = Dist::moderate().sample_vec(&mut rng, n);
            let want: f64 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
            // Scale vs Σ|x·y| so cancellation does not inflate the metric
            // past what a relative bound can promise.
            let scale: f64 = xs.iter().zip(&ys).map(|(a, b)| (a * b).abs()).sum();
            for tier in Tier::ALL {
                let ctx = reg.get(tier);
                let before = ctx.snapshot();
                let ex: Vec<Hrfna> = xs.iter().map(|&v| Hrfna::encode(v, &ctx)).collect();
                let ey: Vec<Hrfna> = ys.iter().map(|&v| Hrfna::encode(v, &ctx)).collect();
                let got =
                    crate::workloads::dot::dot_product_encoded::<Hrfna>(&ex, &ey, &ctx)
                        .decode(&ctx);
                let d = ctx.snapshot().since(&before);
                let budget = tier_rel_bound(
                    reg.cfg(tier),
                    &env(1.0, n as u64, d.norms + d.guard_norms),
                );
                assert!(
                    (got - want).abs() <= budget * scale.max(1e-300),
                    "trial {trial} tier {tier:?}: |{got}-{want}| over {budget:e}·{scale}"
                );
            }
        }
    }

    #[test]
    fn resolve_prefers_the_requested_tier_when_it_covers() {
        let reg = ContextRegistry::new();
        let r = reg.resolve(Tier::Lo, &env(1.0, 512, 0), None, false);
        assert_eq!(
            r,
            Resolution { tier: Tier::Lo, escalations: 0, covered: true, reason: None }
        );
        let r = reg.resolve(Tier::Paper, &env(1.0, 4096, 0), Some(1e-6), false);
        assert_eq!(r.tier, Tier::Paper);
        assert_eq!(r.escalations, 0);
    }

    #[test]
    fn tolerance_below_lo_budget_escalates_to_paper() {
        let reg = ContextRegistry::new();
        // lo budget at 512 terms ≈ √512·2^-17 ≈ 1.7e-4; 1e-7 needs paper.
        let r = reg.resolve(Tier::Lo, &env(1.0, 512, 0), Some(1e-7), false);
        assert_eq!(r.tier, Tier::Paper);
        assert_eq!(r.escalations, 1);
        assert!(r.covered);
        assert_eq!(r.reason, Some(EscalateReason::BoundAboveTolerance));
        // 1e-12 is below paper's ≈ √512·2^-29 ≈ 4e-8 budget too → wide
        // (whose √512·2^-47 ≈ 1.6e-13 budget covers it).
        let r = reg.resolve(Tier::Lo, &env(1.0, 512, 0), Some(1e-12), false);
        assert_eq!(r.tier, Tier::Wide);
        assert_eq!(r.escalations, 2);
        assert!(r.covered);
    }

    #[test]
    fn accumulator_overflow_escalates() {
        let reg = ContextRegistry::new();
        // lo: 2·18 + ceil_log2(terms) + 1 must stay under m_bits−2 ≈ 62;
        // 2^40 terms pushes it to 77 → overflow; paper (budget ~126) fits.
        let r = reg.resolve(Tier::Lo, &env(1.0, 1 << 40, 0), None, false);
        assert_eq!(r.tier, Tier::Paper);
        assert_eq!(r.reason, Some(EscalateReason::AccumulatorOverflow));
        assert!(r.covered);
    }

    #[test]
    fn exponent_range_escalates_subnormal_magnitudes() {
        let reg = ContextRegistry::new();
        // lo: ω=12 → limit 2047; |2f| for a 2^-1022 operand is ≈ 2078.
        let r = reg.resolve(Tier::Lo, &env(f64::MIN_POSITIVE, 8, 0), None, false);
        assert!(r.tier > Tier::Lo, "subnormal-scale input must leave lo");
        assert_eq!(r.reason, Some(EscalateReason::ExponentRange));
    }

    #[test]
    fn authenticated_jobs_burn_one_extra_guard_bit() {
        let reg = ContextRegistry::new();
        // lo: acc_bits = 2·18 + 24 + 1 = 61 < m_bits−2 ≈ 62, so a plain
        // 2^24-term job fits — but the authenticated budget needs 62 and
        // escalates with the MAC reason.
        let e = env(1.0, 1 << 24, 0);
        let plain = reg.resolve(Tier::Lo, &e, None, false);
        assert_eq!(plain.tier, Tier::Lo);
        assert!(plain.covered);
        let auth = reg.resolve(Tier::Lo, &e, None, true);
        assert_eq!(auth.tier, Tier::Paper);
        assert_eq!(auth.escalations, 1);
        assert!(auth.covered);
        assert_eq!(auth.reason, Some(EscalateReason::MacBudget));
        // Modest authenticated jobs stay on the requested tier.
        let small = reg.resolve(Tier::Lo, &env(1.0, 512, 0), None, true);
        assert_eq!(small.tier, Tier::Lo);
        assert!(small.covered);
    }

    #[test]
    fn even_modulus_sets_cannot_carry_macs() {
        // A power-of-two modulus kills the odd-moduli fast path the MAC
        // rescale depends on: plain traffic is still admissible, but
        // authenticated traffic must be refused with the MAC reason.
        let cfg = HrfnaConfig {
            moduli: vec![65536, 65521, 65519],
            ..HrfnaConfig::low_precision()
        };
        let e = env(1.0, 16, 0);
        assert!(tier_covers(&cfg, &e, None, false).is_ok());
        assert_eq!(
            tier_covers(&cfg, &e, None, true),
            Err(EscalateReason::MacBudget)
        );
    }

    #[test]
    fn impossible_tolerance_saturates_at_wide() {
        let reg = ContextRegistry::new();
        let r = reg.resolve(Tier::Lo, &env(1.0, 4096, 0), Some(1e-30), false);
        assert_eq!(r.tier, Tier::Wide);
        assert_eq!(r.escalations, 2);
        assert!(!r.covered, "no tier promises 1e-30");
    }

    #[test]
    fn with_base_replaces_only_the_paper_slot() {
        let cfg = HrfnaConfig {
            tau_bits: 100,
            ..HrfnaConfig::paper_default()
        };
        let reg = ContextRegistry::with_base(cfg.clone());
        assert_eq!(reg.cfg(Tier::Paper), &cfg);
        assert_eq!(reg.cfg(Tier::Lo), &Tier::Lo.config());
        assert_eq!(reg.cfg(Tier::Wide), &Tier::Wide.config());
        assert_eq!(reg.get(Tier::Paper).cfg.tau_bits, 100);
    }

    #[test]
    fn envelope_of_slices_takes_the_max_abs() {
        let a = [1.0, -3.5, 0.25];
        let b = [2.0, 0.5];
        let e = MagnitudeEnvelope::of_slices(&[&a, &b], 3, 0);
        assert_eq!(e.max_abs, 3.5);
        assert_eq!(e.terms, 3);
        // Zero payloads cover everywhere (no exponent to overflow).
        assert!(tier_covers(&Tier::Lo.config(), &env(0.0, 4, 0), None, false).is_ok());
    }

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(0), 0);
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4096), 12);
        assert_eq!(ceil_log2(4097), 13);
    }
}
