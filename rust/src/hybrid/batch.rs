//! Batched HRFNA execution engine over the planar residue layout.
//!
//! A [`HrfnaBatch`] stores a batch of hybrid values structure-of-arrays:
//! one contiguous `u64` residue lane per modulus ([`ResiduePlane`],
//! `residues[channel][elem]`) plus packed exponent (`f`) and interval
//! (`iv_lo`/`iv_hi`) arrays. Elementwise kernels run tight per-channel
//! loops with no per-element allocation; threshold-driven normalization
//! scans the packed intervals in bulk and reconstructs only flagged
//! elements (the Fig. 1a discipline, batched).
//!
//! ## Scalar/batched API split
//!
//! The scalar [`Hrfna`] type remains the *reference implementation*: every
//! batched elementwise op (`mul`, `add`, `neg`, `sub`, `mul_scalar`,
//! `mac_assign`, `normalize_flagged`) is **bit-identical** to applying the
//! corresponding scalar op element-by-element — the fast lane path is only
//! taken when it provably coincides with the scalar fast path (same guard
//! and threshold conditions), and anything else falls back to the scalar
//! code. Property tests in this module assert the bit-identity.
//!
//! The batched reduction [`HrfnaBatch::dot`] is the one *semantic*
//! improvement: it accumulates every product exactly (carry-free residue
//! adds at a common exponent, Algorithm 1 with zero mid-loop rounding)
//! where the scalar MAC loop may take Lemma-1-bounded normalization
//! events mid-accumulation. Its result is therefore at least as accurate
//! as the scalar reference, never less.

use std::sync::atomic::Ordering;

use super::context::HrfnaContext;
use super::interval::Interval;
use super::norm::{self, NormReport};
use super::number::{pow2, signed_mag_to_f64, Hrfna};
use crate::rns::plane::{self, ResiduePlane};
use crate::rns::residue::ResidueVec;

/// A batch of HRFNA values in planar (structure-of-arrays) layout.
/// Fields are crate-visible so the normalization engine
/// ([`crate::hybrid::norm`]) can scan/update the packed control arrays
/// and gather/scatter residue columns without per-element accessors.
#[derive(Clone, Debug)]
pub struct HrfnaBatch {
    pub(crate) res: ResiduePlane,
    pub(crate) f: Vec<i32>,
    pub(crate) iv_lo: Vec<f64>,
    pub(crate) iv_hi: Vec<f64>,
}

impl HrfnaBatch {
    // ------------------------------------------------------------------
    // Construction / element access
    // ------------------------------------------------------------------

    /// A batch of `n` zeros (exponent 0, like `Hrfna::zero`).
    pub fn zeros(n: usize, ctx: &HrfnaContext) -> HrfnaBatch {
        HrfnaBatch {
            res: ResiduePlane::zero(ctx.k(), n),
            f: vec![0; n],
            iv_lo: vec![0.0; n],
            iv_hi: vec![0.0; n],
        }
    }

    /// Encode a slice of reals (per-element exponent, bit-identical to
    /// `Hrfna::encode` element by element — the property test below is
    /// the proof). One planar pass: stage every mantissa first, then run
    /// the contiguous per-channel residue encode — no per-element
    /// `ResidueVec` allocation or strided scatter (the serving encode
    /// path for matmul and RK4 batches).
    pub fn encode(xs: &[f64], ctx: &HrfnaContext) -> HrfnaBatch {
        let n = xs.len();
        let sig = ctx.cfg.sig_bits as i32;
        let mut staged = vec![0i64; n];
        let mut f = vec![0i32; n];
        let mut iv_lo = vec![0.0; n];
        let mut iv_hi = vec![0.0; n];
        for (j, &x) in xs.iter().enumerate() {
            assert!(x.is_finite(), "cannot encode {x}");
            if x == 0.0 {
                continue; // zero stays (r=0, f=0, iv=[0,0]) like Hrfna::zero
            }
            let e = x.abs().log2().floor() as i32;
            let fe = e - sig + 1;
            // Staged power-of-two scaling, exactly as Hrfna::encode: one
            // pow2(-f) can overflow for subnormal inputs.
            let mut scaled = x;
            let mut rem = -fe;
            while rem != 0 {
                let step = rem.clamp(-1000, 1000);
                scaled *= pow2(step);
                rem -= step;
            }
            let m = scaled.round() as i64;
            staged[j] = m;
            f[j] = fe;
            let point = m as f64;
            iv_lo[j] = point;
            iv_hi[j] = point;
        }
        let res = ResiduePlane::encode_signed(&staged, &ctx.cfg.moduli, ctx.barrett());
        HrfnaBatch { res, f, iv_lo, iv_hi }
    }

    /// Pack existing scalar values into a batch (all must share `k`).
    pub fn from_items(items: &[Hrfna], k: usize) -> HrfnaBatch {
        let n = items.len();
        let mut res = ResiduePlane::zero(k, n);
        let mut f = Vec::with_capacity(n);
        let mut iv_lo = Vec::with_capacity(n);
        let mut iv_hi = Vec::with_capacity(n);
        for (j, h) in items.iter().enumerate() {
            debug_assert_eq!(h.r.k(), k);
            res.set(j, &h.r);
            f.push(h.f);
            iv_lo.push(h.iv.lo);
            iv_hi.push(h.iv.hi);
        }
        HrfnaBatch { res, f, iv_lo, iv_hi }
    }

    /// Broadcast one scalar value across a batch of length `n`.
    pub fn broadcast(h: &Hrfna, n: usize) -> HrfnaBatch {
        let k = h.r.k();
        let mut res = ResiduePlane::zero(k, n);
        for c in 0..k {
            res.lane_mut(c).fill(h.r.r[c]);
        }
        HrfnaBatch {
            res,
            f: vec![h.f; n],
            iv_lo: vec![h.iv.lo; n],
            iv_hi: vec![h.iv.hi; n],
        }
    }

    /// Number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.f.len()
    }

    /// True if the batch holds no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.f.is_empty()
    }

    /// Number of residue channels.
    #[inline]
    pub fn k(&self) -> usize {
        self.res.k()
    }

    /// The underlying residue plane.
    #[inline]
    pub fn plane(&self) -> &ResiduePlane {
        &self.res
    }

    /// Packed exponent of element `j`.
    #[inline]
    pub fn exponent(&self, j: usize) -> i32 {
        self.f[j]
    }

    /// Packed interval of element `j` (control-plane view; no residue
    /// data is touched).
    #[inline]
    pub fn interval(&self, j: usize) -> Interval {
        Interval {
            lo: self.iv_lo[j],
            hi: self.iv_hi[j],
        }
    }

    /// Gather element `j` into a scalar [`Hrfna`] (reference-path view).
    pub fn get(&self, j: usize) -> Hrfna {
        Hrfna {
            r: self.res.get(j),
            f: self.f[j],
            iv: self.interval(j),
        }
    }

    /// Scatter a scalar value into element `j`.
    pub fn set(&mut self, j: usize, h: &Hrfna) {
        self.res.set(j, &h.r);
        self.f[j] = h.f;
        self.iv_lo[j] = h.iv.lo;
        self.iv_hi[j] = h.iv.hi;
    }

    /// Unpack into scalar values.
    pub fn to_items(&self) -> Vec<Hrfna> {
        (0..self.len()).map(|j| self.get(j)).collect()
    }

    /// Decode every element: one **batched** signed CRT pass straight over
    /// the channel-major lanes (scratch and per-modulus tables hoisted,
    /// no per-element `ResidueVec` gather), then the per-element exponent
    /// apply. Bit-identical to `self.get(j).decode(ctx)` for every `j`.
    pub fn decode(&self, ctx: &HrfnaContext) -> Vec<f64> {
        let n = self.len();
        ctx.counters
            .reconstructions
            .fetch_add(n as u64, Ordering::Relaxed);
        ctx.crt
            .reconstruct_signed_batch(self.res.lanes(), n)
            .into_iter()
            .zip(&self.f)
            .map(|((neg, mag), &f)| signed_mag_to_f64(neg, &mag, f))
            .collect()
    }

    // ------------------------------------------------------------------
    // Elementwise kernels (bit-identical to the scalar reference)
    // ------------------------------------------------------------------

    /// Elementwise hybrid multiplication; bit-identical to
    /// `self[j].mul(&other[j], ctx)` for every `j`.
    pub fn mul(&self, other: &HrfnaBatch, ctx: &HrfnaContext) -> HrfnaBatch {
        assert_eq!(self.len(), other.len());
        let n = self.len();
        let bud = ctx.signed_budget_bits();
        let tau = ctx.tau_f64();
        let mut iv_lo = vec![0.0; n];
        let mut iv_hi = vec![0.0; n];
        let mut all_fast = true;
        for j in 0..n {
            let ia = self.interval(j);
            let ib = other.interval(j);
            if ia.bits_hi() + ib.bits_hi() >= bud {
                all_fast = false;
                break;
            }
            let z = ia.mul(&ib);
            if z.abs_hi() >= tau {
                all_fast = false;
                break;
            }
            iv_lo[j] = z.lo;
            iv_hi[j] = z.hi;
        }
        if !all_fast {
            // Rare path: element-at-a-time through the scalar reference
            // (guard normalization and threshold events included).
            let items: Vec<Hrfna> = (0..n)
                .map(|j| self.get(j).mul(&other.get(j), ctx))
                .collect();
            return HrfnaBatch::from_items(&items, self.k());
        }
        ctx.counters.muls.fetch_add(n as u64, Ordering::Relaxed);
        HrfnaBatch {
            res: self.res.mul(&other.res, ctx.barrett()),
            f: self.f.iter().zip(&other.f).map(|(a, b)| a + b).collect(),
            iv_lo,
            iv_hi,
        }
    }

    /// Elementwise multiplication by one broadcast scalar value;
    /// bit-identical to `self[j].mul(c, ctx)` for every `j`.
    pub fn mul_scalar(&self, c: &Hrfna, ctx: &HrfnaContext) -> HrfnaBatch {
        let n = self.len();
        let bud = ctx.signed_budget_bits();
        let tau = ctx.tau_f64();
        let cbits = c.iv.bits_hi();
        let mut iv_lo = vec![0.0; n];
        let mut iv_hi = vec![0.0; n];
        let mut all_fast = true;
        for j in 0..n {
            let ia = self.interval(j);
            if ia.bits_hi() + cbits >= bud {
                all_fast = false;
                break;
            }
            let z = ia.mul(&c.iv);
            if z.abs_hi() >= tau {
                all_fast = false;
                break;
            }
            iv_lo[j] = z.lo;
            iv_hi[j] = z.hi;
        }
        if !all_fast {
            let items: Vec<Hrfna> = (0..n).map(|j| self.get(j).mul(c, ctx)).collect();
            return HrfnaBatch::from_items(&items, self.k());
        }
        ctx.counters.muls.fetch_add(n as u64, Ordering::Relaxed);
        let mut res = ResiduePlane::zero(self.k(), n);
        for ch in 0..self.k() {
            plane::lane_scale(ctx.barrett()[ch], self.res.lane(ch), c.r.r[ch], res.lane_mut(ch));
        }
        HrfnaBatch {
            res,
            f: self.f.iter().map(|&a| a + c.f).collect(),
            iv_lo,
            iv_hi,
        }
    }

    /// Multiply every element by the real constant `k` (batched analogue
    /// of `Numeric::scale`, which encodes `k` and multiplies).
    pub fn scale(&self, k: f64, ctx: &HrfnaContext) -> HrfnaBatch {
        self.mul_scalar(&Hrfna::encode(k, ctx), ctx)
    }

    /// Elementwise addition; bit-identical to `self[j].add(&other[j], ctx)`.
    pub fn add(&self, other: &HrfnaBatch, ctx: &HrfnaContext) -> HrfnaBatch {
        assert_eq!(self.len(), other.len());
        let n = self.len();
        let tau = ctx.tau_f64();
        let mut iv_lo = vec![0.0; n];
        let mut iv_hi = vec![0.0; n];
        let mut all_fast = true;
        for j in 0..n {
            if self.f[j] != other.f[j] {
                // Exponent synchronization required: scalar path.
                all_fast = false;
                break;
            }
            let z = self.interval(j).add(&other.interval(j));
            if z.abs_hi() >= tau {
                all_fast = false;
                break;
            }
            iv_lo[j] = z.lo;
            iv_hi[j] = z.hi;
        }
        if !all_fast {
            let items: Vec<Hrfna> = (0..n)
                .map(|j| self.get(j).add(&other.get(j), ctx))
                .collect();
            return HrfnaBatch::from_items(&items, self.k());
        }
        ctx.counters.adds.fetch_add(n as u64, Ordering::Relaxed);
        HrfnaBatch {
            res: self.res.add(&other.res, ctx.barrett()),
            f: self.f.clone(),
            iv_lo,
            iv_hi,
        }
    }

    /// Elementwise negation (always carry-free; bit-identical to
    /// `self[j].neg(ctx)`).
    pub fn neg(&self, ctx: &HrfnaContext) -> HrfnaBatch {
        let n = self.len();
        let mut iv_lo = vec![0.0; n];
        let mut iv_hi = vec![0.0; n];
        for j in 0..n {
            let z = self.interval(j).neg();
            iv_lo[j] = z.lo;
            iv_hi[j] = z.hi;
        }
        HrfnaBatch {
            res: self.res.neg(&ctx.cfg.moduli),
            f: self.f.clone(),
            iv_lo,
            iv_hi,
        }
    }

    /// Elementwise subtraction: `self + (-other)` (as the scalar op).
    pub fn sub(&self, other: &HrfnaBatch, ctx: &HrfnaContext) -> HrfnaBatch {
        self.add(&other.neg(ctx), ctx)
    }

    /// Elementwise fused multiply-accumulate `self[j] += x[j] * y[j]`;
    /// bit-identical to `self[j].mac_assign(&x[j], &y[j], ctx)`.
    pub fn mac_assign(&mut self, x: &HrfnaBatch, y: &HrfnaBatch, ctx: &HrfnaContext) {
        assert_eq!(self.len(), x.len());
        assert_eq!(self.len(), y.len());
        let n = self.len();
        let bud = ctx.signed_budget_bits();
        let tau = ctx.tau_f64();
        let x_nz = x.res.nonzero_mask();
        let y_nz = y.res.nonzero_mask();
        let acc_nz = self.res.nonzero_mask();
        if acc_nz.iter().all(|&nz| !nz) {
            // Whole accumulator is zero — mirror of the scalar acc-zero
            // branch (`*self = p`, threshold no-op), with zero products
            // leaving their element untouched (scalar early return).
            let mut iv_lo = self.iv_lo.clone();
            let mut iv_hi = self.iv_hi.clone();
            let mut f = self.f.clone();
            let mut fast = true;
            for j in 0..n {
                if !(x_nz[j] && y_nz[j]) {
                    continue; // product provably zero: element untouched
                }
                let ia = x.interval(j);
                let ib = y.interval(j);
                if ia.bits_hi() + ib.bits_hi() >= bud {
                    fast = false;
                    break;
                }
                let p = ia.mul(&ib);
                if p.abs_hi() >= tau {
                    fast = false;
                    break;
                }
                iv_lo[j] = p.lo;
                iv_hi[j] = p.hi;
                f[j] = x.f[j] + y.f[j];
            }
            if fast {
                // Zero-product lanes multiply to zero, so one lane pass
                // writes exactly the scalar result for every element.
                ctx.counters.muls.fetch_add(n as u64, Ordering::Relaxed);
                self.res = x.res.mul(&y.res, ctx.barrett());
                self.f = f;
                self.iv_lo = iv_lo;
                self.iv_hi = iv_hi;
                return;
            }
        } else {
            let mut iv_lo = vec![0.0; n];
            let mut iv_hi = vec![0.0; n];
            let mut all_fast = true;
            for j in 0..n {
                // The lane path coincides with the scalar op only when the
                // scalar op would take its exponent-coherent in-place
                // branch: nonzero product, nonzero accumulator, matching
                // exponents, product headroom, no trailing threshold event.
                if !(x_nz[j] && y_nz[j] && acc_nz[j]) {
                    all_fast = false;
                    break;
                }
                let ia = x.interval(j);
                let ib = y.interval(j);
                if ia.bits_hi() + ib.bits_hi() >= bud {
                    all_fast = false;
                    break;
                }
                if x.f[j] + y.f[j] != self.f[j] {
                    all_fast = false;
                    break;
                }
                let z = self.interval(j).add(&ia.mul(&ib));
                if z.abs_hi() >= tau {
                    all_fast = false;
                    break;
                }
                iv_lo[j] = z.lo;
                iv_hi[j] = z.hi;
            }
            if all_fast {
                ctx.counters.muls.fetch_add(n as u64, Ordering::Relaxed);
                ctx.counters.adds.fetch_add(n as u64, Ordering::Relaxed);
                self.res.fma_assign(&x.res, &y.res, ctx.barrett());
                self.iv_lo = iv_lo;
                self.iv_hi = iv_hi;
                return;
            }
        }
        // Mixed/rare batch: element-at-a-time scalar reference.
        for j in 0..n {
            let mut acc = self.get(j);
            acc.mac_assign(&x.get(j), &y.get(j), ctx);
            self.set(j, &acc);
        }
    }

    // ------------------------------------------------------------------
    // Batched normalization
    // ------------------------------------------------------------------

    /// Batched threshold-driven normalization on the planar engine
    /// ([`crate::hybrid::norm::bulk_normalize`]): one scan of the packed
    /// intervals builds the flagged-column set, the flagged columns are
    /// gathered into a dense scratch plane and rescaled by **one**
    /// batched residue-domain CRT pass (zero per-element
    /// `reconstruct_signed` calls, zero per-element allocation), then
    /// scattered back with bulk exponent/interval updates. Bit-identical
    /// to `maybe_normalize` per element; the old per-element path lives
    /// on as `norm::reference` and backs the property tests.
    pub fn normalize_flagged(&mut self, ctx: &HrfnaContext) -> NormReport {
        norm::bulk_normalize(self, ctx, None)
    }

    /// Bulk overflow-guard sweep (§III-C, batched): additionally rescale
    /// every element whose conservative magnitude bound has reached
    /// `max_bits`, even below τ — what a caller runs before an operation
    /// that needs `max_bits` of headroom. Guard events are reported (and
    /// counted) separately from threshold events. `max_bits` must exceed
    /// `sig_bits` (rescaling stops at the significand target, so a
    /// smaller budget is unsatisfiable — asserted).
    pub fn normalize_guarded(&mut self, ctx: &HrfnaContext, max_bits: u32) -> NormReport {
        norm::bulk_normalize(self, ctx, Some(max_bits))
    }

    // ------------------------------------------------------------------
    // Batched reductions (the Algorithm 1 hot loop, planar)
    // ------------------------------------------------------------------

    /// Batched dot product `Σ_j self[j]·other[j]` (Algorithm 1 on the
    /// planar engine): every product is aligned to the lowest product
    /// exponent by an exact residue-domain `2^Δ` scale and accumulated
    /// carry-free — zero mid-loop rounding. Falls back to the scalar MAC
    /// loop when interval headroom cannot guarantee exactness.
    pub fn dot(&self, other: &HrfnaBatch, ctx: &HrfnaContext) -> Hrfna {
        assert_eq!(self.len(), other.len());
        self.dot_range(0, other, 0, self.len(), ctx)
    }

    /// [`HrfnaBatch::dot`] over the sub-ranges `self[xo..xo+len]` and
    /// `other[yo..yo+len]` (matmul uses row/column windows of one plane).
    pub fn dot_range(
        &self,
        xo: usize,
        other: &HrfnaBatch,
        yo: usize,
        len: usize,
        ctx: &HrfnaContext,
    ) -> Hrfna {
        assert!(xo + len <= self.len() && yo + len <= other.len());
        if len == 0 {
            return Hrfna::zero(ctx, 0);
        }
        let bud = ctx.signed_budget_bits();
        // Control-plane prepass: product exponents, conservative product
        // intervals, and the common (lowest) exponent f0.
        let mut fp = vec![0i32; len];
        let mut plo = vec![0.0f64; len];
        let mut phi = vec![0.0f64; len];
        let mut f0 = i32::MAX;
        let mut fast = true;
        for t in 0..len {
            let ia = self.interval(xo + t);
            let ib = other.interval(yo + t);
            if ia.bits_hi() + ib.bits_hi() >= bud {
                fast = false;
                break;
            }
            let p = ia.mul(&ib);
            plo[t] = p.lo;
            phi[t] = p.hi;
            fp[t] = self.f[xo + t] + other.f[yo + t];
            // A [0,0] product interval proves the product is exactly zero
            // (its residues are all zero); it neither constrains f0 nor
            // contributes to the sum.
            if !(p.lo == 0.0 && p.hi == 0.0) {
                f0 = f0.min(fp[t]);
            }
        }
        if fast && f0 == i32::MAX {
            // Full scan, every product provably zero.
            return Hrfna::zero(ctx, 0);
        }
        // Headroom: Σ |product|·2^Δ must stay below 2^budget so the exact
        // residue accumulation cannot wrap past M/2.
        let mut deltas = vec![0u32; len];
        if fast {
            let mut bound = 0.0f64;
            for t in 0..len {
                if plo[t] == 0.0 && phi[t] == 0.0 {
                    continue;
                }
                let d = (fp[t] - f0) as u32;
                deltas[t] = d;
                bound += plo[t].abs().max(phi[t].abs()) * pow2(d as i32);
                if !bound.is_finite() {
                    fast = false;
                    break;
                }
            }
            if fast && bound >= pow2(bud as i32) {
                fast = false;
            }
        }
        if !fast {
            // Reference path: scalar exponent-coherent MAC loop.
            let mut acc = Hrfna::zero(ctx, 0);
            for t in 0..len {
                acc.mac_assign(&self.get(xo + t), &other.get(yo + t), ctx);
            }
            return acc;
        }
        // Planar hot loop: per channel, one contiguous multiply-align-
        // accumulate pass; no allocation, no per-element bookkeeping.
        let k = self.k();
        let bars = ctx.barrett();
        let uniform = deltas.iter().all(|&d| d == 0);
        let mut out = vec![0u64; k];
        let mut mults = vec![0u64; len];
        for (c, acc) in out.iter_mut().enumerate() {
            let bar = bars[c];
            let xs = &self.res.lane(c)[xo..xo + len];
            let ys = &other.res.lane(c)[yo..yo + len];
            *acc = if uniform {
                plane::lane_dot(bar, xs, ys)
            } else {
                for (mult, &d) in mults.iter_mut().zip(&deltas) {
                    *mult = ctx.pow2_mod(c, d);
                }
                plane::lane_dot_scaled(bar, xs, ys, &mults)
            };
        }
        // Algorithm 1 accounting: one mul + one add per element.
        ctx.counters.muls.fetch_add(len as u64, Ordering::Relaxed);
        ctx.counters.adds.fetch_add(len as u64, Ordering::Relaxed);
        // Conservative interval for the exact signed sum.
        let mut iv = Interval::zero();
        for t in 0..len {
            if plo[t] == 0.0 && phi[t] == 0.0 {
                continue;
            }
            iv = iv.add(&Interval { lo: plo[t], hi: phi[t] }.shl(deltas[t]));
        }
        let mut acc = Hrfna {
            r: ResidueVec { r: out },
            f: f0,
            iv,
        };
        acc.maybe_normalize(ctx);
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HrfnaConfig;
    use crate::util::proptest::check_with;
    use crate::util::prng::Rng;
    use crate::workloads::generators::Dist;

    fn ctx() -> HrfnaContext {
        HrfnaContext::paper_default()
    }

    /// Exact structural equality (residues, exponent, interval bounds).
    fn same(a: &Hrfna, b: &Hrfna) -> bool {
        a.r == b.r && a.f == b.f && a.iv.lo == b.iv.lo && a.iv.hi == b.iv.hi
    }

    fn random_values(rng: &mut Rng, n: usize, c: &HrfnaContext) -> Vec<Hrfna> {
        (0..n)
            .map(|_| {
                // Mix of moderate, wide-range and exact-zero values so both
                // the lane path and the scalar fallback are exercised.
                let x = match rng.below(4) {
                    0 => 0.0,
                    1 => rng.sign() * rng.lognormal(0.0, 12.0),
                    _ => rng.uniform(-1.0, 1.0),
                };
                Hrfna::encode(x, c)
            })
            .collect()
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let c = ctx();
        let mut rng = Rng::new(1);
        let items = random_values(&mut rng, 9, &c);
        let b = HrfnaBatch::from_items(&items, c.k());
        assert_eq!(b.len(), 9);
        for (j, it) in items.iter().enumerate() {
            assert!(same(&b.get(j), it), "j={j}");
        }
        let back = b.to_items();
        for (a, x) in back.iter().zip(&items) {
            assert!(same(a, x));
        }
    }

    #[test]
    fn encode_matches_scalar_encode() {
        let c = ctx();
        let xs = [0.0, 1.5, -2.25e10, 3.33e-7, -1.0];
        let b = HrfnaBatch::encode(&xs, &c);
        for (j, &x) in xs.iter().enumerate() {
            assert!(same(&b.get(j), &Hrfna::encode(x, &c)), "x={x}");
        }
    }

    #[test]
    fn broadcast_replicates() {
        let c = ctx();
        let h = Hrfna::encode(2.5, &c);
        let b = HrfnaBatch::broadcast(&h, 5);
        for j in 0..5 {
            assert!(same(&b.get(j), &h));
        }
    }

    #[test]
    fn prop_batched_mul_add_bit_identical_to_scalar() {
        let c = ctx();
        check_with("batch-mul-add-bitident", 48, |rng| {
            let n = 1 + rng.below(24) as usize;
            let xs = random_values(rng, n, &c);
            let ys = random_values(rng, n, &c);
            let bx = HrfnaBatch::from_items(&xs, c.k());
            let by = HrfnaBatch::from_items(&ys, c.k());
            let bm = bx.mul(&by, &c);
            let ba = bx.add(&by, &c);
            let bn = bx.neg(&c);
            let bs = bx.sub(&by, &c);
            for j in 0..n {
                crate::prop_assert!(
                    same(&bm.get(j), &xs[j].mul(&ys[j], &c)),
                    "mul j={j}"
                );
                crate::prop_assert!(
                    same(&ba.get(j), &xs[j].add(&ys[j], &c)),
                    "add j={j}"
                );
                crate::prop_assert!(same(&bn.get(j), &xs[j].neg(&c)), "neg j={j}");
                crate::prop_assert!(
                    same(&bs.get(j), &xs[j].sub(&ys[j], &c)),
                    "sub j={j}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_batched_mac_bit_identical_to_scalar() {
        let c = ctx();
        check_with("batch-mac-bitident", 48, |rng| {
            let n = 1 + rng.below(16) as usize;
            let mut accs = random_values(rng, n, &c);
            let xs = random_values(rng, n, &c);
            let ys = random_values(rng, n, &c);
            let mut bacc = HrfnaBatch::from_items(&accs, c.k());
            let bx = HrfnaBatch::from_items(&xs, c.k());
            let by = HrfnaBatch::from_items(&ys, c.k());
            // Several chained MAC rounds (exercises the exponent-coherent
            // in-place branch once accumulators settle).
            for _ in 0..3 {
                bacc.mac_assign(&bx, &by, &c);
                for j in 0..n {
                    accs[j].mac_assign(&xs[j], &ys[j], &c);
                }
                for j in 0..n {
                    crate::prop_assert!(same(&bacc.get(j), &accs[j]), "mac j={j}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mac_into_zeros_accumulator_bit_identical() {
        // The canonical first MAC: acc = zeros; acc += x*y takes the
        // acc-zero lane class and must still mirror the scalar op exactly,
        // including elements whose product is zero (left untouched).
        let c = ctx();
        let mut rng = Rng::new(41);
        for round in 0..8 {
            let n = 1 + rng.below(20) as usize;
            let xs = random_values(&mut rng, n, &c);
            let ys = random_values(&mut rng, n, &c);
            let bx = HrfnaBatch::from_items(&xs, c.k());
            let by = HrfnaBatch::from_items(&ys, c.k());
            let mut bacc = HrfnaBatch::zeros(n, &c);
            bacc.mac_assign(&bx, &by, &c);
            for j in 0..n {
                let mut acc = Hrfna::zero(&c, 0);
                acc.mac_assign(&xs[j], &ys[j], &c);
                assert!(same(&bacc.get(j), &acc), "round={round} j={j}");
            }
        }
    }

    #[test]
    fn prop_batched_normalize_bit_identical_to_scalar() {
        // Tight threshold so normalization actually fires.
        let c = HrfnaContext::new(HrfnaConfig {
            tau_bits: 40,
            ..HrfnaConfig::paper_default()
        });
        check_with("batch-normalize-bitident", 32, |rng| {
            let n = 1 + rng.below(12) as usize;
            let mut items: Vec<Hrfna> = (0..n)
                .map(|_| {
                    let bits = 20 + rng.below(40) as u32;
                    let v = (rng.next_u64() >> (64 - bits)).max(1) as i64;
                    Hrfna::from_signed_int(if rng.bool() { v } else { -v }, -10, &c)
                })
                .collect();
            let mut b = HrfnaBatch::from_items(&items, c.k());
            let flagged = b.normalize_flagged(&c);
            let mut want_flagged = 0;
            for it in items.iter_mut() {
                let before = it.f;
                it.maybe_normalize(&c);
                if it.f != before {
                    want_flagged += 1;
                }
            }
            crate::prop_assert!(
                flagged.threshold == want_flagged && flagged.guard == 0,
                "flag report {flagged:?} != {want_flagged} threshold events"
            );
            for (j, it) in items.iter().enumerate() {
                crate::prop_assert!(same(&b.get(j), it), "norm j={j}");
            }
            Ok(())
        });
    }

    #[test]
    fn batch_decode_bit_identical_to_scalar_decode() {
        // decode now runs one batched CRT pass; it must agree bit for bit
        // with the per-element scalar decode (and count the same number
        // of reconstructions).
        let c = ctx();
        let mut rng = Rng::new(77);
        let items = random_values(&mut rng, 17, &c);
        let b = HrfnaBatch::from_items(&items, c.k());
        let before = c.snapshot().reconstructions;
        let got = b.decode(&c);
        assert_eq!(c.snapshot().reconstructions, before + 17);
        for (j, it) in items.iter().enumerate() {
            let want = it.decode(&c);
            assert_eq!(got[j].to_bits(), want.to_bits(), "j={j} {} vs {want}", got[j]);
        }
        // Empty batch decodes to an empty vector.
        assert!(HrfnaBatch::zeros(0, &c).decode(&c).is_empty());
    }

    #[test]
    fn dot_matches_f64_reference_moderate() {
        let c = ctx();
        let mut rng = Rng::new(7);
        let n = 1024;
        let xs = Dist::moderate().sample_vec(&mut rng, n);
        let ys = Dist::moderate().sample_vec(&mut rng, n);
        let bx = HrfnaBatch::encode(&xs, &c);
        let by = HrfnaBatch::encode(&ys, &c);
        let acc = bx.dot(&by, &c);
        assert!(acc.interval_is_sound(&c));
        let got = acc.decode(&c);
        let want: f64 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
        // Encode quantization is relative to the non-cancelling magnitude.
        let scale: f64 = xs.iter().zip(&ys).map(|(a, b)| (a * b).abs()).sum();
        assert!(
            (got - want).abs() < 1e-7 * scale + 1e-300,
            "got={got} want={want}"
        );
    }

    #[test]
    fn dot_matches_scalar_reference_closely() {
        let c = ctx();
        let mut rng = Rng::new(13);
        for n in [1usize, 2, 33, 512] {
            let xs = Dist::moderate().sample_vec(&mut rng, n);
            let ys = Dist::moderate().sample_vec(&mut rng, n);
            let ex: Vec<Hrfna> = xs.iter().map(|&x| Hrfna::encode(x, &c)).collect();
            let ey: Vec<Hrfna> = ys.iter().map(|&y| Hrfna::encode(y, &c)).collect();
            let bx = HrfnaBatch::from_items(&ex, c.k());
            let by = HrfnaBatch::from_items(&ey, c.k());
            let planar = bx.dot(&by, &c).decode(&c);
            let mut acc = Hrfna::zero(&c, 0);
            for (x, y) in ex.iter().zip(&ey) {
                acc.mac_assign(x, y, &c);
            }
            let scalar = acc.decode(&c);
            let tol = 1e-9 * scalar.abs().max(1e-12);
            assert!(
                (planar - scalar).abs() <= tol,
                "n={n} planar={planar} scalar={scalar}"
            );
        }
    }

    #[test]
    fn dot_handles_zeros_and_wide_range() {
        let c = ctx();
        let mut rng = Rng::new(21);
        let n = 256;
        let mut xs = Dist::high_dynamic_range().sample_vec(&mut rng, n);
        let ys = Dist::high_dynamic_range().sample_vec(&mut rng, n);
        for j in (0..n).step_by(5) {
            xs[j] = 0.0;
        }
        let bx = HrfnaBatch::encode(&xs, &c);
        let by = HrfnaBatch::encode(&ys, &c);
        let acc = bx.dot(&by, &c);
        assert!(acc.interval_is_sound(&c));
        let got = acc.decode(&c);
        let want: f64 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
        let scale: f64 = xs.iter().zip(&ys).map(|(a, b)| (a * b).abs()).sum();
        assert!(
            (got - want).abs() < 1e-6 * scale + 1e-300,
            "got={got} want={want}"
        );
    }

    #[test]
    fn dot_of_all_zeros_is_zero() {
        let c = ctx();
        let bx = HrfnaBatch::encode(&[0.0; 16], &c);
        let by = HrfnaBatch::encode(&[1.0; 16], &c);
        let acc = bx.dot(&by, &c);
        assert!(acc.is_zero());
        assert_eq!(acc.decode(&c), 0.0);
        let empty = HrfnaBatch::zeros(0, &c);
        assert!(empty.dot(&empty, &c).is_zero());
    }

    #[test]
    fn dot_range_windows_match_full_dot() {
        let c = ctx();
        let mut rng = Rng::new(31);
        let xs = Dist::moderate().sample_vec(&mut rng, 64);
        let ys = Dist::moderate().sample_vec(&mut rng, 64);
        let bx = HrfnaBatch::encode(&xs, &c);
        let by = HrfnaBatch::encode(&ys, &c);
        let window = bx.dot_range(16, &by, 32, 16, &c).decode(&c);
        let want: f64 = (0..16).map(|t| xs[16 + t] * ys[32 + t]).sum();
        assert!(
            (window - want).abs() < 1e-7 * want.abs().max(1.0),
            "window={window} want={want}"
        );
    }
}
