//! Arrays of HRFNA values with deferred, interval-driven selection —
//! the paper's Fig. 1a machinery as a *view over the planar engine*:
//! residue lanes stay untouched in the residue plane ([`HrfnaBatch`]);
//! the packed interval/exponent arrays feed a comparator reduction tree;
//! only the *selected* element is ever reconstructed or normalized.

use super::batch::HrfnaBatch;
use super::context::HrfnaContext;
use super::interval::{argmax_magnitude, Interval};
use super::number::Hrfna;

/// An array of hybrid values backed by the planar batch engine, with the
/// Fig. 1a control-plane view.
#[derive(Clone, Debug)]
pub struct HrfnaArray {
    batch: HrfnaBatch,
}

impl HrfnaArray {
    /// Encode a slice of reals.
    pub fn encode(xs: &[f64], ctx: &HrfnaContext) -> HrfnaArray {
        HrfnaArray {
            batch: HrfnaBatch::encode(xs, ctx),
        }
    }

    /// Build from scalar values (packs them into the plane).
    pub fn from_items(items: Vec<Hrfna>, ctx: &HrfnaContext) -> HrfnaArray {
        HrfnaArray {
            batch: HrfnaBatch::from_items(&items, ctx.k()),
        }
    }

    /// The underlying planar batch.
    pub fn batch(&self) -> &HrfnaBatch {
        &self.batch
    }

    /// Gather one element as a scalar value.
    pub fn get(&self, idx: usize) -> Hrfna {
        self.batch.get(idx)
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.batch.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.batch.is_empty()
    }

    /// The control-plane view: interval evaluations of Φ-magnitude
    /// (N-interval positioned by the exponent), tagged by index. Reads
    /// only the packed exponent/interval arrays — no residue lane is
    /// touched (Fig. 1a left → right hand-off).
    pub fn magnitude_intervals(&self) -> Vec<Interval> {
        (0..self.batch.len())
            .map(|j| {
                // Position the N-interval at the value scale: scale by 2^f
                // conservatively (f64 suffices for a control estimate).
                let iv = self.batch.interval(j);
                let k = super::number::pow2(self.batch.exponent(j));
                Interval::new(
                    (iv.lo * k).min(iv.hi * k),
                    (iv.lo * k).max(iv.hi * k),
                )
            })
            .collect()
    }

    /// Reduction-tree selection of the dominant-magnitude element
    /// (Fig. 1a right side): returns `idx` — comparisons use only the
    /// floating interval evaluations.
    pub fn argmax_magnitude(&self) -> Option<usize> {
        argmax_magnitude(&self.magnitude_intervals())
    }

    /// Fig. 1a normalization policy: reconstruct/normalize *only the
    /// selected element* when its magnitude bound crosses τ. Returns the
    /// selected index if a normalization was performed.
    pub fn normalize_dominant(&mut self, ctx: &HrfnaContext) -> Option<usize> {
        let idx = self.argmax_magnitude()?;
        if self.batch.interval(idx).abs_hi() >= ctx.tau_f64() {
            let mut h = self.batch.get(idx);
            h.normalize_to_sig(ctx, false);
            self.batch.set(idx, &h);
            Some(idx)
        } else {
            None
        }
    }

    /// Batched threshold sweep (the bulk form of the Fig. 1a policy):
    /// normalize every element over τ through the planar engine — one
    /// batched rescale pass over the flagged columns only.
    pub fn normalize_flagged(&mut self, ctx: &HrfnaContext) -> super::norm::NormReport {
        self.batch.normalize_flagged(ctx)
    }

    /// Elementwise product with another array (carry-free, lane-parallel).
    pub fn mul(&self, other: &HrfnaArray, ctx: &HrfnaContext) -> HrfnaArray {
        assert_eq!(self.len(), other.len());
        HrfnaArray {
            batch: self.batch.mul(&other.batch, ctx),
        }
    }

    /// Sum via the planar dot kernel against a broadcast one (Alg. 1
    /// semantics: exponent-aligned, carry-free accumulation).
    pub fn sum(&self, ctx: &HrfnaContext) -> Hrfna {
        if self.is_empty() {
            return Hrfna::zero(ctx, 0);
        }
        let ones = HrfnaBatch::broadcast(&Hrfna::encode(1.0, ctx), self.len());
        self.batch.dot(&ones, ctx)
    }

    /// Decode everything (test/inspection path; one CRT per element).
    pub fn decode(&self, ctx: &HrfnaContext) -> Vec<f64> {
        self.batch.decode(ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> HrfnaContext {
        HrfnaContext::paper_default()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let c = ctx();
        let xs = [1.5, -2.25, 1e10, -1e-10, 0.0];
        let arr = HrfnaArray::encode(&xs, &c);
        let back = arr.decode(&c);
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() * 1e-8 + 1e-300, "{a} vs {b}");
        }
    }

    #[test]
    fn argmax_finds_dominant_without_reconstruction() {
        let c = ctx();
        let before = c.snapshot().reconstructions;
        let arr = HrfnaArray::encode(&[3.0, -5e6, 10.0, 4999.0], &c);
        assert_eq!(arr.argmax_magnitude(), Some(1));
        // Selection must not have reconstructed anything (Fig. 1a point).
        assert_eq!(c.snapshot().reconstructions, before);
    }

    #[test]
    fn argmax_respects_exponent_scale() {
        let c = ctx();
        // Same significand, different exponents: the interval view must
        // weigh by 2^f.
        let mut a = Hrfna::encode(1.0, &c);
        let b = Hrfna::encode(1.0, &c);
        a.f += 10; // a = 1024
        let arr = HrfnaArray::from_items(vec![b, a], &c);
        assert_eq!(arr.argmax_magnitude(), Some(1));
    }

    #[test]
    fn normalize_dominant_only_touches_selected() {
        let cfg = crate::config::HrfnaConfig {
            tau_bits: 40,
            ..crate::config::HrfnaConfig::paper_default()
        };
        let c = HrfnaContext::new(cfg);
        // Build one oversized element among small ones.
        let big = Hrfna::from_signed_int(1 << 20, 0, &c)
            .mul_raw(&Hrfna::from_signed_int(1 << 25, 0, &c), &c);
        let small = Hrfna::encode(2.0, &c);
        let mut arr = HrfnaArray::from_items(vec![small.clone(), big, small], &c);
        let idx = arr.normalize_dominant(&c);
        assert_eq!(idx, Some(1));
        assert!(arr.get(1).magnitude_bits() <= c.cfg.sig_bits);
        // Calling again: dominant no longer over threshold.
        assert_eq!(arr.normalize_dominant(&c), None);
    }

    #[test]
    fn normalize_flagged_sweeps_all_oversized() {
        let cfg = crate::config::HrfnaConfig {
            tau_bits: 40,
            ..crate::config::HrfnaConfig::paper_default()
        };
        let c = HrfnaContext::new(cfg);
        let big = Hrfna::from_signed_int(1 << 20, 0, &c)
            .mul_raw(&Hrfna::from_signed_int(1 << 25, 0, &c), &c);
        let small = Hrfna::encode(2.0, &c);
        let before = big.decode(&c);
        let mut arr =
            HrfnaArray::from_items(vec![big.clone(), small, big.clone()], &c);
        assert_eq!(arr.normalize_flagged(&c).threshold, 2);
        assert!(arr.normalize_flagged(&c).is_empty());
        // Values preserved up to the Lemma 1 rounding.
        let after = arr.get(0).decode(&c);
        assert!(((after - before) / before).abs() < 1e-6);
    }

    #[test]
    fn elementwise_mul_and_sum() {
        let c = ctx();
        let a = HrfnaArray::encode(&[1.0, 2.0, 3.0], &c);
        let b = HrfnaArray::encode(&[4.0, 5.0, 6.0], &c);
        let p = a.mul(&b, &c);
        let s = p.sum(&c).decode(&c);
        assert!((s - 32.0).abs() < 1e-6, "s={s}");
    }
}
