//! Arrays of HRFNA values with deferred, interval-driven selection —
//! the paper's Fig. 1a machinery: residue vectors stay untouched in the
//! "residue plane"; a parallel array of interval evaluations (each tagged
//! with its `idx`) feeds a comparator reduction tree; only the *selected*
//! element is ever reconstructed or normalized.

use super::context::HrfnaContext;
use super::interval::{argmax_magnitude, Interval};
use super::number::Hrfna;

/// An array of hybrid values with the Fig. 1a control-plane view.
#[derive(Clone, Debug, Default)]
pub struct HrfnaArray {
    pub items: Vec<Hrfna>,
}

impl HrfnaArray {
    /// Encode a slice of reals.
    pub fn encode(xs: &[f64], ctx: &HrfnaContext) -> HrfnaArray {
        HrfnaArray {
            items: xs.iter().map(|&x| Hrfna::encode(x, ctx)).collect(),
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The control-plane view: interval evaluations of Φ-magnitude
    /// (N-interval positioned by the exponent), tagged by index.
    /// No residue data is touched (Fig. 1a left → right hand-off).
    pub fn magnitude_intervals(&self) -> Vec<Interval> {
        self.items
            .iter()
            .map(|h| {
                // Position the N-interval at the value scale: scale by 2^f
                // conservatively (f64 suffices for a control estimate).
                let k = super::number::pow2(h.f);
                Interval::new(
                    (h.iv.lo * k).min(h.iv.hi * k),
                    (h.iv.lo * k).max(h.iv.hi * k),
                )
            })
            .collect()
    }

    /// Reduction-tree selection of the dominant-magnitude element
    /// (Fig. 1a right side): returns `idx` — comparisons use only the
    /// floating interval evaluations.
    pub fn argmax_magnitude(&self) -> Option<usize> {
        argmax_magnitude(&self.magnitude_intervals())
    }

    /// Fig. 1a normalization policy: reconstruct/normalize *only the
    /// selected element* when its magnitude bound crosses τ. Returns the
    /// selected index if a normalization was performed.
    pub fn normalize_dominant(&mut self, ctx: &HrfnaContext) -> Option<usize> {
        let idx = self.argmax_magnitude()?;
        let h = &mut self.items[idx];
        if h.iv.abs_hi() >= super::number::pow2(ctx.cfg.tau_bits as i32) {
            h.normalize_to_sig(ctx, false);
            Some(idx)
        } else {
            None
        }
    }

    /// Elementwise product with another array (carry-free, parallel).
    pub fn mul(&self, other: &HrfnaArray, ctx: &HrfnaContext) -> HrfnaArray {
        assert_eq!(self.len(), other.len());
        HrfnaArray {
            items: self
                .items
                .iter()
                .zip(&other.items)
                .map(|(a, b)| a.mul(b, ctx))
                .collect(),
        }
    }

    /// Sum via exponent-coherent accumulation (Alg. 1 semantics).
    pub fn sum(&self, ctx: &HrfnaContext) -> Hrfna {
        let mut acc = Hrfna::zero(ctx, 0);
        let one = Hrfna::encode(1.0, ctx);
        for h in &self.items {
            acc.mac_assign(h, &one, ctx);
        }
        acc
    }

    /// Decode everything (test/inspection path; one CRT per element).
    pub fn decode(&self, ctx: &HrfnaContext) -> Vec<f64> {
        self.items.iter().map(|h| h.decode(ctx)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> HrfnaContext {
        HrfnaContext::paper_default()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let c = ctx();
        let xs = [1.5, -2.25, 1e10, -1e-10, 0.0];
        let arr = HrfnaArray::encode(&xs, &c);
        let back = arr.decode(&c);
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() * 1e-8 + 1e-300, "{a} vs {b}");
        }
    }

    #[test]
    fn argmax_finds_dominant_without_reconstruction() {
        let c = ctx();
        let before = c.snapshot().reconstructions;
        let arr = HrfnaArray::encode(&[3.0, -5e6, 10.0, 4999.0], &c);
        assert_eq!(arr.argmax_magnitude(), Some(1));
        // Selection must not have reconstructed anything (Fig. 1a point).
        assert_eq!(c.snapshot().reconstructions, before);
    }

    #[test]
    fn argmax_respects_exponent_scale() {
        let c = ctx();
        // Same significand, different exponents: the interval view must
        // weigh by 2^f.
        let mut a = Hrfna::encode(1.0, &c);
        let b = Hrfna::encode(1.0, &c);
        a.f += 10; // a = 1024
        let arr = HrfnaArray { items: vec![b, a] };
        assert_eq!(arr.argmax_magnitude(), Some(1));
    }

    #[test]
    fn normalize_dominant_only_touches_selected() {
        let cfg = crate::config::HrfnaConfig {
            tau_bits: 40,
            ..crate::config::HrfnaConfig::paper_default()
        };
        let c = HrfnaContext::new(cfg);
        // Build one oversized element among small ones.
        let big = Hrfna::from_signed_int(1 << 20, 0, &c)
            .mul_raw(&Hrfna::from_signed_int(1 << 25, 0, &c), &c);
        let small = Hrfna::encode(2.0, &c);
        let mut arr = HrfnaArray {
            items: vec![small.clone(), big, small],
        };
        let idx = arr.normalize_dominant(&c);
        assert_eq!(idx, Some(1));
        assert!(arr.items[1].magnitude_bits() <= c.cfg.sig_bits);
        // Calling again: dominant no longer over threshold.
        assert_eq!(arr.normalize_dominant(&c), None);
    }

    #[test]
    fn elementwise_mul_and_sum() {
        let c = ctx();
        let a = HrfnaArray::encode(&[1.0, 2.0, 3.0], &c);
        let b = HrfnaArray::encode(&[4.0, 5.0, 6.0], &c);
        let p = a.mul(&b, &c);
        let s = p.sum(&c).decode(&c);
        assert!((s - 32.0).abs() < 1e-6, "s={s}");
    }
}
