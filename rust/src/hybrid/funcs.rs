//! Division and transcendental functions in the hybrid domain —
//! the paper's §IX-C extension path, implemented: "(i) iterative
//! approximation methods operating in the hybrid domain; (ii) table-based
//! or polynomial approximations combined with HRFNA multiplication".
//!
//! All iterations below use only HRFNA multiplication, addition and
//! scaling — the operations the paper's datapath provides — so every
//! intermediate stays carry-free with threshold-normalization semantics.

use super::context::HrfnaContext;
use super::number::Hrfna;
use crate::workloads::traits::Numeric as _; // for Hrfna::scale

/// Reciprocal `1/x` by Newton–Raphson in the hybrid domain:
/// `y_{n+1} = y_n · (2 − x·y_n)` — quadratic convergence; the seed comes
/// from a coarse floating estimate (hardware: small LUT on the interval
/// estimate), after which all arithmetic is HRFNA.
pub fn reciprocal(x: &Hrfna, ctx: &HrfnaContext) -> Hrfna {
    let xf = x.decode(ctx);
    assert!(xf != 0.0, "reciprocal of zero");
    // Seed with ~8 good bits (mimics a 256-entry LUT seed).
    let seed = 1.0 / xf;
    let seed = f64::from_bits(seed.to_bits() & !((1u64 << 45) - 1));
    let mut y = Hrfna::encode(seed, ctx);
    let two = Hrfna::encode(2.0, ctx);
    // 8 bits -> 16 -> 32; two iterations exceed the 30-bit significand.
    for _ in 0..3 {
        let t = two.sub(&x.mul(&y, ctx), ctx); // 2 - x·y
        y = y.mul(&t, ctx);
    }
    y
}

/// Division `a/b = a · (1/b)`.
pub fn divide(a: &Hrfna, b: &Hrfna, ctx: &HrfnaContext) -> Hrfna {
    a.mul(&reciprocal(b, ctx), ctx)
}

/// Square root by Newton on the inverse square root
/// (`z_{n+1} = z_n·(3 − x·z_n²)/2`, then `√x = x·z`), division-free.
pub fn sqrt(x: &Hrfna, ctx: &HrfnaContext) -> Hrfna {
    let xf = x.decode(ctx);
    assert!(xf >= 0.0, "sqrt of negative");
    if xf == 0.0 {
        return Hrfna::zero(ctx, 0);
    }
    let seed = 1.0 / xf.sqrt();
    let seed = f64::from_bits(seed.to_bits() & !((1u64 << 45) - 1));
    let mut z = Hrfna::encode(seed, ctx);
    let three = Hrfna::encode(3.0, ctx);
    for _ in 0..3 {
        let z2 = z.mul(&z, ctx);
        let t = three.sub(&x.mul(&z2, ctx), ctx);
        z = z.mul(&t, ctx).scale(0.5, ctx);
    }
    x.mul(&z, ctx)
}

/// `exp(x)` via range reduction `x = k·ln2 + r`, `|r| ≤ ln2/2`, then a
/// degree-10 Horner polynomial in the hybrid domain and an exact exponent
/// bump by `k` (free in HRFNA: `f += k`).
pub fn exp(x: &Hrfna, ctx: &HrfnaContext) -> Hrfna {
    let xf = x.decode(ctx);
    assert!(xf.abs() < 700.0, "exp overflow range");
    let k = (xf / std::f64::consts::LN_2).round();
    let r = x.sub(&Hrfna::encode(k * std::f64::consts::LN_2, ctx), ctx);
    // Horner: sum r^i / i! for i = 0..=10.
    let mut acc = Hrfna::encode(1.0 / fact(10), ctx);
    for i in (0..10).rev() {
        acc = acc.mul(&r, ctx).add(&Hrfna::encode(1.0 / fact(i), ctx), ctx);
    }
    // Multiply by 2^k: exact exponent arithmetic (the interval tracks the
    // integer N, which is untouched by an exponent bump).
    let mut out = acc;
    out.f += k as i32;
    out
}

/// `sin(x)` (|x| reduced mod 2π) via odd Taylor polynomial to degree 11.
pub fn sin(x: &Hrfna, ctx: &HrfnaContext) -> Hrfna {
    let xf = x.decode(ctx);
    let r = xf.rem_euclid(std::f64::consts::TAU);
    // Fold into [-π, π], then into [-π/2, π/2] via sin(π − r) = sin(r),
    // keeping the degree-11 polynomial error below ~1e-7.
    let r = if r > std::f64::consts::PI {
        r - std::f64::consts::TAU
    } else {
        r
    };
    let r = if r > std::f64::consts::FRAC_PI_2 {
        std::f64::consts::PI - r
    } else if r < -std::f64::consts::FRAC_PI_2 {
        -std::f64::consts::PI - r
    } else {
        r
    };
    let xr = Hrfna::encode(r, ctx);
    let x2 = xr.mul(&xr, ctx);
    // sin r = r (1 - r²/3! (1 - r²/(4·5) (1 - …)))-style Horner on odd terms.
    let coeffs = [
        1.0 / fact(11),
        -1.0 / fact(9),
        1.0 / fact(7),
        -1.0 / fact(5),
        1.0 / fact(3),
        -1.0,
    ];
    // Horner in x²: p = c0; p = p·x² + c_next …, then sin = -(p)·x.
    let mut p = Hrfna::encode(coeffs[0], ctx);
    for &c in &coeffs[1..] {
        p = p.mul(&x2, ctx).add(&Hrfna::encode(c, ctx), ctx);
    }
    p.mul(&xr, ctx).neg(ctx)
}

fn fact(n: u32) -> f64 {
    (1..=n).map(|i| i as f64).product::<f64>().max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> HrfnaContext {
        HrfnaContext::paper_default()
    }

    #[test]
    fn reciprocal_converges() {
        let c = ctx();
        for x in [2.0, -3.0, 0.1, 1234.5, 1e-8, 1e12] {
            let r = reciprocal(&Hrfna::encode(x, &c), &c).decode(&c);
            let rel = ((r - 1.0 / x) * x).abs();
            assert!(rel < 1e-7, "x={x} rel={rel}");
        }
    }

    #[test]
    #[should_panic(expected = "reciprocal of zero")]
    fn reciprocal_zero_panics() {
        let c = ctx();
        reciprocal(&Hrfna::zero(&c, 0), &c);
    }

    #[test]
    fn divide_matches_f64() {
        let c = ctx();
        let q = divide(&Hrfna::encode(355.0, &c), &Hrfna::encode(113.0, &c), &c);
        let got = q.decode(&c);
        assert!((got - 355.0 / 113.0).abs() < 1e-7, "got={got}");
    }

    #[test]
    fn sqrt_values() {
        let c = ctx();
        for x in [4.0, 2.0, 1e6, 0.25, 1e-10] {
            let r = sqrt(&Hrfna::encode(x, &c), &c).decode(&c);
            let rel = ((r - x.sqrt()) / x.sqrt()).abs();
            assert!(rel < 1e-7, "x={x} rel={rel}");
        }
        assert_eq!(sqrt(&Hrfna::zero(&c, 0), &c).decode(&c), 0.0);
    }

    #[test]
    fn exp_range_reduced() {
        let c = ctx();
        for x in [0.0, 1.0, -1.0, 5.5, -10.25, 50.0] {
            let r = exp(&Hrfna::encode(x, &c), &c).decode(&c);
            let rel = ((r - x.exp()) / x.exp()).abs();
            assert!(rel < 1e-6, "x={x} got={r} rel={rel}");
        }
    }

    #[test]
    fn sin_period_and_symmetry() {
        let c = ctx();
        for x in [0.0, 0.5, 1.0, 3.0, -2.0, 6.5, 100.0] {
            let r = sin(&Hrfna::encode(x, &c), &c).decode(&c);
            assert!((r - x.sin()).abs() < 1e-6, "x={x} got={r} want={}", x.sin());
        }
    }

    #[test]
    fn interval_soundness_preserved() {
        // The iterations must not break the interval invariant.
        let c = ctx();
        let y = reciprocal(&Hrfna::encode(7.25, &c), &c);
        assert!(y.interval_is_sound(&c));
        let s = sqrt(&Hrfna::encode(19.0, &c), &c);
        assert!(s.interval_is_sound(&c));
    }
}
