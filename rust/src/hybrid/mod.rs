//! The HRFNA number system (paper §III–IV): hybrid residue–floating values
//! `(r, f)` with semantics `Φ(r, f) = CRT(r) · 2^f`, carry-free arithmetic,
//! interval-based magnitude management and threshold-driven normalization
//! with formal error bounds.
//!
//! Module map (mirrors the paper's structure):
//! * [`context`]  — shared precomputed state + op/normalization counters
//!   (§VI-F instrumentation, §VII-E normalization-frequency analysis).
//! * [`interval`] — conservative magnitude intervals (§III-E, Fig. 1a) and
//!   the reduction tree used for magnitude selection.
//! * [`number`]   — the `Hrfna` value type: Definitions 1–4, Theorem 1
//!   multiplication, exponent-synchronized addition, MAC, normalization.
//! * [`batch`]    — planar (structure-of-arrays) batched execution engine:
//!   contiguous per-channel residue lanes + packed exponent/interval
//!   arrays, with the scalar `Hrfna` ops as the bit-identical reference.
//! * [`norm`]     — the normalization engine (Definitions 3–4, §VI-E):
//!   the single scalar rescale primitive plus the planar bulk path
//!   (flagged-scan → gather → batched residue-domain rescale → scatter),
//!   with the per-element path kept as `norm::reference`.
//! * [`error`]    — Lemma 1/2 bound calculators and bound-checking probes.
//! * [`registry`] — named precision tiers (`lo`/`paper`/`wide`), each a
//!   lazily-built shared context, plus the bound-driven escalation policy
//!   the serving stack resolves requests through.

pub mod auth;
pub mod context;
pub mod interval;
pub mod number;
pub mod batch;
pub mod norm;
pub mod error;
pub mod funcs;
pub mod array;
pub mod registry;

pub use array::HrfnaArray;
pub use auth::{AuthBatch, AuthFailure, AuthKey};
pub use batch::HrfnaBatch;
pub use context::{HrfnaContext, OpCounters, OpSnapshot};
pub use interval::Interval;
pub use norm::NormReport;
pub use number::Hrfna;
pub use registry::{ContextRegistry, MagnitudeEnvelope, Resolution, Tier};
