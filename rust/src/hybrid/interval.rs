//! Conservative magnitude intervals (paper §III-E, Fig. 1a).
//!
//! Every `Hrfna` value carries an interval `[lo, hi]` bracketing its *signed
//! reconstructed integer* `N` (not Φ — the exponent is tracked separately).
//! The interval is maintained with outward-widened f64 arithmetic, so
//! normalization/comparison decisions never need a CRT reconstruction:
//! exactly the paper's "floating-point interval evaluation" control path.
//! A reduction tree over intervals selects the dominant-magnitude element
//! without disturbing residue-domain data.

/// Outward widening factor: a few ulps per operation, so that accumulated
/// f64 rounding can never make the interval lie about the true integer.
const WIDEN: f64 = 1.0 + 4.0 * f64::EPSILON;

/// A conservative signed interval `[lo, hi]` with `lo ≤ N ≤ hi`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interval {
    pub lo: f64,
    pub hi: f64,
}

#[inline]
fn widen_down(x: f64) -> f64 {
    if x > 0.0 {
        x / WIDEN
    } else {
        x * WIDEN
    }
}

#[inline]
fn widen_up(x: f64) -> f64 {
    if x > 0.0 {
        x * WIDEN
    } else {
        x / WIDEN
    }
}

impl Interval {
    /// Exact point interval.
    pub fn point(x: f64) -> Interval {
        Interval { lo: x, hi: x }
    }

    /// The zero interval.
    pub fn zero() -> Interval {
        Interval::point(0.0)
    }

    /// Interval from bounds (panics if inverted).
    pub fn new(lo: f64, hi: f64) -> Interval {
        assert!(lo <= hi, "inverted interval [{lo}, {hi}]");
        Interval { lo, hi }
    }

    /// Conservative sum.
    #[inline]
    pub fn add(&self, o: &Interval) -> Interval {
        Interval {
            lo: widen_down(self.lo + o.lo),
            hi: widen_up(self.hi + o.hi),
        }
    }

    /// Conservative product (all four corner products).
    #[inline]
    pub fn mul(&self, o: &Interval) -> Interval {
        let c = [
            self.lo * o.lo,
            self.lo * o.hi,
            self.hi * o.lo,
            self.hi * o.hi,
        ];
        let mut lo = c[0];
        let mut hi = c[0];
        for &x in &c[1..] {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        Interval {
            lo: widen_down(lo),
            hi: widen_up(hi),
        }
    }

    /// Negation.
    #[inline]
    pub fn neg(&self) -> Interval {
        Interval {
            lo: -self.hi,
            hi: -self.lo,
        }
    }

    /// Conservative ⌊·/2^s⌋ image (floor shifts toward -inf by < 1).
    #[inline]
    pub fn shr(&self, s: u32) -> Interval {
        let k = 2f64.powi(s as i32);
        Interval {
            lo: widen_down(self.lo / k) - 1.0,
            hi: widen_up(self.hi / k),
        }
    }

    /// Exact doubling by 2^s (exponent-sync exact path).
    #[inline]
    pub fn shl(&self, s: u32) -> Interval {
        let k = 2f64.powi(s as i32);
        Interval {
            lo: widen_down(self.lo * k),
            hi: widen_up(self.hi * k),
        }
    }

    /// Upper bound on |N|.
    #[inline]
    pub fn abs_hi(&self) -> f64 {
        self.lo.abs().max(self.hi.abs())
    }

    /// Lower bound on |N| (0 if the interval straddles zero).
    #[inline]
    pub fn abs_lo(&self) -> f64 {
        if self.lo <= 0.0 && self.hi >= 0.0 {
            0.0
        } else {
            self.lo.abs().min(self.hi.abs())
        }
    }

    /// Conservative bit-length estimate: ⌈log2(|N|_hi)⌉ (0 for |N| ≤ 1).
    /// §Perf: computed from the f64 exponent field (this sits on every
    /// overflow-guard check; `log2().ceil()` was measurably hot).
    #[inline]
    pub fn bits_hi(&self) -> u32 {
        let a = self.abs_hi();
        if a <= 1.0 {
            return 0;
        }
        let bits = a.to_bits();
        let e = ((bits >> 52) & 0x7FF) as i32 - 1023; // floor(log2 a), a ≥ 1
        let mantissa_zero = bits & ((1u64 << 52) - 1) == 0;
        if mantissa_zero {
            e as u32 // exact power of two: ceil == floor
        } else {
            (e + 1) as u32
        }
    }

    /// True if this interval certainly lies below `threshold_bits` bits.
    #[inline]
    pub fn certainly_below(&self, threshold_bits: u32) -> bool {
        self.abs_hi() < 2f64.powi(threshold_bits as i32)
    }

    /// Contains a concrete value?
    #[inline]
    pub fn contains(&self, x: f64) -> bool {
        self.lo <= x && x <= self.hi
    }
}

/// Reduction tree (Fig. 1a right side): return the index of the element
/// with the largest conservative magnitude, comparing only intervals.
/// Logarithmic depth in hardware; linear scan with tree semantics here.
pub fn argmax_magnitude(intervals: &[Interval]) -> Option<usize> {
    if intervals.is_empty() {
        return None;
    }
    // Pairwise tournament to mirror the hardware tree (and keep the same
    // tie-breaking as a comparator tree: lower index wins ties).
    let mut winners: Vec<usize> = (0..intervals.len()).collect();
    while winners.len() > 1 {
        let mut next = Vec::with_capacity(winners.len().div_ceil(2));
        for pair in winners.chunks(2) {
            if pair.len() == 1 {
                next.push(pair[0]);
            } else {
                let (a, b) = (pair[0], pair[1]);
                next.push(if intervals[b].abs_hi() > intervals[a].abs_hi() {
                    b
                } else {
                    a
                });
            }
        }
        winners = next;
    }
    Some(winners[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn point_and_contains() {
        let i = Interval::point(5.0);
        assert!(i.contains(5.0));
        assert!(!i.contains(5.1));
    }

    #[test]
    fn add_is_conservative() {
        let a = Interval::new(1.0, 2.0);
        let b = Interval::new(-3.0, 4.0);
        let s = a.add(&b);
        assert!(s.lo <= -2.0 && s.hi >= 6.0);
    }

    #[test]
    fn mul_signs() {
        let a = Interval::new(-2.0, 3.0);
        let b = Interval::new(-5.0, 1.0);
        let p = a.mul(&b);
        // corners: 10, -2, -15, 3 -> [-15, 10]
        assert!(p.lo <= -15.0 && p.lo > -15.1);
        assert!(p.hi >= 10.0 && p.hi < 10.1);
    }

    #[test]
    fn shr_brackets_floor() {
        let a = Interval::point(1000.0);
        let s = a.shr(3);
        assert!(s.contains((1000f64 / 8.0).floor()));
        let neg = Interval::point(-1000.0);
        let s = neg.shr(3);
        assert!(s.contains((-1000f64 / 8.0).floor()));
    }

    #[test]
    fn abs_bounds() {
        assert_eq!(Interval::new(-3.0, 2.0).abs_hi(), 3.0);
        assert_eq!(Interval::new(-3.0, 2.0).abs_lo(), 0.0);
        assert_eq!(Interval::new(2.0, 5.0).abs_lo(), 2.0);
        assert_eq!(Interval::new(-5.0, -2.0).abs_lo(), 2.0);
    }

    #[test]
    fn bits_hi_estimates() {
        assert_eq!(Interval::point(0.0).bits_hi(), 0);
        assert_eq!(Interval::point(1024.0).bits_hi(), 10);
        assert!(Interval::point(1025.0).bits_hi() >= 11);
    }

    #[test]
    fn argmax_tree() {
        let iv = [
            Interval::point(3.0),
            Interval::point(-10.0),
            Interval::point(7.0),
        ];
        assert_eq!(argmax_magnitude(&iv), Some(1));
        assert_eq!(argmax_magnitude(&[]), None);
        assert_eq!(argmax_magnitude(&iv[..1]), Some(0));
    }

    #[test]
    fn argmax_tie_prefers_lower_index() {
        let iv = [Interval::point(5.0), Interval::point(-5.0)];
        assert_eq!(argmax_magnitude(&iv), Some(0));
    }

    #[test]
    fn prop_interval_arithmetic_contains_truth() {
        check("interval-contains", |rng| {
            let a = rng.uniform(-1e6, 1e6);
            let b = rng.uniform(-1e6, 1e6);
            let ia = Interval::point(a);
            let ib = Interval::point(b);
            crate::prop_assert!(ia.add(&ib).contains(a + b), "add a={a} b={b}");
            crate::prop_assert!(ia.mul(&ib).contains(a * b), "mul a={a} b={b}");
            crate::prop_assert!(ia.neg().contains(-a), "neg a={a}");
            let s = rng.below(20) as u32;
            crate::prop_assert!(
                ia.shr(s).contains((a / 2f64.powi(s as i32)).floor()),
                "shr a={a} s={s}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_chained_ops_stay_conservative() {
        check("interval-chain", |rng| {
            let mut truth = rng.uniform(-100.0, 100.0);
            let mut iv = Interval::point(truth);
            for _ in 0..50 {
                let x = rng.uniform(-3.0, 3.0);
                if rng.bool() {
                    truth += x;
                    iv = iv.add(&Interval::point(x));
                } else {
                    truth *= x;
                    iv = iv.mul(&Interval::point(x));
                }
            }
            crate::prop_assert!(
                iv.contains(truth),
                "drift: truth={truth} iv=[{}, {}]",
                iv.lo,
                iv.hi
            );
            Ok(())
        });
    }
}
