//! The normalization engine (paper Definitions 3–4, §VI-E): the single
//! owner of "reconstruct → round-half-away shift-by-`s` → re-encode →
//! interval update", shared by the scalar and batched paths.
//!
//! * [`rescale`] is the scalar primitive. [`Hrfna::normalize`] (and
//!   through it `normalize_to_sig`, `align_to`'s lossy branch, the MAC
//!   accumulator guard and the batched `dot` tail) all delegate here —
//!   no call site hand-rolls the reconstruct/shift/re-encode sequence
//!   anymore.
//! * [`bulk_normalize`] is the planar bulk path: scan the packed
//!   exponent/interval arrays to build the flagged-column set, gather
//!   those columns into a dense scratch plane
//!   ([`crate::rns::plane::ResiduePlane::gather_columns`]), rescale them
//!   with one batched residue-domain pass
//!   ([`crate::rns::crt::CrtContext::rescale_batch`]: fixed-width
//!   reconstruction + `2^{-s} mod m_i` Shoup re-encode), scatter back,
//!   and update exponents + intervals in bulk. Zero per-element
//!   `reconstruct_signed` calls, zero per-element allocation, and the
//!   reconstruction counter advances **once per event set** — the
//!   steady-state planar loop never serializes on bigint.
//! * [`reference`] keeps the old per-element path as the executable
//!   specification; property tests pin the bulk engine bit-identical to
//!   it (residues, exponents, and interval bounds as raw u64 bits).
//!
//! In debug/test builds every event — scalar or bulk — is verified
//! against its Lemma 1/2 budget through
//! [`super::error::assert_events_within_bounds`].

use std::sync::atomic::Ordering;

use super::batch::HrfnaBatch;
use super::context::HrfnaContext;
use super::error;
use super::interval::Interval;
use super::number::Hrfna;

/// Relative widening applied when an interval is re-seeded from a
/// reconstruction (the f64 conversion truncates below the top 128 bits).
pub(crate) const RESEED_REL: f64 = 1e-9;

/// Interval re-seeded from a reconstructed value (with truncation slack).
pub(crate) fn reseeded_interval(v: f64) -> Interval {
    if v == 0.0 {
        return Interval::zero();
    }
    let slack = v.abs() * RESEED_REL;
    Interval::new(v - slack, v + slack)
}

/// What a bulk normalization sweep did: how many elements took a
/// threshold (Definition 3) event and how many took an overflow-guard
/// (§III-C) event. Callers feed these straight into `OpCounters`-style
/// accounting, so the §VII-E normalization-frequency measurement stays
/// exact even when events are batched.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NormReport {
    /// Threshold-triggered events (|N| reached τ).
    pub threshold: usize,
    /// Guard-triggered events (headroom, not τ, forced the rescale).
    pub guard: usize,
}

impl NormReport {
    /// Total events in the sweep.
    pub fn total(&self) -> usize {
        self.threshold + self.guard
    }

    /// True when the sweep touched nothing.
    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }

    /// Accumulate another sweep's counts.
    pub fn merge(&mut self, other: &NormReport) {
        self.threshold += other.threshold;
        self.guard += other.guard;
    }
}

/// The scalar rescale primitive (Definition 4): `N → round(N / 2^s)`
/// (round-half-away-from-zero, so the Lemma 1 half-unit bound holds),
/// `f → f + s`, residues re-encoded, interval re-seeded. Every scalar
/// normalization in the system funnels through here — and this in turn
/// is the batched kernel at `n = 1`: a `ResidueVec` *is* a `k × 1`
/// channel-major lane block, so the scalar path shares
/// [`crate::rns::crt::CrtContext::rescale_batch`]'s allocation-free
/// fixed-width arithmetic instead of keeping a BigUint copy of the
/// reconstruct → round → re-encode sequence alive.
pub fn rescale(h: &mut Hrfna, s: u32, ctx: &HrfnaContext, guard: bool) {
    assert!(s > 0);
    HrfnaContext::count(if guard {
        &ctx.counters.guard_norms
    } else {
        &ctx.counters.norms
    });
    HrfnaContext::count(&ctx.counters.reconstructions);
    let f_before = h.f;
    let mut lanes = std::mem::take(&mut h.r.r);
    let outcome = ctx.crt.rescale_batch(&mut lanes, 1, &[s])[0];
    h.r.r = lanes;
    h.f += s as i32;
    let signed = if outcome.neg {
        -outcome.mag_after
    } else {
        outcome.mag_after
    };
    h.iv = reseeded_interval(signed);
    if cfg!(debug_assertions) || cfg!(test) {
        error::assert_events_within_bounds(std::iter::once(error::event_sample(
            outcome.mag_before,
            outcome.mag_after,
            f_before,
            s,
        )));
    }
}

/// Guard budgets at or below the significand target are unsatisfiable:
/// rescaling stops at `sig` bits (`s = bits − sig`), so an element could
/// sit over such a budget forever. Reject the misconfiguration loudly
/// instead of silently leaving elements above the stated headroom.
fn assert_guard_budget(guard_bits: Option<u32>, sig: u32) {
    if let Some(g) = guard_bits {
        assert!(
            g > sig,
            "guard budget ({g} bits) must exceed the significand target ({sig} bits): \
             normalization cannot shrink an element below sig"
        );
    }
}

/// Event class of one flagged element.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Flag {
    Threshold,
    Guard,
}

/// Flag classification for one element — shared verbatim by the bulk
/// engine and the per-element [`reference`], so the two paths can only
/// ever disagree in the rescale arithmetic (which the property suite
/// pins bit-identical). Returns the class and the shift `s = bits − sig`
/// that returns the magnitude to the significand target, or `None` when
/// the element stays untouched (below every trigger, or already at/below
/// the significand target so `normalize_to_sig` would no-op).
fn classify(iv: &Interval, tau: f64, sig: u32, guard_bits: Option<u32>) -> Option<(Flag, u32)> {
    let bits = iv.bits_hi();
    let guard = matches!(guard_bits, Some(g) if bits >= g);
    if !(guard || iv.abs_hi() >= tau) {
        return None;
    }
    if bits <= sig {
        return None;
    }
    let class = if guard { Flag::Guard } else { Flag::Threshold };
    Some((class, bits - sig))
}

/// The planar bulk path: one flagged-column sweep over a whole batch.
///
/// `guard_bits = None` mirrors the per-element `maybe_normalize`
/// discipline (threshold events only); `Some(b)` additionally takes a
/// guard event on every element whose conservative magnitude bound has
/// reached `b` bits, even below τ — the batched form of the §III-C
/// pre-multiplication overflow guard.
pub fn bulk_normalize(
    b: &mut HrfnaBatch,
    ctx: &HrfnaContext,
    guard_bits: Option<u32>,
) -> NormReport {
    let tau = ctx.tau_f64();
    let sig = ctx.cfg.sig_bits;
    assert_guard_budget(guard_bits, sig);
    let mut idx: Vec<usize> = Vec::new();
    let mut shifts: Vec<u32> = Vec::new();
    let mut report = NormReport::default();
    for j in 0..b.len() {
        let Some((class, s)) = classify(&b.interval(j), tau, sig, guard_bits) else {
            continue;
        };
        idx.push(j);
        shifts.push(s);
        match class {
            Flag::Threshold => report.threshold += 1,
            Flag::Guard => report.guard += 1,
        }
    }
    if idx.is_empty() {
        return report;
    }
    // §VII-E accounting: per-element event counts (so frequency
    // measurement stays exact), ONE reconstruction pass per event set
    // (the planar engine's counter contract — no per-element CRT).
    ctx.counters
        .norms
        .fetch_add(report.threshold as u64, Ordering::Relaxed);
    ctx.counters
        .guard_norms
        .fetch_add(report.guard as u64, Ordering::Relaxed);
    ctx.counters.reconstructions.fetch_add(1, Ordering::Relaxed);
    let check_bounds = cfg!(debug_assertions) || cfg!(test);
    let f_before: Vec<i32> = if check_bounds {
        idx.iter().map(|&j| b.f[j]).collect()
    } else {
        Vec::new()
    };
    // Gather flagged columns densely, rescale them in one batched
    // residue-domain pass, scatter back.
    let mut scratch = b.res.gather_columns(&idx);
    let outcomes = ctx.crt.rescale_batch(scratch.lanes_mut(), idx.len(), &shifts);
    b.res.scatter_columns(&idx, &scratch);
    // Bulk exponent + interval update from the recorded outcomes.
    for ((&j, o), &s) in idx.iter().zip(&outcomes).zip(&shifts) {
        b.f[j] += s as i32;
        let signed = if o.neg { -o.mag_after } else { o.mag_after };
        let iv = reseeded_interval(signed);
        b.iv_lo[j] = iv.lo;
        b.iv_hi[j] = iv.hi;
    }
    if check_bounds {
        error::assert_events_within_bounds(
            outcomes
                .iter()
                .zip(&shifts)
                .zip(&f_before)
                .map(|((o, &s), &f)| error::event_sample(o.mag_before, o.mag_after, f, s)),
        );
    }
    report
}

/// MAC-carrying bulk normalization (the authenticated-serving form of
/// [`bulk_normalize`]): identical flag classification and value-lane
/// arithmetic, but the flagged columns of the companion MAC plane are
/// gathered alongside the value columns and rescaled through
/// [`crate::rns::crt::CrtContext::rescale_batch_with_mac`], which applies
/// the same Definition-4 offset scaled by the channel key — so
/// `mac_i = α_i·r_i` holds exactly after the sweep without ever
/// recomputing a MAC from a value. Requires the odd-moduli fast path
/// (enforced at admission by `registry::tier_covers` for authenticated
/// traffic; panics loudly otherwise).
pub fn bulk_normalize_authenticated(
    b: &mut HrfnaBatch,
    mac: &mut crate::rns::plane::ResiduePlane,
    alpha: &[u64],
    ctx: &HrfnaContext,
    guard_bits: Option<u32>,
) -> NormReport {
    let tau = ctx.tau_f64();
    let sig = ctx.cfg.sig_bits;
    assert_guard_budget(guard_bits, sig);
    debug_assert_eq!(mac.k(), b.k());
    debug_assert_eq!(mac.n(), b.len());
    let mut idx: Vec<usize> = Vec::new();
    let mut shifts: Vec<u32> = Vec::new();
    let mut report = NormReport::default();
    for j in 0..b.len() {
        let Some((class, s)) = classify(&b.interval(j), tau, sig, guard_bits) else {
            continue;
        };
        idx.push(j);
        shifts.push(s);
        match class {
            Flag::Threshold => report.threshold += 1,
            Flag::Guard => report.guard += 1,
        }
    }
    if idx.is_empty() {
        return report;
    }
    ctx.counters
        .norms
        .fetch_add(report.threshold as u64, Ordering::Relaxed);
    ctx.counters
        .guard_norms
        .fetch_add(report.guard as u64, Ordering::Relaxed);
    ctx.counters.reconstructions.fetch_add(1, Ordering::Relaxed);
    let check_bounds = cfg!(debug_assertions) || cfg!(test);
    let f_before: Vec<i32> = if check_bounds {
        idx.iter().map(|&j| b.f[j]).collect()
    } else {
        Vec::new()
    };
    let mut scratch = b.res.gather_columns(&idx);
    let mut mac_scratch = mac.gather_columns(&idx);
    let outcomes = ctx.crt.rescale_batch_with_mac(
        scratch.lanes_mut(),
        mac_scratch.lanes_mut(),
        alpha,
        idx.len(),
        &shifts,
    );
    b.res.scatter_columns(&idx, &scratch);
    mac.scatter_columns(&idx, &mac_scratch);
    for ((&j, o), &s) in idx.iter().zip(&outcomes).zip(&shifts) {
        b.f[j] += s as i32;
        let signed = if o.neg { -o.mag_after } else { o.mag_after };
        let iv = reseeded_interval(signed);
        b.iv_lo[j] = iv.lo;
        b.iv_hi[j] = iv.hi;
    }
    if check_bounds {
        error::assert_events_within_bounds(
            outcomes
                .iter()
                .zip(&shifts)
                .zip(&f_before)
                .map(|((o, &s), &f)| error::event_sample(o.mag_before, o.mag_after, f, s)),
        );
    }
    report
}

/// The former per-element bulk path, kept as the executable
/// specification: identical flag classification, then the scalar
/// normalize per flagged element. Backs the bit-identity property tests
/// and the `bench_norm` cost comparison.
pub mod reference {
    use super::{classify, Flag, HrfnaBatch, HrfnaContext, NormReport};

    /// Per-element mirror of [`super::bulk_normalize`].
    pub fn bulk_normalize(
        b: &mut HrfnaBatch,
        ctx: &HrfnaContext,
        guard_bits: Option<u32>,
    ) -> NormReport {
        let tau = ctx.tau_f64();
        let sig = ctx.cfg.sig_bits;
        super::assert_guard_budget(guard_bits, sig);
        let mut report = NormReport::default();
        for j in 0..b.len() {
            let Some((class, _)) = classify(&b.interval(j), tau, sig, guard_bits) else {
                continue;
            };
            let guard = class == Flag::Guard;
            let mut h = b.get(j);
            h.normalize_to_sig(ctx, guard);
            b.set(j, &h);
            match class {
                Flag::Threshold => report.threshold += 1,
                Flag::Guard => report.guard += 1,
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HrfnaConfig;
    use crate::rns::moduli::generate_prime_moduli;
    use crate::util::proptest::check_with;
    use crate::util::prng::Rng;

    /// Tight-threshold context so events actually fire.
    fn tight_ctx() -> HrfnaContext {
        HrfnaContext::new(HrfnaConfig {
            tau_bits: 40,
            ..HrfnaConfig::paper_default()
        })
    }

    /// A value with exactly `bits` magnitude bits (top bit pinned, so
    /// flag classification is deterministic per `bits`) at exponent `f`.
    fn value_with_bits(rng: &mut Rng, bits: u32, f: i32, c: &HrfnaContext) -> Hrfna {
        let v = ((rng.next_u64() >> (64 - bits)) | (1 << (bits - 1))) as i64;
        Hrfna::from_signed_int(if rng.bool() { v } else { -v }, f, c)
    }

    fn assert_batches_bit_identical(a: &HrfnaBatch, b: &HrfnaBatch) {
        assert_eq!(a.len(), b.len());
        for j in 0..a.len() {
            let (x, y) = (a.get(j), b.get(j));
            assert_eq!(x.r, y.r, "residues j={j}");
            assert_eq!(x.f, y.f, "exponent j={j}");
            // Interval bounds as raw bits: the bulk reseed must match the
            // scalar path exactly, not merely bracket the same value.
            assert_eq!(x.iv.lo.to_bits(), y.iv.lo.to_bits(), "iv.lo j={j}");
            assert_eq!(x.iv.hi.to_bits(), y.iv.hi.to_bits(), "iv.hi j={j}");
        }
    }

    #[test]
    fn prop_bulk_bit_identical_to_reference_thresholds() {
        // Densities: none / one / mixed / all flagged, random magnitudes
        // straddling τ, random exponents.
        let c = tight_ctx();
        check_with("norm-bulk-vs-reference", 48, |rng| {
            let n = 1 + rng.below(24) as usize;
            let density = rng.below(4);
            let items: Vec<Hrfna> = (0..n)
                .map(|j| {
                    let over = match density {
                        0 => false,
                        1 => j == 0,
                        2 => rng.bool(),
                        _ => true,
                    };
                    let bits = if over {
                        41 + rng.below(22) as u32
                    } else {
                        5 + rng.below(30) as u32
                    };
                    let f = rng.range_i64(-40, 40) as i32;
                    value_with_bits(rng, bits, f, &c)
                })
                .collect();
            let mut bulk = HrfnaBatch::from_items(&items, c.k());
            let mut refr = bulk.clone();
            let got = bulk_normalize(&mut bulk, &c, None);
            let want = reference::bulk_normalize(&mut refr, &c, None);
            crate::prop_assert!(got == want, "report {got:?} != {want:?}");
            assert_batches_bit_identical(&bulk, &refr);
            // Second sweep finds nothing new on either path.
            let again = bulk_normalize(&mut bulk, &c, None);
            crate::prop_assert!(again.is_empty(), "resweep {again:?}");
            Ok(())
        });
    }

    #[test]
    fn prop_bulk_bit_identical_with_guard_triggers() {
        // Guard class: elements over the bit budget are guard events even
        // below τ; elements over τ stay threshold events.
        let c = tight_ctx();
        check_with("norm-bulk-guard", 32, |rng| {
            let n = 1 + rng.below(16) as usize;
            let items: Vec<Hrfna> = (0..n)
                .map(|_| {
                    let bits = 5 + rng.below(58) as u32;
                    let f = rng.range_i64(-20, 20) as i32;
                    value_with_bits(rng, bits, f, &c)
                })
                .collect();
            let guard_bits = Some(36);
            let mut bulk = HrfnaBatch::from_items(&items, c.k());
            let mut refr = bulk.clone();
            let got = bulk_normalize(&mut bulk, &c, guard_bits);
            let want = reference::bulk_normalize(&mut refr, &c, guard_bits);
            crate::prop_assert!(got == want, "report {got:?} != {want:?}");
            assert_batches_bit_identical(&bulk, &refr);
            Ok(())
        });
    }

    #[test]
    fn prop_bulk_bit_identical_on_random_moduli() {
        check_with("norm-bulk-random-moduli", 16, |rng| {
            let k = 4 + rng.below(4) as usize;
            let width = 16 + rng.below(12) as u32;
            let cfg = HrfnaConfig {
                moduli: generate_prime_moduli(k, width),
                tau_bits: 40,
                scale_step: 16,
                sig_bits: 20,
                exponent_width: 16,
                clock_mhz: 300.0,
            };
            let c = HrfnaContext::new(cfg);
            let n = 1 + rng.below(12) as usize;
            let items: Vec<Hrfna> = (0..n)
                .map(|_| {
                    let bits = 10 + rng.below(45) as u32;
                    let f = rng.range_i64(-20, 20) as i32;
                    value_with_bits(rng, bits, f, &c)
                })
                .collect();
            let mut bulk = HrfnaBatch::from_items(&items, c.k());
            let mut refr = bulk.clone();
            let got = bulk_normalize(&mut bulk, &c, None);
            let want = reference::bulk_normalize(&mut refr, &c, None);
            crate::prop_assert!(got == want, "report {got:?} != {want:?}");
            assert_batches_bit_identical(&bulk, &refr);
            Ok(())
        });
    }

    #[test]
    fn bulk_counts_one_reconstruction_per_event_set() {
        // The acceptance contract: zero per-element reconstructions in
        // the bulk path — the reconstruction counter advances once per
        // non-empty event set, while the event counters stay per-element.
        let c = tight_ctx();
        let mut rng = Rng::new(17);
        let mut items: Vec<Hrfna> = (0..6)
            .map(|_| value_with_bits(&mut rng, 50, -5, &c))
            .collect();
        items.extend((0..3).map(|_| value_with_bits(&mut rng, 10, 0, &c)));
        let mut b = HrfnaBatch::from_items(&items, c.k());
        let before = c.snapshot();
        let report = b.normalize_flagged(&c);
        let d = c.snapshot().since(&before);
        assert_eq!(report, NormReport { threshold: 6, guard: 0 });
        assert_eq!(d.reconstructions, 1, "one bulk CRT pass per event set");
        assert_eq!(d.norms, 6, "per-element event accounting");
        assert_eq!(d.guard_norms, 0);
        // Nothing flagged → no reconstruction at all.
        let before = c.snapshot();
        assert!(b.normalize_flagged(&c).is_empty());
        assert_eq!(c.snapshot().since(&before).reconstructions, 0);
    }

    #[test]
    fn bulk_guard_events_counted_separately() {
        let c = tight_ctx();
        let mut rng = Rng::new(29);
        let items: Vec<Hrfna> = (0..4)
            .map(|_| value_with_bits(&mut rng, 38, 0, &c)) // below τ=2^40
            .collect();
        let mut b = HrfnaBatch::from_items(&items, c.k());
        let before = c.snapshot();
        let report = b.normalize_guarded(&c, 36);
        let d = c.snapshot().since(&before);
        assert_eq!(report, NormReport { threshold: 0, guard: 4 });
        assert_eq!(d.guard_norms, 4);
        assert_eq!(d.norms, 0);
        assert_eq!(d.reconstructions, 1);
        for j in 0..b.len() {
            assert!(b.get(j).magnitude_bits() <= c.cfg.sig_bits + 1, "j={j}");
        }
    }

    #[test]
    fn interval_shr_widening_pinned_to_scalar_path() {
        // Regression pin (ISSUE 4 satellite): after a bulk sweep the
        // packed intervals equal the scalar `maybe_normalize` intervals
        // *bit for bit* — an interval that merely contains the decoded
        // value would let the batch path drift wide (`Interval::shr`
        // style widening) and desynchronize later flag decisions.
        let c = tight_ctx();
        let mut rng = Rng::new(41);
        let mut items: Vec<Hrfna> = (0..12)
            .map(|_| {
                let bits = 30 + rng.below(30) as u32;
                value_with_bits(&mut rng, bits, -8, &c)
            })
            .collect();
        let mut b = HrfnaBatch::from_items(&items, c.k());
        b.normalize_flagged(&c);
        for (j, it) in items.iter_mut().enumerate() {
            it.maybe_normalize(&c);
            let got = b.get(j);
            assert_eq!(got.iv.lo.to_bits(), it.iv.lo.to_bits(), "iv.lo j={j}");
            assert_eq!(got.iv.hi.to_bits(), it.iv.hi.to_bits(), "iv.hi j={j}");
            assert_eq!(got.f, it.f, "f j={j}");
        }
    }

    #[test]
    #[should_panic(expected = "guard budget")]
    fn guard_budget_at_or_below_sig_rejected() {
        let c = tight_ctx(); // sig_bits = 30
        let mut b = HrfnaBatch::zeros(2, &c);
        b.normalize_guarded(&c, 30);
    }

    #[test]
    fn report_merge_and_total() {
        let mut a = NormReport { threshold: 2, guard: 1 };
        let b = NormReport { threshold: 3, guard: 4 };
        a.merge(&b);
        assert_eq!(a, NormReport { threshold: 5, guard: 5 });
        assert_eq!(a.total(), 10);
        assert!(!a.is_empty());
        assert!(NormReport::default().is_empty());
    }

    #[test]
    fn scalar_rescale_matches_legacy_normalize_semantics() {
        // The delegated Hrfna::normalize must behave exactly as before:
        // Lemma 1 bound, exponent advance, interval soundness.
        let c = HrfnaContext::paper_default();
        let mut v = Hrfna::from_signed_int(0x7FFF_FFFF_FFFF, -20, &c);
        let before = v.decode(&c);
        let f0 = v.f;
        v.normalize(16, &c, false);
        assert_eq!(v.f, f0 + 16);
        let after = v.decode(&c);
        assert!((after - before).abs() <= super::super::number::pow2(-20 + 15));
        assert!(v.interval_is_sound(&c));
    }
}
