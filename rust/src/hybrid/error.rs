//! Formal error-bound calculators and measurement probes (paper §III-D).
//!
//! Lemma 1 (absolute): one normalization with scale `K = 2^s` at exponent
//! `f` introduces `|ε| ≤ 2^{f+s-1}` (half a post-scaling unit, since the
//! implementation rounds half-away-from-zero).
//!
//! Lemma 2 (relative): if normalization triggers at `|N| ≥ τ = 2^{τbits}`,
//! the relative error per event is `|ε|/|Φ| ≤ 2^{s-1}/|N| ≤ 2^{s-1-τbits}`.
//! (The paper states the looser `2^{-s}`; we compute both and verify the
//! tight form, which implies the paper's whenever `2s ≤ τbits + 1`.)
//!
//! These bounds compose: a computation with `E` normalization events and
//! magnitude-`|Φ|`-scale values accumulates at most `E · 2^{s-1-τbits}`
//! relative error — the "deterministic block-floating" behaviour of §III-D.

use super::context::HrfnaContext;
use super::number::{ldexp_staged, pow2, Hrfna};

/// Lemma 1: absolute error bound for one normalization event.
pub fn lemma1_abs_bound(f_before: i32, s: u32) -> f64 {
    ldexp_staged(1.0, f_before + s as i32 - 1)
}

/// Tight relative error bound for one normalization event triggered at
/// `|N| ≥ 2^{tau_bits}`.
pub fn lemma2_rel_bound_tight(s: u32, tau_bits: u32) -> f64 {
    pow2(s as i32 - 1 - tau_bits as i32)
}

/// The paper's stated Lemma 2 form: `2^{-s}`.
pub fn lemma2_rel_bound_paper(s: u32) -> f64 {
    pow2(-(s as i32))
}

/// Composed relative-error budget after `events` normalizations.
pub fn composed_rel_bound(events: u64, s: u32, tau_bits: u32) -> f64 {
    events as f64 * lemma2_rel_bound_tight(s, tau_bits)
}

/// Result of one measured normalization event.
#[derive(Clone, Copy, Debug)]
pub struct NormErrorSample {
    /// Φ before normalization (exact reconstruction).
    pub before: f64,
    /// Φ after normalization.
    pub after: f64,
    /// |after - before|.
    pub abs_err: f64,
    /// Lemma 1 bound for this event.
    pub abs_bound: f64,
    /// |err| / |before|.
    pub rel_err: f64,
    /// Tight relative bound for this event (uses the actual |N|).
    pub rel_bound: f64,
}

impl NormErrorSample {
    /// Both bounds hold? The check allows f64 *measurement* slack: the
    /// before/after values are themselves decoded through ~3-ulp f64
    /// conversions, so an apparent error of up to ~1e-14·|Φ| is probe
    /// noise, not a bound violation (the residue-domain arithmetic under
    /// measurement is exact integers).
    pub fn within_bounds(&self) -> bool {
        let probe_noise = self.before.abs() * 1e-14;
        self.abs_err <= self.abs_bound * (1.0 + 1e-9) + probe_noise
            && (self.before == 0.0
                || self.rel_err <= self.rel_bound * (1.0 + 1e-9) + 1e-14)
    }
}

/// Build the §III-D verification sample for one *already-performed*
/// normalization event from its recorded magnitudes — no extra
/// reconstruction: the batched engine hands over `|N|` before/after from
/// the same fixed-width pass that produced the residues, and the scalar
/// rescale primitive from its own reconstruction.
pub fn event_sample(mag_before: f64, mag_after: f64, f_before: i32, s: u32) -> NormErrorSample {
    let before = ldexp_staged(mag_before, f_before);
    let after = ldexp_staged(mag_after, f_before + s as i32);
    let abs_err = (after - before).abs();
    let rel_err = if before == 0.0 { 0.0 } else { abs_err / before };
    let rel_bound = if mag_before == 0.0 {
        0.0
    } else {
        pow2(s as i32 - 1) / mag_before * 1.0001 // to_f64 truncation slack
    };
    NormErrorSample {
        before,
        after,
        abs_err,
        abs_bound: lemma1_abs_bound(f_before, s),
        rel_err,
        rel_bound,
    }
}

/// Debug/test hook of the normalization engine: assert the Lemma 1/2
/// budgets for every event of a bulk set. Φ probes that saturate f64
/// (extreme exponents decode to ±inf) are probe overflow, not bound
/// violations, and are skipped.
pub fn assert_events_within_bounds(events: impl Iterator<Item = NormErrorSample>) {
    for (i, sample) in events.enumerate() {
        if !(sample.before.is_finite() && sample.after.is_finite()) {
            continue;
        }
        // A Lemma 1 budget below f64's subnormal floor cannot be measured
        // with f64 probes (any ulp of probe quantization would exceed it,
        // including a `before` that ties to 0.0 while `after` rounds to
        // the minimum subnormal); the bound is still exact in the integer
        // domain — skip the probe.
        if sample.abs_bound == 0.0 {
            continue;
        }
        assert!(
            sample.within_bounds(),
            "normalization event {i} violates its Lemma 1/2 budget: {sample:?}"
        );
    }
}

/// Normalize `v` by `s` and measure the error against the exact
/// reconstruction before/after — the §III-D verification probe.
pub fn measure_normalization(v: &mut Hrfna, s: u32, ctx: &HrfnaContext) -> NormErrorSample {
    let before = v.decode(ctx);
    let f_before = v.f;
    // Actual |N| before the event (for the tight relative bound).
    let (_, mag) = v.reconstruct_signed(ctx);
    let n_abs = mag.to_f64();
    v.normalize(s, ctx, false);
    let after = v.decode(ctx);
    let abs_err = (after - before).abs();
    let abs_bound = lemma1_abs_bound(f_before, s);
    let rel_err = if before == 0.0 {
        0.0
    } else {
        abs_err / before.abs()
    };
    let rel_bound = if n_abs == 0.0 {
        0.0
    } else {
        pow2(s as i32 - 1) / n_abs * 1.0001 // to_f64 truncation slack
    };
    NormErrorSample {
        before,
        after,
        abs_err,
        abs_bound,
        rel_err,
        rel_bound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_with;

    fn ctx() -> HrfnaContext {
        HrfnaContext::paper_default()
    }

    #[test]
    fn bound_values() {
        assert_eq!(lemma1_abs_bound(0, 1), 1.0);
        assert_eq!(lemma1_abs_bound(-4, 5), 1.0);
        assert_eq!(lemma2_rel_bound_paper(8), 1.0 / 256.0);
        assert!(lemma2_rel_bound_tight(32, 112) < lemma2_rel_bound_paper(32));
    }

    #[test]
    fn composed_budget_scales_linearly() {
        let one = composed_rel_bound(1, 32, 112);
        let many = composed_rel_bound(1000, 32, 112);
        assert!((many / one - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn measured_normalization_within_bounds() {
        let c = ctx();
        let mut v = Hrfna::from_signed_int(0x0012_3456_789A_BCDE, -30, &c);
        let sample = measure_normalization(&mut v, 20, &c);
        assert!(sample.within_bounds(), "{sample:?}");
        assert!(sample.abs_err > 0.0, "rounding should be visible here");
    }

    #[test]
    fn prop_lemma_bounds_never_violated() {
        let c = ctx();
        check_with("lemma-bounds", 128, |rng| {
            // Random magnitude 2^20..2^60, random exponent, random step.
            let bits = 20 + rng.below(40) as u32;
            let n = (rng.next_u64() >> (64 - bits)).max(1) as i64;
            let f = rng.range_i64(-60, 60) as i32;
            let s = 1 + rng.below(24) as u32;
            let mut v = Hrfna::from_signed_int(
                if rng.bool() { n } else { -n },
                f,
                &c,
            );
            let sample = measure_normalization(&mut v, s, &c);
            crate::prop_assert!(
                sample.within_bounds(),
                "bits={bits} f={f} s={s} sample={sample:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn event_sample_matches_measured_probe() {
        // The allocation-free bulk sample must agree with the
        // reconstruct-twice probe on the same event.
        let c = ctx();
        let mut v = Hrfna::from_signed_int(0x0012_3456_789A_BCDE, -30, &c);
        let (_, mag) = v.reconstruct_signed(&c);
        let (f_before, mag_before) = (v.f, mag.to_f64());
        let measured = measure_normalization(&mut v, 20, &c);
        let (_, mag2) = v.reconstruct_signed(&c);
        let bulk = event_sample(mag_before, mag2.to_f64(), f_before, 20);
        assert!(bulk.within_bounds(), "{bulk:?}");
        assert_eq!(bulk.before.to_bits(), measured.before.to_bits());
        assert_eq!(bulk.after.to_bits(), measured.after.to_bits());
        assert_eq!(bulk.abs_bound.to_bits(), measured.abs_bound.to_bits());
        assert_eq!(bulk.rel_bound.to_bits(), measured.rel_bound.to_bits());
    }

    #[test]
    fn assert_events_skips_saturated_probes_and_zero() {
        // ±inf probes (decode overflow) and exact-zero events must not
        // trip the bulk assertion.
        assert_events_within_bounds(
            [
                event_sample(f64::MAX, f64::MAX, 2000, 8), // before saturates
                event_sample(0.0, 0.0, 0, 8),
                event_sample(1024.0, 512.0, 0, 1),
                // Probe floor: the budget 2^{-1076} underflows to 0 while
                // `after` lands on the minimum subnormal — skipped, not a
                // violation.
                event_sample(1.0, 1.0, -1075, 1),
            ]
            .into_iter(),
        );
    }

    #[test]
    #[should_panic(expected = "Lemma 1/2 budget")]
    fn assert_events_flags_violations() {
        // A fabricated event whose error grossly exceeds Lemma 1.
        assert_events_within_bounds(std::iter::once(event_sample(1024.0, 1000.0, 0, 1)));
    }

    #[test]
    fn threshold_triggered_events_meet_tight_relative_bound() {
        // Values at/above tau normalized by scale_step must satisfy the
        // tight Lemma 2 form 2^{s-1-tau_bits}.
        let cfg = crate::config::HrfnaConfig {
            tau_bits: 50,
            scale_step: 16,
            ..crate::config::HrfnaConfig::paper_default()
        };
        let c = HrfnaContext::new(cfg);
        let mut v = Hrfna::from_signed_int(1 << 51, -10, &c); // above tau
        let s = c.cfg.scale_step;
        let sample = measure_normalization(&mut v, s, &c);
        let tight = lemma2_rel_bound_tight(s, c.cfg.tau_bits);
        assert!(
            sample.rel_err <= tight * (1.0 + 1e-6),
            "rel={} tight={tight}",
            sample.rel_err
        );
    }
}
