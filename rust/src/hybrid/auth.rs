//! SPDZ-style authenticated residue batches (ROADMAP item 2): per-channel
//! MAC lanes `mac_i(x) = α_i·x mod m_i` carried alongside each value lane
//! and checked at decode, plus the Freivalds randomized verifier for
//! matmul results and the wire checksum for authenticated result frames.
//!
//! ## MAC lane layout and algebra
//!
//! An [`AuthBatch`] pairs an [`HrfnaBatch`] with a second `k × n`
//! channel-major [`ResiduePlane`] holding the MAC lanes, and a duplicate
//! of the packed exponent array (`f_dup`) covering the exponent words.
//! Because every residue channel is an independent ring (the carry-free
//! channel independence the paper builds on), the MAC composes through
//! the existing kernels with *public* (unauthenticated) co-operands:
//!
//! * `lane_mul` / `lane_fma`: `mac(x)·y = α·x·y = mac(x·y)` per channel,
//! * `lane_scale` by a constant `c`: `mac(x)·c = mac(c·x)`,
//! * `lane_dot`: `Σ mac(x_t)·y_t = α·Σ x_t·y_t = mac(Σ x_t·y_t)`,
//! * `norm::bulk_normalize`: the Definition-4 rescale applies the same
//!   offset `d` scaled by `α` to the MAC lane
//!   ([`crate::rns::crt::CrtContext::rescale_batch_with_mac`]), so
//!   `mac' = (mac ± α·d)·2^{-s} = α·r'` **exactly** — the MAC is updated
//!   homomorphically, never recomputed from the (possibly corrupted)
//!   value.
//!
//! ## Detection probability
//!
//! A fault that changes value or MAC residues in channel `i` is accepted
//! only if the corruption pair `(δ, δ')` happens to satisfy
//! `δ' = α_i·δ mod m_i`. For the physical fault model — a single bit
//! flip, `δ = ±2^b` with `δ' = 0` (or vice versa) — detection is
//! **deterministic** on odd moduli: `α_i·δ ≠ 0` because `α_i ≠ 0` and
//! `2^b` is invertible. Against an adversary who crafts both `δ ≠ 0` and
//! `δ'` without knowing the key, exactly one `α_i` of the `m_i − 1`
//! possible keys satisfies the relation, so the per-channel miss
//! probability is at most `1/(m_i − 1)` — within one part in `m_i` of
//! the information-theoretic `1/m_i` bound — which
//! [`AuthKey::sample`] guarantees by drawing `α_i` uniformly from
//! `[1, m_i)`. The one blind spot is arithmetic wraparound past `M/2`
//! (both value and MAC wrap consistently); that is exactly the overflow
//! `registry::tier_covers` excludes, with one extra guard bit demanded
//! for authenticated traffic.

use crate::hybrid::batch::HrfnaBatch;
use crate::hybrid::context::HrfnaContext;
use crate::hybrid::norm::{self, NormReport};
use crate::rns::barrett::Barrett;
use crate::rns::plane::{self, ResiduePlane};
use crate::util::prng::Rng;
use thiserror::Error;

/// Why an authenticated batch failed verification.
#[derive(Clone, Copy, Debug, Error, PartialEq, Eq)]
pub enum AuthFailure {
    /// A lane word is out of its modulus range (no in-range residue ever
    /// leaves the kernels, so this is itself a corruption).
    #[error("residue out of range: element {elem} channel {channel}")]
    Range { elem: usize, channel: usize },
    /// The per-channel check `mac_i ?= α_i·r_i` failed.
    #[error("MAC check failed: element {elem} channel {channel}")]
    Mac { elem: usize, channel: usize },
    /// The duplicated exponent word disagrees with the primary.
    #[error("exponent duplicate mismatch: element {elem} ({f} vs {dup})")]
    Exponent { elem: usize, f: i32, dup: i32 },
}

/// The per-channel MAC key `α_i ∈ [1, m_i)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AuthKey {
    pub alpha: Vec<u64>,
}

impl AuthKey {
    /// Sample a key uniformly from `[1, m_i)` per channel. Zero is
    /// excluded (`α_i = 0` would accept any value in that channel), which
    /// is what makes the documented `≤ 1/(m_i − 1)` per-channel miss
    /// bound hold.
    pub fn sample(moduli: &[u64], seed: u64) -> AuthKey {
        let mut rng = Rng::new(seed ^ 0xA1FA_4E7_5EED_00D1);
        AuthKey {
            alpha: moduli.iter().map(|&m| 1 + rng.below(m - 1)).collect(),
        }
    }

    /// Number of channels.
    pub fn k(&self) -> usize {
        self.alpha.len()
    }

    /// Worst-channel adversarial miss probability: `max_i 1/(m_i − 1)`.
    /// (Random single bit flips are detected deterministically; see the
    /// module docs.)
    pub fn miss_probability(moduli: &[u64]) -> f64 {
        moduli
            .iter()
            .map(|&m| 1.0 / (m as f64 - 1.0))
            .fold(0.0, f64::max)
    }
}

/// An authenticated batch: value batch + MAC lanes + duplicated exponents.
#[derive(Clone, Debug)]
pub struct AuthBatch {
    pub(crate) b: HrfnaBatch,
    pub(crate) mac: ResiduePlane,
    pub(crate) f_dup: Vec<i32>,
}

impl AuthBatch {
    /// Derive the MAC lanes for a freshly encoded batch (one
    /// [`plane::lane_scale`] Shoup pass per channel) and duplicate the
    /// exponent words. Authentication happens at the trust boundary —
    /// right after encode, before data enters the untrusted compute.
    pub fn authenticate(b: HrfnaBatch, key: &AuthKey, ctx: &HrfnaContext) -> AuthBatch {
        debug_assert_eq!(key.k(), b.k());
        let mac = b.res.scale_channels(&key.alpha, ctx.barrett());
        let f_dup = b.f.clone();
        AuthBatch { b, mac, f_dup }
    }

    /// The value batch (read-only).
    pub fn batch(&self) -> &HrfnaBatch {
        &self.b
    }

    /// The MAC plane (read-only).
    pub fn mac_plane(&self) -> &ResiduePlane {
        &self.mac
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.b.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.b.is_empty()
    }

    /// Check every element: residues in range, `mac_i = α_i·r_i` per
    /// channel, duplicated exponent equal. First failure wins.
    pub fn verify(&self, key: &AuthKey, ctx: &HrfnaContext) -> Result<(), AuthFailure> {
        let n = self.b.len();
        for (elem, (&f, &dup)) in self.b.f.iter().zip(&self.f_dup).enumerate() {
            if f != dup {
                return Err(AuthFailure::Exponent { elem, f, dup });
            }
        }
        for channel in 0..self.b.k() {
            let bar = ctx.barrett()[channel];
            let m = ctx.cfg.moduli[channel];
            let alpha = key.alpha[channel];
            let vals = self.b.res.lane(channel);
            let macs = self.mac.lane(channel);
            for elem in 0..n {
                let (r, mw) = (vals[elem], macs[elem]);
                if r >= m || mw >= m {
                    return Err(AuthFailure::Range { elem, channel });
                }
                if bar.mul(alpha, r) != mw {
                    return Err(AuthFailure::Mac { elem, channel });
                }
            }
        }
        Ok(())
    }

    /// Verify, then decode (the only way values leave an authenticated
    /// batch).
    pub fn decode_verified(
        &self,
        key: &AuthKey,
        ctx: &HrfnaContext,
    ) -> Result<Vec<f64>, AuthFailure> {
        self.verify(key, ctx)?;
        Ok(self.b.decode(ctx))
    }

    /// Elementwise multiply by a *public* batch: value lanes through
    /// `lane_mul`, MAC lanes through the same kernel (`mac(x)·y =
    /// mac(x·y)`). Carry-free only — the caller runs the MAC-aware
    /// normalization between ops (the scalar auto-normalize would
    /// re-encode residues outside the MAC update path, which is exactly
    /// the laundering authentication forbids). Panics if a product could
    /// overflow the signed headroom.
    pub fn mul_plain(&self, y: &HrfnaBatch, ctx: &HrfnaContext) -> AuthBatch {
        assert_eq!(self.len(), y.len());
        let bud = ctx.signed_budget_bits();
        let n = self.len();
        let mut iv_lo = vec![0.0; n];
        let mut iv_hi = vec![0.0; n];
        for j in 0..n {
            let ia = self.b.interval(j);
            let ib = y.interval(j);
            assert!(
                ia.bits_hi() + ib.bits_hi() < bud,
                "authenticated mul would overflow: normalize first (element {j})"
            );
            let z = ia.mul(&ib);
            iv_lo[j] = z.lo;
            iv_hi[j] = z.hi;
        }
        let bars = ctx.barrett();
        AuthBatch {
            b: HrfnaBatch {
                res: self.b.res.mul(&y.res, bars),
                f: self.b.f.iter().zip(&y.f).map(|(a, b)| a + b).collect(),
                iv_lo,
                iv_hi,
            },
            mac: self.mac.mul(&y.res, bars),
            f_dup: self.f_dup.iter().zip(&y.f).map(|(a, b)| a + b).collect(),
        }
    }

    /// Multiply every element by the public real constant `c` (encode,
    /// then one `lane_scale` per channel on both planes).
    pub fn scale_plain(&self, c: f64, ctx: &HrfnaContext) -> AuthBatch {
        let enc = crate::hybrid::number::Hrfna::encode(c, ctx);
        let bud = ctx.signed_budget_bits();
        let cbits = enc.iv.bits_hi();
        let n = self.len();
        let mut iv_lo = vec![0.0; n];
        let mut iv_hi = vec![0.0; n];
        for j in 0..n {
            let ia = self.b.interval(j);
            assert!(
                ia.bits_hi() + cbits < bud,
                "authenticated scale would overflow: normalize first (element {j})"
            );
            let z = ia.mul(&enc.iv);
            iv_lo[j] = z.lo;
            iv_hi[j] = z.hi;
        }
        let bars = ctx.barrett();
        let k = self.b.k();
        let mut res = ResiduePlane::zero(k, n);
        let mut mac = ResiduePlane::zero(k, n);
        for ch in 0..k {
            plane::lane_scale(bars[ch], self.b.res.lane(ch), enc.r.r[ch], res.lane_mut(ch));
            plane::lane_scale(bars[ch], self.mac.lane(ch), enc.r.r[ch], mac.lane_mut(ch));
        }
        AuthBatch {
            b: HrfnaBatch {
                res,
                f: self.b.f.iter().map(|&a| a + enc.f).collect(),
                iv_lo,
                iv_hi,
            },
            mac,
            f_dup: self.f_dup.iter().map(|&a| a + enc.f).collect(),
        }
    }

    /// MAC-aware bulk normalization: the value lanes rescale exactly as
    /// [`norm::bulk_normalize`] would, and the MAC lanes rescale with the
    /// same Definition-4 offset scaled by `α`
    /// ([`crate::rns::crt::CrtContext::rescale_batch_with_mac`]). The
    /// exponent duplicate advances by the same applied shift — not
    /// re-copied from `f`, so a pre-existing exponent corruption is
    /// still caught afterwards.
    pub fn normalize_flagged(&mut self, key: &AuthKey, ctx: &HrfnaContext) -> NormReport {
        let f_before: Vec<i32> = self.b.f.clone();
        let report = norm::bulk_normalize_authenticated(&mut self.b, &mut self.mac, &key.alpha, ctx, None);
        for (j, &fb) in f_before.iter().enumerate() {
            self.f_dup[j] += self.b.f[j] - fb;
        }
        report
    }
}

/// One dual-MAC verified planar dot over the column window
/// `[lo, lo + len)` of four channel-major planes: the value result
/// `r_c = Σ x·y`, checked against **both** `Σ mac(x)·y ?= α·r` and
/// `Σ x·mac(y) ?= α·r` per channel. The first check replays the dot with
/// `x` entering through its MAC lanes (catching post-encode corruption
/// of `x` or of its MACs), the second with `y` (symmetrically) — a
/// corruption of any one of the four operand planes breaks at least one
/// equation in the corrupted channel. Returns the per-channel dot
/// residues, or the first failing channel.
pub fn verified_window_dot(
    bars: &[Barrett],
    key: &AuthKey,
    x: &ResiduePlane,
    mac_x: &ResiduePlane,
    y: &ResiduePlane,
    mac_y: &ResiduePlane,
    lo: usize,
    len: usize,
) -> Result<Vec<u64>, usize> {
    verified_window_dot_at(bars, key, x, mac_x, y, mac_y, lo, lo, len)
}

/// [`verified_window_dot`] with independent column offsets per operand —
/// the FIR executor dots a suffix of the reversed-taps plane against a
/// sliding window of the signal plane. Every word of all four windows is
/// range-checked against its modulus *before* the dots, so an
/// out-of-range corruption is detected deterministically and the lane
/// kernels never see a word outside their `< m < 2^31` invariant.
pub fn verified_window_dot_at(
    bars: &[Barrett],
    key: &AuthKey,
    x: &ResiduePlane,
    mac_x: &ResiduePlane,
    y: &ResiduePlane,
    mac_y: &ResiduePlane,
    x_lo: usize,
    y_lo: usize,
    len: usize,
) -> Result<Vec<u64>, usize> {
    let k = bars.len();
    let mut out = vec![0u64; k];
    for (c, slot) in out.iter_mut().enumerate() {
        let bar = bars[c];
        let m = bar.m;
        let xs = &x.lane(c)[x_lo..x_lo + len];
        let ys = &y.lane(c)[y_lo..y_lo + len];
        let mxs = &mac_x.lane(c)[x_lo..x_lo + len];
        let mys = &mac_y.lane(c)[y_lo..y_lo + len];
        let in_range = |w: &[u64]| w.iter().all(|&v| v < m);
        if !(in_range(xs) && in_range(ys) && in_range(mxs) && in_range(mys)) {
            return Err(c);
        }
        let r = plane::lane_dot(bar, xs, ys);
        let tx = plane::lane_dot(bar, mxs, ys);
        let ty = plane::lane_dot(bar, xs, mys);
        let want = bar.mul(key.alpha[c], r);
        if tx != want || ty != want {
            return Err(c);
        }
        *slot = r;
    }
    Ok(out)
}

/// Freivalds randomized verification of `A·B ?= C` (all `dim × dim`,
/// row-major f64): per round, draw `r ∈ {−1, +1}^dim` and compare
/// `A·(B·r)` against `C·r` — O(dim²) per round against the O(dim³)
/// product. Comparison is tolerance-based: floating evaluation orders
/// differ, so the check catches corruptions whose magnitude exceeds
/// `tol` per output element (the serving path computes `tol` from the
/// tier's relative bound and the result scale; an undetected residue
/// flip decodes to an error many orders of magnitude above it, so the
/// fault model is firmly inside the detected region). Miss probability
/// for a genuinely wrong product is ≤ 2^-rounds.
pub fn freivalds_matmul_check(
    a: &[f64],
    b: &[f64],
    c: &[f64],
    dim: usize,
    rounds: u32,
    seed: u64,
    tol: f64,
) -> bool {
    debug_assert_eq!(a.len(), dim * dim);
    debug_assert_eq!(b.len(), dim * dim);
    debug_assert_eq!(c.len(), dim * dim);
    let mut rng = Rng::new(seed ^ 0xF4EE_7A1D_5EED_0001);
    let mut r = vec![0.0f64; dim];
    let mut br = vec![0.0f64; dim];
    for _ in 0..rounds.max(1) {
        for v in r.iter_mut() {
            *v = if rng.bool() { 1.0 } else { -1.0 };
        }
        for (i, slot) in br.iter_mut().enumerate() {
            let row = &b[i * dim..(i + 1) * dim];
            *slot = row.iter().zip(&r).map(|(&bv, &rv)| bv * rv).sum();
        }
        for i in 0..dim {
            let arow = &a[i * dim..(i + 1) * dim];
            let abr: f64 = arow.iter().zip(&br).map(|(&av, &bv)| av * bv).sum();
            let crow = &c[i * dim..(i + 1) * dim];
            let cr: f64 = crow.iter().zip(&r).map(|(&cv, &rv)| cv * rv).sum();
            // The negated form keeps NaN on the reject side: a NaN
            // difference fails `<= tol` and therefore fails the check.
            if !((abr - cr).abs() <= tol) {
                return false;
            }
        }
    }
    true
}

/// FNV-1a checksum over canonical f64 bit patterns — the wire-integrity
/// cover for authenticated result frames (a frame corrupted in flight or
/// in worker serialization fails the router-side recompute). NaN payloads
/// collapse to the canonical quiet NaN and `-0.0` to `+0.0`, so the
/// checksum survives a JSON round trip.
pub fn values_checksum(values: &[f64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &v in values {
        let canon = if v.is_nan() {
            f64::NAN
        } else if v == 0.0 {
            0.0
        } else {
            v
        };
        for byte in canon.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Content digest over *exact* f64 bit patterns — the operand-cache key
/// (see `coordinator::op_cache`). Unlike [`values_checksum`] this does
/// **not** canonicalize NaN or `-0.0`: two operand vectors map to the
/// same cached encode only when every input bit is identical, which is
/// exactly the condition under which a block encode is replayable
/// bit-for-bit. The element count is folded in so a prefix and its
/// extension can't collide trivially.
pub fn operand_digest(values: &[f64]) -> u64 {
    operand_digest_with(0, values)
}

/// [`operand_digest`] with a caller salt folded in first. Call sites
/// caching different operand roles (matmul RHS, FIR taps, reversed
/// authenticated taps) salt differently so equal raw bytes in different
/// roles never alias one cache entry.
pub fn operand_digest_with(salt: u64, values: &[f64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let head = salt
        .to_le_bytes()
        .into_iter()
        .chain((values.len() as u64).to_le_bytes());
    for byte in head {
        h ^= byte as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    for &v in values {
        for byte in v.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::faults::flip_bit;
    use crate::util::proptest::check_with;
    use crate::workloads::generators::Dist;

    fn ctx() -> HrfnaContext {
        HrfnaContext::paper_default()
    }

    fn key(c: &HrfnaContext, seed: u64) -> AuthKey {
        AuthKey::sample(&c.cfg.moduli, seed)
    }

    #[test]
    fn operand_digest_is_exact_bits_not_canonical() {
        // values_checksum folds -0.0 into +0.0 and all NaNs together;
        // the cache digest must NOT (a cached encode of -0.0 is a
        // different bit pattern than one of +0.0).
        assert_eq!(values_checksum(&[0.0]), values_checksum(&[-0.0]));
        assert_ne!(operand_digest(&[0.0]), operand_digest(&[-0.0]));
        assert_eq!(operand_digest(&[1.5, -2.0]), operand_digest(&[1.5, -2.0]));
        assert_ne!(operand_digest(&[1.5, -2.0]), operand_digest(&[1.5, -2.5]));
        // Length is folded in: a zero-padded extension can't collide
        // with its prefix.
        assert_ne!(operand_digest(&[1.0]), operand_digest(&[1.0, 0.0]));
        assert_ne!(operand_digest(&[]), operand_digest(&[0.0]));
    }

    #[test]
    fn operand_digest_salt_separates_roles() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0];
        assert_ne!(operand_digest_with(1, &xs), operand_digest_with(2, &xs));
        assert_eq!(operand_digest_with(0, &xs), operand_digest(&xs));
    }

    #[test]
    fn alpha_sampling_respects_range_and_miss_bound() {
        let c = ctx();
        for seed in 0..64 {
            let k = key(&c, seed);
            for (a, &m) in k.alpha.iter().zip(&c.cfg.moduli) {
                assert!((1..m).contains(a), "alpha {a} outside [1, {m})");
            }
        }
        // The documented adversarial bound: max_i 1/(m_i − 1), i.e. one
        // part in m_i above the information-theoretic 1/m_i.
        let min_m = *c.cfg.moduli.iter().min().unwrap() as f64;
        let p = AuthKey::miss_probability(&c.cfg.moduli);
        assert_eq!(p, 1.0 / (min_m - 1.0));
        assert!(p < 2.0 / min_m, "bound must stay within 2/m of 1/m");
    }

    #[test]
    fn authenticate_verify_decode_roundtrip() {
        let c = ctx();
        let k = key(&c, 7);
        let mut rng = Rng::new(3);
        let xs = Dist::moderate().sample_vec(&mut rng, 33);
        let b = HrfnaBatch::encode(&xs, &c);
        let want = b.decode(&c);
        let a = AuthBatch::authenticate(b, &k, &c);
        assert_eq!(a.decode_verified(&k, &c).expect("clean batch"), want);
    }

    #[test]
    fn prop_any_single_bit_flip_is_detected() {
        // The ISSUE-8 single-event-upset property: one bit flip in any
        // value lane word, MAC lane word, or exponent word of an
        // authenticated batch fails verification. Lane flips below the
        // modulus break the α-relation (odd m ⇒ 2^b invertible); flips
        // landing at/above the modulus fail the range check.
        let c = ctx();
        check_with("auth-single-flip-detected", 64, |rng| {
            let k = key(&c, rng.next_u64());
            let n = 1 + rng.below(16) as usize;
            let xs: Vec<f64> = (0..n).map(|_| rng.uniform(-1e6, 1e6)).collect();
            let mut a = AuthBatch::authenticate(HrfnaBatch::encode(&xs, &c), &k, &c);
            crate::prop_assert!(a.verify(&k, &c).is_ok(), "clean batch must verify");
            let elem = rng.below(n as u64) as usize;
            let chan = rng.below(a.b.k() as u64) as usize;
            match rng.below(3) {
                0 => {
                    // Value lane: flip a bit of the residue word. Bits
                    // within the modulus width change the residue; higher
                    // bits push it out of range. Either way: detected.
                    let bit = rng.below(33) as u32;
                    let w = a.b.res.lane(chan)[elem];
                    a.b.res.lane_mut(chan)[elem] = flip_bit(w, bit);
                }
                1 => {
                    let bit = rng.below(33) as u32;
                    let w = a.mac.lane(chan)[elem];
                    a.mac.lane_mut(chan)[elem] = flip_bit(w, bit);
                }
                _ => {
                    let bit = rng.below(32) as u32;
                    a.b.f[elem] ^= 1i32 << (bit % 31);
                }
            }
            crate::prop_assert!(
                a.verify(&k, &c).is_err(),
                "single flip must be detected (elem {elem} chan {chan})"
            );
            Ok(())
        });
    }

    #[test]
    fn mac_survives_mul_and_scale() {
        // Homomorphism through the multiplicative kernels: the value
        // lanes of mul_plain are exactly the planar lane product, the MAC
        // lanes are exactly α·(that product), and the batch verifies.
        let c = ctx();
        let k = key(&c, 11);
        let mut rng = Rng::new(5);
        let n = 17;
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform(-1e4, 1e4)).collect();
        let ys: Vec<f64> = (0..n).map(|_| rng.uniform(-1e4, 1e4)).collect();
        let bx = HrfnaBatch::encode(&xs, &c);
        let by = HrfnaBatch::encode(&ys, &c);
        let auth = AuthBatch::authenticate(bx.clone(), &k, &c).mul_plain(&by, &c);
        let want_res = bx.plane().mul(by.plane(), c.barrett());
        assert_eq!(auth.b.res, want_res, "value lanes are the plain lane product");
        assert_eq!(
            auth.mac,
            want_res.scale_channels(&k.alpha, c.barrett()),
            "MAC lanes are α·product"
        );
        assert!(auth.verify(&k, &c).is_ok());
        let scaled = auth.scale_plain(0.5, &c);
        assert!(scaled.verify(&k, &c).is_ok());
        let got = scaled.decode_verified(&k, &c).unwrap();
        for (j, g) in got.iter().enumerate() {
            let w = 0.5 * xs[j] * ys[j];
            assert!((g - w).abs() <= 1e-7 * w.abs().max(1.0), "j={j} got {g} want {w}");
        }
    }

    #[test]
    fn mac_survives_bulk_normalization_bit_identically() {
        // The MAC-aware rescale: after a flagged sweep the value lanes are
        // bit-identical to the plain bulk_normalize, the exponent
        // duplicate tracked the applied shifts, and the MAC still checks.
        let c = HrfnaContext::new(crate::config::HrfnaConfig {
            tau_bits: 40,
            ..crate::config::HrfnaConfig::paper_default()
        });
        let k = key(&c, 19);
        let mut rng = Rng::new(23);
        for round in 0..8 {
            let n = 1 + rng.below(12) as usize;
            let items: Vec<crate::hybrid::number::Hrfna> = (0..n)
                .map(|_| {
                    let bits = 20 + rng.below(40) as u32;
                    let v = (rng.next_u64() >> (64 - bits)).max(1) as i64;
                    crate::hybrid::number::Hrfna::from_signed_int(
                        if rng.bool() { v } else { -v },
                        -10,
                        &c,
                    )
                })
                .collect();
            let b = HrfnaBatch::from_items(&items, c.k());
            let mut plain = b.clone();
            let mut auth = AuthBatch::authenticate(b, &k, &c);
            let got = auth.normalize_flagged(&k, &c);
            let want = plain.normalize_flagged(&c);
            assert_eq!(got, want, "round {round}: event report diverged");
            assert_eq!(auth.b.res, plain.res, "round {round}: value lanes diverged");
            assert_eq!(auth.b.f, plain.f, "round {round}: exponents diverged");
            assert_eq!(auth.f_dup, plain.f, "round {round}: duplicate exponents stale");
            assert!(auth.verify(&k, &c).is_ok(), "round {round}: MAC broken by rescale");
        }
    }

    #[test]
    fn verified_window_dot_accepts_clean_and_catches_flips() {
        let c = ctx();
        let k = key(&c, 13);
        let bars = c.barrett();
        let mut rng = Rng::new(9);
        let n = 96;
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform(-100.0, 100.0)).collect();
        let ys: Vec<f64> = (0..n).map(|_| rng.uniform(-100.0, 100.0)).collect();
        let bx = HrfnaBatch::encode(&xs, &c);
        let by = HrfnaBatch::encode(&ys, &c);
        let mx = bx.plane().scale_channels(&k.alpha, bars);
        let my = by.plane().scale_channels(&k.alpha, bars);
        let clean = verified_window_dot(bars, &k, bx.plane(), &mx, by.plane(), &my, 0, n);
        let r = clean.expect("clean dot verifies");
        // The verified residues are the plain lane dots.
        for (ch, &rc) in r.iter().enumerate() {
            assert_eq!(
                rc,
                plane::lane_dot(bars[ch], bx.plane().lane(ch), by.plane().lane(ch))
            );
        }
        // Flip one x element in one channel: the mac_x·y replay diverges.
        let mut bx2 = bx.clone();
        let w = bx2.plane().lane(3)[17];
        bx2.res.lane_mut(3)[17] = flip_bit(w, 5);
        let err = verified_window_dot(bars, &k, bx2.plane(), &mx, by.plane(), &my, 0, n);
        assert_eq!(err, Err(3), "x flip detected in its channel");
        // Flip one y element: the x·mac_y replay diverges.
        let mut by2 = by.clone();
        let w = by2.plane().lane(6)[40];
        by2.res.lane_mut(6)[40] = flip_bit(w, 2);
        let err = verified_window_dot(bars, &k, bx.plane(), &mx, by2.plane(), &my, 0, n);
        assert_eq!(err, Err(6), "y flip detected in its channel");
        // Flip a MAC word: its own replay diverges.
        let mut mx2 = mx.clone();
        let w = mx2.lane(1)[8];
        mx2.lane_mut(1)[8] = flip_bit(w, 9);
        let err = verified_window_dot(bars, &k, bx.plane(), &mx2, by.plane(), &my, 0, n);
        assert_eq!(err, Err(1), "mac_x flip detected in its channel");
    }

    #[test]
    fn freivalds_accepts_true_products_and_rejects_corruption() {
        let mut rng = Rng::new(21);
        let dim = 24;
        let a: Vec<f64> = (0..dim * dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let b: Vec<f64> = (0..dim * dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let mut cm = vec![0.0f64; dim * dim];
        for i in 0..dim {
            for j in 0..dim {
                cm[i * dim + j] = (0..dim).map(|t| a[i * dim + t] * b[t * dim + j]).sum();
            }
        }
        let tol = 1e-9 * (dim as f64);
        for seed in 0..16 {
            assert!(freivalds_matmul_check(&a, &b, &cm, dim, 2, seed, tol));
        }
        // A single high-bit flip (the decoded shape of a lane corruption)
        // is far outside tolerance: rejected for every seed.
        let mut bad = cm.clone();
        bad[5 * dim + 7] = crate::util::faults::flip_f64_high_bit(bad[5 * dim + 7], 3);
        for seed in 0..16 {
            assert!(
                !freivalds_matmul_check(&a, &b, &bad, dim, 2, seed, tol),
                "seed {seed} missed the corruption"
            );
        }
    }

    #[test]
    fn checksum_is_order_sensitive_and_canonicalizes() {
        let a = values_checksum(&[1.0, 2.0, 3.0]);
        let b = values_checksum(&[3.0, 2.0, 1.0]);
        assert_ne!(a, b);
        assert_eq!(values_checksum(&[]), values_checksum(&[]));
        assert_eq!(
            values_checksum(&[f64::NAN, -0.0]),
            values_checksum(&[f64::from_bits(0x7ff8_dead_beef_0001), 0.0]),
            "NaN payloads and signed zero must canonicalize"
        );
        assert_ne!(values_checksum(&[1.0]), values_checksum(&[1.0 + 1e-12]));
    }
}
