//! Fixed-shape batcher: AOT executables have frozen shapes, so incoming
//! jobs are bucketed per kind and dispatched in batches — a batch amortizes
//! worker wakeups and engine dispatch overhead over several jobs (the
//! vLLM-router-style dynamic batching policy, adapted to fixed shapes).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::Job;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many jobs are queued…
    pub max_batch: usize,
    /// …or when the oldest job has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

#[derive(Default)]
struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// A blocking batch queue for one job kind.
pub struct BatchQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    pub policy: BatchPolicy,
}

impl BatchQueue {
    /// New queue with the given policy.
    pub fn new(policy: BatchPolicy) -> BatchQueue {
        BatchQueue {
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
            policy,
        }
    }

    /// Enqueue a job.
    pub fn push(&self, job: Job) {
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "queue closed");
        st.jobs.push_back(job);
        self.cv.notify_one();
    }

    /// Number of queued jobs.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    /// True if no jobs are waiting.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: wakes all waiters; `next_batch` drains and then
    /// returns `None`.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    /// Block until a batch is ready per the policy (or the queue closes).
    /// Returns `None` only when closed *and* drained.
    pub fn next_batch(&self) -> Option<Vec<Job>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.jobs.is_empty() {
                let oldest = st.jobs.front().unwrap().submitted;
                let waited = oldest.elapsed();
                if st.jobs.len() >= self.policy.max_batch
                    || waited >= self.policy.max_wait
                    || st.closed
                {
                    let take = st.jobs.len().min(self.policy.max_batch);
                    return Some(st.jobs.drain(..take).collect());
                }
                // Wait out the remaining batching window.
                let remaining = self.policy.max_wait - waited;
                let (guard, _) = self.cv.wait_timeout(st, remaining).unwrap();
                st = guard;
            } else if st.closed {
                return None;
            } else {
                st = self.cv.wait(st).unwrap();
            }
        }
    }

    /// Age of the oldest queued job (None if empty) — scheduling metric.
    pub fn oldest_wait(&self) -> Option<Duration> {
        let st = self.state.lock().unwrap();
        st.jobs.front().map(|j| j.submitted.elapsed())
    }
}

/// Compute the dispatch deadline for a job submitted at `t` under `p`.
pub fn deadline(t: Instant, p: &BatchPolicy) -> Instant {
    t + p.max_wait
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{JobKind, Payload};
    use std::sync::mpsc;
    use std::sync::Arc;

    fn mkjob(id: u64) -> (Job, mpsc::Receiver<crate::coordinator::request::JobResult>) {
        let (tx, rx) = mpsc::channel();
        (
            Job {
                id,
                kind: JobKind::DotF32,
                payload: Payload::Dot {
                    x: vec![1.0],
                    y: vec![1.0],
                },
                submitted: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let q = BatchQueue::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(60),
        });
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (j, rx) = mkjob(i);
            q.push(j);
            rxs.push(rx);
        }
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 0);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let q = BatchQueue::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        });
        let (j, _rx) = mkjob(7);
        q.push(j);
        let t0 = Instant::now();
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BatchQueue::new(BatchPolicy::default());
        let (j, _rx) = mkjob(1);
        q.push(j);
        q.close();
        assert_eq!(q.next_batch().unwrap().len(), 1);
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_no_loss_no_dup() {
        let q = Arc::new(BatchQueue::new(BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
        }));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..50u64 {
                        let (j, _rx) = mkjob(p * 1000 + i);
                        std::mem::forget(_rx); // keep channel alive
                        q.push(j);
                    }
                })
            })
            .collect();
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = q.next_batch() {
                    for j in batch {
                        seen.push(j.id);
                    }
                }
                seen
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut seen = consumer.join().unwrap();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 200, "lost or duplicated jobs");
    }
}
