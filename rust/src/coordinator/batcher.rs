//! Sharded fixed-shape batcher: AOT executables have frozen shapes, so
//! incoming jobs are bucketed per (kind, tier, shape) lane and dispatched
//! in batches — a batch amortizes worker wakeups and one planar encode
//! over several jobs (the vLLM-router-style dynamic batching policy,
//! adapted to fixed shapes). A lane's queue only ever holds jobs of one
//! precision tier, so every popped batch resolves a single context.
//!
//! The queue is **sharded**: one deque (and one lock) per worker, with
//! round-robin placement on push and work stealing on pop — a worker that
//! drains its own shard takes a *ready* batch from a sibling rather than
//! idling. Shards are **bounded**: when every shard is at capacity the
//! push fails and the coordinator surfaces a typed `Overloaded` error
//! instead of growing without bound (the backpressure contract).
//!
//! Sleeping workers park on one queue-wide condvar guarded by a generation
//! counter (per-shard locks stay uncontended on the hot path; the counter
//! is bumped under the signal lock on every push/close, so a wakeup can
//! never be missed between a worker's scan and its wait).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::Job;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many jobs are queued…
    pub max_batch: usize,
    /// …or when the oldest job has waited this long.
    pub max_wait: Duration,
    /// Bounded per-shard queue depth; pushes beyond it are rejected
    /// (`usize::MAX` disables the bound).
    pub capacity: usize,
}

impl Default for BatchPolicy {
    fn default() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            capacity: 1024,
        }
    }
}

/// A rejected push, returning the job to the caller.
#[derive(Debug)]
pub enum PushError {
    /// Every shard is at capacity (backpressure: shed load upstream).
    Full(Job),
    /// The queue is closed (coordinator shutting down).
    Closed(Job),
}

#[derive(Default)]
struct Shard {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// Outcome of one non-blocking shard poll.
enum Pop {
    /// A batch ready per the policy (full, window expired, or draining).
    Ready(Vec<Job>),
    /// Jobs queued but the batching window is still open for this long.
    Wait(Duration),
    /// No jobs queued.
    Empty,
    /// Closed and fully drained.
    Done,
}

/// Sleep cap while no shard reports a pending batching window: the
/// generation counter makes wakeups exact, so this only bounds staleness
/// if a waiter raced a bump it has already observed.
const IDLE_SLICE: Duration = Duration::from_millis(50);

/// A sharded, bounded, work-stealing batch queue for one (kind, shape)
/// lane.
pub struct BatchQueue {
    shards: Vec<Mutex<Shard>>,
    /// Push/close generation, paired with `cv` (see module docs).
    signal: Mutex<u64>,
    cv: Condvar,
    rr: AtomicUsize,
    pub policy: BatchPolicy,
}

impl BatchQueue {
    /// Single-shard queue with the given policy.
    pub fn new(policy: BatchPolicy) -> BatchQueue {
        BatchQueue::sharded(policy, 1)
    }

    /// Queue with `shards` independent shards (typically one per worker).
    pub fn sharded(policy: BatchPolicy, shards: usize) -> BatchQueue {
        let shards = shards.max(1);
        BatchQueue {
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
            signal: Mutex::new(0),
            cv: Condvar::new(),
            rr: AtomicUsize::new(0),
            policy,
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn bump(&self) {
        let mut g = self.signal.lock().unwrap();
        *g = g.wrapping_add(1);
        self.cv.notify_all();
    }

    /// Enqueue with backpressure: round-robin home shard first, then any
    /// shard with room. Fails with `Full` only when every shard is at
    /// capacity, `Closed` once the queue is shut down.
    pub fn try_push(&self, job: Job) -> Result<(), PushError> {
        let s = self.shards.len();
        let home = self.rr.fetch_add(1, Ordering::Relaxed) % s;
        for i in 0..s {
            let mut shard = self.shards[(home + i) % s].lock().unwrap();
            if shard.closed {
                drop(shard);
                return Err(PushError::Closed(job));
            }
            if shard.jobs.len() >= self.policy.capacity {
                drop(shard);
                continue;
            }
            shard.jobs.push_back(job);
            drop(shard);
            self.bump();
            return Ok(());
        }
        // All shards full: hand the job back so the caller can reject the
        // request with a typed error (it still owns the reply channel).
        Err(PushError::Full(job))
    }

    /// Infallible enqueue for tests and unbounded policies; panics if the
    /// queue is closed or every shard is full.
    pub fn push(&self, job: Job) {
        match self.try_push(job) {
            Ok(()) => {}
            Err(PushError::Full(_)) => panic!("queue full"),
            Err(PushError::Closed(_)) => panic!("queue closed"),
        }
    }

    /// Total queued jobs across shards.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().jobs.len())
            .sum()
    }

    /// True if no jobs are waiting in any shard.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: wakes all waiters; `next_batch` drains remaining
    /// jobs and then returns `None`.
    pub fn close(&self) {
        for s in &self.shards {
            s.lock().unwrap().closed = true;
        }
        self.bump();
    }

    /// Age of the oldest queued job (None if empty) — scheduling metric.
    pub fn oldest_wait(&self) -> Option<Duration> {
        self.shards
            .iter()
            .filter_map(|s| {
                let sh = s.lock().unwrap();
                sh.jobs.front().map(|j| j.submitted.elapsed())
            })
            .max()
    }

    /// Non-blocking poll of one shard against the batching policy.
    fn pop_shard(&self, idx: usize) -> Pop {
        let mut shard = self.shards[idx].lock().unwrap();
        if shard.jobs.is_empty() {
            return if shard.closed { Pop::Done } else { Pop::Empty };
        }
        let waited = shard.jobs.front().unwrap().submitted.elapsed();
        if shard.jobs.len() >= self.policy.max_batch
            || waited >= self.policy.max_wait
            || shard.closed
        {
            let take = shard.jobs.len().min(self.policy.max_batch);
            return Pop::Ready(shard.jobs.drain(..take).collect());
        }
        Pop::Wait(self.policy.max_wait - waited)
    }

    /// Block until a batch is ready per the policy (or the queue closes).
    /// Single-consumer convenience over [`BatchQueue::next_batch_for`].
    pub fn next_batch(&self) -> Option<Vec<Job>> {
        self.next_batch_for(0).map(|(batch, _)| batch)
    }

    /// Worker `w`'s next batch: polls its own shard first, then *steals a
    /// ready batch* from sibling shards (idle workers never wait out a
    /// sibling's full batch). Returns the batch and whether it was stolen;
    /// `None` only when the queue is closed *and* every shard is drained.
    pub fn next_batch_for(&self, w: usize) -> Option<(Vec<Job>, bool)> {
        let s = self.shards.len();
        loop {
            let gen_before = *self.signal.lock().unwrap();
            let mut wait = IDLE_SLICE;
            let mut live = false;
            for i in 0..s {
                match self.pop_shard((w + i) % s) {
                    Pop::Ready(batch) => return Some((batch, i != 0)),
                    Pop::Wait(d) => {
                        live = true;
                        wait = wait.min(d);
                    }
                    Pop::Empty => live = true,
                    Pop::Done => {}
                }
            }
            if !live {
                return None;
            }
            // Park until a push/close bumps the generation or the nearest
            // batching window elapses.
            let g = self.signal.lock().unwrap();
            if *g == gen_before {
                let _ = self.cv.wait_timeout(g, wait).unwrap();
            }
        }
    }
}

/// Compute the dispatch deadline for a job submitted at `t` under `p`.
pub fn deadline(t: Instant, p: &BatchPolicy) -> Instant {
    t + p.max_wait
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{JobKind, Payload};
    use std::sync::mpsc;
    use std::sync::Arc;

    type ReplyRx = mpsc::Receiver<
        Result<crate::coordinator::request::JobResult, crate::coordinator::error::Error>,
    >;

    fn mkjob(id: u64) -> (Job, ReplyRx) {
        let (tx, rx) = mpsc::channel();
        (
            Job {
                id,
                kind: JobKind::DotF32,
                payload: Payload::Dot {
                    x: vec![1.0],
                    y: vec![1.0],
                },
                tier: crate::hybrid::registry::Tier::Paper,
                bucket: 1,
                auth: false,
                submitted: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let q = BatchQueue::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(60),
            ..BatchPolicy::default()
        });
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (j, rx) = mkjob(i);
            q.push(j);
            rxs.push(rx);
        }
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 0);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let q = BatchQueue::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
            ..BatchPolicy::default()
        });
        let (j, _rx) = mkjob(7);
        q.push(j);
        let t0 = Instant::now();
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BatchQueue::new(BatchPolicy::default());
        let (j, _rx) = mkjob(1);
        q.push(j);
        q.close();
        assert_eq!(q.next_batch().unwrap().len(), 1);
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn bounded_push_rejects_when_all_shards_full() {
        let q = BatchQueue::sharded(
            BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_secs(60),
                capacity: 2,
            },
            2,
        );
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (j, rx) = mkjob(i);
            assert!(q.try_push(j).is_ok(), "push {i} within capacity");
            rxs.push(rx);
        }
        let (j, _rx) = mkjob(99);
        match q.try_push(j) {
            Err(PushError::Full(job)) => assert_eq!(job.id, 99),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn push_after_close_is_rejected() {
        let q = BatchQueue::new(BatchPolicy::default());
        q.close();
        let (j, _rx) = mkjob(1);
        assert!(matches!(q.try_push(j), Err(PushError::Closed(_))));
    }

    #[test]
    fn worker_steals_ready_batch_from_sibling_shard() {
        // Two shards; both jobs round-robin to different shards. With a
        // 1-job batch everything is immediately ready, so worker 1 can
        // take work placed on shard 0.
        let q = BatchQueue::sharded(
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_secs(60),
                ..BatchPolicy::default()
            },
            2,
        );
        let (j, _rx0) = mkjob(0);
        q.push(j);
        let (j, _rx1) = mkjob(1);
        q.push(j);
        let (b0, _) = q.next_batch_for(1).unwrap();
        let (b1, _) = q.next_batch_for(1).unwrap();
        // Worker 1 drained both shards; one of the two pops crossed shards.
        let mut ids = vec![b0[0].id, b1[0].id];
        ids.sort();
        assert_eq!(ids, vec![0, 1]);
        q.close();
        assert!(q.next_batch_for(1).is_none());
    }

    #[test]
    fn concurrent_producers_no_loss_no_dup() {
        let q = Arc::new(BatchQueue::sharded(
            BatchPolicy {
                max_batch: 16,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            3,
        ));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..50u64 {
                        let (j, _rx) = mkjob(p * 1000 + i);
                        std::mem::forget(_rx); // keep channel alive
                        q.push(j);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|w| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut seen = Vec::new();
                    while let Some((batch, _)) = q.next_batch_for(w) {
                        for j in batch {
                            seen.push(j.id);
                        }
                    }
                    seen
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut seen: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 200, "lost or duplicated jobs");
    }
}
