//! Serving metrics: per-lane latency histograms (p50/p95/p99), queue
//! depth, worker occupancy, steal/reject counters and throughput — all
//! lock-free (relaxed atomics; these are metrics, not synchronization).

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::request::JobKind;
use crate::util::table::Table;

/// Log-linear latency histogram: `SUB` sub-buckets per power-of-two octave
/// of microseconds — octave `o`, sub `s` covers
/// `[2^o·(1 + s/SUB), 2^o·(1 + (s+1)/SUB))` µs. Four sub-buckets keep the
/// worst-case percentile quantization error below ~12%, against ~50% for
/// the plain log2 histogram this replaces.
const SUB: usize = 4;
const OCTAVES: usize = 26; // up to 2^26 µs ≈ 67 s
const BUCKETS: usize = SUB * OCTAVES;

fn bucket_of(latency_us: f64) -> usize {
    let v = latency_us.max(1.0);
    let oct = v.log2().floor() as usize;
    if oct >= OCTAVES {
        return BUCKETS - 1;
    }
    let frac = v / 2f64.powi(oct as i32) - 1.0; // in [0, 1)
    let sub = ((frac * SUB as f64) as usize).min(SUB - 1);
    oct * SUB + sub
}

/// Midpoint (µs) of histogram bucket `i`.
fn bucket_mid_us(i: usize) -> f64 {
    let oct = i / SUB;
    let sub = i % SUB;
    2f64.powi(oct as i32) * (1.0 + (sub as f64 + 0.5) / SUB as f64)
}

struct KindMetrics {
    jobs: AtomicU64,
    macs: AtomicU64,
    batches: AtomicU64,
    accepted: AtomicU64,
    rejected: AtomicU64,
    steals: AtomicU64,
    /// Threshold-triggered normalization events taken while executing
    /// this lane's batches (§VII-E frequency accounting, per lane).
    norm_events: AtomicU64,
    /// Overflow-guard normalization events for this lane.
    guard_events: AtomicU64,
    /// Wall time workers of this lane spent executing batches (ns).
    busy_ns: AtomicU64,
    /// Currently queued jobs (gauge; +1 on accept, −batch on dequeue).
    depth: AtomicI64,
    latency_sum_us: AtomicU64,
    histogram: [AtomicU64; BUCKETS],
}

impl Default for KindMetrics {
    fn default() -> KindMetrics {
        KindMetrics {
            jobs: AtomicU64::new(0),
            macs: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            norm_events: AtomicU64::new(0),
            guard_events: AtomicU64::new(0),
            busy_ns: AtomicU64::new(0),
            depth: AtomicI64::new(0),
            latency_sum_us: AtomicU64::new(0),
            histogram: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Aggregated per-kind serving metrics.
pub struct Metrics {
    kinds: [KindMetrics; JobKind::ALL.len()],
    /// Claim cursors over the shared `OpCounters` totals: workers report
    /// the *running totals* they observe after a batch, and the cursor
    /// hands each event to exactly one reporter (`fetch_max` partition)
    /// — overlapping execution windows cannot double-count.
    claimed_norms: AtomicU64,
    claimed_guards: AtomicU64,
    start: Instant,
}

fn kind_index(kind: JobKind) -> usize {
    match kind {
        JobKind::DotHybrid => 0,
        JobKind::DotF32 => 1,
        JobKind::MatmulHybrid => 2,
        JobKind::MatmulF32 => 3,
        JobKind::Rk4Hybrid => 4,
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            kinds: std::array::from_fn(|_| KindMetrics::default()),
            claimed_norms: AtomicU64::new(0),
            claimed_guards: AtomicU64::new(0),
            start: Instant::now(),
        }
    }
}

impl Metrics {
    /// Record one completed job.
    pub fn record(&self, kind: JobKind, latency_us: f64, macs: u64) {
        let k = &self.kinds[kind_index(kind)];
        k.jobs.fetch_add(1, Ordering::Relaxed);
        k.macs.fetch_add(macs, Ordering::Relaxed);
        k.latency_sum_us
            .fetch_add(latency_us.max(0.0) as u64, Ordering::Relaxed);
        k.histogram[bucket_of(latency_us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a dispatched batch and the wall time its execution took.
    pub fn record_batch(&self, kind: JobKind, size: usize, busy: Duration) {
        let k = &self.kinds[kind_index(kind)];
        k.batches.fetch_add(1, Ordering::Relaxed);
        k.busy_ns
            .fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
        k.depth.fetch_sub(size as i64, Ordering::Relaxed);
    }

    /// Record a job accepted into a lane queue.
    pub fn record_accepted(&self, kind: JobKind) {
        let k = &self.kinds[kind_index(kind)];
        k.accepted.fetch_add(1, Ordering::Relaxed);
        k.depth.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rejected submission (admission failure or overload).
    pub fn record_rejected(&self, kind: JobKind) {
        self.kinds[kind_index(kind)]
            .rejected
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Record a batch stolen from a sibling shard.
    pub fn record_steal(&self, kind: JobKind) {
        self.kinds[kind_index(kind)]
            .steals
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Seed the normalization claim cursors from the shared context's
    /// current totals: events taken before serving started (client-side
    /// warmup on the same `HrfnaContext`) must not be attributed to the
    /// first lane that completes a batch. `Coordinator::start` calls
    /// this once before spawning workers.
    pub fn seed_norm_cursor(&self, total_norms: u64, total_guards: u64) {
        self.claimed_norms.fetch_max(total_norms, Ordering::Relaxed);
        self.claimed_guards.fetch_max(total_guards, Ordering::Relaxed);
    }

    /// Record normalization events from the shared context's *running
    /// totals* (threshold and guard separately — the per-lane §VII-E
    /// counters). Workers call this with the `OpSnapshot` observed after
    /// `execute_batch`; the claim cursor (`fetch_max`) hands every event
    /// to exactly one caller, so concurrent workers with overlapping
    /// execution windows never double-count. Aggregate totals are exact;
    /// *per-kind attribution* of an event taken while two different
    /// kinds were executing is approximate (whichever window closed
    /// later claims it) — metrics, not synchronization.
    pub fn record_norm_totals(&self, kind: JobKind, total_norms: u64, total_guards: u64) {
        let k = &self.kinds[kind_index(kind)];
        let prev = self.claimed_norms.fetch_max(total_norms, Ordering::Relaxed);
        let dn = total_norms.saturating_sub(prev);
        if dn > 0 {
            k.norm_events.fetch_add(dn, Ordering::Relaxed);
        }
        let prev = self.claimed_guards.fetch_max(total_guards, Ordering::Relaxed);
        let dg = total_guards.saturating_sub(prev);
        if dg > 0 {
            k.guard_events.fetch_add(dg, Ordering::Relaxed);
        }
    }

    /// Threshold-normalization events recorded for a kind.
    pub fn norm_events(&self, kind: JobKind) -> u64 {
        self.kinds[kind_index(kind)]
            .norm_events
            .load(Ordering::Relaxed)
    }

    /// Guard-normalization events recorded for a kind.
    pub fn guard_events(&self, kind: JobKind) -> u64 {
        self.kinds[kind_index(kind)]
            .guard_events
            .load(Ordering::Relaxed)
    }

    /// Jobs completed for a kind.
    pub fn jobs(&self, kind: JobKind) -> u64 {
        self.kinds[kind_index(kind)].jobs.load(Ordering::Relaxed)
    }

    /// Total jobs across kinds.
    pub fn total_jobs(&self) -> u64 {
        JobKind::ALL.iter().map(|&k| self.jobs(k)).sum()
    }

    /// Jobs accepted into a lane queue.
    pub fn accepted(&self, kind: JobKind) -> u64 {
        self.kinds[kind_index(kind)].accepted.load(Ordering::Relaxed)
    }

    /// Total accepted across kinds.
    pub fn total_accepted(&self) -> u64 {
        JobKind::ALL.iter().map(|&k| self.accepted(k)).sum()
    }

    /// Rejected submissions for a kind.
    pub fn rejected(&self, kind: JobKind) -> u64 {
        self.kinds[kind_index(kind)].rejected.load(Ordering::Relaxed)
    }

    /// Total rejected across kinds.
    pub fn total_rejected(&self) -> u64 {
        JobKind::ALL.iter().map(|&k| self.rejected(k)).sum()
    }

    /// Batches stolen across shards for a kind.
    pub fn steals(&self, kind: JobKind) -> u64 {
        self.kinds[kind_index(kind)].steals.load(Ordering::Relaxed)
    }

    /// Currently queued jobs in a lane (gauge; may transiently read ±1).
    pub fn queue_depth(&self, kind: JobKind) -> i64 {
        self.kinds[kind_index(kind)].depth.load(Ordering::Relaxed)
    }

    /// Mean latency (µs) for a kind.
    pub fn mean_latency_us(&self, kind: JobKind) -> f64 {
        let k = &self.kinds[kind_index(kind)];
        let n = k.jobs.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            k.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate latency percentile (µs) from the log-linear histogram.
    pub fn latency_percentile_us(&self, kind: JobKind, p: f64) -> f64 {
        let k = &self.kinds[kind_index(kind)];
        let total: u64 = k
            .histogram
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, b) in k.histogram.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_mid_us(i);
            }
        }
        bucket_mid_us(BUCKETS - 1)
    }

    /// Mean jobs per dispatched batch.
    pub fn mean_batch_size(&self, kind: JobKind) -> f64 {
        let k = &self.kinds[kind_index(kind)];
        let b = k.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            k.jobs.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Occupancy in [0, 1]: fraction of aggregate worker wall time spent
    /// executing batches since startup. `workers` must be the *total*
    /// worker threads serving this kind (all its bucket lanes share one
    /// `busy_ns` accumulator — `Coordinator::metrics_table` passes the
    /// correct count from its lane map).
    pub fn occupancy(&self, kind: JobKind, workers: usize) -> f64 {
        let busy = self.kinds[kind_index(kind)].busy_ns.load(Ordering::Relaxed) as f64;
        let wall = self.start.elapsed().as_nanos().max(1) as f64 * workers.max(1) as f64;
        (busy / wall).min(1.0)
    }

    /// MAC-equivalents per second since startup, per kind.
    pub fn throughput_mops(&self, kind: JobKind) -> f64 {
        let k = &self.kinds[kind_index(kind)];
        let macs = k.macs.load(Ordering::Relaxed) as f64;
        macs / self.start.elapsed().as_micros().max(1) as f64
    }

    /// Render the serving report table; `workers_of(kind)` gives the
    /// total worker threads serving each kind (occupancy denominator).
    pub fn table_with(&self, workers_of: &dyn Fn(JobKind) -> usize) -> Table {
        let mut t = Table::new(
            "Serving metrics",
            &[
                "lane", "jobs", "rej", "steal", "mean batch", "p50 us", "p95 us", "p99 us",
                "occ %", "Mops", "norms", "guards",
            ],
        );
        for &kind in &JobKind::ALL {
            if self.jobs(kind) == 0 && self.rejected(kind) == 0 {
                continue;
            }
            t.rowv(&[
                kind.label().to_string(),
                self.jobs(kind).to_string(),
                self.rejected(kind).to_string(),
                self.steals(kind).to_string(),
                format!("{:.1}", self.mean_batch_size(kind)),
                format!("{:.1}", self.latency_percentile_us(kind, 50.0)),
                format!("{:.1}", self.latency_percentile_us(kind, 95.0)),
                format!("{:.1}", self.latency_percentile_us(kind, 99.0)),
                format!("{:.1}", self.occupancy(kind, workers_of(kind)) * 100.0),
                format!("{:.2}", self.throughput_mops(kind)),
                self.norm_events(kind).to_string(),
                self.guard_events(kind).to_string(),
            ]);
        }
        t
    }

    /// Render the serving report table with a flat per-kind worker count.
    pub fn table_with_workers(&self, workers: usize) -> Table {
        self.table_with(&move |_| workers)
    }

    /// Render the serving report table with the default worker count.
    pub fn table(&self) -> Table {
        self.table_with_workers(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::default();
        m.record_accepted(JobKind::DotHybrid);
        m.record_accepted(JobKind::DotHybrid);
        assert_eq!(m.queue_depth(JobKind::DotHybrid), 2);
        m.record(JobKind::DotHybrid, 10.0, 4096);
        m.record(JobKind::DotHybrid, 1000.0, 4096);
        m.record_batch(JobKind::DotHybrid, 2, Duration::from_micros(500));
        assert_eq!(m.queue_depth(JobKind::DotHybrid), 0);
        assert_eq!(m.jobs(JobKind::DotHybrid), 2);
        assert_eq!(m.total_jobs(), 2);
        assert_eq!(m.total_accepted(), 2);
        assert!((m.mean_latency_us(JobKind::DotHybrid) - 505.0).abs() < 1.0);
        assert_eq!(m.mean_batch_size(JobKind::DotHybrid), 2.0);
        assert!(m.throughput_mops(JobKind::DotHybrid) > 0.0);
        assert!(m.occupancy(JobKind::DotHybrid, 2) > 0.0);
    }

    #[test]
    fn rejects_and_steals_counted() {
        let m = Metrics::default();
        m.record_rejected(JobKind::DotF32);
        m.record_rejected(JobKind::DotF32);
        m.record_steal(JobKind::DotF32);
        assert_eq!(m.rejected(JobKind::DotF32), 2);
        assert_eq!(m.total_rejected(), 2);
        assert_eq!(m.steals(JobKind::DotF32), 1);
    }

    #[test]
    fn norm_events_claimed_exactly_once() {
        let m = Metrics::default();
        // Running totals: 0 → 5 events (2 guards) claimed by rk4...
        m.record_norm_totals(JobKind::Rk4Hybrid, 5, 2);
        // ...then 5 → 8: only the 3 new events are claimed.
        m.record_norm_totals(JobKind::Rk4Hybrid, 8, 2);
        // A stale/overlapping window (total 6 < cursor 8) claims nothing
        // — this is exactly the concurrent-worker double-count case.
        m.record_norm_totals(JobKind::DotHybrid, 6, 2);
        assert_eq!(m.norm_events(JobKind::Rk4Hybrid), 8);
        assert_eq!(m.guard_events(JobKind::Rk4Hybrid), 2);
        assert_eq!(m.norm_events(JobKind::DotHybrid), 0);
        assert_eq!(m.guard_events(JobKind::DotHybrid), 0);
        // Later events are attributed to the window that closed later.
        m.record_norm_totals(JobKind::DotHybrid, 10, 3);
        assert_eq!(m.norm_events(JobKind::DotHybrid), 2);
        assert_eq!(m.guard_events(JobKind::DotHybrid), 1);
        // A seeded cursor swallows pre-serving events: a fresh Metrics
        // seeded at totals (10, 3) attributes nothing until new events.
        let seeded = Metrics::default();
        seeded.seed_norm_cursor(10, 3);
        seeded.record_norm_totals(JobKind::DotHybrid, 10, 3);
        assert_eq!(seeded.norm_events(JobKind::DotHybrid), 0);
        seeded.record_norm_totals(JobKind::DotHybrid, 12, 3);
        assert_eq!(seeded.norm_events(JobKind::DotHybrid), 2);
        // Aggregate equals the true total — nothing double-counted.
        assert_eq!(
            m.norm_events(JobKind::Rk4Hybrid) + m.norm_events(JobKind::DotHybrid),
            10
        );
        // The events surface in the report table.
        m.record(JobKind::Rk4Hybrid, 10.0, 64);
        let s = m.table().render();
        assert!(s.contains("norms"));
        assert!(s.contains("guards"));
    }

    #[test]
    fn percentiles_monotonic_and_tight() {
        let m = Metrics::default();
        for i in 0..1000 {
            m.record(JobKind::DotF32, (i % 100) as f64 + 1.0, 1);
        }
        let p50 = m.latency_percentile_us(JobKind::DotF32, 50.0);
        let p95 = m.latency_percentile_us(JobKind::DotF32, 95.0);
        let p99 = m.latency_percentile_us(JobKind::DotF32, 99.0);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p50 > 0.0);
        // Log-linear buckets: the true p50 of this stream is ~50 µs; the
        // estimate must land within one sub-bucket (~±12%).
        assert!((25.0..=75.0).contains(&p50), "p50={p50}");
        assert!(p99 >= 80.0, "p99={p99}");
    }

    #[test]
    fn bucket_layout_is_monotonic() {
        let mut last = 0;
        for v in [1.0, 1.3, 1.8, 2.0, 3.0, 10.0, 1e3, 1e6, 1e9, 1e12] {
            let b = bucket_of(v);
            assert!(b >= last, "bucket_of({v}) went backwards");
            assert!(b < BUCKETS);
            last = b;
        }
        // Midpoint of a value's own bucket brackets the value.
        for v in [1.5, 7.0, 333.0, 80_000.0] {
            let mid = bucket_mid_us(bucket_of(v));
            assert!(mid / v < 1.3 && v / mid < 1.3, "v={v} mid={mid}");
        }
    }

    #[test]
    fn table_renders_active_lanes_only() {
        let m = Metrics::default();
        m.record(JobKind::MatmulF32, 5.0, 64);
        let s = m.table().render();
        assert!(s.contains("matmul/fp32"));
        assert!(!s.contains("dot/hrfna"));
    }
}
