//! Serving metrics: per-kind latency histograms, counters and throughput.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::request::JobKind;
use crate::util::table::Table;

/// Log2-µs latency histogram: bucket i covers [2^i, 2^{i+1}) µs.
const BUCKETS: usize = 24;

#[derive(Default)]
struct KindMetrics {
    jobs: AtomicU64,
    macs: AtomicU64,
    batches: AtomicU64,
    latency_sum_us: AtomicU64,
    histogram: [AtomicU64; BUCKETS],
}

/// Aggregated per-kind serving metrics (lock-free).
pub struct Metrics {
    kinds: [KindMetrics; 4],
    start: Instant,
}

fn kind_index(kind: JobKind) -> usize {
    match kind {
        JobKind::DotHybrid => 0,
        JobKind::DotF32 => 1,
        JobKind::MatmulHybrid => 2,
        JobKind::MatmulF32 => 3,
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            kinds: Default::default(),
            start: Instant::now(),
        }
    }
}

impl Metrics {
    /// Record one completed job.
    pub fn record(&self, kind: JobKind, latency_us: f64, macs: u64) {
        let k = &self.kinds[kind_index(kind)];
        k.jobs.fetch_add(1, Ordering::Relaxed);
        k.macs.fetch_add(macs, Ordering::Relaxed);
        k.latency_sum_us
            .fetch_add(latency_us.max(0.0) as u64, Ordering::Relaxed);
        let bucket = (latency_us.max(1.0).log2() as usize).min(BUCKETS - 1);
        k.histogram[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a dispatched batch.
    pub fn record_batch(&self, kind: JobKind) {
        self.kinds[kind_index(kind)]
            .batches
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Jobs completed for a kind.
    pub fn jobs(&self, kind: JobKind) -> u64 {
        self.kinds[kind_index(kind)].jobs.load(Ordering::Relaxed)
    }

    /// Total jobs across kinds.
    pub fn total_jobs(&self) -> u64 {
        JobKind::ALL.iter().map(|&k| self.jobs(k)).sum()
    }

    /// Mean latency (µs) for a kind.
    pub fn mean_latency_us(&self, kind: JobKind) -> f64 {
        let k = &self.kinds[kind_index(kind)];
        let n = k.jobs.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            k.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate latency percentile (µs) from the log2 histogram.
    pub fn latency_percentile_us(&self, kind: JobKind, p: f64) -> f64 {
        let k = &self.kinds[kind_index(kind)];
        let total: u64 = k
            .histogram
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in k.histogram.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                // Bucket midpoint in µs.
                return 2f64.powi(i as i32) * 1.5;
            }
        }
        2f64.powi(BUCKETS as i32)
    }

    /// Mean jobs per dispatched batch.
    pub fn mean_batch_size(&self, kind: JobKind) -> f64 {
        let k = &self.kinds[kind_index(kind)];
        let b = k.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            k.jobs.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// MAC-equivalents per second since startup, per kind.
    pub fn throughput_mops(&self, kind: JobKind) -> f64 {
        let k = &self.kinds[kind_index(kind)];
        let macs = k.macs.load(Ordering::Relaxed) as f64;
        macs / self.start.elapsed().as_micros().max(1) as f64
    }

    /// Render the serving report table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Serving metrics",
            &[
                "lane", "jobs", "mean batch", "mean us", "p50 us", "p99 us", "Mops",
            ],
        );
        for &kind in &JobKind::ALL {
            if self.jobs(kind) == 0 {
                continue;
            }
            t.rowv(&[
                kind.label().to_string(),
                self.jobs(kind).to_string(),
                format!("{:.1}", self.mean_batch_size(kind)),
                format!("{:.1}", self.mean_latency_us(kind)),
                format!("{:.1}", self.latency_percentile_us(kind, 50.0)),
                format!("{:.1}", self.latency_percentile_us(kind, 99.0)),
                format!("{:.2}", self.throughput_mops(kind)),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::default();
        m.record(JobKind::DotHybrid, 10.0, 4096);
        m.record(JobKind::DotHybrid, 1000.0, 4096);
        m.record_batch(JobKind::DotHybrid);
        assert_eq!(m.jobs(JobKind::DotHybrid), 2);
        assert_eq!(m.total_jobs(), 2);
        assert!((m.mean_latency_us(JobKind::DotHybrid) - 505.0).abs() < 1.0);
        assert_eq!(m.mean_batch_size(JobKind::DotHybrid), 2.0);
        assert!(m.throughput_mops(JobKind::DotHybrid) > 0.0);
    }

    #[test]
    fn percentiles_monotonic() {
        let m = Metrics::default();
        for i in 0..1000 {
            m.record(JobKind::DotF32, (i % 100) as f64 + 1.0, 1);
        }
        let p50 = m.latency_percentile_us(JobKind::DotF32, 50.0);
        let p99 = m.latency_percentile_us(JobKind::DotF32, 99.0);
        assert!(p50 <= p99);
        assert!(p50 > 0.0);
    }

    #[test]
    fn table_renders_active_lanes_only() {
        let m = Metrics::default();
        m.record(JobKind::MatmulF32, 5.0, 64);
        let s = m.table().render();
        assert!(s.contains("matmul/fp32"));
        assert!(!s.contains("dot/hrfna"));
    }
}
