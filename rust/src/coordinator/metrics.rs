//! Serving metrics: per-(kind, tier) latency histograms (p50/p95/p99),
//! queue depth, worker occupancy, steal/reject/escalation counters and
//! throughput — all lock-free (relaxed atomics; these are metrics, not
//! synchronization).
//!
//! Every row of the report is one **(kind, tier)** slot: hybrid lanes
//! produce one row per active precision tier (with its own §VII-E
//! norm/guard/reconstruction accounting against that tier's context
//! counters), FP32 lanes live in the tier-agnostic [`Tier::Paper`] slot.
//! Per-kind aggregate getters (summing across tiers) keep the historical
//! API for drain accounting and the saturation tests.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::request::JobKind;
use crate::hybrid::registry::Tier;
use crate::util::table::Table;

/// Log-linear latency histogram: `SUB` sub-buckets per power-of-two octave
/// of microseconds — octave `o`, sub `s` covers
/// `[2^o·(1 + s/SUB), 2^o·(1 + (s+1)/SUB))` µs. Four sub-buckets keep the
/// worst-case percentile quantization error below ~12%, against ~50% for
/// the plain log2 histogram this replaces.
const SUB: usize = 4;
const OCTAVES: usize = 26; // up to 2^26 µs ≈ 67 s
const BUCKETS: usize = SUB * OCTAVES;

const KINDS: usize = JobKind::ALL.len();
const TIERS: usize = Tier::ALL.len();

fn bucket_of(latency_us: f64) -> usize {
    let v = latency_us.max(1.0);
    let oct = v.log2().floor() as usize;
    if oct >= OCTAVES {
        return BUCKETS - 1;
    }
    let frac = v / 2f64.powi(oct as i32) - 1.0; // in [0, 1)
    let sub = ((frac * SUB as f64) as usize).min(SUB - 1);
    oct * SUB + sub
}

/// Midpoint (µs) of histogram bucket `i`.
fn bucket_mid_us(i: usize) -> f64 {
    let oct = i / SUB;
    let sub = i % SUB;
    2f64.powi(oct as i32) * (1.0 + (sub as f64 + 0.5) / SUB as f64)
}

/// One (kind, tier) slot of counters + histogram.
struct SlotMetrics {
    jobs: AtomicU64,
    macs: AtomicU64,
    batches: AtomicU64,
    accepted: AtomicU64,
    rejected: AtomicU64,
    steals: AtomicU64,
    /// Jobs escalated *into* this tier (admission bumped them past their
    /// requested tier because its bound could not cover the request).
    escalations: AtomicU64,
    /// Threshold-triggered normalization events taken while executing
    /// this slot's batches (§VII-E frequency accounting, per lane).
    norm_events: AtomicU64,
    /// Overflow-guard normalization events for this slot.
    guard_events: AtomicU64,
    /// Full CRT reconstructions claimed by this slot's batches.
    recon_events: AtomicU64,
    /// Integrity detections: authenticated results this slot caught as
    /// corrupted (MAC/exponent/checksum/Freivalds) before delivery.
    integrity_detections: AtomicU64,
    /// Encoded-operand cache hits attributed to this slot's lookups.
    cache_hits: AtomicU64,
    /// Encoded-operand cache misses (cold or post-invalidation encodes).
    cache_misses: AtomicU64,
    /// Entries the cache evicted to admit this slot's inserts.
    cache_evictions: AtomicU64,
    /// Wall time workers of this slot spent executing batches (ns).
    busy_ns: AtomicU64,
    /// Currently queued jobs (gauge; +1 on accept, −batch on dequeue).
    depth: AtomicI64,
    latency_sum_us: AtomicU64,
    histogram: [AtomicU64; BUCKETS],
}

impl Default for SlotMetrics {
    fn default() -> SlotMetrics {
        SlotMetrics {
            jobs: AtomicU64::new(0),
            macs: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            escalations: AtomicU64::new(0),
            norm_events: AtomicU64::new(0),
            guard_events: AtomicU64::new(0),
            recon_events: AtomicU64::new(0),
            integrity_detections: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            busy_ns: AtomicU64::new(0),
            depth: AtomicI64::new(0),
            latency_sum_us: AtomicU64::new(0),
            histogram: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Per-tier claim cursors over one shared `OpCounters` total (see
/// [`Metrics::record_norm_totals`]).
#[derive(Default)]
struct TierCursor {
    norms: AtomicU64,
    guards: AtomicU64,
    recons: AtomicU64,
}

/// Aggregated per-(kind, tier) serving metrics.
pub struct Metrics {
    slots: [[SlotMetrics; TIERS]; KINDS],
    /// Claim cursors over each tier context's `OpCounters` totals:
    /// workers report the *running totals* they observe after a batch,
    /// and the cursor hands each event to exactly one reporter
    /// (`fetch_max` partition) — overlapping execution windows cannot
    /// double-count. One cursor per tier because each tier's context
    /// carries independent counters.
    cursors: [TierCursor; TIERS],
    start: Instant,
}

fn kind_index(kind: JobKind) -> usize {
    match kind {
        JobKind::DotHybrid => 0,
        JobKind::DotF32 => 1,
        JobKind::MatmulHybrid => 2,
        JobKind::MatmulF32 => 3,
        JobKind::Rk4Hybrid => 4,
        JobKind::FirHybrid => 5,
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            slots: std::array::from_fn(|_| std::array::from_fn(|_| SlotMetrics::default())),
            cursors: std::array::from_fn(|_| TierCursor::default()),
            start: Instant::now(),
        }
    }
}

impl Metrics {
    #[inline]
    fn slot(&self, kind: JobKind, tier: Tier) -> &SlotMetrics {
        &self.slots[kind_index(kind)][tier.index()]
    }

    /// Record one completed job.
    pub fn record(&self, kind: JobKind, tier: Tier, latency_us: f64, macs: u64) {
        let s = self.slot(kind, tier);
        s.jobs.fetch_add(1, Ordering::Relaxed);
        s.macs.fetch_add(macs, Ordering::Relaxed);
        s.latency_sum_us
            .fetch_add(latency_us.max(0.0) as u64, Ordering::Relaxed);
        s.histogram[bucket_of(latency_us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a dispatched batch and the wall time its execution took.
    pub fn record_batch(&self, kind: JobKind, tier: Tier, size: usize, busy: Duration) {
        let s = self.slot(kind, tier);
        s.batches.fetch_add(1, Ordering::Relaxed);
        s.busy_ns
            .fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
        s.depth.fetch_sub(size as i64, Ordering::Relaxed);
    }

    /// Record a job accepted into a lane queue.
    pub fn record_accepted(&self, kind: JobKind, tier: Tier) {
        let s = self.slot(kind, tier);
        s.accepted.fetch_add(1, Ordering::Relaxed);
        s.depth.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rejected submission (admission failure or overload).
    pub fn record_rejected(&self, kind: JobKind, tier: Tier) {
        self.slot(kind, tier).rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a batch stolen from a sibling shard.
    pub fn record_steal(&self, kind: JobKind, tier: Tier) {
        self.slot(kind, tier).steals.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a tier escalation: admission bumped a job *into* `tier`
    /// because the tiers below could not cover its envelope/tolerance.
    pub fn record_escalation(&self, kind: JobKind, tier: Tier) {
        self.slot(kind, tier)
            .escalations
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Record an integrity detection: an authenticated result of this
    /// slot failed verification and was quarantined instead of delivered.
    pub fn record_integrity(&self, kind: JobKind, tier: Tier) {
        self.slot(kind, tier)
            .integrity_detections
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Record one encoded-operand cache lookup: a hit or a miss, plus
    /// any evictions the miss's insert forced. Workers call this from
    /// the cache-consulting executors (`execute_batch_cached`).
    pub fn record_cache_lookup(&self, kind: JobKind, tier: Tier, hit: bool, evictions: u64) {
        let s = self.slot(kind, tier);
        if hit {
            s.cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            s.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        if evictions > 0 {
            s.cache_evictions.fetch_add(evictions, Ordering::Relaxed);
        }
    }

    /// Seed a tier's claim cursors from its context's current totals:
    /// events taken before serving started (client-side warmup on the
    /// same context) must not be attributed to the first lane that
    /// completes a batch. `Coordinator::start` calls this once per
    /// already-constructed tier before spawning workers.
    pub fn seed_norm_cursor(&self, tier: Tier, norms: u64, guards: u64, recons: u64) {
        let c = &self.cursors[tier.index()];
        c.norms.fetch_max(norms, Ordering::Relaxed);
        c.guards.fetch_max(guards, Ordering::Relaxed);
        c.recons.fetch_max(recons, Ordering::Relaxed);
    }

    /// Record normalization/reconstruction events from a tier context's
    /// *running totals* (threshold, guard and CRT reconstructions — the
    /// per-lane §VII-E counters). Workers call this with the
    /// `OpSnapshot` observed after `execute_batch`; the tier's claim
    /// cursor (`fetch_max`) hands every event to exactly one caller, so
    /// concurrent workers with overlapping execution windows never
    /// double-count. Aggregate totals are exact; *per-kind attribution*
    /// of an event taken while two kinds were executing on the same
    /// tier is approximate (whichever window closed later claims it) —
    /// metrics, not synchronization.
    pub fn record_norm_totals(
        &self,
        kind: JobKind,
        tier: Tier,
        total_norms: u64,
        total_guards: u64,
        total_recons: u64,
    ) {
        let s = self.slot(kind, tier);
        let c = &self.cursors[tier.index()];
        let prev = c.norms.fetch_max(total_norms, Ordering::Relaxed);
        let dn = total_norms.saturating_sub(prev);
        if dn > 0 {
            s.norm_events.fetch_add(dn, Ordering::Relaxed);
        }
        let prev = c.guards.fetch_max(total_guards, Ordering::Relaxed);
        let dg = total_guards.saturating_sub(prev);
        if dg > 0 {
            s.guard_events.fetch_add(dg, Ordering::Relaxed);
        }
        let prev = c.recons.fetch_max(total_recons, Ordering::Relaxed);
        let dr = total_recons.saturating_sub(prev);
        if dr > 0 {
            s.recon_events.fetch_add(dr, Ordering::Relaxed);
        }
    }

    // ------------------------------------------------------------------
    // Tier-scoped getters
    // ------------------------------------------------------------------

    /// Jobs completed for a (kind, tier) slot.
    pub fn jobs_tier(&self, kind: JobKind, tier: Tier) -> u64 {
        self.slot(kind, tier).jobs.load(Ordering::Relaxed)
    }

    /// Jobs escalated into a (kind, tier) slot.
    pub fn escalations_tier(&self, kind: JobKind, tier: Tier) -> u64 {
        self.slot(kind, tier).escalations.load(Ordering::Relaxed)
    }

    /// Threshold-normalization events recorded for a (kind, tier) slot.
    pub fn norm_events_tier(&self, kind: JobKind, tier: Tier) -> u64 {
        self.slot(kind, tier).norm_events.load(Ordering::Relaxed)
    }

    /// Guard-normalization events recorded for a (kind, tier) slot.
    pub fn guard_events_tier(&self, kind: JobKind, tier: Tier) -> u64 {
        self.slot(kind, tier).guard_events.load(Ordering::Relaxed)
    }

    /// CRT reconstructions recorded for a (kind, tier) slot.
    pub fn recon_events_tier(&self, kind: JobKind, tier: Tier) -> u64 {
        self.slot(kind, tier).recon_events.load(Ordering::Relaxed)
    }

    /// Integrity detections recorded for a (kind, tier) slot.
    pub fn integrity_tier(&self, kind: JobKind, tier: Tier) -> u64 {
        self.slot(kind, tier)
            .integrity_detections
            .load(Ordering::Relaxed)
    }

    /// Operand-cache hits recorded for a (kind, tier) slot.
    pub fn cache_hits_tier(&self, kind: JobKind, tier: Tier) -> u64 {
        self.slot(kind, tier).cache_hits.load(Ordering::Relaxed)
    }

    /// Operand-cache misses recorded for a (kind, tier) slot.
    pub fn cache_misses_tier(&self, kind: JobKind, tier: Tier) -> u64 {
        self.slot(kind, tier).cache_misses.load(Ordering::Relaxed)
    }

    /// Operand-cache evictions recorded for a (kind, tier) slot.
    pub fn cache_evictions_tier(&self, kind: JobKind, tier: Tier) -> u64 {
        self.slot(kind, tier).cache_evictions.load(Ordering::Relaxed)
    }

    /// Occupancy of one (kind, tier) slot in [0, 1]: that slot's batch
    /// execution wall time against the kind's worker pool (`workers` =
    /// total threads serving the kind, as for [`Metrics::occupancy`] —
    /// tier rows therefore sum to the kind aggregate, never over it).
    pub fn occupancy_tier(&self, kind: JobKind, tier: Tier, workers: usize) -> f64 {
        let busy = self.slot(kind, tier).busy_ns.load(Ordering::Relaxed) as f64;
        let wall = self.start.elapsed().as_nanos().max(1) as f64 * workers.max(1) as f64;
        (busy / wall).min(1.0)
    }

    /// MAC-equivalents per second for one (kind, tier) slot.
    pub fn throughput_mops_tier(&self, kind: JobKind, tier: Tier) -> f64 {
        let macs = self.slot(kind, tier).macs.load(Ordering::Relaxed) as f64;
        macs / self.start.elapsed().as_micros().max(1) as f64
    }

    /// Mean latency (µs) for a (kind, tier) slot.
    pub fn mean_latency_us_tier(&self, kind: JobKind, tier: Tier) -> f64 {
        let s = self.slot(kind, tier);
        let n = s.jobs.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            s.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate latency percentile (µs) for one (kind, tier) slot.
    pub fn latency_percentile_us_tier(&self, kind: JobKind, tier: Tier, p: f64) -> f64 {
        self.percentile_over(&[self.slot(kind, tier)], p)
    }

    // ------------------------------------------------------------------
    // Per-kind aggregates (sum over tiers — the historical API)
    // ------------------------------------------------------------------

    fn sum_over_tiers(&self, kind: JobKind, read: impl Fn(&SlotMetrics) -> u64) -> u64 {
        Tier::ALL.iter().map(|&t| read(self.slot(kind, t))).sum()
    }

    /// Jobs completed for a kind.
    pub fn jobs(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.jobs.load(Ordering::Relaxed))
    }

    /// Total jobs across kinds.
    pub fn total_jobs(&self) -> u64 {
        JobKind::ALL.iter().map(|&k| self.jobs(k)).sum()
    }

    /// Jobs accepted into a lane queue.
    pub fn accepted(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.accepted.load(Ordering::Relaxed))
    }

    /// Total accepted across kinds.
    pub fn total_accepted(&self) -> u64 {
        JobKind::ALL.iter().map(|&k| self.accepted(k)).sum()
    }

    /// Rejected submissions for a kind.
    pub fn rejected(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.rejected.load(Ordering::Relaxed))
    }

    /// Total rejected across kinds.
    pub fn total_rejected(&self) -> u64 {
        JobKind::ALL.iter().map(|&k| self.rejected(k)).sum()
    }

    /// Batches stolen across shards for a kind.
    pub fn steals(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.steals.load(Ordering::Relaxed))
    }

    /// Tier escalations that landed on a kind.
    pub fn escalations(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.escalations.load(Ordering::Relaxed))
    }

    /// Total escalations across kinds and tiers.
    pub fn total_escalations(&self) -> u64 {
        JobKind::ALL.iter().map(|&k| self.escalations(k)).sum()
    }

    /// Threshold-normalization events recorded for a kind.
    pub fn norm_events(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.norm_events.load(Ordering::Relaxed))
    }

    /// Guard-normalization events recorded for a kind.
    pub fn guard_events(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.guard_events.load(Ordering::Relaxed))
    }

    /// Integrity detections recorded for a kind.
    pub fn integrity_detections(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.integrity_detections.load(Ordering::Relaxed))
    }

    /// Total integrity detections across kinds and tiers.
    pub fn total_integrity_detections(&self) -> u64 {
        JobKind::ALL.iter().map(|&k| self.integrity_detections(k)).sum()
    }

    /// Operand-cache hits recorded for a kind.
    pub fn cache_hits(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.cache_hits.load(Ordering::Relaxed))
    }

    /// Operand-cache misses recorded for a kind.
    pub fn cache_misses(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.cache_misses.load(Ordering::Relaxed))
    }

    /// Operand-cache evictions recorded for a kind.
    pub fn cache_evictions(&self, kind: JobKind) -> u64 {
        self.sum_over_tiers(kind, |s| s.cache_evictions.load(Ordering::Relaxed))
    }

    /// Operand-cache hit ratio for a kind in [0, 1]; 0 when the kind
    /// performed no lookups.
    pub fn cache_hit_ratio(&self, kind: JobKind) -> f64 {
        let hits = self.cache_hits(kind);
        let total = hits + self.cache_misses(kind);
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Currently queued jobs in a kind's lanes (gauge; transiently ±1).
    pub fn queue_depth(&self, kind: JobKind) -> i64 {
        Tier::ALL
            .iter()
            .map(|&t| self.slot(kind, t).depth.load(Ordering::Relaxed))
            .sum()
    }

    /// Currently queued jobs across every lane (gauge; transiently ±1).
    /// This is the occupancy signal the cluster router reads per shard
    /// through the `health` RPC for overload diversion.
    pub fn queue_depth_total(&self) -> i64 {
        JobKind::ALL.iter().map(|&k| self.queue_depth(k)).sum()
    }

    /// Mean latency (µs) for a kind.
    pub fn mean_latency_us(&self, kind: JobKind) -> f64 {
        let n = self.jobs(kind);
        if n == 0 {
            return 0.0;
        }
        let sum = self.sum_over_tiers(kind, |s| s.latency_sum_us.load(Ordering::Relaxed));
        sum as f64 / n as f64
    }

    /// Histogram percentile over a set of slots (merged bucketwise).
    fn percentile_over(&self, slots: &[&SlotMetrics], p: f64) -> f64 {
        let counts: Vec<u64> = (0..BUCKETS)
            .map(|i| {
                slots
                    .iter()
                    .map(|s| s.histogram[i].load(Ordering::Relaxed))
                    .sum()
            })
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return bucket_mid_us(i);
            }
        }
        bucket_mid_us(BUCKETS - 1)
    }

    /// Approximate latency percentile (µs) across a kind's tiers.
    pub fn latency_percentile_us(&self, kind: JobKind, p: f64) -> f64 {
        let slots: Vec<&SlotMetrics> =
            Tier::ALL.iter().map(|&t| self.slot(kind, t)).collect();
        self.percentile_over(&slots, p)
    }

    /// Mean jobs per dispatched batch.
    pub fn mean_batch_size(&self, kind: JobKind) -> f64 {
        let b = self.sum_over_tiers(kind, |s| s.batches.load(Ordering::Relaxed));
        if b == 0 {
            0.0
        } else {
            self.jobs(kind) as f64 / b as f64
        }
    }

    /// Occupancy in [0, 1]: fraction of aggregate worker wall time spent
    /// executing batches since startup. `workers` must be the *total*
    /// worker threads serving this kind across all its tier/bucket lanes
    /// (`Coordinator::metrics_table` passes the correct count from its
    /// lane map).
    pub fn occupancy(&self, kind: JobKind, workers: usize) -> f64 {
        let busy = self.sum_over_tiers(kind, |s| s.busy_ns.load(Ordering::Relaxed)) as f64;
        let wall = self.start.elapsed().as_nanos().max(1) as f64 * workers.max(1) as f64;
        (busy / wall).min(1.0)
    }

    /// MAC-equivalents per second since startup, per kind.
    pub fn throughput_mops(&self, kind: JobKind) -> f64 {
        let macs = self.sum_over_tiers(kind, |s| s.macs.load(Ordering::Relaxed)) as f64;
        macs / self.start.elapsed().as_micros().max(1) as f64
    }

    /// Render the serving report table — one row per active (kind, tier)
    /// slot, every column slot-scoped (occ %/Mops use the per-slot
    /// accumulators, so tier rows sum to the kind aggregate instead of
    /// each repeating it); `workers_of(kind)` gives the total worker
    /// threads serving each kind (occupancy denominator, shared across
    /// its tiers).
    pub fn table_with(&self, workers_of: &dyn Fn(JobKind) -> usize) -> Table {
        let mut t = Table::new(
            "Serving metrics",
            &[
                "lane", "jobs", "rej", "steal", "esc", "integ", "mean batch", "p50 us",
                "p95 us", "p99 us", "occ %", "Mops", "norms", "guards", "recon", "chit",
                "cmiss", "cevict",
            ],
        );
        for &kind in &JobKind::ALL {
            for &tier in &Tier::ALL {
                let s = self.slot(kind, tier);
                let jobs = s.jobs.load(Ordering::Relaxed);
                let rej = s.rejected.load(Ordering::Relaxed);
                if jobs == 0 && rej == 0 {
                    continue;
                }
                // FP32 lanes are tier-agnostic: plain label, no suffix.
                let label = if kind.is_hybrid() {
                    format!("{}@{}", kind.label(), tier.label())
                } else {
                    kind.label().to_string()
                };
                let batches = s.batches.load(Ordering::Relaxed);
                let mean_batch = if batches == 0 {
                    0.0
                } else {
                    jobs as f64 / batches as f64
                };
                t.rowv(&[
                    label,
                    jobs.to_string(),
                    rej.to_string(),
                    s.steals.load(Ordering::Relaxed).to_string(),
                    s.escalations.load(Ordering::Relaxed).to_string(),
                    s.integrity_detections.load(Ordering::Relaxed).to_string(),
                    format!("{mean_batch:.1}"),
                    format!("{:.1}", self.latency_percentile_us_tier(kind, tier, 50.0)),
                    format!("{:.1}", self.latency_percentile_us_tier(kind, tier, 95.0)),
                    format!("{:.1}", self.latency_percentile_us_tier(kind, tier, 99.0)),
                    format!("{:.1}", self.occupancy_tier(kind, tier, workers_of(kind)) * 100.0),
                    format!("{:.2}", self.throughput_mops_tier(kind, tier)),
                    s.norm_events.load(Ordering::Relaxed).to_string(),
                    s.guard_events.load(Ordering::Relaxed).to_string(),
                    s.recon_events.load(Ordering::Relaxed).to_string(),
                    s.cache_hits.load(Ordering::Relaxed).to_string(),
                    s.cache_misses.load(Ordering::Relaxed).to_string(),
                    s.cache_evictions.load(Ordering::Relaxed).to_string(),
                ]);
            }
        }
        t
    }

    /// Render the serving report table with a flat per-kind worker count.
    pub fn table_with_workers(&self, workers: usize) -> Table {
        self.table_with(&move |_| workers)
    }

    /// Render the serving report table with the default worker count.
    pub fn table(&self) -> Table {
        self.table_with_workers(2)
    }
}

// ----------------------------------------------------------------------
// Wire-level metrics (the RPC serving edge)
// ----------------------------------------------------------------------

/// Per-client wire counters: one set per accepted connection (the RPC
/// edge's client identity is the connection). All relaxed atomics —
/// metrics, not synchronization.
#[derive(Default)]
pub struct ClientCounters {
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    /// Subset of `frames_in` that arrived as binary envelopes (the
    /// negotiated bulk-`f64` encoding); the remainder were pure JSON.
    pub bin_frames_in: AtomicU64,
    /// Subset of `frames_out` written as binary envelopes.
    pub bin_frames_out: AtomicU64,
    /// Payload bytes of the inbound binary-envelope subset.
    pub bin_bytes_in: AtomicU64,
    /// Payload bytes of the outbound binary-envelope subset.
    pub bin_bytes_out: AtomicU64,
    /// Jobs this client submitted that the coordinator accepted.
    pub submits: AtomicU64,
    /// Job results delivered back over this connection.
    pub results: AtomicU64,
    /// Error responses sent (admission, overload, bad request, …).
    pub wire_errors: AtomicU64,
    /// Submissions shed by the client's token-bucket rate quota.
    pub rate_limited: AtomicU64,
    /// Submissions shed by the client's in-flight cap.
    pub inflight_limited: AtomicU64,
}

macro_rules! wire_counter {
    ($($(#[$doc:meta])* $name:ident),+ $(,)?) => {
        $($(#[$doc])*
        pub fn $name(&self) -> u64 {
            self.$name.load(Ordering::Relaxed)
        })+
    };
}

impl ClientCounters {
    wire_counter!(frames_in, frames_out, bytes_in, bytes_out, bin_frames_in,
        bin_frames_out, bin_bytes_in, bin_bytes_out, submits, results,
        wire_errors, rate_limited, inflight_limited);
}

/// Wire-level serving metrics for the RPC edge: connection/frame/byte
/// totals plus a registry of per-client counters (rendered as one table
/// row per connection). Lives here rather than in the feature-gated
/// `rpc` module so the counters — and their exactly-once accounting —
/// stay compiled and unit-tested in the default (tier-1) build.
#[derive(Default)]
pub struct WireMetrics {
    conns_opened: AtomicU64,
    conns_closed: AtomicU64,
    totals: ClientCounters,
    /// Frames that failed to parse / violated the protocol (counted
    /// globally: a malformed frame may have no attributable client
    /// request).
    protocol_errors: AtomicU64,
    /// Per-connection handler panics caught at the connection boundary:
    /// the connection died, the server survived.
    conn_panics: AtomicU64,
    clients: Mutex<Vec<(String, Arc<ClientCounters>)>>,
}

impl WireMetrics {
    /// Register a new connection; returns its counter set. `label`
    /// identifies the client in the report table (peer address + a
    /// connection sequence number, by convention).
    pub fn register_client(&self, label: &str) -> Arc<ClientCounters> {
        self.conns_opened.fetch_add(1, Ordering::Relaxed);
        let c = Arc::new(ClientCounters::default());
        self.clients
            .lock()
            .expect("wire client registry")
            .push((label.to_string(), Arc::clone(&c)));
        c
    }

    /// Record a connection teardown.
    pub fn record_conn_closed(&self) {
        self.conns_closed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one decoded inbound frame of `bytes` payload bytes.
    pub fn record_frame_in(&self, c: &ClientCounters, bytes: usize) {
        c.frames_in.fetch_add(1, Ordering::Relaxed);
        c.bytes_in.fetch_add(bytes as u64, Ordering::Relaxed);
        self.totals.frames_in.fetch_add(1, Ordering::Relaxed);
        self.totals.bytes_in.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Record one written outbound frame of `bytes` payload bytes.
    pub fn record_frame_out(&self, c: &ClientCounters, bytes: usize) {
        c.frames_out.fetch_add(1, Ordering::Relaxed);
        c.bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
        self.totals.frames_out.fetch_add(1, Ordering::Relaxed);
        self.totals.bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// [`WireMetrics::record_frame_in`] split by encoding: `binary`
    /// additionally attributes the frame to the binary-envelope subset.
    pub fn record_frame_in_encoded(&self, c: &ClientCounters, bytes: usize, binary: bool) {
        self.record_frame_in(c, bytes);
        if binary {
            c.bin_frames_in.fetch_add(1, Ordering::Relaxed);
            c.bin_bytes_in.fetch_add(bytes as u64, Ordering::Relaxed);
            self.totals.bin_frames_in.fetch_add(1, Ordering::Relaxed);
            self.totals.bin_bytes_in.fetch_add(bytes as u64, Ordering::Relaxed);
        }
    }

    /// [`WireMetrics::record_frame_out`] split by encoding.
    pub fn record_frame_out_encoded(&self, c: &ClientCounters, bytes: usize, binary: bool) {
        self.record_frame_out(c, bytes);
        if binary {
            c.bin_frames_out.fetch_add(1, Ordering::Relaxed);
            c.bin_bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
            self.totals.bin_frames_out.fetch_add(1, Ordering::Relaxed);
            self.totals.bin_bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
        }
    }

    /// Record an accepted submission.
    pub fn record_submit(&self, c: &ClientCounters) {
        c.submits.fetch_add(1, Ordering::Relaxed);
        self.totals.submits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a job result delivered to its client.
    pub fn record_result(&self, c: &ClientCounters) {
        c.results.fetch_add(1, Ordering::Relaxed);
        self.totals.results.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an error response sent to a client.
    pub fn record_wire_error(&self, c: &ClientCounters) {
        c.wire_errors.fetch_add(1, Ordering::Relaxed);
        self.totals.wire_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a submission shed by the rate quota.
    pub fn record_rate_limited(&self, c: &ClientCounters) {
        c.rate_limited.fetch_add(1, Ordering::Relaxed);
        self.totals.rate_limited.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a submission shed by the in-flight cap.
    pub fn record_inflight_limited(&self, c: &ClientCounters) {
        c.inflight_limited.fetch_add(1, Ordering::Relaxed);
        self.totals.inflight_limited.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an unparseable/protocol-violating frame.
    pub fn record_protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a panic caught at a connection boundary (the handler died;
    /// the serve loop and every other connection kept running).
    pub fn record_conn_panic(&self) {
        self.conn_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Connections accepted over the server's lifetime.
    pub fn conns_opened(&self) -> u64 {
        self.conns_opened.load(Ordering::Relaxed)
    }

    /// Connections torn down.
    pub fn conns_closed(&self) -> u64 {
        self.conns_closed.load(Ordering::Relaxed)
    }

    /// Protocol errors (malformed frames).
    pub fn protocol_errors(&self) -> u64 {
        self.protocol_errors.load(Ordering::Relaxed)
    }

    /// Connection-handler panics contained at the connection boundary.
    pub fn conn_panics(&self) -> u64 {
        self.conn_panics.load(Ordering::Relaxed)
    }

    /// Aggregate counters across all clients.
    pub fn totals(&self) -> &ClientCounters {
        &self.totals
    }

    /// Render the wire report: one row per connection plus a totals row.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Wire metrics",
            &[
                "client", "fr in", "fr out", "KiB in", "KiB out", "bin in", "bin out",
                "bKiB in", "bKiB out", "submit", "result", "err", "rate-shed", "infl-shed",
            ],
        );
        let row = |t: &mut Table, label: &str, c: &ClientCounters| {
            t.rowv(&[
                label.to_string(),
                c.frames_in().to_string(),
                c.frames_out().to_string(),
                format!("{:.1}", c.bytes_in() as f64 / 1024.0),
                format!("{:.1}", c.bytes_out() as f64 / 1024.0),
                c.bin_frames_in().to_string(),
                c.bin_frames_out().to_string(),
                format!("{:.1}", c.bin_bytes_in() as f64 / 1024.0),
                format!("{:.1}", c.bin_bytes_out() as f64 / 1024.0),
                c.submits().to_string(),
                c.results().to_string(),
                c.wire_errors().to_string(),
                c.rate_limited().to_string(),
                c.inflight_limited().to_string(),
            ]);
        };
        for (label, c) in self.clients.lock().expect("wire client registry").iter() {
            row(&mut t, label, c);
        }
        row(&mut t, "TOTAL", &self.totals);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: Tier = Tier::Paper;

    #[test]
    fn records_and_reports() {
        let m = Metrics::default();
        m.record_accepted(JobKind::DotHybrid, P);
        m.record_accepted(JobKind::DotHybrid, P);
        assert_eq!(m.queue_depth(JobKind::DotHybrid), 2);
        m.record(JobKind::DotHybrid, P, 10.0, 4096);
        m.record(JobKind::DotHybrid, P, 1000.0, 4096);
        m.record_batch(JobKind::DotHybrid, P, 2, Duration::from_micros(500));
        assert_eq!(m.queue_depth(JobKind::DotHybrid), 0);
        assert_eq!(m.jobs(JobKind::DotHybrid), 2);
        assert_eq!(m.jobs_tier(JobKind::DotHybrid, P), 2);
        assert_eq!(m.jobs_tier(JobKind::DotHybrid, Tier::Lo), 0);
        assert_eq!(m.total_jobs(), 2);
        assert_eq!(m.total_accepted(), 2);
        assert!((m.mean_latency_us(JobKind::DotHybrid) - 505.0).abs() < 1.0);
        assert_eq!(m.mean_batch_size(JobKind::DotHybrid), 2.0);
        assert!(m.throughput_mops(JobKind::DotHybrid) > 0.0);
        assert!(m.occupancy(JobKind::DotHybrid, 2) > 0.0);
    }

    #[test]
    fn cache_lookup_counters_per_slot_and_reported() {
        let m = Metrics::default();
        let k = JobKind::MatmulHybrid;
        m.record_cache_lookup(k, P, false, 0);
        m.record_cache_lookup(k, P, true, 0);
        m.record_cache_lookup(k, P, true, 0);
        m.record_cache_lookup(k, Tier::Lo, false, 2);
        assert_eq!(m.cache_hits_tier(k, P), 2);
        assert_eq!(m.cache_misses_tier(k, P), 1);
        assert_eq!(m.cache_misses_tier(k, Tier::Lo), 1);
        assert_eq!(m.cache_evictions_tier(k, Tier::Lo), 2);
        assert_eq!(m.cache_hits(k), 2);
        assert_eq!(m.cache_misses(k), 2);
        assert_eq!(m.cache_evictions(k), 2);
        assert!((m.cache_hit_ratio(k) - 0.5).abs() < 1e-12);
        assert_eq!(m.cache_hit_ratio(JobKind::FirHybrid), 0.0);
        // The rendered table carries the cache columns for active rows.
        m.record(k, P, 10.0, 64);
        let rendered = m.table().render();
        for col in ["chit", "cmiss", "cevict"] {
            assert!(rendered.contains(col), "missing column {col}");
        }
    }

    #[test]
    fn tiers_are_separate_rows() {
        let m = Metrics::default();
        m.record(JobKind::DotHybrid, Tier::Lo, 10.0, 512);
        m.record(JobKind::DotHybrid, Tier::Wide, 50.0, 512);
        m.record_batch(JobKind::DotHybrid, Tier::Lo, 1, Duration::from_micros(400));
        assert_eq!(m.jobs_tier(JobKind::DotHybrid, Tier::Lo), 1);
        assert_eq!(m.jobs_tier(JobKind::DotHybrid, Tier::Wide), 1);
        assert_eq!(m.jobs_tier(JobKind::DotHybrid, P), 0);
        assert_eq!(m.jobs(JobKind::DotHybrid), 2, "aggregate sums tiers");
        // Slot-scoped occupancy/throughput: only the tier that did the
        // work shows it, and the rows sum to the kind aggregate. (The
        // sleep makes elapsed() large against the drift between the
        // per-call elapsed reads below.)
        assert!(m.occupancy_tier(JobKind::DotHybrid, Tier::Lo, 2) > 0.0);
        assert_eq!(m.occupancy_tier(JobKind::DotHybrid, Tier::Wide, 2), 0.0);
        std::thread::sleep(Duration::from_millis(10));
        let tier_sum: f64 = Tier::ALL
            .iter()
            .map(|&t| m.throughput_mops_tier(JobKind::DotHybrid, t))
            .sum();
        let agg = m.throughput_mops(JobKind::DotHybrid);
        assert!((tier_sum - agg).abs() <= agg * 0.05, "{tier_sum} vs {agg}");
        let s = m.table().render();
        assert!(s.contains("dot/hrfna@lo"));
        assert!(s.contains("dot/hrfna@wide"));
        assert!(!s.contains("dot/hrfna@paper"));
    }

    #[test]
    fn escalations_counted_per_slot() {
        let m = Metrics::default();
        m.record_escalation(JobKind::DotHybrid, P);
        m.record_escalation(JobKind::DotHybrid, Tier::Wide);
        m.record_escalation(JobKind::Rk4Hybrid, Tier::Wide);
        assert_eq!(m.escalations_tier(JobKind::DotHybrid, P), 1);
        assert_eq!(m.escalations_tier(JobKind::DotHybrid, Tier::Wide), 1);
        assert_eq!(m.escalations(JobKind::DotHybrid), 2);
        assert_eq!(m.total_escalations(), 3);
    }

    #[test]
    fn rejects_and_steals_counted() {
        let m = Metrics::default();
        m.record_rejected(JobKind::DotF32, P);
        m.record_rejected(JobKind::DotF32, P);
        m.record_steal(JobKind::DotF32, P);
        assert_eq!(m.rejected(JobKind::DotF32), 2);
        assert_eq!(m.total_rejected(), 2);
        assert_eq!(m.steals(JobKind::DotF32), 1);
    }

    #[test]
    fn integrity_detections_counted_per_slot_and_reported() {
        let m = Metrics::default();
        m.record_integrity(JobKind::DotHybrid, P);
        m.record_integrity(JobKind::DotHybrid, P);
        m.record_integrity(JobKind::FirHybrid, Tier::Wide);
        assert_eq!(m.integrity_tier(JobKind::DotHybrid, P), 2);
        assert_eq!(m.integrity_tier(JobKind::DotHybrid, Tier::Wide), 0);
        assert_eq!(m.integrity_detections(JobKind::DotHybrid), 2);
        assert_eq!(m.total_integrity_detections(), 3);
        m.record(JobKind::DotHybrid, P, 10.0, 512);
        let s = m.table().render();
        assert!(s.contains("integ"), "table must carry the detection column");
    }

    #[test]
    fn norm_events_claimed_exactly_once_per_tier() {
        let m = Metrics::default();
        // Running totals on the paper tier: 0 → 5 events (2 guards,
        // 3 recons) claimed by rk4...
        m.record_norm_totals(JobKind::Rk4Hybrid, P, 5, 2, 3);
        // ...then 5 → 8: only the 3 new events are claimed.
        m.record_norm_totals(JobKind::Rk4Hybrid, P, 8, 2, 3);
        // A stale/overlapping window (total 6 < cursor 8) claims nothing
        // — this is exactly the concurrent-worker double-count case.
        m.record_norm_totals(JobKind::DotHybrid, P, 6, 2, 3);
        assert_eq!(m.norm_events_tier(JobKind::Rk4Hybrid, P), 8);
        assert_eq!(m.guard_events_tier(JobKind::Rk4Hybrid, P), 2);
        assert_eq!(m.recon_events_tier(JobKind::Rk4Hybrid, P), 3);
        assert_eq!(m.norm_events_tier(JobKind::DotHybrid, P), 0);
        // Later events are attributed to the window that closed later.
        m.record_norm_totals(JobKind::DotHybrid, P, 10, 3, 4);
        assert_eq!(m.norm_events_tier(JobKind::DotHybrid, P), 2);
        assert_eq!(m.guard_events_tier(JobKind::DotHybrid, P), 1);
        assert_eq!(m.recon_events_tier(JobKind::DotHybrid, P), 1);
        // Cursors are per tier: identical totals on a *different* tier
        // claim independently (its own context, its own counters).
        m.record_norm_totals(JobKind::DotHybrid, Tier::Lo, 4, 0, 1);
        assert_eq!(m.norm_events_tier(JobKind::DotHybrid, Tier::Lo), 4);
        assert_eq!(m.norm_events_tier(JobKind::DotHybrid, P), 2, "paper unchanged");
        // A seeded cursor swallows pre-serving events: seeding at
        // (10, 3, 4) attributes nothing until new events arrive.
        let seeded = Metrics::default();
        seeded.seed_norm_cursor(P, 10, 3, 4);
        seeded.record_norm_totals(JobKind::DotHybrid, P, 10, 3, 4);
        assert_eq!(seeded.norm_events_tier(JobKind::DotHybrid, P), 0);
        seeded.record_norm_totals(JobKind::DotHybrid, P, 12, 3, 4);
        assert_eq!(seeded.norm_events_tier(JobKind::DotHybrid, P), 2);
        // Aggregate on paper equals the true total — nothing double-counted.
        assert_eq!(
            m.norm_events(JobKind::Rk4Hybrid) + m.norm_events_tier(JobKind::DotHybrid, P),
            10
        );
        // The events surface in the report table.
        m.record(JobKind::Rk4Hybrid, P, 10.0, 64);
        let s = m.table().render();
        assert!(s.contains("norms"));
        assert!(s.contains("guards"));
        assert!(s.contains("recon"));
        assert!(s.contains("esc"));
    }

    #[test]
    fn percentiles_monotonic_and_tight() {
        let m = Metrics::default();
        for i in 0..1000 {
            m.record(JobKind::DotF32, P, (i % 100) as f64 + 1.0, 1);
        }
        let p50 = m.latency_percentile_us(JobKind::DotF32, 50.0);
        let p95 = m.latency_percentile_us(JobKind::DotF32, 95.0);
        let p99 = m.latency_percentile_us(JobKind::DotF32, 99.0);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p50 > 0.0);
        // Log-linear buckets: the true p50 of this stream is ~50 µs; the
        // estimate must land within one sub-bucket (~±12%).
        assert!((25.0..=75.0).contains(&p50), "p50={p50}");
        assert!(p99 >= 80.0, "p99={p99}");
        // Tier-scoped percentile agrees when only one tier is active.
        assert_eq!(m.latency_percentile_us_tier(JobKind::DotF32, P, 50.0), p50);
    }

    #[test]
    fn bucket_layout_is_monotonic() {
        let mut last = 0;
        for v in [1.0, 1.3, 1.8, 2.0, 3.0, 10.0, 1e3, 1e6, 1e9, 1e12] {
            let b = bucket_of(v);
            assert!(b >= last, "bucket_of({v}) went backwards");
            assert!(b < BUCKETS);
            last = b;
        }
        // Midpoint of a value's own bucket brackets the value.
        for v in [1.5, 7.0, 333.0, 80_000.0] {
            let mid = bucket_mid_us(bucket_of(v));
            assert!(mid / v < 1.3 && v / mid < 1.3, "v={v} mid={mid}");
        }
    }

    #[test]
    fn wire_metrics_count_per_client_and_in_total() {
        let w = WireMetrics::default();
        let a = w.register_client("127.0.0.1:5000#0");
        let b = w.register_client("127.0.0.1:5001#1");
        assert_eq!(w.conns_opened(), 2);
        w.record_frame_in(&a, 100);
        w.record_frame_in(&a, 50);
        w.record_frame_in(&b, 7);
        w.record_frame_out(&a, 2048);
        w.record_submit(&a);
        w.record_result(&a);
        w.record_wire_error(&b);
        w.record_rate_limited(&b);
        w.record_inflight_limited(&b);
        w.record_protocol_error();
        w.record_conn_panic();
        w.record_conn_closed();
        assert_eq!(a.frames_in(), 2);
        assert_eq!(a.bytes_in(), 150);
        assert_eq!(b.frames_in(), 1);
        assert_eq!(w.totals().frames_in(), 3);
        assert_eq!(w.totals().bytes_in(), 157);
        assert_eq!(w.totals().frames_out(), 1);
        assert_eq!(w.totals().bytes_out(), 2048);
        assert_eq!(w.totals().submits(), 1);
        assert_eq!(w.totals().results(), 1);
        assert_eq!(w.totals().wire_errors(), 1);
        assert_eq!(w.totals().rate_limited(), 1);
        assert_eq!(w.totals().inflight_limited(), 1);
        assert_eq!(w.protocol_errors(), 1);
        assert_eq!(w.conn_panics(), 1);
        assert_eq!(w.conns_closed(), 1);
        let s = w.table().render();
        assert!(s.contains("127.0.0.1:5000#0"));
        assert!(s.contains("127.0.0.1:5001#1"));
        assert!(s.contains("TOTAL"));
    }

    #[test]
    fn table_renders_active_lanes_only() {
        let m = Metrics::default();
        m.record(JobKind::MatmulF32, P, 5.0, 64);
        let s = m.table().render();
        assert!(s.contains("matmul/fp32"));
        assert!(!s.contains("dot/hrfna"));
        // FP32 rows carry no tier suffix.
        assert!(!s.contains("matmul/fp32@"));
    }
}
