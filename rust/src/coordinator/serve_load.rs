//! Serving load generators: closed-loop (a fixed fleet of clients, each
//! submitting a burst and waiting for it) and open-loop (submissions
//! paced at a fixed offered rate regardless of completions — the
//! arrival-process model that actually exposes backpressure). Both return
//! a [`LoadReport`]; `bench_serve` and the saturation tests drive the
//! coordinator exclusively through these.
//!
//! Generators build full [`JobSpec`]s, so a `make` closure can emit
//! mixed-**tier** traffic (different requested tiers and tolerances per
//! request) as naturally as mixed-kind traffic — the multi-scenario load
//! shape the tier registry exists to serve.
//!
//! The generators drive **any [`Backend`]** — the in-process coordinator
//! ([`super::backend::InProcess`]), an RPC client, or the cluster's
//! shard router — through the one ticket-based submission API, so the
//! same load shape measures every topology. Their socket-level
//! counterparts live in `coordinator::rpc::load` (`--features rpc`) and
//! share [`LoadReport`]; the socket closed loop holds **one persistent
//! connection per client** for the whole run, so it measures
//! steady-state wire throughput, not per-job connect overhead (a
//! reconnect-per-job mode exists purely to quantify that overhead in
//! `bench_rpc`).

use std::time::{Duration, Instant};

use super::backend::{Backend, JobTicket};
use super::request::JobSpec;
use crate::util::stats::Summary;

/// Outcome of one generated load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Jobs the generator attempted to submit.
    pub offered: usize,
    /// Jobs accepted into queues.
    pub accepted: usize,
    /// Submissions shed with `Overloaded` (the backpressure signal).
    pub rejected: usize,
    /// Results received.
    pub completed: usize,
    /// Wall time of the whole run.
    pub wall: Duration,
    /// Completed jobs per second of wall time.
    pub jobs_per_s: f64,
    /// End-to-end latency summary (µs) over completed jobs.
    pub latency_us: Option<Summary>,
    /// Delivered results whose authenticated checksum failed the
    /// client-side recompute — the "zero corrupted results delivered"
    /// invariant the fault-smoke gate asserts. Always 0 for
    /// unauthenticated runs (no checksum to verify).
    pub corrupted: usize,
}

impl LoadReport {
    /// Assemble a report from raw counts (shared with the socket-level
    /// generators in `coordinator::rpc::load`).
    pub(crate) fn from_parts(
        offered: usize,
        accepted: usize,
        rejected: usize,
        latencies: Vec<f64>,
        wall: Duration,
    ) -> LoadReport {
        let completed = latencies.len();
        LoadReport {
            offered,
            accepted,
            rejected,
            completed,
            wall,
            jobs_per_s: completed as f64 / wall.as_secs_f64().max(1e-9),
            latency_us: if latencies.is_empty() {
                None
            } else {
                Some(Summary::of(&latencies))
            },
            corrupted: 0,
        }
    }
}

/// How long a generator waits for an accepted job's result before giving
/// the run up as wedged (deadlocks surface as missing completions, not as
/// a hung bench).
const RESULT_TIMEOUT: Duration = Duration::from_secs(120);

fn drain(backend: &dyn Backend, pending: Vec<JobTicket>, latencies: &mut Vec<f64>) {
    for ticket in pending {
        if let Ok(r) = backend.wait(&ticket, RESULT_TIMEOUT) {
            latencies.push(r.latency_us);
        }
    }
}

/// Closed-loop load: `clients` threads each submit `jobs_per_client`
/// jobs in bursts of `burst` (submit the burst, then wait for all of it —
/// bursts keep the batcher fed so batches of ≥ `burst` actually form).
/// `make(client, i)` builds the i-th spec of a client.
pub fn closed_loop(
    backend: &dyn Backend,
    clients: usize,
    jobs_per_client: usize,
    burst: usize,
    make: &(dyn Fn(u64, usize) -> JobSpec + Sync),
) -> LoadReport {
    let burst = burst.max(1);
    let t0 = Instant::now();
    let results: Vec<(usize, usize, Vec<f64>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || {
                    let mut accepted = 0;
                    let mut rejected = 0;
                    let mut latencies = Vec::with_capacity(jobs_per_client);
                    let mut i = 0;
                    while i < jobs_per_client {
                        let mut pending = Vec::with_capacity(burst);
                        for _ in 0..burst.min(jobs_per_client - i) {
                            let spec = make(c as u64, i);
                            i += 1;
                            match backend.submit(spec) {
                                Ok(ticket) => {
                                    accepted += 1;
                                    pending.push(ticket);
                                }
                                // Overloaded (and any admission failure)
                                // counts as shed load.
                                Err(_) => rejected += 1,
                            }
                        }
                        drain(backend, pending, &mut latencies);
                    }
                    (accepted, rejected, latencies)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed();
    let mut accepted = 0;
    let mut rejected = 0;
    let mut latencies = Vec::new();
    for (a, r, l) in results {
        accepted += a;
        rejected += r;
        latencies.extend(l);
    }
    LoadReport::from_parts(clients * jobs_per_client, accepted, rejected, latencies, wall)
}

/// Open-loop load: submit `total` jobs paced at `rate_per_s` regardless
/// of completions (results are collected afterwards). When the offered
/// rate exceeds lane capacity the bounded queues fill and submissions
/// come back `Overloaded` — the report's `rejected` count is the
/// load-shedding measurement.
pub fn open_loop(
    backend: &dyn Backend,
    total: usize,
    rate_per_s: f64,
    make: &(dyn Fn(u64, usize) -> JobSpec + Sync),
) -> LoadReport {
    assert!(rate_per_s > 0.0, "open_loop needs a positive rate");
    let interval = Duration::from_secs_f64(1.0 / rate_per_s);
    let t0 = Instant::now();
    let mut accepted = 0;
    let mut rejected = 0;
    let mut pending = Vec::with_capacity(total);
    for i in 0..total {
        let due = t0 + interval.mul_f64(i as f64);
        if let Some(sleep) = due.checked_duration_since(Instant::now()) {
            std::thread::sleep(sleep);
        }
        let spec = make(0, i);
        match backend.submit(spec) {
            Ok(ticket) => {
                accepted += 1;
                pending.push(ticket);
            }
            Err(_) => rejected += 1,
        }
    }
    let mut latencies = Vec::with_capacity(accepted);
    drain(backend, pending, &mut latencies);
    let wall = t0.elapsed();
    LoadReport::from_parts(total, accepted, rejected, latencies, wall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_rates_are_consistent() {
        let r = LoadReport::from_parts(
            10,
            8,
            2,
            vec![100.0, 200.0, 300.0, 400.0],
            Duration::from_secs(2),
        );
        assert_eq!(r.offered, 10);
        assert_eq!(r.accepted, 8);
        assert_eq!(r.rejected, 2);
        assert_eq!(r.completed, 4);
        assert!((r.jobs_per_s - 2.0).abs() < 1e-9);
        let lat = r.latency_us.unwrap();
        assert_eq!(lat.n, 4);
        assert!((lat.mean - 250.0).abs() < 1e-9);
    }

    #[test]
    fn empty_run_has_no_latency_summary() {
        let r = LoadReport::from_parts(0, 0, 0, Vec::new(), Duration::from_millis(1));
        assert!(r.latency_us.is_none());
        assert_eq!(r.completed, 0);
    }
}
