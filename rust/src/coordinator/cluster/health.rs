//! Per-shard health and occupancy tracking.
//!
//! Each worker link owns one [`HealthGauge`]; the router's monitor
//! thread feeds it `health` RPC outcomes (success carries the worker's
//! total queue depth — the PR 2 occupancy gauge, summed over lanes) and
//! submission paths feed it transport failures and `Overloaded`
//! rejections. Routing reads one question off it: *is this shard
//! routable right now?* — which is false while the shard is `Down`
//! (consecutive failures), inside an overload-diversion window, or
//! reporting a queue depth above the diversion threshold.
//!
//! All state is atomics: gauges are read on every submission, written
//! from monitor + reader threads, and never need to be consistent with
//! each other — stale by one probe interval is fine for diversion.

use std::sync::atomic::{AtomicI64, AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::time::{Duration, Instant};

/// Consecutive probe/transport failures before a shard is `Down`.
pub const DOWN_AFTER_FAILURES: u32 = 3;

/// Total integrity detections (MAC / checksum / Freivalds failures
/// attributed to a shard) before it is quarantined. Unlike transport
/// failures the count never resets: a worker that corrupts results is
/// presumed faulty hardware, not a transient.
pub const QUARANTINE_AFTER_DETECTIONS: u32 = 3;

/// Shard availability as the router sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Responding; routable.
    Up,
    /// Recent failure(s), not yet past the Down threshold: still
    /// routable (the next submission doubles as a probe), but a
    /// failover candidate is preferred when one is Up.
    Suspect,
    /// Past the failure threshold: skipped by routing until a probe or
    /// reconnect succeeds.
    Down,
}

/// Lock-free health/occupancy record for one shard.
pub struct HealthGauge {
    /// Epoch for relative time stamps (gauge creation).
    start: Instant,
    state: AtomicU8,
    consecutive_failures: AtomicU32,
    /// Last queue depth reported by the worker's `health` RPC.
    queue_depth: AtomicI64,
    /// Millis-since-`start` until which the shard is overload-diverted.
    overloaded_until_ms: AtomicU64,
    /// Lifetime integrity detections charged to this shard (never
    /// reset — see [`QUARANTINE_AFTER_DETECTIONS`]).
    integrity_detections: AtomicU32,
    /// Sticky quarantine latch: once set, probe successes no longer
    /// lift the shard back to `Up`.
    quarantined: AtomicU8,
}

impl Default for HealthGauge {
    fn default() -> HealthGauge {
        HealthGauge {
            start: Instant::now(),
            state: AtomicU8::new(HealthState::Up as u8),
            consecutive_failures: AtomicU32::new(0),
            queue_depth: AtomicI64::new(0),
            overloaded_until_ms: AtomicU64::new(0),
            integrity_detections: AtomicU32::new(0),
            quarantined: AtomicU8::new(0),
        }
    }
}

impl HealthGauge {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    pub fn state(&self) -> HealthState {
        match self.state.load(Ordering::Relaxed) {
            0 => HealthState::Up,
            1 => HealthState::Suspect,
            _ => HealthState::Down,
        }
    }

    fn set_state(&self, s: HealthState) {
        let v = match s {
            HealthState::Up => 0,
            HealthState::Suspect => 1,
            HealthState::Down => 2,
        };
        self.state.store(v, Ordering::Relaxed);
    }

    /// A probe (or any round trip) succeeded; `depth` is the worker's
    /// reported total queue depth.
    pub fn record_success(&self, depth: i64) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.queue_depth.store(depth, Ordering::Relaxed);
        // A quarantined shard answers probes just fine — that's the
        // point: health RPCs can't see silent result corruption, so
        // success never lifts the quarantine latch.
        if !self.quarantined() {
            self.set_state(HealthState::Up);
        }
    }

    /// A verification failure (MAC, checksum, or Freivalds) was charged
    /// to this shard. Escalates `Up → Suspect` immediately and latches
    /// `Down` for good once [`QUARANTINE_AFTER_DETECTIONS`] accumulate.
    /// Returns the lifetime detection count.
    pub fn record_integrity(&self) -> u32 {
        let n = self.integrity_detections.fetch_add(1, Ordering::Relaxed) + 1;
        if n >= QUARANTINE_AFTER_DETECTIONS {
            self.quarantined.store(1, Ordering::Relaxed);
            self.set_state(HealthState::Down);
        } else if self.state() == HealthState::Up {
            self.set_state(HealthState::Suspect);
        }
        n
    }

    /// Lifetime integrity detections charged to this shard.
    pub fn integrity_detections(&self) -> u32 {
        self.integrity_detections.load(Ordering::Relaxed)
    }

    /// True once the quarantine latch is set (terminal for the link).
    pub fn quarantined(&self) -> bool {
        self.quarantined.load(Ordering::Relaxed) != 0
    }

    /// A probe or transport operation failed.
    pub fn record_failure(&self) {
        let n = self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        self.set_state(if n >= DOWN_AFTER_FAILURES {
            HealthState::Down
        } else {
            HealthState::Suspect
        });
    }

    /// The link dropped (EOF / connect refused): immediately Down.
    pub fn record_disconnect(&self) {
        self.consecutive_failures
            .store(DOWN_AFTER_FAILURES, Ordering::Relaxed);
        self.set_state(HealthState::Down);
    }

    /// The shard answered `Overloaded`: divert traffic away from it for
    /// `window` (its queue needs to drain; hammering it just burns RPCs).
    pub fn record_overloaded(&self, window: Duration) {
        let until = self.now_ms().saturating_add(window.as_millis() as u64);
        self.overloaded_until_ms.fetch_max(until, Ordering::Relaxed);
    }

    pub fn queue_depth(&self) -> i64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// True while an overload-diversion window is open.
    pub fn overload_diverted(&self) -> bool {
        self.now_ms() < self.overloaded_until_ms.load(Ordering::Relaxed)
    }

    /// The routing predicate: not Down, not inside a diversion window,
    /// and (when `divert_depth > 0`) not reporting a deeper queue than
    /// the threshold.
    pub fn routable(&self, divert_depth: i64) -> bool {
        if self.state() == HealthState::Down || self.overload_diverted() {
            return false;
        }
        divert_depth <= 0 || self.queue_depth() <= divert_depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failures_escalate_to_down_and_success_recovers() {
        let g = HealthGauge::default();
        assert_eq!(g.state(), HealthState::Up);
        g.record_failure();
        assert_eq!(g.state(), HealthState::Suspect);
        assert!(g.routable(0), "suspect shards still take traffic");
        g.record_failure();
        g.record_failure();
        assert_eq!(g.state(), HealthState::Down);
        assert!(!g.routable(0));
        g.record_success(5);
        assert_eq!(g.state(), HealthState::Up);
        assert!(g.routable(0));
        assert_eq!(g.queue_depth(), 5);
    }

    #[test]
    fn disconnect_is_immediately_down() {
        let g = HealthGauge::default();
        g.record_disconnect();
        assert_eq!(g.state(), HealthState::Down);
        assert!(!g.routable(0));
    }

    #[test]
    fn overload_window_diverts_then_expires() {
        let g = HealthGauge::default();
        g.record_overloaded(Duration::from_millis(40));
        assert!(g.overload_diverted());
        assert!(!g.routable(0));
        std::thread::sleep(Duration::from_millis(60));
        assert!(!g.overload_diverted());
        assert!(g.routable(0));
    }

    #[test]
    fn integrity_detections_escalate_and_quarantine_is_sticky() {
        let g = HealthGauge::default();
        assert_eq!(g.record_integrity(), 1);
        assert_eq!(g.state(), HealthState::Suspect);
        assert!(g.routable(0), "below the threshold the shard still serves");
        // A healthy probe lifts the sub-threshold Suspect...
        g.record_success(0);
        assert_eq!(g.state(), HealthState::Up);
        // ...but the detection count never resets.
        assert_eq!(g.record_integrity(), 2);
        assert_eq!(g.record_integrity(), 3);
        assert_eq!(g.state(), HealthState::Down);
        assert!(g.quarantined());
        assert!(!g.routable(0));
        // Probe successes no longer resurrect a quarantined shard.
        g.record_success(0);
        assert_eq!(g.state(), HealthState::Down);
        assert!(!g.routable(0));
        assert_eq!(g.integrity_detections(), 3);
    }

    #[test]
    fn deep_queue_diverts_when_thresholded() {
        let g = HealthGauge::default();
        g.record_success(1000);
        assert!(g.routable(0), "zero threshold disables depth diversion");
        assert!(!g.routable(512));
        g.record_success(100);
        assert!(g.routable(512));
    }
}
