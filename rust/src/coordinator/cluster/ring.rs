//! Consistent-hash ring for lane placement.
//!
//! Each worker contributes `vnodes` virtual points (hashes of
//! `"{id}#{v}"`) on a u64 ring; a lane key hashes to a point and routes
//! to the first worker clockwise from it. Virtual nodes smooth the load
//! split; the consistent-hash property is what cluster mode leans on at
//! membership change: adding a worker to an N-worker ring remaps only
//! ≈1/(N+1) of lane keys — every moved key moves *to* the new worker,
//! never between survivors — so a scale-out event invalidates the
//! minimum amount of placement state (property-tested below).
//!
//! `candidates` returns all distinct workers in ring order from the
//! key's point: position 0 is the primary, the rest are the failover /
//! overload-diversion sequence, which every router replica computes
//! identically without coordination.

/// SplitMix64 finalizer — the bit mixer behind both the point hashes and
/// the key hashes. (The PRNG in `util::prng` keeps its own private copy;
/// ring hashing must stay independent of PRNG stream evolution.)
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// FNV-1a over bytes, finalized through [`mix64`].
fn hash_str(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    mix64(h)
}

/// Placement hash of a lane key. The inputs are the **wire labels**
/// (`"dot/hrfna"`, `"paper"`), not enum discriminants, so router and
/// tooling in any language agree on placement.
pub fn lane_hash(kind_label: &str, tier_label: &str, bucket: usize) -> u64 {
    mix64(hash_str(kind_label) ^ hash_str(tier_label).rotate_left(17) ^ (bucket as u64))
}

/// Consistent-hash ring over worker indices.
pub struct HashRing {
    /// Sorted (point, worker-index) pairs.
    points: Vec<(u64, usize)>,
    workers: usize,
}

impl HashRing {
    /// Default virtual nodes per worker — enough that a 4-worker ring's
    /// per-worker share stays within a few percent of 1/N.
    pub const DEFAULT_VNODES: usize = 64;

    /// Build a ring over `ids` (one entry per worker, index = position)
    /// with `vnodes` virtual points each.
    pub fn new(ids: &[String], vnodes: usize) -> HashRing {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(ids.len() * vnodes);
        for (w, id) in ids.iter().enumerate() {
            for v in 0..vnodes {
                points.push((hash_str(&format!("{id}#{v}")), w));
            }
        }
        points.sort_unstable();
        HashRing { points, workers: ids.len() }
    }

    /// Number of workers on the ring.
    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Index of the first ring point clockwise from `key`.
    fn successor(&self, key: u64) -> usize {
        // partition_point: first point with hash > key, wrapping to 0.
        let i = self.points.partition_point(|&(h, _)| h <= key);
        if i == self.points.len() {
            0
        } else {
            i
        }
    }

    /// The worker owning `key` (its primary placement).
    pub fn primary(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        Some(self.points[self.successor(key)].1)
    }

    /// All distinct workers in ring order from `key`: `[0]` is the
    /// primary, the rest the failover sequence. Deterministic for a
    /// given membership, so independent routers agree.
    pub fn candidates(&self, key: u64) -> Vec<usize> {
        let mut order = Vec::with_capacity(self.workers);
        if self.points.is_empty() {
            return order;
        }
        let start = self.successor(key);
        for i in 0..self.points.len() {
            let w = self.points[(start + i) % self.points.len()].1;
            if !order.contains(&w) {
                order.push(w);
                if order.len() == self.workers {
                    break;
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::ShapeBuckets;
    use crate::hybrid::registry::Tier;
    use crate::prop_assert;
    use crate::util::proptest::check_with;

    fn ids(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("w{i}")).collect()
    }

    /// Lane-key hashes for every lane the default bucket set serves,
    /// plus synthetic buckets for volume.
    fn lane_keys() -> Vec<u64> {
        let mut keys: Vec<u64> = ShapeBuckets::default()
            .lanes()
            .iter()
            .map(|&(k, t, b)| lane_hash(k.label(), t.label(), b))
            .collect();
        // Real deployments have O(10) lanes; the 1/N property needs
        // volume to measure, so extend with synthetic shape buckets.
        for bucket in 0..2048usize {
            keys.push(lane_hash("dot/hrfna", Tier::Paper.label(), 8 << (bucket % 16) | bucket));
        }
        keys
    }

    #[test]
    fn primary_is_deterministic_and_total() {
        let ring = HashRing::new(&ids(3), HashRing::DEFAULT_VNODES);
        for key in lane_keys() {
            let w = ring.primary(key).unwrap();
            assert!(w < 3);
            assert_eq!(ring.primary(key).unwrap(), w);
        }
        assert_eq!(HashRing::new(&[], 64).primary(1), None);
    }

    #[test]
    fn candidates_enumerate_all_workers_primary_first() {
        let ring = HashRing::new(&ids(4), HashRing::DEFAULT_VNODES);
        for key in lane_keys() {
            let c = ring.candidates(key);
            assert_eq!(c.len(), 4);
            assert_eq!(c[0], ring.primary(key).unwrap());
            let mut sorted = c.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3], "candidates must be a permutation");
        }
    }

    /// The consistent-hash stability property (satellite test): growing
    /// the ring from N to N+1 workers moves ≈1/(N+1) of lane keys, and
    /// every moved key moves TO the new worker.
    #[test]
    fn adding_a_shard_moves_about_one_nth_of_keys() {
        check_with("ring_scale_out_stability", 64, |rng| {
            let n = 1 + rng.below(7) as usize; // 1..=7 existing workers
            let before = HashRing::new(&ids(n), HashRing::DEFAULT_VNODES);
            let after = HashRing::new(&ids(n + 1), HashRing::DEFAULT_VNODES);
            let keys: Vec<u64> = (0..4096).map(|_| rng.next_u64()).collect();
            let mut moved = 0usize;
            for &key in &keys {
                let a = before.primary(key).unwrap();
                let b = after.primary(key).unwrap();
                if a != b {
                    moved += 1;
                    prop_assert!(
                        b == n,
                        "key moved between surviving workers {a}->{b} (new worker is {n})"
                    );
                }
            }
            let expected = keys.len() as f64 / (n + 1) as f64;
            // Virtual-node placement is statistical; allow a wide band
            // around 1/(N+1) but reject both "nothing moved" and "mass
            // reshuffle".
            prop_assert!(
                (moved as f64) > 0.4 * expected && (moved as f64) < 2.0 * expected,
                "moved {moved} of {} keys, expected ≈{expected:.0} (n={n})",
                keys.len()
            );
            Ok(())
        });
    }

    #[test]
    fn vnodes_spread_load_roughly_evenly() {
        let n = 4;
        let ring = HashRing::new(&ids(n), HashRing::DEFAULT_VNODES);
        let mut counts = vec![0usize; n];
        let keys = 16384u64;
        for i in 0..keys {
            counts[ring.primary(mix64(i)).unwrap()] += 1;
        }
        let ideal = keys as usize / n;
        for (w, &c) in counts.iter().enumerate() {
            assert!(
                c > ideal / 2 && c < ideal * 2,
                "worker {w} owns {c} of {keys} keys (ideal {ideal})"
            );
        }
    }

    #[test]
    fn lane_hash_separates_lanes() {
        let lanes = ShapeBuckets::default().lanes();
        let mut hashes: Vec<u64> = lanes
            .iter()
            .map(|&(k, t, b)| lane_hash(k.label(), t.label(), b))
            .collect();
        hashes.sort_unstable();
        hashes.dedup();
        assert_eq!(hashes.len(), lanes.len(), "lane hash collision");
    }
}
