//! The shard router: a [`Backend`] that places jobs on a fleet of
//! worker `RpcServer`s by consistent-hashing their lane key.
//!
//! Placement is **tier-aware**: the route key is
//! `lane_hash(kind, resolved-tier, bucket)` — the same `(kind, tier,
//! bucket)` triple the in-process coordinator shards its queues by —
//! computed with the non-mutating admission probe
//! ([`probe_bucket`]) and the bucket set's tier clamp. All jobs of one
//! lane land on one worker, so each worker's batcher sees the same
//! shape-coherent stream it would see in-process and planar batching
//! efficiency survives the sharding.
//!
//! Failure handling, in routing order ([`HashRing::candidates`]):
//!
//! * **Overload diversion** — a worker answering `Overloaded` (or whose
//!   `health` probe reports a queue deeper than `divert_depth`) is
//!   skipped for `overload_divert` while its queue drains; the job goes
//!   to the next candidate. If *every* candidate is diverted the job is
//!   still offered to one (honest backpressure beats a false
//!   `Unavailable`).
//! * **Failover** — a dead link (connect refused, EOF, mid-frame close)
//!   marks the shard `Down` and in-flight jobs on it are **resubmitted**
//!   to the next candidate. Jobs here are pure computations, so
//!   at-least-once redelivery is safe (a kill may execute a job twice;
//!   it can never corrupt state). The monitor thread keeps probing and
//!   reconnects the shard when it returns; reconnection bumps a
//!   per-link connection **generation**, and routes submitted on an
//!   older generation fail over too — wire ids are per-connection, so
//!   polling a stale id on the new connection could hang forever or
//!   steal another job's response.
//! * **Drain on membership change** — [`ShardRouter::remove_worker`]
//!   fences the shard out of the ring, asks it to drain (its in-flight
//!   results are still delivered over the open connection), and reports
//!   the handoff as a [`DrainReport`] snapshot taken at fencing time
//!   (see its doc for the exact field semantics).
//!
//! **Coalescing** (`RouterConfig::coalesce_window > 0`): submissions
//! stage per lane and flush as one `submit_batch` frame when the lane
//! fills (`coalesce_max`) or its window expires — Nagle for job frames.
//! A flushed group shares one wire id; whichever member's poll pulls
//! the batch response resolves every member (outcomes park in a
//! delivery buffer until their owners poll), and transport loss fails
//! the *whole group* over through the same generation-fenced resubmit
//! path as single jobs, so worker kill still loses nothing. With the
//! window at zero every code path above is byte-for-byte the
//! pre-coalescing behavior.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::backend::{Backend, JobPoll, JobTicket};
use crate::coordinator::error::Error;
use crate::coordinator::request::{JobResult, JobSpec, Payload};
use crate::coordinator::router::{probe_bucket, ShapeBuckets};
use crate::coordinator::rpc::client::{batch_outcomes, RpcClient};
use crate::coordinator::rpc::protocol::{result_from_json, ResponseBody};
use crate::coordinator::server::DrainReport;
use crate::hybrid::auth;
use crate::hybrid::registry::Tier;

use super::health::{HealthGauge, HealthState};
use super::membership::{Membership, WorkerSpec};
use super::ring::{lane_hash, HashRing};

/// Router tuning.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Shape buckets used to compute route keys. Must match the
    /// workers' admission buckets, or jobs the router routes get
    /// rejected at the worker.
    pub buckets: ShapeBuckets,
    /// Virtual nodes per worker on the hash ring.
    pub vnodes: usize,
    /// Health-probe cadence of the monitor thread.
    pub health_interval: Duration,
    /// How long an `Overloaded` answer diverts traffic off a shard.
    pub overload_divert: Duration,
    /// Queue-depth threshold for occupancy diversion (0 disables).
    pub divert_depth: i64,
    /// Per-attempt connect budget (startup and monitor reconnects).
    pub connect_wait: Duration,
    /// How long `shutdown` keeps polling uncollected tickets before
    /// declaring them dropped.
    pub drain_wait: Duration,
    /// Nagle-style micro-batching window: submissions for one (worker,
    /// lane) are staged up to this long and flushed as a single
    /// `submit_batch` frame. `ZERO` disables coalescing entirely —
    /// every submission places immediately, exactly the pre-coalescing
    /// behavior.
    pub coalesce_window: Duration,
    /// Flush a staged lane early once it holds this many jobs (the
    /// count trigger; the window is the time trigger).
    pub coalesce_max: usize,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            buckets: ShapeBuckets::default(),
            vnodes: HashRing::DEFAULT_VNODES,
            health_interval: Duration::from_millis(500),
            overload_divert: Duration::from_millis(250),
            divert_depth: 0,
            connect_wait: Duration::from_secs(5),
            drain_wait: Duration::from_secs(10),
            coalesce_window: Duration::ZERO,
            coalesce_max: 8,
        }
    }
}

/// One worker shard as the router sees it: the connection (rebuilt by
/// the monitor on loss), its health gauge, and forwarding counters.
struct WorkerLink {
    spec: WorkerSpec,
    conn: Mutex<Option<RpcClient>>,
    /// Bumped (under the `conn` lock) every time a new connection is
    /// installed. Wire ids are per-connection — `RpcClient` restarts
    /// its id counter at 1 — so a route records the generation it
    /// submitted on, and a mismatch at poll time means the id is
    /// meaningless on the current connection: polling with it would
    /// either hang forever or collide with a fresh submission's id and
    /// steal its response.
    generation: AtomicU64,
    health: HealthGauge,
    /// Fenced out by `remove_worker`: the monitor stops reconnecting it
    /// and placement never offers it jobs.
    retired: AtomicBool,
    forwarded: AtomicU64,
    completed: AtomicU64,
    errored: AtomicU64,
}

/// Why a response probe could not be answered from the link.
enum RouteLoss {
    /// The link reconnected since the job was submitted; the old wire
    /// id must not be polled on the new connection.
    Stale,
    /// No connection (down, or it died on this very probe).
    Lost,
}

impl WorkerLink {
    fn new(spec: WorkerSpec) -> WorkerLink {
        WorkerLink {
            spec,
            conn: Mutex::new(None),
            generation: AtomicU64::new(0),
            health: HealthGauge::default(),
            retired: AtomicBool::new(false),
            forwarded: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errored: AtomicU64::new(0),
        }
    }

    fn retired(&self) -> bool {
        self.retired.load(Ordering::SeqCst)
    }

    /// Ensure a live connection; true when one exists after the call.
    ///
    /// Dials with the lock **released**: the `conn` mutex is only ever
    /// held for short I/O (a frame write, a 1 ms poll, a health round
    /// trip), never across `connect_retry`'s sleep-and-redial loop —
    /// so submission and polling never stall behind a reconnect to a
    /// dead worker. Only `start` and the monitor thread dial, so there
    /// is no concurrent-dial race to arbitrate.
    fn connect(&self, wait: Duration) -> bool {
        if self.conn.lock().expect("link conn lock").is_some() {
            return true;
        }
        match RpcClient::connect_retry(&self.spec.addr, wait) {
            Ok(c) => {
                let mut conn = self.conn.lock().expect("link conn lock");
                if conn.is_none() {
                    *conn = Some(c);
                    self.generation.fetch_add(1, Ordering::SeqCst);
                }
                true
            }
            Err(_) => {
                self.health.record_failure();
                false
            }
        }
    }

    /// Drop the connection and mark the shard Down (what a transport
    /// error does inline; split out so tests can force the state).
    #[cfg(test)]
    fn disconnect(&self) {
        *self.conn.lock().expect("link conn lock") = None;
        self.health.record_disconnect();
    }

    /// Fire one submission; returns the wire id **and** the connection
    /// generation it was sent on — the pair a later poll needs to
    /// correlate the response safely across reconnects.
    fn submit(&self, spec: &JobSpec) -> Result<(u64, u64), ()> {
        let mut conn = self.conn.lock().expect("link conn lock");
        let Some(client) = conn.as_mut() else { return Err(()) };
        match client.submit_spec(spec) {
            Ok(id) => {
                self.forwarded.fetch_add(1, Ordering::Relaxed);
                Ok((id, self.generation.load(Ordering::SeqCst)))
            }
            Err(_) => {
                *conn = None;
                self.health.record_disconnect();
                Err(())
            }
        }
    }

    /// Fire one coalesced group as a single `submit_batch` frame;
    /// returns the batch's wire id and the connection generation, the
    /// correlation pair shared by every member of the group.
    fn submit_batch(&self, specs: &[JobSpec]) -> Result<(u64, u64), ()> {
        let mut conn = self.conn.lock().expect("link conn lock");
        let Some(client) = conn.as_mut() else { return Err(()) };
        match client.submit_batch_spec(specs) {
            Ok(id) => {
                self.forwarded.fetch_add(specs.len() as u64, Ordering::Relaxed);
                Ok((id, self.generation.load(Ordering::SeqCst)))
            }
            Err(_) => {
                *conn = None;
                self.health.record_disconnect();
                Err(())
            }
        }
    }

    /// Non-blocking response probe for one wire id, valid only on the
    /// connection generation it was submitted on.
    fn try_take(
        &self,
        wire_id: u64,
        gen: u64,
    ) -> Result<Option<crate::coordinator::rpc::Response>, RouteLoss> {
        let mut conn = self.conn.lock().expect("link conn lock");
        // Checked under the lock (the generation only changes under it):
        // a bump means the connection the job went out on is gone.
        if self.generation.load(Ordering::SeqCst) != gen {
            return Err(RouteLoss::Stale);
        }
        let Some(client) = conn.as_mut() else { return Err(RouteLoss::Lost) };
        match client.try_take(wire_id) {
            Ok(r) => Ok(r),
            Err(_) => {
                *conn = None;
                self.health.record_disconnect();
                Err(RouteLoss::Lost)
            }
        }
    }

    /// One `health` RPC round trip; feeds the gauge.
    fn probe(&self) {
        let mut conn = self.conn.lock().expect("link conn lock");
        let Some(client) = conn.as_mut() else { return };
        match client.health() {
            Ok((_, queued)) => self.health.record_success(queued),
            Err(_) => {
                *conn = None;
                self.health.record_disconnect();
            }
        }
    }

    /// Best-effort drain request.
    fn send_shutdown(&self) {
        let mut conn = self.conn.lock().expect("link conn lock");
        if let Some(client) = conn.as_mut() {
            let _ = client.shutdown_server();
        }
    }
}

/// Ring + the mapping from ring worker index to link index, rebuilt
/// together on every membership change.
struct Placement {
    ring: HashRing,
    link_of: Vec<usize>,
}

/// Where one accepted job currently lives.
struct RouteState {
    spec: JobSpec,
    key: u64,
    link: usize,
    wire_id: u64,
    /// The link's connection generation at submit time; a mismatch at
    /// poll time means `wire_id` is stale and the job must fail over.
    gen: u64,
    /// Links already offered this job (failover never re-offers).
    tried: Vec<usize>,
    /// Set when the job went out inside a coalesced `submit_batch`
    /// frame: every member shares the batch's (link, wire_id, gen) and
    /// this group record. `None` means a plain per-job submission.
    group: Option<Arc<GroupShared>>,
}

/// The shared identity of one coalesced flush: which tickets rode the
/// batch frame and which links the group has been offered (whole-group
/// failover never re-offers one). Immutable once placed — a failover
/// builds a fresh group for the re-placed batch.
struct GroupShared {
    members: Vec<u64>,
    tried: Vec<usize>,
}

/// One lane's staged submissions, awaiting a count- or time-triggered
/// flush.
struct CoalesceBuf {
    entries: Vec<(u64, JobSpec)>,
    since: Instant,
}

/// Coalescing observability: flush count, jobs coalesced, and a depth
/// histogram (how many jobs each flushed frame carried).
#[derive(Default)]
struct CoalesceStats {
    flushes: AtomicU64,
    jobs: AtomicU64,
    /// Depth buckets: 1, 2, 3–4, 5–8, 9+.
    depth: [AtomicU64; 5],
}

impl CoalesceStats {
    fn record_flush(&self, depth: usize) {
        self.flushes.fetch_add(1, Ordering::Relaxed);
        self.jobs.fetch_add(depth as u64, Ordering::Relaxed);
        let bucket = match depth {
            0 | 1 => 0,
            2 => 1,
            3..=4 => 2,
            5..=8 => 3,
            _ => 4,
        };
        self.depth[bucket].fetch_add(1, Ordering::Relaxed);
    }
}

/// Failover/diversion ordering: routable candidates first (in ring
/// order), then — as the honest-backpressure fallback — the remaining
/// untried, unretired candidates.
fn failover_order(
    candidates: &[usize],
    tried: &[usize],
    routable: impl Fn(usize) -> bool,
    retired: impl Fn(usize) -> bool,
) -> Vec<usize> {
    let mut order = Vec::with_capacity(candidates.len());
    for &i in candidates {
        if !tried.contains(&i) && !retired(i) && routable(i) {
            order.push(i);
        }
    }
    for &i in candidates {
        if !tried.contains(&i) && !retired(i) && !order.contains(&i) {
            order.push(i);
        }
    }
    order
}

/// The sharded cluster front: a [`Backend`] whose `submit` places jobs
/// on worker `RpcServer`s. Serve it with `RpcServer::bind` to get the
/// `hrfna route` process.
pub struct ShardRouter {
    cfg: RouterConfig,
    links: Vec<Arc<WorkerLink>>,
    placement: RwLock<Placement>,
    membership: Mutex<Membership>,
    routes: Mutex<HashMap<u64, RouteState>>,
    /// Staged (not yet placed) submissions, keyed by lane route key.
    staging: Mutex<HashMap<u64, CoalesceBuf>>,
    /// Ticket → staging lane key, for staged tickets only.
    staged: Mutex<HashMap<u64, u64>>,
    /// Delivery buffer for group members resolved by *another* member's
    /// poll: outcomes parked here until their owner polls.
    ready: Mutex<HashMap<u64, Result<JobResult, Error>>>,
    coalesce: CoalesceStats,
    next_ticket: AtomicU64,
    accepted: AtomicU64,
    /// Jobs delivered with a successful result.
    completed: AtomicU64,
    /// Jobs delivered with a terminal error (worker error passed
    /// through, or failover exhausted every candidate).
    failed: AtomicU64,
    rejected: AtomicU64,
    dropped: AtomicU64,
    /// Verification failures the router observed: results quarantined
    /// after a checksum/Freivalds mismatch here, plus workers' own
    /// typed `IntegrityFailure` answers.
    integrity_detections: AtomicU64,
    /// Quarantined jobs resubmitted to another shard (each detection
    /// that found a surviving candidate).
    integrity_resubmits: AtomicU64,
    shutting_down: AtomicBool,
    stop_monitor: Arc<AtomicBool>,
    monitor: Mutex<Option<thread::JoinHandle<()>>>,
}

impl ShardRouter {
    /// Connect the fleet and start the health monitor. Fails with
    /// `Unavailable` only when *no* worker is reachable — a partial
    /// fleet serves degraded rather than not at all.
    pub fn start(workers: Vec<WorkerSpec>, cfg: RouterConfig) -> Result<ShardRouter, Error> {
        if workers.is_empty() {
            return Err(Error::Rejected("cluster needs at least one worker".into()));
        }
        let links: Vec<Arc<WorkerLink>> =
            workers.iter().cloned().map(|w| Arc::new(WorkerLink::new(w))).collect();
        let mut up = 0;
        for link in &links {
            if link.connect(cfg.connect_wait) {
                link.probe();
                if link.health.state() == HealthState::Up {
                    up += 1;
                }
            }
        }
        if up == 0 {
            return Err(Error::Unavailable(format!(
                "none of the {} workers answered a health probe",
                links.len()
            )));
        }
        let membership = Membership::new(workers);
        let placement = Placement {
            ring: HashRing::new(&membership.ids(), cfg.vnodes),
            link_of: (0..links.len()).collect(),
        };

        let stop_monitor = Arc::new(AtomicBool::new(false));
        let monitor = {
            let links: Vec<Arc<WorkerLink>> = links.clone();
            let stop = Arc::clone(&stop_monitor);
            let interval = cfg.health_interval;
            let connect_wait = cfg.connect_wait.min(interval);
            thread::Builder::new()
                .name("cluster-monitor".into())
                .spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        for link in &links {
                            if link.retired() {
                                continue;
                            }
                            if link.connect(connect_wait) {
                                link.probe();
                            }
                        }
                        let tick = Instant::now();
                        while tick.elapsed() < interval && !stop.load(Ordering::SeqCst) {
                            thread::sleep(Duration::from_millis(20));
                        }
                    }
                })
                .map_err(|e| Error::Internal(format!("spawn cluster monitor: {e}")))?
        };

        Ok(ShardRouter {
            cfg,
            links,
            placement: RwLock::new(placement),
            membership: Mutex::new(membership),
            routes: Mutex::new(HashMap::new()),
            staging: Mutex::new(HashMap::new()),
            staged: Mutex::new(HashMap::new()),
            ready: Mutex::new(HashMap::new()),
            coalesce: CoalesceStats::default(),
            next_ticket: AtomicU64::new(1),
            accepted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            integrity_detections: AtomicU64::new(0),
            integrity_resubmits: AtomicU64::new(0),
            shutting_down: AtomicBool::new(false),
            stop_monitor,
            monitor: Mutex::new(Some(monitor)),
        })
    }

    /// The route key of a spec: its lane, hashed over wire labels.
    fn route_key(&self, spec: &JobSpec) -> Result<u64, Error> {
        let bucket = probe_bucket(&spec.payload, spec.kind, &self.cfg.buckets).ok_or_else(|| {
            Error::Rejected(format!("no lane bucket admits this {:?} payload", spec.kind))
        })?;
        let tier = if spec.kind.is_hybrid() {
            self.cfg.buckets.enabled_tier_at_or_above(spec.tier).ok_or_else(|| {
                Error::Rejected(format!("no enabled tier at or above {:?}", spec.tier))
            })?
        } else {
            Tier::Paper
        };
        Ok(lane_hash(spec.kind.label(), tier.label(), bucket))
    }

    /// Offer `spec` to candidates in failover order, recording each
    /// attempt in `tried`. Returns the accepting (link index, wire id,
    /// connection generation).
    ///
    /// Placement never dials: a candidate with no live connection fails
    /// the `submit` fast and is skipped — the monitor owns reconnection
    /// — so `place` (and therefore `poll`, whose failover path lands
    /// here) stays non-blocking even with a dead shard in the ring.
    fn place(
        &self,
        key: u64,
        spec: &JobSpec,
        tried: &mut Vec<usize>,
    ) -> Result<(usize, u64, u64), Error> {
        let candidates: Vec<usize> = {
            let placement = self.placement.read().expect("placement lock");
            placement.ring.candidates(key).iter().map(|&w| placement.link_of[w]).collect()
        };
        let order = failover_order(
            &candidates,
            tried,
            |i| self.links[i].health.routable(self.cfg.divert_depth),
            |i| self.links[i].retired(),
        );
        for i in order {
            tried.push(i);
            if let Ok((wire_id, gen)) = self.links[i].submit(spec) {
                return Ok((i, wire_id, gen));
            }
        }
        Err(Error::Unavailable("no routable worker for this lane".into()))
    }

    /// Move a ticket's job to the next candidate after its current
    /// shard failed it; `on_exhausted` is what the caller reports when
    /// no candidate is left.
    fn failover(&self, ticket_id: u64, on_exhausted: Error) -> JobPoll {
        let Some(mut state) = self.routes.lock().expect("routes lock").remove(&ticket_id) else {
            return JobPoll::Ready(Err(Error::Internal("unknown ticket".into())));
        };
        match self.place(state.key, &state.spec, &mut state.tried) {
            Ok((link, wire_id, gen)) => {
                state.link = link;
                state.wire_id = wire_id;
                state.gen = gen;
                state.group = None;
                self.routes.lock().expect("routes lock").insert(ticket_id, state);
                JobPoll::Pending
            }
            Err(_) => {
                self.failed.fetch_add(1, Ordering::Relaxed);
                JobPoll::Ready(Err(on_exhausted))
            }
        }
    }

    /// Flush one staged lane: pop its buffer and place the jobs — one
    /// job goes out as a plain `submit`, several as one coalesced
    /// `submit_batch` frame. Placement failures park typed errors in
    /// the delivery buffer (the jobs were accepted at staging time, so
    /// errors surface at poll, not as a submit rejection).
    fn flush_key(&self, key: u64) {
        let batch = {
            let mut staging = self.staging.lock().expect("staging lock");
            match staging.remove(&key) {
                Some(buf) if !buf.entries.is_empty() => buf.entries,
                _ => return,
            }
        };
        {
            let mut staged = self.staged.lock().expect("staged lock");
            for (id, _) in &batch {
                staged.remove(id);
            }
        }
        self.coalesce.record_flush(batch.len());
        if batch.len() == 1 {
            let (id, spec) = batch.into_iter().next().expect("one entry");
            let mut tried = Vec::new();
            match self.place(key, &spec, &mut tried) {
                Ok((link, wire_id, gen)) => {
                    self.routes.lock().expect("routes lock").insert(
                        id,
                        RouteState { spec, key, link, wire_id, gen, tried, group: None },
                    );
                }
                Err(e) => {
                    self.failed.fetch_add(1, Ordering::Relaxed);
                    self.ready.lock().expect("ready lock").insert(id, Err(e));
                }
            }
            return;
        }
        self.place_group(key, batch, Vec::new());
    }

    /// Offer a coalesced group to candidates in failover order as one
    /// `submit_batch` frame. On acceptance every member's route shares
    /// the batch's (link, wire id, generation) and a fresh
    /// [`GroupShared`]; on exhaustion every member fails typed.
    fn place_group(&self, key: u64, entries: Vec<(u64, JobSpec)>, mut tried: Vec<usize>) {
        let specs: Vec<JobSpec> = entries.iter().map(|(_, s)| s.clone()).collect();
        let candidates: Vec<usize> = {
            let placement = self.placement.read().expect("placement lock");
            placement.ring.candidates(key).iter().map(|&w| placement.link_of[w]).collect()
        };
        let order = failover_order(
            &candidates,
            &tried,
            |i| self.links[i].health.routable(self.cfg.divert_depth),
            |i| self.links[i].retired(),
        );
        for i in order {
            tried.push(i);
            if let Ok((wire_id, gen)) = self.links[i].submit_batch(&specs) {
                let group = Arc::new(GroupShared {
                    members: entries.iter().map(|(id, _)| *id).collect(),
                    tried: tried.clone(),
                });
                let mut routes = self.routes.lock().expect("routes lock");
                for (id, spec) in entries {
                    routes.insert(
                        id,
                        RouteState {
                            spec,
                            key,
                            link: i,
                            wire_id,
                            gen,
                            tried: tried.clone(),
                            group: Some(Arc::clone(&group)),
                        },
                    );
                }
                return;
            }
        }
        let e = Error::Unavailable("no routable worker for this lane".into());
        let mut ready = self.ready.lock().expect("ready lock");
        for (id, _) in entries {
            self.failed.fetch_add(1, Ordering::Relaxed);
            ready.insert(id, Err(e.clone()));
        }
    }

    /// Claim every member of `group` that is still routed at the polled
    /// (link, wire id, generation). An empty claim means another poller
    /// already resolved or failed the group over — the caller's view is
    /// stale and it must answer `Pending`.
    fn claim_group(
        &self,
        group: &GroupShared,
        link_idx: usize,
        wire_id: u64,
        gen: u64,
    ) -> Vec<(u64, RouteState)> {
        let mut routes = self.routes.lock().expect("routes lock");
        let mut claimed = Vec::with_capacity(group.members.len());
        for &m in &group.members {
            let matches = routes
                .get(&m)
                .map(|s| s.link == link_idx && s.wire_id == wire_id && s.gen == gen)
                .unwrap_or(false);
            if matches {
                let state = routes.remove(&m).expect("checked above");
                claimed.push((m, state));
            }
        }
        claimed
    }

    /// Deliver one group's `submit_batch` response: zip members against
    /// entries, verify authenticated results, and park each member's
    /// outcome in the delivery buffer — except members whose entry asks
    /// for a retry (overload, integrity, shutdown), which re-place
    /// individually through the normal failover machinery.
    #[allow(clippy::too_many_arguments)]
    fn resolve_group(
        &self,
        group: &GroupShared,
        link_idx: usize,
        wire_id: u64,
        gen: u64,
        resp: crate::coordinator::rpc::Response,
    ) {
        let link = &self.links[link_idx];
        let claimed = self.claim_group(group, link_idx, wire_id, gen);
        if claimed.is_empty() {
            // Another poller moved the group first; this response is a
            // duplicate of work already re-placed (at-least-once).
            return;
        }
        let outcomes = match batch_outcomes(resp) {
            Ok(o) => o,
            Err(e) => {
                // Undecodable wholesale: terminal for every claimed member.
                let mut ready = self.ready.lock().expect("ready lock");
                for (id, _) in claimed {
                    link.completed.fetch_add(1, Ordering::Relaxed);
                    self.failed.fetch_add(1, Ordering::Relaxed);
                    ready.insert(
                        id,
                        Err(Error::Internal(format!("undecodable batch response: {e:#}"))),
                    );
                }
                return;
            }
        };
        for (slot, (id, state)) in claimed.into_iter().enumerate() {
            match outcomes.get(slot) {
                Some(Ok(r)) => {
                    if let Some(reason) = self.verify_outcome(&state.spec, id, r) {
                        self.integrity_detections.fetch_add(1, Ordering::Relaxed);
                        link.errored.fetch_add(1, Ordering::Relaxed);
                        let n = link.health.record_integrity();
                        eprintln!(
                            "[router] integrity detection on worker {} ({n} lifetime): {reason}; result quarantined, resubmitting",
                            link.spec.id
                        );
                        let exhausted = Error::IntegrityFailure(format!(
                            "{reason} (worker {}) and failover is exhausted",
                            link.spec.id
                        ));
                        if self.replace_single(id, (state.key, state.spec), group.tried.clone(), exhausted)
                        {
                            self.integrity_resubmits.fetch_add(1, Ordering::Relaxed);
                        }
                    } else {
                        link.completed.fetch_add(1, Ordering::Relaxed);
                        self.completed.fetch_add(1, Ordering::Relaxed);
                        self.ready.lock().expect("ready lock").insert(id, Ok(r.clone()));
                    }
                }
                Some(Err(e)) => {
                    link.errored.fetch_add(1, Ordering::Relaxed);
                    match e {
                        Error::Overloaded { .. } => {
                            link.health.record_overloaded(self.cfg.overload_divert);
                            self.replace_single(
                                id,
                                (state.key, state.spec),
                                group.tried.clone(),
                                e.clone(),
                            );
                        }
                        Error::ShuttingDown | Error::Unavailable(_) => {
                            self.replace_single(
                                id,
                                (state.key, state.spec),
                                group.tried.clone(),
                                e.clone(),
                            );
                        }
                        Error::IntegrityFailure(_) => {
                            self.integrity_detections.fetch_add(1, Ordering::Relaxed);
                            let n = link.health.record_integrity();
                            eprintln!(
                                "[router] worker {} reported an integrity failure ({n} lifetime); resubmitting",
                                link.spec.id
                            );
                            if self.replace_single(
                                id,
                                (state.key, state.spec),
                                group.tried.clone(),
                                e.clone(),
                            ) {
                                self.integrity_resubmits.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        _ => {
                            self.failed.fetch_add(1, Ordering::Relaxed);
                            self.ready.lock().expect("ready lock").insert(id, Err(e.clone()));
                        }
                    }
                }
                None => {
                    // The worker answered fewer entries than the batch
                    // carried — a protocol violation; terminal.
                    self.failed.fetch_add(1, Ordering::Relaxed);
                    self.ready.lock().expect("ready lock").insert(
                        id,
                        Err(Error::Internal("batch response is missing this entry".into())),
                    );
                }
            }
        }
    }

    /// Re-place one former group member as a plain single-job route,
    /// never re-offering links its group already tried. Parks
    /// `on_exhausted` in the delivery buffer when no candidate is left.
    /// Returns whether the job found a new home.
    fn replace_single(
        &self,
        id: u64,
        state: (u64, JobSpec),
        tried: Vec<usize>,
        on_exhausted: Error,
    ) -> bool {
        let (key, spec) = state;
        let mut tried = tried;
        match self.place(key, &spec, &mut tried) {
            Ok((link, wire_id, gen)) => {
                self.routes.lock().expect("routes lock").insert(
                    id,
                    RouteState { spec, key, link, wire_id, gen, tried, group: None },
                );
                true
            }
            Err(_) => {
                self.failed.fetch_add(1, Ordering::Relaxed);
                self.ready.lock().expect("ready lock").insert(id, Err(on_exhausted));
                false
            }
        }
    }

    /// Whole-group failover after transport loss or a stale generation:
    /// re-place every still-claimed member as one batch on the next
    /// candidate, carrying the group's tried list forward so the dead
    /// link is never re-offered. Zero loss is inherited from the
    /// single-job invariant: members either land on a survivor or fail
    /// typed via `place_group`'s exhaustion path.
    fn failover_group(&self, group: &GroupShared, link_idx: usize, wire_id: u64, gen: u64) {
        let claimed = self.claim_group(group, link_idx, wire_id, gen);
        if claimed.is_empty() {
            return;
        }
        let key = claimed[0].1.key;
        let entries: Vec<(u64, JobSpec)> =
            claimed.into_iter().map(|(id, s)| (id, s.spec)).collect();
        self.place_group(key, entries, group.tried.clone());
    }

    /// Fence `id` out of the ring, ask it to drain, and report the
    /// handoff. In-flight jobs on the shard finish over the still-open
    /// connection (the worker's drain semantics); new jobs go to the
    /// survivors the rebuilt ring picks.
    ///
    /// The returned report is a **fencing-time snapshot of the
    /// handoff**, not a completed drain (routes only resolve when their
    /// owners poll, so waiting here could deadlock a single-threaded
    /// caller): `drained` counts the jobs still in flight on the shard
    /// at that moment — each either delivers over the still-open
    /// connection or is resubmitted to a survivor on its owner's next
    /// poll, which is why `dropped` is 0 by construction. `rejected` is
    /// the shard's lifetime count of error answers (overload shedding
    /// plus terminal errors). Final delivered/dropped accounting lands
    /// in the router-wide [`shutdown`](Backend::shutdown) report.
    pub fn remove_worker(&self, id: &str) -> Result<DrainReport, Error> {
        let mut membership = self.membership.lock().expect("membership lock");
        let removed = membership
            .remove(id)
            .ok_or_else(|| Error::Rejected(format!("unknown worker {id:?}")))?;
        if membership.workers().is_empty() {
            // Put it back: a router with zero shards serves nothing.
            membership.add(removed);
            return Err(Error::Rejected("cannot remove the last worker".into()));
        }
        let link_of: Vec<usize> = membership
            .ids()
            .iter()
            .map(|mid| {
                self.links
                    .iter()
                    .position(|l| &l.spec.id == mid)
                    .expect("membership id has a link")
            })
            .collect();
        let ring = HashRing::new(&membership.ids(), self.cfg.vnodes);
        drop(membership);
        *self.placement.write().expect("placement lock") = Placement { ring, link_of };

        let link = self
            .links
            .iter()
            .find(|l| l.spec.id == id)
            .expect("removed id has a link");
        link.retired.store(true, Ordering::SeqCst);
        link.send_shutdown();
        let in_flight = self
            .routes
            .lock()
            .expect("routes lock")
            .values()
            .filter(|s| self.links[s.link].spec.id == id)
            .count() as u64;
        Ok(DrainReport {
            accepted: link.forwarded.load(Ordering::Relaxed),
            completed: link.completed.load(Ordering::Relaxed),
            rejected: link.errored.load(Ordering::Relaxed),
            drained: in_flight,
            dropped: 0,
        })
    }

    /// Router-side verification of an authenticated result: recompute
    /// the checksum the worker attached (covering the wire hop — the
    /// worker's own MAC/Freivalds checks stop at serialization), and
    /// for matmul re-run a coarse Freivalds screen against the
    /// operands retained in the route. `None` means clean (or the job
    /// was not authenticated).
    fn verify_result(&self, ticket_id: u64, r: &JobResult) -> Option<String> {
        let spec = {
            let routes = self.routes.lock().expect("routes lock");
            routes.get(&ticket_id)?.spec.clone()
        };
        self.verify_outcome(&spec, ticket_id, r)
    }

    /// The verification body, spec in hand — shared by the single-job
    /// path (spec looked up from the route) and the coalesced path
    /// (spec already claimed out of the routes map). `seed` feeds the
    /// Freivalds probe's randomness; the ticket id keeps it
    /// per-job-deterministic.
    fn verify_outcome(&self, spec: &JobSpec, seed: u64, r: &JobResult) -> Option<String> {
        if !spec.auth {
            return None;
        }
        match r.check {
            None => return Some("authenticated result arrived without a checksum".into()),
            Some(c) if auth::values_checksum(&r.values) != c => {
                return Some("result checksum does not match the delivered values".into());
            }
            Some(_) => {}
        }
        if let Payload::Matmul { a, b, dim } = &spec.payload {
            if r.values.len() != dim * dim {
                return Some(format!(
                    "matmul result has {} values, expected {}",
                    r.values.len(),
                    dim * dim
                ));
            }
            // Coarse screen only — the worker already enforced the
            // tier-aware bound. 2^-8 of the operand scale catches the
            // gross corruption a faulty link produces without
            // false-positiving on any supported tier's rounding.
            let amax = a.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
            let bmax = b.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
            let tol = (*dim * *dim) as f64 * amax.max(1.0) * bmax.max(1.0) * 0.00390625;
            if !auth::freivalds_matmul_check(a, b, &r.values, *dim, 2, seed, tol) {
                return Some("Freivalds screen rejected the matmul product".into());
            }
        }
        None
    }

    /// Shards currently reported Up.
    pub fn up_count(&self) -> usize {
        self.links
            .iter()
            .filter(|l| !l.retired() && l.health.state() == HealthState::Up)
            .count()
    }
}

impl Drop for ShardRouter {
    fn drop(&mut self) {
        self.stop_monitor.store(true, Ordering::SeqCst);
        if let Some(h) = self.monitor.lock().expect("monitor lock").take() {
            let _ = h.join();
        }
    }
}

impl Backend for ShardRouter {
    fn label(&self) -> &'static str {
        "shard-router"
    }

    fn submit(&self, spec: JobSpec) -> Result<JobTicket, Error> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err(Error::ShuttingDown);
        }
        let key = self.route_key(&spec).map_err(|e| {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            e
        })?;
        if !self.cfg.coalesce_window.is_zero() {
            // Coalescing: stage the job on its lane and flush when the
            // count trigger fires (the time trigger fires from `poll`).
            // Placement errors surface at poll time — the job is
            // accepted here.
            let id = self.next_ticket.fetch_add(1, Ordering::Relaxed);
            // Register the ticket in `staged` *before* it appears in a
            // buffer: a concurrent flush of this lane must never pop an
            // entry whose `staged` record does not exist yet.
            self.staged.lock().expect("staged lock").insert(id, key);
            let full = {
                let mut staging = self.staging.lock().expect("staging lock");
                let buf = staging
                    .entry(key)
                    .or_insert_with(|| CoalesceBuf { entries: Vec::new(), since: Instant::now() });
                if buf.entries.is_empty() {
                    buf.since = Instant::now();
                }
                buf.entries.push((id, spec));
                buf.entries.len() >= self.cfg.coalesce_max.max(1)
            };
            self.accepted.fetch_add(1, Ordering::Relaxed);
            if full {
                self.flush_key(key);
            }
            return Ok(JobTicket { id });
        }
        let mut tried = Vec::new();
        let (link, wire_id, gen) = self.place(key, &spec, &mut tried).map_err(|e| {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            e
        })?;
        let id = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        self.routes
            .lock()
            .expect("routes lock")
            .insert(id, RouteState { spec, key, link, wire_id, gen, tried, group: None });
        self.accepted.fetch_add(1, Ordering::Relaxed);
        Ok(JobTicket { id })
    }

    fn poll(&self, ticket: &JobTicket) -> JobPoll {
        // A group member resolved by another member's poll (or a staged
        // job whose placement failed) has its outcome parked here.
        if let Some(outcome) = self.ready.lock().expect("ready lock").remove(&ticket.id) {
            return JobPoll::Ready(outcome);
        }
        // Still staged: fire the time trigger if the window expired,
        // otherwise the job has not even been placed yet.
        let staged_key = self.staged.lock().expect("staged lock").get(&ticket.id).copied();
        if let Some(key) = staged_key {
            let expired = {
                let staging = self.staging.lock().expect("staging lock");
                staging
                    .get(&key)
                    .map(|buf| buf.since.elapsed() >= self.cfg.coalesce_window)
                    .unwrap_or(true)
            };
            if !expired {
                return JobPoll::Pending;
            }
            self.flush_key(key);
            if let Some(outcome) = self.ready.lock().expect("ready lock").remove(&ticket.id) {
                return JobPoll::Ready(outcome);
            }
            // Fall through: the flush routed the job; probe it now.
        }
        let located = {
            let routes = self.routes.lock().expect("routes lock");
            routes
                .get(&ticket.id)
                .map(|s| (s.link, s.wire_id, s.gen, s.group.as_ref().map(Arc::clone)))
        };
        let Some((link_idx, wire_id, gen, group)) = located else {
            return JobPoll::Ready(Err(Error::Internal("unknown ticket".into())));
        };
        let link = &self.links[link_idx];
        if let Some(group) = group {
            // Coalesced member: whichever member's poll pulls the batch
            // response resolves (or fails over) the whole group, then
            // every member collects from the delivery buffer.
            match link.try_take(wire_id, gen) {
                Ok(None) => return JobPoll::Pending,
                Ok(Some(resp)) => self.resolve_group(&group, link_idx, wire_id, gen, resp),
                Err(RouteLoss::Stale) | Err(RouteLoss::Lost) => {
                    self.failover_group(&group, link_idx, wire_id, gen)
                }
            }
            return match self.ready.lock().expect("ready lock").remove(&ticket.id) {
                Some(outcome) => JobPoll::Ready(outcome),
                // Re-placed (failover / per-entry retry) or claimed by a
                // concurrent poller — either way, not resolved yet.
                None => JobPoll::Pending,
            };
        }
        match link.try_take(wire_id, gen) {
            Ok(None) => JobPoll::Pending,
            Ok(Some(resp)) => match resp.body {
                ResponseBody::Result(v) => {
                    match result_from_json(&v) {
                        Ok(r) => {
                            // Quarantine a result that fails router-side
                            // verification: never deliver it, charge the
                            // detection to the shard (sticky quarantine
                            // after K), and resubmit via failover. The
                            // route stays in the map — `failover` owns
                            // its removal.
                            if let Some(reason) = self.verify_result(ticket.id, &r) {
                                self.integrity_detections.fetch_add(1, Ordering::Relaxed);
                                link.errored.fetch_add(1, Ordering::Relaxed);
                                let n = link.health.record_integrity();
                                eprintln!(
                                    "[router] integrity detection on worker {} ({n} lifetime): {reason}; result quarantined, resubmitting",
                                    link.spec.id
                                );
                                let poll = self.failover(
                                    ticket.id,
                                    Error::IntegrityFailure(format!(
                                        "{reason} (worker {}) and failover is exhausted",
                                        link.spec.id
                                    )),
                                );
                                if matches!(poll, JobPoll::Pending) {
                                    self.integrity_resubmits.fetch_add(1, Ordering::Relaxed);
                                }
                                return poll;
                            }
                            self.routes.lock().expect("routes lock").remove(&ticket.id);
                            link.completed.fetch_add(1, Ordering::Relaxed);
                            self.completed.fetch_add(1, Ordering::Relaxed);
                            JobPoll::Ready(Ok(r))
                        }
                        Err(e) => {
                            self.routes.lock().expect("routes lock").remove(&ticket.id);
                            link.completed.fetch_add(1, Ordering::Relaxed);
                            self.failed.fetch_add(1, Ordering::Relaxed);
                            JobPoll::Ready(Err(Error::Internal(format!(
                                "undecodable worker result: {e}"
                            ))))
                        }
                    }
                }
                ResponseBody::Error(e) => {
                    link.errored.fetch_add(1, Ordering::Relaxed);
                    match &e {
                        // The shard sheds load or is leaving: divert and
                        // re-place. The error passes through only when
                        // every candidate is exhausted.
                        Error::Overloaded { .. } => {
                            link.health.record_overloaded(self.cfg.overload_divert);
                            self.failover(ticket.id, e)
                        }
                        Error::ShuttingDown | Error::Unavailable(_) => self.failover(ticket.id, e),
                        // The worker's own MAC/Freivalds verification
                        // caught a fault before the result left it: the
                        // corrupted result was never sent. Charge the
                        // detection to the shard and resubmit elsewhere.
                        Error::IntegrityFailure(_) => {
                            self.integrity_detections.fetch_add(1, Ordering::Relaxed);
                            let n = link.health.record_integrity();
                            eprintln!(
                                "[router] worker {} reported an integrity failure ({n} lifetime); resubmitting",
                                link.spec.id
                            );
                            let poll = self.failover(ticket.id, e);
                            if matches!(poll, JobPoll::Pending) {
                                self.integrity_resubmits.fetch_add(1, Ordering::Relaxed);
                            }
                            poll
                        }
                        _ => {
                            self.routes.lock().expect("routes lock").remove(&ticket.id);
                            self.failed.fetch_add(1, Ordering::Relaxed);
                            JobPoll::Ready(Err(e))
                        }
                    }
                }
            },
            // The link reconnected since this job was submitted: the
            // wire id is meaningless on the new connection (ids restart
            // per connection), so the job's fate is unknown — exactly
            // like transport loss. Resubmit rather than poll a stale id
            // that could steal a fresh submission's response.
            Err(RouteLoss::Stale) => self.failover(
                ticket.id,
                Error::Unavailable(format!(
                    "connection to worker {} was replaced mid-job",
                    link.spec.id
                )),
            ),
            // Transport loss: the job's fate on that shard is unknown;
            // resubmit to the next candidate (pure computation ⇒
            // at-least-once is safe).
            Err(RouteLoss::Lost) => self.failover(
                ticket.id,
                Error::Unavailable(format!("worker {} lost mid-job", link.spec.id)),
            ),
        }
    }

    fn forget(&self, ticket: &JobTicket) {
        if self.ready.lock().expect("ready lock").remove(&ticket.id).is_some() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if let Some(key) = self.staged.lock().expect("staged lock").remove(&ticket.id) {
            if let Some(buf) = self.staging.lock().expect("staging lock").get_mut(&key) {
                buf.entries.retain(|(id, _)| *id != ticket.id);
            }
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if self.routes.lock().expect("routes lock").remove(&ticket.id).is_some() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn metrics_text(&self) -> String {
        let mut out = format!(
            "shard-router: {} workers, {} up | accepted {} completed {} failed {} rejected {} dropped {} | integrity detections {} resubmits {}\n",
            self.links.len(),
            self.up_count(),
            self.accepted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.dropped.load(Ordering::Relaxed),
            self.integrity_detections.load(Ordering::Relaxed),
            self.integrity_resubmits.load(Ordering::Relaxed),
        );
        if !self.cfg.coalesce_window.is_zero() {
            let d = &self.coalesce.depth;
            out.push_str(&format!(
                "  coalesce: window {:?} max {} | flushes {} jobs {} depth 1:{} 2:{} 3-4:{} 5-8:{} 9+:{}\n",
                self.cfg.coalesce_window,
                self.cfg.coalesce_max,
                self.coalesce.flushes.load(Ordering::Relaxed),
                self.coalesce.jobs.load(Ordering::Relaxed),
                d[0].load(Ordering::Relaxed),
                d[1].load(Ordering::Relaxed),
                d[2].load(Ordering::Relaxed),
                d[3].load(Ordering::Relaxed),
                d[4].load(Ordering::Relaxed),
            ));
        }
        for link in &self.links {
            let mark = if link.health.quarantined() {
                " (quarantined)"
            } else if link.retired() {
                " (retired)"
            } else {
                ""
            };
            out.push_str(&format!(
                "  {:<12} {:<20} {:?}{} queued {} forwarded {} completed {} errored {} detections {}\n",
                link.spec.id,
                link.spec.addr,
                link.health.state(),
                mark,
                link.health.queue_depth(),
                link.forwarded.load(Ordering::Relaxed),
                link.completed.load(Ordering::Relaxed),
                link.errored.load(Ordering::Relaxed),
                link.health.integrity_detections(),
            ));
        }
        out
    }

    fn queue_depth(&self) -> i64 {
        self.links
            .iter()
            .filter(|l| !l.retired())
            .map(|l| l.health.queue_depth())
            .sum()
    }

    fn integrity_detections(&self) -> u64 {
        self.integrity_detections.load(Ordering::Relaxed)
    }

    fn quarantined_workers(&self) -> u64 {
        self.links.iter().filter(|l| l.health.quarantined()).count() as u64
    }

    fn shutdown(&self) -> Result<DrainReport, Error> {
        if self.shutting_down.swap(true, Ordering::SeqCst) {
            return Err(Error::ShuttingDown);
        }
        // Drain: flush anything still staged, then keep polling
        // uncollected tickets so late results land in the accounting
        // instead of as drops.
        let staged_keys: Vec<u64> =
            self.staging.lock().expect("staging lock").keys().copied().collect();
        for key in staged_keys {
            self.flush_key(key);
        }
        let deadline = Instant::now() + self.cfg.drain_wait;
        loop {
            let ids: Vec<u64> = {
                let routes = self.routes.lock().expect("routes lock");
                routes.keys().copied().collect()
            };
            if ids.is_empty() || Instant::now() >= deadline {
                break;
            }
            for id in ids {
                let _ = self.poll(&JobTicket { id });
            }
            thread::sleep(Duration::from_millis(5));
        }
        let undrained = self.routes.lock().expect("routes lock").len() as u64;
        self.dropped.fetch_add(undrained, Ordering::Relaxed);

        self.stop_monitor.store(true, Ordering::SeqCst);
        if let Some(h) = self.monitor.lock().expect("monitor lock").take() {
            let _ = h.join();
        }
        for link in &self.links {
            if !link.retired() {
                link.send_shutdown();
            }
        }
        Ok(DrainReport {
            accepted: self.accepted.load(Ordering::Relaxed),
            // `DrainReport::completed` counts delivered outcomes
            // *including error results*; the router splits successes
            // (`completed`) from terminal errors (`failed`) internally
            // — `metrics_text` shows both.
            completed: self.completed.load(Ordering::Relaxed)
                + self.failed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            drained: 0,
            dropped: self.dropped.load(Ordering::Relaxed),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failover_order_prefers_routable_then_falls_back() {
        let candidates = [2usize, 0, 1, 3];
        // 0 and 2 unroutable, 3 retired.
        let order = failover_order(&candidates, &[], |i| i == 1, |i| i == 3);
        assert_eq!(order, vec![1, 2, 0]);
        // Tried links never reappear.
        let order = failover_order(&candidates, &[1, 2], |i| i == 1, |i| i == 3);
        assert_eq!(order, vec![0]);
        // Everything tried: empty.
        let order = failover_order(&candidates, &[0, 1, 2, 3], |_| true, |_| false);
        assert!(order.is_empty());
    }

    #[test]
    fn reconnect_bumps_generation_and_stales_old_wire_ids() {
        // A listener whose backlog accepts connections but never answers
        // — enough to exercise submit/poll framing without a server.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind test listener");
        let link = WorkerLink::new(WorkerSpec {
            id: "w0".into(),
            addr: listener.local_addr().expect("listener addr").to_string(),
        });
        assert!(link.connect(Duration::from_millis(500)), "first dial");
        let spec = JobSpec::dot(vec![1.0; 4], vec![2.0; 4]);
        let (id, gen) = link.submit(&spec).expect("submit on live conn");

        // Silent wire: the probe is Pending, not an error.
        assert!(matches!(link.try_take(id, gen), Ok(None)));

        // Connection lost, then rebuilt (what the monitor does after a
        // worker restart): the old (id, gen) pair must read as Stale —
        // never as Pending on the new connection, where the restarted
        // id counter would eventually collide with it.
        link.disconnect();
        assert!(matches!(link.try_take(id, gen), Err(RouteLoss::Lost)));
        assert!(link.connect(Duration::from_millis(500)), "re-dial");
        assert!(matches!(link.try_take(id, gen), Err(RouteLoss::Stale)));

        // A fresh submit on the new connection reuses the same wire id
        // (per-connection counter) under a new generation, and polls
        // cleanly.
        let (id2, gen2) = link.submit(&spec).expect("submit on new conn");
        assert_eq!(id2, id, "wire ids restart per connection");
        assert_ne!(gen2, gen, "generation must move on reconnect");
        assert!(matches!(link.try_take(id2, gen2), Ok(None)));
    }

    #[test]
    fn router_config_default_is_sane() {
        let cfg = RouterConfig::default();
        assert!(cfg.vnodes >= 16);
        assert!(cfg.health_interval > Duration::ZERO);
        assert!(cfg.overload_divert > Duration::ZERO);
        assert_eq!(cfg.divert_depth, 0, "depth diversion is opt-in");
        assert!(cfg.coalesce_window.is_zero(), "coalescing is opt-in");
        assert!(cfg.coalesce_max >= 2);
    }

    #[test]
    fn coalesce_stats_bucket_flush_depths() {
        let s = CoalesceStats::default();
        for depth in [1usize, 2, 3, 4, 5, 8, 9, 100] {
            s.record_flush(depth);
        }
        assert_eq!(s.flushes.load(Ordering::Relaxed), 8);
        assert_eq!(s.jobs.load(Ordering::Relaxed), 1 + 2 + 3 + 4 + 5 + 8 + 9 + 100);
        let d: Vec<u64> = s.depth.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        assert_eq!(d, vec![1, 1, 2, 2, 2]);
    }

    #[test]
    fn starting_with_no_reachable_worker_is_unavailable() {
        // Port 1 on localhost refuses immediately.
        let workers = vec![WorkerSpec { id: "w0".into(), addr: "127.0.0.1:1".into() }];
        let cfg = RouterConfig {
            connect_wait: Duration::from_millis(50),
            ..RouterConfig::default()
        };
        match ShardRouter::start(workers, cfg) {
            Err(Error::Unavailable(_)) => {}
            other => panic!("expected Unavailable, got {:?}", other.map(|_| "router")),
        }
    }

    #[test]
    fn starting_with_no_workers_is_rejected() {
        match ShardRouter::start(Vec::new(), RouterConfig::default()) {
            Err(Error::Rejected(_)) => {}
            other => panic!("expected Rejected, got {:?}", other.map(|_| "router")),
        }
    }
}
