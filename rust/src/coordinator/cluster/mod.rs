//! Sharded multi-process cluster serving: consistent-hash placement of
//! `(kind, tier, bucket)` lanes onto worker processes, with health-
//! driven overload diversion, replica failover, and drain/rebalance on
//! membership change.
//!
//! Topology (two `RpcServer` layers around one [`Backend`] seam):
//!
//! ```text
//! clients ── RpcServer ── ShardRouter ──┬── RpcClient ── RpcServer ── InProcess (worker 0)
//!            (hrfna route)              ├── RpcClient ── RpcServer ── InProcess (worker 1)
//!                                       └── ...                       (hrfna worker)
//! ```
//!
//! * [`ring`] — the consistent-hash ring and `lane_hash` (placement is
//!   over wire labels, so any tooling can compute it),
//! * [`membership`] — the worker list, `--workers` flag syntax, and the
//!   rebalance epoch,
//! * [`health`] — per-shard availability + occupancy gauges (fed by the
//!   `health` RPC carrying the PR 2 queue-depth gauges),
//! * [`router`] (`rpc` feature) — [`ShardRouter`], the routing
//!   [`Backend`](crate::coordinator::Backend) itself.
//!
//! Ring, membership, and health are std-only and tier-1-tested; only
//! the router, which speaks the wire, is feature-gated.

pub mod health;
pub mod membership;
pub mod ring;
#[cfg(feature = "rpc")]
pub mod router;

pub use health::{HealthGauge, HealthState, DOWN_AFTER_FAILURES};
pub use membership::{parse_workers, Membership, WorkerSpec};
pub use ring::{lane_hash, HashRing};
#[cfg(feature = "rpc")]
pub use router::{RouterConfig, ShardRouter};
