//! Cluster membership: the worker list, its epoch, and the CLI flag
//! syntax that names it.
//!
//! Membership is configuration here, not consensus: the router is told
//! its workers (`--workers host:port,host:port` or `--workers
//! a=host:port,b=host:port`) and bumps an epoch on every change. The
//! epoch is the rebalance fence — a ring built at epoch E serves until a
//! membership change produces E+1, at which point the router rebuilds
//! placement and drains the removed workers (see `cluster::router`).

/// One worker shard: a stable identity (the ring hashes the id, so a
/// worker keeps its lane share across address changes) and its RPC
/// address.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkerSpec {
    pub id: String,
    pub addr: String,
}

/// Parse the `--workers` flag: comma-separated `addr` or `id=addr`
/// entries. Bare addresses get positional ids `w0, w1, ...` (stable as
/// long as the flag order is stable).
pub fn parse_workers(s: &str) -> Result<Vec<WorkerSpec>, String> {
    let mut out = Vec::new();
    for (i, part) in s.split(',').map(str::trim).enumerate() {
        if part.is_empty() {
            return Err(format!("empty worker entry at position {i}"));
        }
        let spec = match part.split_once('=') {
            Some((id, addr)) => {
                if id.is_empty() || addr.is_empty() {
                    return Err(format!("malformed worker entry {part:?}"));
                }
                WorkerSpec { id: id.to_string(), addr: addr.to_string() }
            }
            None => WorkerSpec { id: format!("w{i}"), addr: part.to_string() },
        };
        out.push(spec);
    }
    if out.is_empty() {
        return Err("no workers given".into());
    }
    let mut ids: Vec<&str> = out.iter().map(|w| w.id.as_str()).collect();
    ids.sort_unstable();
    ids.dedup();
    if ids.len() != out.len() {
        return Err("duplicate worker ids".into());
    }
    Ok(out)
}

/// The router's membership view: worker list + change epoch.
pub struct Membership {
    workers: Vec<WorkerSpec>,
    epoch: u64,
}

impl Membership {
    pub fn new(workers: Vec<WorkerSpec>) -> Membership {
        Membership { workers, epoch: 0 }
    }

    pub fn workers(&self) -> &[WorkerSpec] {
        &self.workers
    }

    pub fn ids(&self) -> Vec<String> {
        self.workers.iter().map(|w| w.id.clone()).collect()
    }

    /// Epoch counter; bumps on every add/remove.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Add a worker; `false` (no epoch bump) if the id already exists.
    pub fn add(&mut self, spec: WorkerSpec) -> bool {
        if self.workers.iter().any(|w| w.id == spec.id) {
            return false;
        }
        self.workers.push(spec);
        self.epoch += 1;
        true
    }

    /// Remove a worker by id; returns its spec if present.
    pub fn remove(&mut self, id: &str) -> Option<WorkerSpec> {
        let i = self.workers.iter().position(|w| w.id == id)?;
        self.epoch += 1;
        Some(self.workers.remove(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_bare_addresses_with_positional_ids() {
        let w = parse_workers("127.0.0.1:9401,127.0.0.1:9402").unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0], WorkerSpec { id: "w0".into(), addr: "127.0.0.1:9401".into() });
        assert_eq!(w[1].id, "w1");
    }

    #[test]
    fn parses_named_entries_and_rejects_malformed() {
        let w = parse_workers("a=h1:1, b=h2:2").unwrap();
        assert_eq!(w[0].id, "a");
        assert_eq!(w[1].addr, "h2:2");
        assert!(parse_workers("").is_err());
        assert!(parse_workers("a=,b=x:1").is_err());
        assert!(parse_workers("a=h:1,a=h:2").is_err());
        assert!(parse_workers("h:1,,h:2").is_err());
    }

    #[test]
    fn epoch_bumps_only_on_real_changes() {
        let mut m = Membership::new(parse_workers("h1:1,h2:2").unwrap());
        assert_eq!(m.epoch(), 0);
        assert!(m.add(WorkerSpec { id: "w9".into(), addr: "h9:9".into() }));
        assert_eq!(m.epoch(), 1);
        assert!(!m.add(WorkerSpec { id: "w9".into(), addr: "h9:9".into() }));
        assert_eq!(m.epoch(), 1);
        assert!(m.remove("w0").is_some());
        assert_eq!(m.epoch(), 2);
        assert!(m.remove("w0").is_none());
        assert_eq!(m.epoch(), 2);
        assert_eq!(m.workers().len(), 2);
    }
}
