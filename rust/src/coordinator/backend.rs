//! The `Backend` trait: one submission surface for every execution
//! topology. `serve_load`, the benches, the RPC edge and the CLI all
//! drive a `&dyn Backend`; whether jobs run on in-process lane threads
//! ([`InProcess`]), across a socket (`rpc::Remote`), or sharded over a
//! worker fleet (`cluster::ShardRouter`) is the caller's one-line
//! choice at construction.
//!
//! The contract is ticket-based: `submit` returns a [`JobTicket`]
//! immediately (or a typed [`Error`]), `poll` is non-blocking, and
//! `wait` blocks with a timeout. Tickets are single-result: once `poll`
//! returns [`JobPoll::Ready`] (or `wait` returns), the ticket is spent
//! and later calls report an unknown-ticket internal error. `forget`
//! abandons a ticket whose result nobody will collect, so long-poll
//! loops (the RPC completer's pending timeout) don't leak result
//! channels.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Mutex, RwLock};
use std::time::{Duration, Instant};

use super::error::Error;
use super::request::{JobResult, JobSpec};
use super::server::{Coordinator, DrainReport};

/// Default ceiling for [`Backend::call`] and the blocking waits built on
/// it — generous enough for a saturated wide-tier lane, small enough to
/// turn a lost result into a test failure instead of a hang.
pub const DEFAULT_WAIT: Duration = Duration::from_secs(120);

/// Polling granularity of the default `wait` implementation.
const POLL_INTERVAL: Duration = Duration::from_micros(200);

/// Handle to one submitted job. Cheap, `Copy`, and meaningful only to
/// the backend that issued it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct JobTicket {
    pub id: u64,
}

/// Non-blocking result probe.
#[derive(Debug)]
pub enum JobPoll {
    /// Still executing (or still queued); poll again.
    Pending,
    /// Terminal: the job's result or its typed failure. Consumes the
    /// ticket.
    Ready(Result<JobResult, Error>),
}

/// A place jobs can be submitted to and results collected from.
///
/// Implementations must be `Send + Sync`: the serving edge polls
/// tickets from a completer thread while reader threads submit.
pub trait Backend: Send + Sync {
    /// Short name for logs and metrics headers ("in-process",
    /// "rpc-client", "shard-router").
    fn label(&self) -> &'static str;

    /// Admit and enqueue one job. Fails fast with the typed error
    /// (admission, backpressure, or routing) without blocking on
    /// execution.
    fn submit(&self, spec: JobSpec) -> Result<JobTicket, Error>;

    /// Non-blocking result probe. `Ready` consumes the ticket.
    fn poll(&self, ticket: &JobTicket) -> JobPoll;

    /// Abandon a ticket: release any result channel held for it. After
    /// this, `poll` on the ticket reports unknown-ticket. Default no-op
    /// for backends without per-ticket state.
    fn forget(&self, ticket: &JobTicket) {
        let _ = ticket;
    }

    /// Block until the ticket resolves or `timeout` elapses (timeout
    /// forgets the ticket and yields `Internal`). Backends with a real
    /// blocking primitive should override the default poll loop.
    fn wait(&self, ticket: &JobTicket, timeout: Duration) -> Result<JobResult, Error> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.poll(ticket) {
                JobPoll::Ready(out) => return out,
                JobPoll::Pending => {
                    if Instant::now() >= deadline {
                        self.forget(ticket);
                        return Err(Error::Internal("result wait timed out".into()));
                    }
                    std::thread::sleep(POLL_INTERVAL);
                }
            }
        }
    }

    /// Submit-and-wait convenience with the default ceiling.
    fn call(&self, spec: JobSpec) -> Result<JobResult, Error> {
        let ticket = self.submit(spec)?;
        self.wait(&ticket, DEFAULT_WAIT)
    }

    /// Rendered metrics table(s) for operator output.
    fn metrics_text(&self) -> String;

    /// Total queued jobs across lanes — the occupancy signal cluster
    /// routing uses for overload diversion. Backends without a queue
    /// view report 0.
    fn queue_depth(&self) -> i64 {
        0
    }

    /// Lifetime integrity detections (MAC, checksum, or Freivalds
    /// verification failures) this backend has observed. Surfaced by
    /// the `health` RPC so operators and the fault-smoke gate can read
    /// it without parsing metrics tables. 0 for backends that don't
    /// verify.
    fn integrity_detections(&self) -> u64 {
        0
    }

    /// Workers currently quarantined for integrity failures. Nonzero
    /// only for cluster backends.
    fn quarantined_workers(&self) -> u64 {
        0
    }

    /// Drain and stop. Idempotence is not required: a second call may
    /// fail with `ShuttingDown`.
    fn shutdown(&self) -> Result<DrainReport, Error>;
}

/// [`Backend`] over an owned in-process [`Coordinator`].
///
/// This replaces the old `Arc::try_unwrap(coord)` teardown dance:
/// `shutdown` takes the coordinator out of an `RwLock<Option<_>>`, so
/// any number of `Arc` clones can exist at drain time.
pub struct InProcess {
    coord: RwLock<Option<Coordinator>>,
    pending: Mutex<HashMap<u64, mpsc::Receiver<Result<JobResult, Error>>>>,
    next_ticket: AtomicU64,
}

impl InProcess {
    pub fn new(coord: Coordinator) -> InProcess {
        InProcess {
            coord: RwLock::new(Some(coord)),
            pending: Mutex::new(HashMap::new()),
            next_ticket: AtomicU64::new(1),
        }
    }

    /// Run `f` against the live coordinator (metrics inspection,
    /// registry access). `None` after shutdown.
    pub fn with_coordinator<T>(&self, f: impl FnOnce(&Coordinator) -> T) -> Option<T> {
        self.coord.read().expect("coordinator lock").as_ref().map(f)
    }

    /// Pull a pending receiver out of the ticket map (consuming the
    /// ticket) so blocking waits don't hold the map lock.
    fn take_rx(
        &self,
        ticket: &JobTicket,
    ) -> Option<mpsc::Receiver<Result<JobResult, Error>>> {
        self.pending.lock().expect("pending lock").remove(&ticket.id)
    }
}

impl Backend for InProcess {
    fn label(&self) -> &'static str {
        "in-process"
    }

    fn submit(&self, spec: JobSpec) -> Result<JobTicket, Error> {
        let guard = self.coord.read().expect("coordinator lock");
        let coord = guard.as_ref().ok_or(Error::ShuttingDown)?;
        let rx = coord.submit(spec)?;
        let id = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        self.pending.lock().expect("pending lock").insert(id, rx);
        Ok(JobTicket { id })
    }

    fn poll(&self, ticket: &JobTicket) -> JobPoll {
        let mut pending = self.pending.lock().expect("pending lock");
        let Some(rx) = pending.get(&ticket.id) else {
            return JobPoll::Ready(Err(Error::Internal("unknown ticket".into())));
        };
        match rx.try_recv() {
            Ok(result) => {
                pending.remove(&ticket.id);
                JobPoll::Ready(result)
            }
            Err(mpsc::TryRecvError::Empty) => JobPoll::Pending,
            Err(mpsc::TryRecvError::Disconnected) => {
                pending.remove(&ticket.id);
                JobPoll::Ready(Err(Error::Internal("result channel closed".into())))
            }
        }
    }

    fn forget(&self, ticket: &JobTicket) {
        self.take_rx(ticket);
    }

    /// Blocking wait on the job's own result channel — no poll
    /// granularity in the latency numbers.
    fn wait(&self, ticket: &JobTicket, timeout: Duration) -> Result<JobResult, Error> {
        let Some(rx) = self.take_rx(ticket) else {
            return Err(Error::Internal("unknown ticket".into()));
        };
        match rx.recv_timeout(timeout) {
            Ok(result) => result,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(Error::Internal("result wait timed out".into()))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Internal("result channel closed".into()))
            }
        }
    }

    fn metrics_text(&self) -> String {
        self.with_coordinator(|c| c.metrics_table().render())
            .unwrap_or_else(|| "coordinator: shut down".into())
    }

    fn queue_depth(&self) -> i64 {
        self.with_coordinator(|c| c.metrics.queue_depth_total())
            .unwrap_or(0)
    }

    fn integrity_detections(&self) -> u64 {
        self.with_coordinator(|c| c.metrics.total_integrity_detections())
            .unwrap_or(0)
    }

    fn shutdown(&self) -> Result<DrainReport, Error> {
        let coord = self
            .coord
            .write()
            .expect("coordinator lock")
            .take()
            .ok_or(Error::ShuttingDown)?;
        Ok(coord.shutdown())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::JobKind;
    use crate::coordinator::server::CoordinatorConfig;
    use crate::coordinator::ContextRegistry;
    use crate::runtime::EngineHandle;
    use std::sync::Arc;

    fn backend() -> InProcess {
        let engine = EngineHandle::spawn(None).expect("engine load");
        InProcess::new(Coordinator::start(
            engine,
            Arc::new(ContextRegistry::new()),
            CoordinatorConfig::default(),
        ))
    }

    #[test]
    fn submit_poll_wait_round_trip() {
        let b = backend();
        let x = vec![1.0; 512];
        let y = vec![2.0; 512];
        let ticket = b.submit(JobSpec::dot(x, y)).unwrap();
        let r = b.wait(&ticket, DEFAULT_WAIT).unwrap();
        assert_eq!(r.kind, JobKind::DotHybrid);
        assert!((r.values[0] - 1024.0).abs() < 1e-9);
        // Ticket is spent.
        match b.poll(&ticket) {
            JobPoll::Ready(Err(Error::Internal(msg))) => assert!(msg.contains("unknown")),
            other => panic!("expected unknown-ticket, got {other:?}"),
        }
        assert!(b.shutdown().unwrap().is_clean());
    }

    #[test]
    fn call_runs_submit_and_wait() {
        let b = backend();
        let r = b.call(JobSpec::dot(vec![3.0; 512], vec![1.0; 512])).unwrap();
        assert!((r.values[0] - 1536.0).abs() < 1e-9);
        assert!(b.shutdown().unwrap().is_clean());
    }

    #[test]
    fn shutdown_is_terminal() {
        let b = backend();
        assert!(b.shutdown().unwrap().is_clean());
        assert_eq!(
            b.submit(JobSpec::dot(vec![1.0; 512], vec![1.0; 512])),
            Err(Error::ShuttingDown)
        );
        assert_eq!(b.shutdown(), Err(Error::ShuttingDown));
        assert_eq!(b.queue_depth(), 0);
    }

    #[test]
    fn forget_releases_the_ticket() {
        let b = backend();
        let ticket = b.submit(JobSpec::dot(vec![1.0; 512], vec![1.0; 512])).unwrap();
        b.forget(&ticket);
        match b.poll(&ticket) {
            JobPoll::Ready(Err(Error::Internal(msg))) => assert!(msg.contains("unknown")),
            other => panic!("expected unknown-ticket, got {other:?}"),
        }
        // The worker still completes the job; drain accounting stays
        // consistent because the coordinator counts completion, not
        // collection.
        let report = b.shutdown().unwrap();
        assert_eq!(report.dropped, 0);
    }
}
