//! Protocol types: JSON-RPC 2.0-shaped requests/responses plus the
//! serialization of the coordinator's domain types ([`JobSpec`],
//! [`JobResult`], `Tier`, `JobKind`) and of the unified
//! [`coordinator::Error`](crate::coordinator::Error) — whose
//! `wire_code()` IS the stable error-code table clients branch on.
//!
//! Compatibility contract (pinned by the golden fixtures in
//! `tests/fixtures/rpc/` and the property tests in `integration_rpc`):
//!
//! * request/response field names and order,
//! * `JobKind::label` / `Tier::label` strings as the kind/tier encodings,
//! * the numeric codes in [`coordinator::error::WIRE_CODES`]
//!   (`crate::coordinator::error::WIRE_CODES`).
//!
//! Changing any of those is a wire break and must version the protocol.
//!
//! Error mapping is **lossless across hops**: `error_to_json` writes the
//! variant's code, its Display string as the message, and (for
//! `Overloaded`) the typed queue state as structured `data`;
//! `error_from_json` rebuilds the identical enum value. A cluster router
//! that decodes a worker's error and re-encodes it for the client emits
//! the same bytes the worker sent.

use crate::coordinator::error::Error;
use crate::coordinator::request::{JobKind, JobResult, JobSpec, Payload};
use crate::hybrid::registry::Tier;

use super::json::Json;

/// Protocol version tag carried in every message.
pub const JSONRPC_VERSION: &str = "2.0";

/// Pre-PR7 shim: the typed-error → wire-code mapping is now a method on
/// the unified enum.
#[deprecated(note = "use Error::wire_code")]
pub fn code_for_submit_error(e: &Error) -> i64 {
    e.wire_code()
}

/// Encode an error as the wire error **object**:
/// `{"code":C,"message":"...","data":...}` (`data` only for
/// `Overloaded`, carrying `{kind, tier, queued, capacity}`).
pub fn error_to_json(e: &Error) -> Json {
    let mut fields = vec![
        ("code".to_string(), Json::Num(e.wire_code() as f64)),
        ("message".to_string(), Json::Str(e.to_string())),
    ];
    if let Error::Overloaded { kind, tier, queued, capacity } = e {
        fields.push((
            "data".to_string(),
            Json::obj(vec![
                ("kind", Json::str(kind.label())),
                ("tier", Json::str(tier.label())),
                ("queued", Json::Num(*queued as f64)),
                ("capacity", Json::Num(*capacity as f64)),
            ]),
        ));
    }
    Json::Obj(fields)
}

/// Inverse of [`error_to_json`]. Unknown codes are decode errors (a
/// client must not misfile an error contract it does not know).
/// `Overloaded` rebuilds its typed fields from `data`, which is
/// **mandatory** for code -32002 (a missing object is a decode error,
/// never a zeroed placeholder); the other variants recover their
/// payload by stripping the Display prefix off the message
/// ([`Error::from_wire`]).
pub fn error_from_json(v: &Json) -> Result<Error, String> {
    let code = v.get("code").and_then(Json::as_i64).ok_or("error without code")?;
    let message = v.get("message").and_then(Json::as_str).unwrap_or_default();
    let base = Error::from_wire(code, message).ok_or_else(|| format!("unknown error code {code}"))?;
    if let Error::Overloaded { .. } = base {
        // `data` is mandatory for -32002: without it the queue-state
        // fields could only be invented, and a router hop would forward
        // the fabrication as fact.
        let data = v.get("data").ok_or("overloaded error without data")?;
        let kind = data
            .get("kind")
            .and_then(Json::as_str)
            .and_then(JobKind::from_label)
            .ok_or("overloaded data without kind")?;
        let tier = data
            .get("tier")
            .and_then(Json::as_str)
            .and_then(Tier::from_label)
            .ok_or("overloaded data without tier")?;
        let queued = data
            .get("queued")
            .and_then(Json::as_u64)
            .ok_or("overloaded data without queued")? as usize;
        let capacity = data
            .get("capacity")
            .and_then(Json::as_u64)
            .ok_or("overloaded data without capacity")? as usize;
        return Ok(Error::Overloaded { kind, tier, queued, capacity });
    }
    Ok(base)
}

/// A request frame: `{"jsonrpc":"2.0","id":N,"method":"...","params":...}`.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub method: String,
    pub params: Json,
}

impl Request {
    pub fn new(id: u64, method: &str, params: Json) -> Request {
        Request { id, method: method.to_string(), params }
    }

    /// Deterministic encoding (field order fixed).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jsonrpc", Json::str(JSONRPC_VERSION)),
            ("id", Json::Num(self.id as f64)),
            ("method", Json::str(&self.method)),
            ("params", self.params.clone()),
        ])
    }

    /// Parse a request object. `Err` carries the typed error the server
    /// should answer with (`InvalidRequest` for shape problems).
    pub fn from_json(v: &Json) -> Result<Request, Error> {
        let bad = |m: &str| Error::InvalidRequest(m.to_string());
        if v.get("jsonrpc").and_then(Json::as_str) != Some(JSONRPC_VERSION) {
            return Err(bad("missing or unsupported jsonrpc version"));
        }
        let id = v
            .get("id")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad("missing or non-integer id"))?;
        let method = v
            .get("method")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing method"))?
            .to_string();
        let params = v.get("params").cloned().unwrap_or(Json::Null);
        Ok(Request { id, method, params })
    }
}

/// Response payload: a result or a typed error.
#[derive(Clone, Debug, PartialEq)]
pub enum ResponseBody {
    Result(Json),
    Error(Error),
}

/// A response frame, correlated to its request by `id`.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub id: u64,
    pub body: ResponseBody,
}

impl Response {
    pub fn result(id: u64, value: Json) -> Response {
        Response { id, body: ResponseBody::Result(value) }
    }

    pub fn error(id: u64, err: Error) -> Response {
        Response { id, body: ResponseBody::Error(err) }
    }

    /// Deterministic encoding:
    /// `{"jsonrpc":"2.0","id":N,"result":...}` or
    /// `{"jsonrpc":"2.0","id":N,"error":{"code":C,"message":"...","data":...}}`
    /// (`data` only when the variant carries structured data).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("jsonrpc".to_string(), Json::str(JSONRPC_VERSION)),
            ("id".to_string(), Json::Num(self.id as f64)),
        ];
        match &self.body {
            ResponseBody::Result(v) => fields.push(("result".to_string(), v.clone())),
            ResponseBody::Error(e) => fields.push(("error".to_string(), error_to_json(e))),
        }
        Json::Obj(fields)
    }

    /// Parse a response object (client side).
    pub fn from_json(v: &Json) -> Result<Response, String> {
        if v.get("jsonrpc").and_then(Json::as_str) != Some(JSONRPC_VERSION) {
            return Err("missing or unsupported jsonrpc version".into());
        }
        let id = v.get("id").and_then(Json::as_u64).ok_or("missing response id")?;
        if let Some(result) = v.get("result") {
            return Ok(Response::result(id, result.clone()));
        }
        let err = v.get("error").ok_or("response has neither result nor error")?;
        Ok(Response::error(id, error_from_json(err)?))
    }
}

fn payload_to_json(p: &Payload) -> Json {
    match p {
        Payload::Dot { x, y } => Json::obj(vec![
            ("type", Json::str("dot")),
            ("x", Json::arr_f64(x)),
            ("y", Json::arr_f64(y)),
        ]),
        Payload::Matmul { a, b, dim } => Json::obj(vec![
            ("type", Json::str("matmul")),
            ("dim", Json::Num(*dim as f64)),
            ("a", Json::arr_f64(a)),
            ("b", Json::arr_f64(b)),
        ]),
        Payload::Rk4 { y0, mu, dt, steps } => Json::obj(vec![
            ("type", Json::str("rk4")),
            ("y0", Json::arr_f64(y0)),
            ("mu", Json::Num(*mu)),
            ("dt", Json::Num(*dt)),
            ("steps", Json::Num(*steps as f64)),
        ]),
        Payload::Fir { taps, x } => Json::obj(vec![
            ("type", Json::str("fir")),
            ("taps", Json::arr_f64(taps)),
            ("x", Json::arr_f64(x)),
        ]),
    }
}

fn payload_from_json(v: &Json) -> Result<Payload, String> {
    let ty = v.get("type").and_then(Json::as_str).ok_or("payload without type")?;
    let vec_field = |k: &str| -> Result<Vec<f64>, String> {
        v.get(k)
            .and_then(Json::f64_vec)
            .ok_or_else(|| format!("payload field {k:?} is not a number array"))
    };
    let num_field = |k: &str| -> Result<f64, String> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("payload field {k:?} is not a number"))
    };
    match ty {
        "dot" => Ok(Payload::Dot { x: vec_field("x")?, y: vec_field("y")? }),
        "matmul" => Ok(Payload::Matmul {
            a: vec_field("a")?,
            b: vec_field("b")?,
            dim: v
                .get("dim")
                .and_then(Json::as_u64)
                .ok_or("matmul payload without integral dim")? as usize,
        }),
        "rk4" => Ok(Payload::Rk4 {
            y0: vec_field("y0")?,
            mu: num_field("mu")?,
            dt: num_field("dt")?,
            steps: v
                .get("steps")
                .and_then(Json::as_u64)
                .ok_or("rk4 payload without integral steps")?,
        }),
        "fir" => Ok(Payload::Fir { taps: vec_field("taps")?, x: vec_field("x")? }),
        other => Err(format!("unknown payload type {other:?}")),
    }
}

/// Serialize a spec:
/// `{"kind":"dot/hrfna","tier":"paper","tolerance":T,"auth":true,"payload":{...}}`
/// (`tolerance` omitted when `None`; `auth` omitted when `false`, so
/// unauthenticated frames are byte-identical to the pre-auth protocol).
pub fn spec_to_json(spec: &JobSpec) -> Json {
    let mut fields = vec![
        ("kind".to_string(), Json::str(spec.kind.label())),
        ("tier".to_string(), Json::str(spec.tier.label())),
    ];
    if let Some(tol) = spec.tolerance {
        fields.push(("tolerance".to_string(), Json::Num(tol)));
    }
    if spec.auth {
        fields.push(("auth".to_string(), Json::Bool(true)));
    }
    fields.push(("payload".to_string(), payload_to_json(&spec.payload)));
    Json::Obj(fields)
}

/// Inverse of [`spec_to_json`].
pub fn spec_from_json(v: &Json) -> Result<JobSpec, String> {
    let kind_label = v.get("kind").and_then(Json::as_str).ok_or("spec without kind")?;
    let kind =
        JobKind::from_label(kind_label).ok_or_else(|| format!("unknown kind {kind_label:?}"))?;
    let tier = match v.get("tier") {
        None => Tier::Paper,
        Some(t) => {
            let label = t.as_str().ok_or("tier is not a string")?;
            Tier::from_label(label).ok_or_else(|| format!("unknown tier {label:?}"))?
        }
    };
    let tolerance = match v.get("tolerance") {
        None | Some(Json::Null) => None,
        Some(t) => Some(t.as_f64().ok_or("tolerance is not a number")?),
    };
    let auth = match v.get("auth") {
        None | Some(Json::Null) => false,
        Some(a) => a.as_bool().ok_or("auth is not a boolean")?,
    };
    let payload = payload_from_json(v.get("payload").ok_or("spec without payload")?)?;
    Ok(JobSpec { kind, payload, tier, tolerance, auth })
}

/// Serialize a result:
/// `{"id":N,"kind":K,"tier":T,"values":[...],"latency_us":L,"batch_size":B,"check":"hex"}`
/// (`check` — the FNV-1a checksum of an authenticated result — is a
/// 16-digit hex **string**, because JSON numbers are f64 and would
/// silently destroy u64 bits above 2^53; omitted for unauthenticated
/// results, keeping those frames byte-identical to the pre-auth
/// protocol).
pub fn result_to_json(r: &JobResult) -> Json {
    let mut fields = vec![
        ("id".to_string(), Json::Num(r.id as f64)),
        ("kind".to_string(), Json::str(r.kind.label())),
        ("tier".to_string(), Json::str(r.tier.label())),
        ("values".to_string(), Json::arr_f64(&r.values)),
        ("latency_us".to_string(), Json::Num(r.latency_us)),
        ("batch_size".to_string(), Json::Num(r.batch_size as f64)),
    ];
    if let Some(check) = r.check {
        fields.push(("check".to_string(), Json::Str(format!("{check:016x}"))));
    }
    Json::Obj(fields)
}

/// Inverse of [`result_to_json`]. Failed-job NaN sentinels survive the
/// trip as `null` → NaN.
pub fn result_from_json(v: &Json) -> Result<JobResult, String> {
    let kind_label = v.get("kind").and_then(Json::as_str).ok_or("result without kind")?;
    let tier_label = v.get("tier").and_then(Json::as_str).ok_or("result without tier")?;
    let check = match v.get("check") {
        None | Some(Json::Null) => None,
        Some(c) => {
            let s = c.as_str().ok_or("check is not a string")?;
            Some(u64::from_str_radix(s, 16).map_err(|e| format!("bad check {s:?}: {e}"))?)
        }
    };
    Ok(JobResult {
        id: v.get("id").and_then(Json::as_u64).ok_or("result without id")?,
        kind: JobKind::from_label(kind_label)
            .ok_or_else(|| format!("unknown kind {kind_label:?}"))?,
        tier: Tier::from_label(tier_label)
            .ok_or_else(|| format!("unknown tier {tier_label:?}"))?,
        values: v
            .get("values")
            .and_then(Json::f64_vec)
            .ok_or("result without values array")?,
        latency_us: v
            .get("latency_us")
            .and_then(Json::as_f64)
            .ok_or("result without latency_us")?,
        batch_size: v
            .get("batch_size")
            .and_then(Json::as_u64)
            .ok_or("result without batch_size")? as usize,
        check,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::error::WIRE_CODES;

    #[test]
    fn error_codes_are_stable_and_unique() {
        let expect: &[(i64, &str)] = &[
            (-32700, "parse_error"),
            (-32600, "invalid_request"),
            (-32601, "method_not_found"),
            (-32602, "invalid_params"),
            (-32603, "internal"),
            (-32001, "rejected"),
            (-32002, "overloaded"),
            (-32003, "shutting_down"),
            (-32004, "rate_limited"),
            (-32005, "too_many_in_flight"),
            (-32006, "unavailable"),
            (-32007, "integrity_failure"),
        ];
        assert_eq!(expect, &WIRE_CODES[..], "wire code table drifted");
        assert!(Error::from_wire(-1, "x").is_none());
    }

    #[test]
    fn submit_errors_map_to_backpressure_codes() {
        let overloaded = Error::Overloaded {
            kind: JobKind::DotHybrid,
            tier: Tier::Wide,
            queued: 32,
            capacity: 32,
        };
        assert_eq!(overloaded.wire_code(), -32002);
        assert!(overloaded.is_backpressure());
        let obj = error_to_json(&overloaded);
        let data = obj.get("data").unwrap();
        assert_eq!(data.get("kind").unwrap().as_str(), Some("dot/hrfna"));
        assert_eq!(data.get("tier").unwrap().as_str(), Some("wide"));
        assert_eq!(data.get("queued").unwrap().as_u64(), Some(32));
        assert_eq!(data.get("capacity").unwrap().as_u64(), Some(32));

        let rejected = Error::Rejected("bad shape".into());
        assert_eq!(rejected.wire_code(), -32001);
        assert!(!rejected.is_backpressure());
        assert!(error_to_json(&rejected).get("data").is_none());

        assert_eq!(Error::ShuttingDown.wire_code(), -32003);
        assert_eq!(Error::Unavailable("no worker".into()).wire_code(), -32006);
    }

    #[test]
    fn errors_round_trip_losslessly_including_overloaded_data() {
        let errors = vec![
            Error::Parse("bad frame".into()),
            Error::InvalidParams("spec without kind".into()),
            Error::Rejected("bad shape".into()),
            Error::Overloaded {
                kind: JobKind::MatmulHybrid,
                tier: Tier::Lo,
                queued: 17,
                capacity: 16,
            },
            Error::ShuttingDown,
            Error::RateLimited("rate above 10/s".into()),
            Error::TooManyInFlight("cap 256".into()),
            Error::Unavailable("worker w1 unreachable".into()),
            Error::IntegrityFailure("MAC mismatch in channel 3".into()),
        ];
        for e in errors {
            let text = error_to_json(&e).encode();
            let back = error_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, e, "decode must rebuild the identical value");
            // Router hop: re-encoding the decoded error is byte-identical.
            assert_eq!(error_to_json(&back).encode(), text, "re-encode drifted");
        }
    }

    #[test]
    fn overloaded_without_data_is_a_decode_error_not_a_placeholder() {
        let bad = "{\"code\":-32002,\"message\":\"lane overloaded\"}";
        let err = error_from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert!(err.contains("without data"), "{err}");
        // Codes whose variants carry no structured data still decode.
        let ok = "{\"code\":-32003,\"message\":\"server is shutting down\"}";
        assert_eq!(error_from_json(&Json::parse(ok).unwrap()), Ok(Error::ShuttingDown));
    }

    #[test]
    fn request_round_trip() {
        let req = Request::new(7, "submit", Json::obj(vec![("kind", Json::str("dot/hrfna"))]));
        let encoded = req.to_json().encode();
        assert!(encoded.starts_with("{\"jsonrpc\":\"2.0\",\"id\":7,\"method\":\"submit\""));
        let back = Request::from_json(&Json::parse(&encoded).unwrap()).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn malformed_requests_yield_invalid_request() {
        for bad in [
            "{}",
            "{\"jsonrpc\":\"1.0\",\"id\":1,\"method\":\"ping\"}",
            "{\"jsonrpc\":\"2.0\",\"method\":\"ping\"}",
            "{\"jsonrpc\":\"2.0\",\"id\":-1,\"method\":\"ping\"}",
            "{\"jsonrpc\":\"2.0\",\"id\":1}",
        ] {
            let err = Request::from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert!(matches!(err, Error::InvalidRequest(_)), "{bad}");
        }
    }

    #[test]
    fn response_round_trip_both_arms() {
        let ok = Response::result(3, Json::str("pong"));
        let back = Response::from_json(&Json::parse(&ok.to_json().encode()).unwrap()).unwrap();
        assert_eq!(back, ok);

        let err = Response::error(4, Error::RateLimited("slow down".into()));
        let text = err.to_json().encode();
        assert!(text.contains("\"code\":-32004"));
        let back = Response::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, err);

        // Unknown codes are decode failures, not silent passthrough.
        let unknown =
            "{\"jsonrpc\":\"2.0\",\"id\":4,\"error\":{\"code\":-1,\"message\":\"?\"}}";
        assert!(Response::from_json(&Json::parse(unknown).unwrap()).is_err());
    }

    #[test]
    fn spec_round_trips_all_payload_kinds() {
        let specs = [
            JobSpec::dot(vec![1.0, -2.5], vec![0.5, 4.0]).tier(Tier::Lo).tolerance(1e-3),
            JobSpec::matmul_f32(vec![1.0; 4], vec![2.0; 4], 2),
            JobSpec::rk4(vec![2.0, 0.0], 1.5, 0.01, 32).tier(Tier::Wide),
            JobSpec::fir(vec![0.25, 0.5, 0.25], vec![1.0; 8]),
            JobSpec::dot(vec![1.0; 4], vec![2.0; 4]).authenticated(),
        ];
        for spec in &specs {
            let text = spec_to_json(spec).encode();
            let back = spec_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.kind, spec.kind);
            assert_eq!(back.tier, spec.tier);
            assert_eq!(back.tolerance, spec.tolerance);
            assert_eq!(back.auth, spec.auth);
            assert_eq!(spec_to_json(&back).encode(), text, "canonical re-encode");
        }
        // `auth` appears on the wire only when set: unauthenticated specs
        // are byte-identical to the pre-auth protocol.
        assert!(!spec_to_json(&specs[0]).encode().contains("auth"));
        assert!(spec_to_json(&specs[4]).encode().contains("\"auth\":true"));
        // Tier defaults to paper when absent (old clients).
        let spec = spec_from_json(
            &Json::parse(
                "{\"kind\":\"dot/fp32\",\"payload\":{\"type\":\"dot\",\"x\":[1],\"y\":[2]}}",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(spec.tier, Tier::Paper);
        assert!(spec.tolerance.is_none());
    }

    #[test]
    fn bad_specs_are_decode_errors() {
        for bad in [
            "{\"payload\":{\"type\":\"dot\",\"x\":[],\"y\":[]}}",
            "{\"kind\":\"nope\",\"payload\":{\"type\":\"dot\",\"x\":[],\"y\":[]}}",
            "{\"kind\":\"dot/hrfna\",\"tier\":\"huge\",\"payload\":{\"type\":\"dot\",\"x\":[],\"y\":[]}}",
            "{\"kind\":\"dot/hrfna\"}",
            "{\"kind\":\"dot/hrfna\",\"payload\":{\"type\":\"warp\"}}",
            "{\"kind\":\"matmul/hrfna\",\"payload\":{\"type\":\"matmul\",\"a\":[],\"b\":[]}}",
        ] {
            assert!(spec_from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn result_round_trips_including_nan_values() {
        let r = JobResult {
            id: 11,
            kind: JobKind::Rk4Hybrid,
            tier: Tier::Wide,
            values: vec![1.25, f64::NAN],
            latency_us: 123.5,
            batch_size: 16,
            check: None,
        };
        let text = result_to_json(&r).encode();
        assert!(text.contains("null"), "NaN encodes as null: {text}");
        assert!(!text.contains("check"), "unauthenticated frames carry no check");
        let back = result_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, r.id);
        assert_eq!(back.kind, r.kind);
        assert_eq!(back.tier, r.tier);
        assert_eq!(back.values[0], 1.25);
        assert!(back.values[1].is_nan());
        assert_eq!(back.latency_us, 123.5);
        assert_eq!(back.batch_size, 16);
        assert_eq!(back.check, None);
    }

    #[test]
    fn authenticated_result_checksum_survives_the_wire_as_hex() {
        // The checksum is a full-width u64; a JSON number (f64) would
        // destroy bits above 2^53, so it travels as a hex string.
        let check = 0xdead_beef_cafe_f00du64;
        let r = JobResult {
            id: 3,
            kind: JobKind::DotHybrid,
            tier: Tier::Paper,
            values: vec![42.0],
            latency_us: 10.0,
            batch_size: 1,
            check: Some(check),
        };
        let text = result_to_json(&r).encode();
        assert!(text.contains("\"check\":\"deadbeefcafef00d\""), "{text}");
        let back = result_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.check, Some(check));
        assert!(result_from_json(
            &Json::parse(&text.replace("deadbeefcafef00d", "not-hex")).unwrap()
        )
        .is_err());
    }
}
