//! Protocol types: JSON-RPC 2.0-shaped requests/responses plus the
//! serialization of the coordinator's domain types ([`JobSpec`],
//! [`JobResult`], `Tier`, `JobKind`) and the **stable error-code table**
//! that maps every typed [`SubmitError`] and quota/parse failure to a
//! wire code clients can branch on.
//!
//! Compatibility contract (pinned by the golden fixtures in
//! `tests/fixtures/rpc/` and the property tests in `integration_rpc`):
//!
//! * request/response field names and order,
//! * `JobKind::label` / `Tier::label` strings as the kind/tier encodings,
//! * the numeric values in [`ErrorCode`].
//!
//! Changing any of those is a wire break and must version the protocol.

use crate::coordinator::request::{JobKind, JobResult, JobSpec, Payload, SubmitError};
use crate::hybrid::registry::Tier;

use super::json::Json;

/// Protocol version tag carried in every message.
pub const JSONRPC_VERSION: &str = "2.0";

/// Stable wire error codes. Standard JSON-RPC codes for transport/shape
/// errors; `-32000..` implementation range for the coordinator's typed
/// backpressure contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ErrorCode {
    /// Frame payload was not valid JSON.
    ParseError,
    /// JSON was valid but not a well-formed request object.
    InvalidRequest,
    /// Unknown `method`.
    MethodNotFound,
    /// Params failed to decode into the method's types.
    InvalidParams,
    /// Server-side invariant failure (result channel died, ...).
    Internal,
    /// Admission rejected the spec (shape/value/tier-escalation refusal)
    /// — maps `SubmitError::Rejected`.
    Rejected,
    /// Bounded lane queue full — maps `SubmitError::Overloaded`; the
    /// error `data` carries `{kind, tier, queued, capacity}`.
    Overloaded,
    /// Coordinator draining — maps `SubmitError::ShuttingDown`.
    ShuttingDown,
    /// Client exceeded its token-bucket submission rate.
    RateLimited,
    /// Client exceeded its in-flight job quota.
    TooManyInFlight,
}

impl ErrorCode {
    /// Every code (property tests iterate this).
    pub const ALL: [ErrorCode; 10] = [
        ErrorCode::ParseError,
        ErrorCode::InvalidRequest,
        ErrorCode::MethodNotFound,
        ErrorCode::InvalidParams,
        ErrorCode::Internal,
        ErrorCode::Rejected,
        ErrorCode::Overloaded,
        ErrorCode::ShuttingDown,
        ErrorCode::RateLimited,
        ErrorCode::TooManyInFlight,
    ];

    /// The wire value. **Stable**: committed fixtures assert these.
    pub fn code(self) -> i64 {
        match self {
            ErrorCode::ParseError => -32700,
            ErrorCode::InvalidRequest => -32600,
            ErrorCode::MethodNotFound => -32601,
            ErrorCode::InvalidParams => -32602,
            ErrorCode::Internal => -32603,
            ErrorCode::Rejected => -32001,
            ErrorCode::Overloaded => -32002,
            ErrorCode::ShuttingDown => -32003,
            ErrorCode::RateLimited => -32004,
            ErrorCode::TooManyInFlight => -32005,
        }
    }

    /// Inverse of [`ErrorCode::code`].
    pub fn from_code(code: i64) -> Option<ErrorCode> {
        ErrorCode::ALL.iter().copied().find(|c| c.code() == code)
    }

    /// Human label (metrics/log lines).
    pub fn label(self) -> &'static str {
        match self {
            ErrorCode::ParseError => "parse_error",
            ErrorCode::InvalidRequest => "invalid_request",
            ErrorCode::MethodNotFound => "method_not_found",
            ErrorCode::InvalidParams => "invalid_params",
            ErrorCode::Internal => "internal",
            ErrorCode::Rejected => "rejected",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::RateLimited => "rate_limited",
            ErrorCode::TooManyInFlight => "too_many_in_flight",
        }
    }

    /// True for the backpressure codes a well-behaved client answers
    /// with backoff-and-retry (as opposed to fixing its request).
    pub fn is_backpressure(self) -> bool {
        matches!(
            self,
            ErrorCode::Overloaded
                | ErrorCode::ShuttingDown
                | ErrorCode::RateLimited
                | ErrorCode::TooManyInFlight
        )
    }
}

/// The typed-submit-error → wire-code mapping. Total by construction:
/// adding a `SubmitError` variant fails compilation here until it gets a
/// code.
pub fn code_for_submit_error(e: &SubmitError) -> ErrorCode {
    match e {
        SubmitError::Rejected(_) => ErrorCode::Rejected,
        SubmitError::Overloaded { .. } => ErrorCode::Overloaded,
        SubmitError::ShuttingDown => ErrorCode::ShuttingDown,
    }
}

/// A structured wire error.
#[derive(Clone, Debug, PartialEq)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
    /// Machine-readable detail (e.g. `Overloaded` carries queue state).
    pub data: Option<Json>,
}

impl WireError {
    /// Error with no structured data.
    pub fn new(code: ErrorCode, message: impl Into<String>) -> WireError {
        WireError { code, message: message.into(), data: None }
    }

    /// Map a typed submission failure, attaching `Overloaded` queue
    /// state as structured data.
    pub fn from_submit_error(e: &SubmitError) -> WireError {
        let code = code_for_submit_error(e);
        let data = match e {
            SubmitError::Overloaded { kind, tier, queued, capacity } => Some(Json::obj(vec![
                ("kind", Json::str(kind.label())),
                ("tier", Json::str(tier.label())),
                ("queued", Json::Num(*queued as f64)),
                ("capacity", Json::Num(*capacity as f64)),
            ])),
            _ => None,
        };
        WireError { code, message: e.to_string(), data }
    }
}

/// A request frame: `{"jsonrpc":"2.0","id":N,"method":"...","params":...}`.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub method: String,
    pub params: Json,
}

impl Request {
    pub fn new(id: u64, method: &str, params: Json) -> Request {
        Request { id, method: method.to_string(), params }
    }

    /// Deterministic encoding (field order fixed).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jsonrpc", Json::str(JSONRPC_VERSION)),
            ("id", Json::Num(self.id as f64)),
            ("method", Json::str(&self.method)),
            ("params", self.params.clone()),
        ])
    }

    /// Parse a request object. `Err` carries the code the server should
    /// answer with (`InvalidRequest` for shape problems).
    pub fn from_json(v: &Json) -> Result<Request, WireError> {
        let bad = |m: &str| WireError::new(ErrorCode::InvalidRequest, m);
        if v.get("jsonrpc").and_then(Json::as_str) != Some(JSONRPC_VERSION) {
            return Err(bad("missing or unsupported jsonrpc version"));
        }
        let id = v
            .get("id")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad("missing or non-integer id"))?;
        let method = v
            .get("method")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing method"))?
            .to_string();
        let params = v.get("params").cloned().unwrap_or(Json::Null);
        Ok(Request { id, method, params })
    }
}

/// Response payload: a result or a structured error.
#[derive(Clone, Debug, PartialEq)]
pub enum ResponseBody {
    Result(Json),
    Error(WireError),
}

/// A response frame, correlated to its request by `id`.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub id: u64,
    pub body: ResponseBody,
}

impl Response {
    pub fn result(id: u64, value: Json) -> Response {
        Response { id, body: ResponseBody::Result(value) }
    }

    pub fn error(id: u64, err: WireError) -> Response {
        Response { id, body: ResponseBody::Error(err) }
    }

    /// Deterministic encoding:
    /// `{"jsonrpc":"2.0","id":N,"result":...}` or
    /// `{"jsonrpc":"2.0","id":N,"error":{"code":C,"message":"...","data":...}}`
    /// (`data` omitted when absent).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("jsonrpc".to_string(), Json::str(JSONRPC_VERSION)),
            ("id".to_string(), Json::Num(self.id as f64)),
        ];
        match &self.body {
            ResponseBody::Result(v) => fields.push(("result".to_string(), v.clone())),
            ResponseBody::Error(e) => {
                let mut err = vec![
                    ("code".to_string(), Json::Num(e.code.code() as f64)),
                    ("message".to_string(), Json::Str(e.message.clone())),
                ];
                if let Some(d) = &e.data {
                    err.push(("data".to_string(), d.clone()));
                }
                fields.push(("error".to_string(), Json::Obj(err)));
            }
        }
        Json::Obj(fields)
    }

    /// Parse a response object (client side).
    pub fn from_json(v: &Json) -> Result<Response, String> {
        if v.get("jsonrpc").and_then(Json::as_str) != Some(JSONRPC_VERSION) {
            return Err("missing or unsupported jsonrpc version".into());
        }
        let id = v.get("id").and_then(Json::as_u64).ok_or("missing response id")?;
        if let Some(result) = v.get("result") {
            return Ok(Response::result(id, result.clone()));
        }
        let err = v.get("error").ok_or("response has neither result nor error")?;
        let raw_code = err.get("code").and_then(Json::as_i64).ok_or("error without code")?;
        let code = ErrorCode::from_code(raw_code)
            .ok_or_else(|| format!("unknown error code {raw_code}"))?;
        let message = err
            .get("message")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string();
        Ok(Response::error(id, WireError { code, message, data: err.get("data").cloned() }))
    }
}

fn payload_to_json(p: &Payload) -> Json {
    match p {
        Payload::Dot { x, y } => Json::obj(vec![
            ("type", Json::str("dot")),
            ("x", Json::arr_f64(x)),
            ("y", Json::arr_f64(y)),
        ]),
        Payload::Matmul { a, b, dim } => Json::obj(vec![
            ("type", Json::str("matmul")),
            ("dim", Json::Num(*dim as f64)),
            ("a", Json::arr_f64(a)),
            ("b", Json::arr_f64(b)),
        ]),
        Payload::Rk4 { y0, mu, dt, steps } => Json::obj(vec![
            ("type", Json::str("rk4")),
            ("y0", Json::arr_f64(y0)),
            ("mu", Json::Num(*mu)),
            ("dt", Json::Num(*dt)),
            ("steps", Json::Num(*steps as f64)),
        ]),
    }
}

fn payload_from_json(v: &Json) -> Result<Payload, String> {
    let ty = v.get("type").and_then(Json::as_str).ok_or("payload without type")?;
    let vec_field = |k: &str| -> Result<Vec<f64>, String> {
        v.get(k)
            .and_then(Json::f64_vec)
            .ok_or_else(|| format!("payload field {k:?} is not a number array"))
    };
    let num_field = |k: &str| -> Result<f64, String> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("payload field {k:?} is not a number"))
    };
    match ty {
        "dot" => Ok(Payload::Dot { x: vec_field("x")?, y: vec_field("y")? }),
        "matmul" => Ok(Payload::Matmul {
            a: vec_field("a")?,
            b: vec_field("b")?,
            dim: v
                .get("dim")
                .and_then(Json::as_u64)
                .ok_or("matmul payload without integral dim")? as usize,
        }),
        "rk4" => Ok(Payload::Rk4 {
            y0: vec_field("y0")?,
            mu: num_field("mu")?,
            dt: num_field("dt")?,
            steps: v
                .get("steps")
                .and_then(Json::as_u64)
                .ok_or("rk4 payload without integral steps")?,
        }),
        other => Err(format!("unknown payload type {other:?}")),
    }
}

/// Serialize a spec:
/// `{"kind":"dot/hrfna","tier":"paper","tolerance":T,"payload":{...}}`
/// (`tolerance` omitted when `None`).
pub fn spec_to_json(spec: &JobSpec) -> Json {
    let mut fields = vec![
        ("kind".to_string(), Json::str(spec.kind.label())),
        ("tier".to_string(), Json::str(spec.tier.label())),
    ];
    if let Some(tol) = spec.tolerance {
        fields.push(("tolerance".to_string(), Json::Num(tol)));
    }
    fields.push(("payload".to_string(), payload_to_json(&spec.payload)));
    Json::Obj(fields)
}

/// Inverse of [`spec_to_json`].
pub fn spec_from_json(v: &Json) -> Result<JobSpec, String> {
    let kind_label = v.get("kind").and_then(Json::as_str).ok_or("spec without kind")?;
    let kind =
        JobKind::from_label(kind_label).ok_or_else(|| format!("unknown kind {kind_label:?}"))?;
    let tier = match v.get("tier") {
        None => Tier::Paper,
        Some(t) => {
            let label = t.as_str().ok_or("tier is not a string")?;
            Tier::from_label(label).ok_or_else(|| format!("unknown tier {label:?}"))?
        }
    };
    let tolerance = match v.get("tolerance") {
        None | Some(Json::Null) => None,
        Some(t) => Some(t.as_f64().ok_or("tolerance is not a number")?),
    };
    let payload = payload_from_json(v.get("payload").ok_or("spec without payload")?)?;
    Ok(JobSpec { kind, payload, tier, tolerance })
}

/// Serialize a result:
/// `{"id":N,"kind":K,"tier":T,"values":[...],"latency_us":L,"batch_size":B}`.
pub fn result_to_json(r: &JobResult) -> Json {
    Json::obj(vec![
        ("id", Json::Num(r.id as f64)),
        ("kind", Json::str(r.kind.label())),
        ("tier", Json::str(r.tier.label())),
        ("values", Json::arr_f64(&r.values)),
        ("latency_us", Json::Num(r.latency_us)),
        ("batch_size", Json::Num(r.batch_size as f64)),
    ])
}

/// Inverse of [`result_to_json`]. Failed-job NaN sentinels survive the
/// trip as `null` → NaN.
pub fn result_from_json(v: &Json) -> Result<JobResult, String> {
    let kind_label = v.get("kind").and_then(Json::as_str).ok_or("result without kind")?;
    let tier_label = v.get("tier").and_then(Json::as_str).ok_or("result without tier")?;
    Ok(JobResult {
        id: v.get("id").and_then(Json::as_u64).ok_or("result without id")?,
        kind: JobKind::from_label(kind_label)
            .ok_or_else(|| format!("unknown kind {kind_label:?}"))?,
        tier: Tier::from_label(tier_label)
            .ok_or_else(|| format!("unknown tier {tier_label:?}"))?,
        values: v
            .get("values")
            .and_then(Json::f64_vec)
            .ok_or("result without values array")?,
        latency_us: v
            .get("latency_us")
            .and_then(Json::as_f64)
            .ok_or("result without latency_us")?,
        batch_size: v
            .get("batch_size")
            .and_then(Json::as_u64)
            .ok_or("result without batch_size")? as usize,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_are_stable_and_unique() {
        let expect: &[(ErrorCode, i64)] = &[
            (ErrorCode::ParseError, -32700),
            (ErrorCode::InvalidRequest, -32600),
            (ErrorCode::MethodNotFound, -32601),
            (ErrorCode::InvalidParams, -32602),
            (ErrorCode::Internal, -32603),
            (ErrorCode::Rejected, -32001),
            (ErrorCode::Overloaded, -32002),
            (ErrorCode::ShuttingDown, -32003),
            (ErrorCode::RateLimited, -32004),
            (ErrorCode::TooManyInFlight, -32005),
        ];
        assert_eq!(expect.len(), ErrorCode::ALL.len());
        for &(c, n) in expect {
            assert_eq!(c.code(), n, "{}", c.label());
            assert_eq!(ErrorCode::from_code(n), Some(c));
        }
        assert_eq!(ErrorCode::from_code(-1), None);
    }

    #[test]
    fn submit_errors_map_to_backpressure_codes() {
        let overloaded = SubmitError::Overloaded {
            kind: JobKind::DotHybrid,
            tier: Tier::Wide,
            queued: 32,
            capacity: 32,
        };
        let w = WireError::from_submit_error(&overloaded);
        assert_eq!(w.code, ErrorCode::Overloaded);
        assert!(w.code.is_backpressure());
        let data = w.data.unwrap();
        assert_eq!(data.get("kind").unwrap().as_str(), Some("dot/hrfna"));
        assert_eq!(data.get("tier").unwrap().as_str(), Some("wide"));
        assert_eq!(data.get("queued").unwrap().as_u64(), Some(32));
        assert_eq!(data.get("capacity").unwrap().as_u64(), Some(32));

        let rejected = WireError::from_submit_error(&SubmitError::Rejected("bad shape".into()));
        assert_eq!(rejected.code, ErrorCode::Rejected);
        assert!(!rejected.code.is_backpressure());
        assert!(rejected.data.is_none());

        assert_eq!(
            WireError::from_submit_error(&SubmitError::ShuttingDown).code,
            ErrorCode::ShuttingDown,
        );
    }

    #[test]
    fn request_round_trip() {
        let req = Request::new(7, "submit", Json::obj(vec![("kind", Json::str("dot/hrfna"))]));
        let encoded = req.to_json().encode();
        assert!(encoded.starts_with("{\"jsonrpc\":\"2.0\",\"id\":7,\"method\":\"submit\""));
        let back = Request::from_json(&Json::parse(&encoded).unwrap()).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn malformed_requests_yield_invalid_request() {
        for bad in [
            "{}",
            "{\"jsonrpc\":\"1.0\",\"id\":1,\"method\":\"ping\"}",
            "{\"jsonrpc\":\"2.0\",\"method\":\"ping\"}",
            "{\"jsonrpc\":\"2.0\",\"id\":-1,\"method\":\"ping\"}",
            "{\"jsonrpc\":\"2.0\",\"id\":1}",
        ] {
            let err = Request::from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert_eq!(err.code, ErrorCode::InvalidRequest, "{bad}");
        }
    }

    #[test]
    fn response_round_trip_both_arms() {
        let ok = Response::result(3, Json::str("pong"));
        let back = Response::from_json(&Json::parse(&ok.to_json().encode()).unwrap()).unwrap();
        assert_eq!(back, ok);

        let err = Response::error(
            4,
            WireError {
                code: ErrorCode::RateLimited,
                message: "slow down".into(),
                data: Some(Json::Num(12.0)),
            },
        );
        let text = err.to_json().encode();
        assert!(text.contains("\"code\":-32004"));
        let back = Response::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, err);
    }

    #[test]
    fn spec_round_trips_all_payload_kinds() {
        let specs = [
            JobSpec::new(
                JobKind::DotHybrid,
                Payload::Dot { x: vec![1.0, -2.5], y: vec![0.5, 4.0] },
            )
            .with_tier(Tier::Lo)
            .with_tolerance(1e-3),
            JobSpec::new(
                JobKind::MatmulF32,
                Payload::Matmul { a: vec![1.0; 4], b: vec![2.0; 4], dim: 2 },
            ),
            JobSpec::new(
                JobKind::Rk4Hybrid,
                Payload::Rk4 { y0: vec![2.0, 0.0], mu: 1.5, dt: 0.01, steps: 32 },
            )
            .with_tier(Tier::Wide),
        ];
        for spec in &specs {
            let text = spec_to_json(spec).encode();
            let back = spec_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.kind, spec.kind);
            assert_eq!(back.tier, spec.tier);
            assert_eq!(back.tolerance, spec.tolerance);
            assert_eq!(spec_to_json(&back).encode(), text, "canonical re-encode");
        }
        // Tier defaults to paper when absent (old clients).
        let spec = spec_from_json(
            &Json::parse(
                "{\"kind\":\"dot/fp32\",\"payload\":{\"type\":\"dot\",\"x\":[1],\"y\":[2]}}",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(spec.tier, Tier::Paper);
        assert!(spec.tolerance.is_none());
    }

    #[test]
    fn bad_specs_are_decode_errors() {
        for bad in [
            "{\"payload\":{\"type\":\"dot\",\"x\":[],\"y\":[]}}",
            "{\"kind\":\"nope\",\"payload\":{\"type\":\"dot\",\"x\":[],\"y\":[]}}",
            "{\"kind\":\"dot/hrfna\",\"tier\":\"huge\",\"payload\":{\"type\":\"dot\",\"x\":[],\"y\":[]}}",
            "{\"kind\":\"dot/hrfna\"}",
            "{\"kind\":\"dot/hrfna\",\"payload\":{\"type\":\"warp\"}}",
            "{\"kind\":\"matmul/hrfna\",\"payload\":{\"type\":\"matmul\",\"a\":[],\"b\":[]}}",
        ] {
            assert!(spec_from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn result_round_trips_including_nan_values() {
        let r = JobResult {
            id: 11,
            kind: JobKind::Rk4Hybrid,
            tier: Tier::Wide,
            values: vec![1.25, f64::NAN],
            latency_us: 123.5,
            batch_size: 16,
        };
        let text = result_to_json(&r).encode();
        assert!(text.contains("null"), "NaN encodes as null: {text}");
        let back = result_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, r.id);
        assert_eq!(back.kind, r.kind);
        assert_eq!(back.tier, r.tier);
        assert_eq!(back.values[0], 1.25);
        assert!(back.values[1].is_nan());
        assert_eq!(back.latency_us, 123.5);
        assert_eq!(back.batch_size, 16);
    }
}
