//! The network serving edge (`--features rpc`): a length-prefix-framed
//! JSON-RPC protocol over TCP, a std-only async-shim server on top of
//! the [`Coordinator`](crate::coordinator::Coordinator), a pipelining
//! client, and a socket-level load generator.
//!
//! Layering, bottom up:
//!
//! * [`json`] — minimal JSON value/parser/writer (no serde offline;
//!   deterministic encoding is the fixture contract),
//! * [`codec`] — 4-byte big-endian length-prefix framing with
//!   partial-frame buffering for timeout-polled sockets,
//! * [`protocol`] — request/response/error types, the stable error-code
//!   table, and `JobSpec`/`JobResult` (de)serialization,
//! * [`server`] — accept loop + reader/completer thread pair per
//!   connection, per-client token-bucket and in-flight quotas,
//! * [`client`] — persistent-connection client with pipelined submits,
//! * [`load`] — the socket closed loop sharing
//!   [`LoadReport`](crate::coordinator::LoadReport) with the in-process
//!   generators.
//!
//! Everything here is feature-gated; the default (tier-1) build carries
//! only the wire *metrics* (`coordinator::metrics::WireMetrics`) and the
//! label contracts (`JobKind::label`, `Tier::label`) the protocol pins.

pub mod client;
pub mod codec;
pub mod json;
pub mod load;
pub mod protocol;
pub mod server;

pub use client::{RpcClient, SubmitOutcome};
pub use codec::{write_frame, FrameReader, MAX_FRAME_BYTES};
pub use json::Json;
pub use load::{socket_closed_loop, ConnMode};
pub use protocol::{
    code_for_submit_error, result_from_json, result_to_json, spec_from_json, spec_to_json,
    ErrorCode, Request, Response, ResponseBody, WireError,
};
pub use server::{QuotaConfig, RpcServer, RpcServerConfig, TokenBucket};
