//! The network serving edge (`--features rpc`): a length-prefix-framed
//! JSON-RPC protocol over TCP, a std-only async-shim server over any
//! [`Backend`](crate::coordinator::Backend), a pipelining client, and a
//! socket-level load generator.
//!
//! Layering, bottom up:
//!
//! * [`json`] — minimal JSON value/parser/writer (no serde offline;
//!   deterministic encoding is the fixture contract),
//! * [`codec`] — 4-byte big-endian length-prefix framing with
//!   partial-frame buffering for timeout-polled sockets,
//! * [`wire`] — the frame-type discriminator: pure-JSON payloads vs the
//!   binary envelope that ships bulk `f64` arrays as raw little-endian
//!   bytes (negotiated per connection via `hello`; old peers fall back
//!   to pure JSON transparently),
//! * [`protocol`] — request/response types, the (de)serialization of
//!   the unified [`Error`](crate::coordinator::Error) (whose
//!   `wire_code()` is the stable code table), and
//!   `JobSpec`/`JobResult` (de)serialization,
//! * [`server`] — accept loop + reader/completer thread pair per
//!   connection, per-client token-bucket and in-flight quotas; serves
//!   any `Backend`, which is how one binary is both cluster **worker**
//!   (over `InProcess`) and cluster **router** (over
//!   `cluster::ShardRouter`),
//! * [`client`] — persistent-connection client with pipelined submits,
//!   plus [`Remote`], the client wrapped as a `Backend`,
//! * [`load`] — the socket closed loop sharing
//!   [`LoadReport`](crate::coordinator::LoadReport) with the in-process
//!   generators.
//!
//! Everything here is feature-gated; the default (tier-1) build carries
//! only the wire *metrics* (`coordinator::metrics::WireMetrics`), the
//! unified error enum, and the label contracts (`JobKind::label`,
//! `Tier::label`) the protocol pins.

pub mod client;
pub mod codec;
pub mod json;
pub mod load;
pub mod protocol;
pub mod server;
pub mod wire;

pub use client::{batch_outcomes, Remote, RpcClient, SubmitOutcome};
pub use codec::{write_frame, write_frame_capped, FramePoll, FrameReader, MAX_FRAME_BYTES};
pub use wire::{decode_payload, encode_payload, CAP_BINARY};
pub use json::Json;
pub use load::{socket_closed_loop, socket_closed_loop_binary, ConnMode};
#[allow(deprecated)]
pub use protocol::code_for_submit_error;
pub use protocol::{
    error_from_json, error_to_json, result_from_json, result_to_json, spec_from_json,
    spec_to_json, Request, Response, ResponseBody,
};
pub use server::{QuotaConfig, RpcServer, RpcServerConfig, TokenBucket};
