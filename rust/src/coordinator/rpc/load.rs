//! Socket-level load generation: the wire counterpart of
//! `coordinator::serve_load`, reusing [`LoadReport`] so in-process and
//! over-the-wire runs are directly comparable (their ratio *is* the
//! wire overhead, and `bench_rpc` records it).
//!
//! The closed loop holds **one persistent connection per client** for
//! the whole run — the steady-state measurement. [`ConnMode::PerJob`]
//! reconnects for every job purely to quantify the connect overhead the
//! persistent mode avoids; it is not a serving configuration.

use std::time::{Duration, Instant};

use crate::coordinator::request::{JobResult, JobSpec};
use crate::coordinator::serve_load::LoadReport;
use crate::hybrid::auth;

use super::client::RpcClient;

/// Client-side integrity recompute for a delivered result: true when
/// the result carries a checksum and the values no longer hash to it
/// (corruption on the delivery hop that every server-side check ran
/// before).
fn is_corrupted(r: &JobResult) -> bool {
    matches!(r.check, Some(c) if auth::values_checksum(&r.values) != c)
}

/// Connection discipline of the socket closed loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnMode {
    /// One connection per client, reused for every job (the default and
    /// the steady-state benchmark mode).
    Persistent,
    /// A fresh connect/close per job — the anti-pattern the persistent
    /// mode exists to avoid, kept measurable on purpose.
    PerJob,
}

/// How long a client keeps retrying the initial connect (the server may
/// still be binding when the generator starts).
const CONNECT_WAIT: Duration = Duration::from_secs(10);

/// Closed-loop load over the wire: `clients` threads each submit
/// `jobs_per_client` jobs in pipelined bursts of `burst` over TCP to
/// `addr`. `make(client, i)` builds the i-th spec of a client, exactly
/// as in `serve_load::closed_loop` — swap the coordinator handle for an
/// address and a report from one generator is comparable to the other.
///
/// Accounting: a job that comes back with a result counts as
/// accepted+completed; a typed wire error (backpressure, admission,
/// quota) counts as rejected; a transport failure ends that client's
/// run with its remaining jobs uncounted (they were never offered).
pub fn socket_closed_loop(
    addr: &str,
    clients: usize,
    jobs_per_client: usize,
    burst: usize,
    mode: ConnMode,
    make: &(dyn Fn(u64, usize) -> JobSpec + Sync),
) -> LoadReport {
    socket_closed_loop_binary(addr, clients, jobs_per_client, burst, mode, false, make)
}

/// [`socket_closed_loop`] with an encoding choice: when `binary` is
/// set, each persistent client negotiates the binary payload envelope
/// via `hello` before its first job (falling back to pure JSON against
/// a server that predates it). Per-job connections skip negotiation —
/// a hello round trip per connect would swamp the quantity that mode
/// measures.
pub fn socket_closed_loop_binary(
    addr: &str,
    clients: usize,
    jobs_per_client: usize,
    burst: usize,
    mode: ConnMode,
    binary: bool,
    make: &(dyn Fn(u64, usize) -> JobSpec + Sync),
) -> LoadReport {
    let burst = burst.max(1);
    let t0 = Instant::now();
    let results: Vec<(usize, usize, usize, Vec<f64>, usize)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || match mode {
                    ConnMode::Persistent => {
                        run_persistent(addr, c as u64, jobs_per_client, burst, binary, make)
                    }
                    ConnMode::PerJob => run_per_job(addr, c as u64, jobs_per_client, make),
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed();
    let mut offered = 0;
    let mut accepted = 0;
    let mut rejected = 0;
    let mut latencies = Vec::new();
    let mut corrupted = 0;
    for (o, a, r, l, c) in results {
        offered += o;
        accepted += a;
        rejected += r;
        latencies.extend(l);
        corrupted += c;
    }
    let mut report = LoadReport::from_parts(offered, accepted, rejected, latencies, wall);
    report.corrupted = corrupted;
    report
}

/// One client over one persistent connection: fire a burst of pipelined
/// submits, then collect the burst's outcomes.
fn run_persistent(
    addr: &str,
    client: u64,
    jobs: usize,
    burst: usize,
    binary: bool,
    make: &(dyn Fn(u64, usize) -> JobSpec + Sync),
) -> (usize, usize, usize, Vec<f64>, usize) {
    let mut conn = match RpcClient::connect_retry(addr, CONNECT_WAIT) {
        Ok(c) => c,
        Err(_) => return (0, 0, 0, Vec::new(), 0),
    };
    if binary && conn.negotiate_binary().is_err() {
        return (0, 0, 0, Vec::new(), 0);
    }
    let mut offered = 0;
    let mut accepted = 0;
    let mut rejected = 0;
    let mut corrupted = 0;
    let mut latencies = Vec::with_capacity(jobs);
    let mut i = 0;
    while i < jobs {
        let mut fired: Vec<(u64, Instant)> = Vec::with_capacity(burst);
        for _ in 0..burst.min(jobs - i) {
            let spec = make(client, i);
            i += 1;
            offered += 1;
            match conn.submit_spec(&spec) {
                Ok(id) => fired.push((id, Instant::now())),
                Err(_) => {
                    rejected += 1;
                    return (offered, accepted, rejected, latencies, corrupted);
                }
            }
        }
        for (id, fired_at) in fired {
            match conn.wait_submit(id) {
                Ok(Ok(result)) => {
                    accepted += 1;
                    if is_corrupted(&result) {
                        corrupted += 1;
                    }
                    latencies.push(fired_at.elapsed().as_secs_f64() * 1e6);
                }
                Ok(Err(_wire_err)) => rejected += 1,
                Err(_) => {
                    rejected += 1;
                    return (offered, accepted, rejected, latencies, corrupted);
                }
            }
        }
    }
    (offered, accepted, rejected, latencies, corrupted)
}

/// One client reconnecting per job (overhead-measurement mode).
fn run_per_job(
    addr: &str,
    client: u64,
    jobs: usize,
    make: &(dyn Fn(u64, usize) -> JobSpec + Sync),
) -> (usize, usize, usize, Vec<f64>, usize) {
    let mut offered = 0;
    let mut accepted = 0;
    let mut rejected = 0;
    let mut corrupted = 0;
    let mut latencies = Vec::with_capacity(jobs);
    for i in 0..jobs {
        let spec = make(client, i);
        offered += 1;
        let t = Instant::now();
        let mut conn = match RpcClient::connect_retry(addr, CONNECT_WAIT) {
            Ok(c) => c,
            Err(_) => {
                rejected += 1;
                return (offered, accepted, rejected, latencies, corrupted);
            }
        };
        match conn.call(&spec) {
            Ok(Ok(result)) => {
                accepted += 1;
                if is_corrupted(&result) {
                    corrupted += 1;
                }
                latencies.push(t.elapsed().as_secs_f64() * 1e6);
            }
            Ok(Err(_wire_err)) => rejected += 1,
            Err(_) => rejected += 1,
        }
    }
    (offered, accepted, rejected, latencies, corrupted)
}
