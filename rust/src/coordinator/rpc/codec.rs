//! Length-prefix framing: every wire message is a 4-byte big-endian
//! payload length followed by that many bytes of UTF-8 JSON. Framing is
//! independent of the JSON layer — the reader yields raw payload bytes,
//! so malformed JSON inside a well-formed frame is a *protocol* error
//! (answered with `ParseError`), not a connection error.
//!
//! [`FrameReader`] is built for sockets with a read timeout: the server's
//! per-connection reader polls a stop flag between reads, so `read` may
//! return `WouldBlock`/`TimedOut` mid-frame. The reader keeps all
//! partial progress in its buffer across calls and also retains any
//! pipelined bytes beyond the first complete frame, so clients may batch
//! many frames into one TCP segment.

use std::io::{self, Read, Write};

/// Hard cap on a single frame's payload (32 MiB — a 4096-dim matmul pair
/// of f64 lanes encodes to ~12 MiB of JSON, so the cap clears the largest
/// admissible job with headroom while bounding a hostile length prefix).
pub const MAX_FRAME_BYTES: usize = 32 << 20;

/// Byte size of the length prefix.
pub const HEADER_BYTES: usize = 4;

/// Write one frame (length prefix + payload) and flush, enforcing the
/// default [`MAX_FRAME_BYTES`] cap.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    write_frame_capped(w, payload, MAX_FRAME_BYTES)
}

/// Write one frame under an explicit payload cap (the `--max-frame`
/// knob: binary matmul payloads change the size profile, so deployments
/// can raise or shrink the bound without recompiling). The error names
/// both the offending size and the cap in force.
pub fn write_frame_capped(w: &mut impl Write, payload: &[u8], max: usize) -> io::Result<()> {
    if payload.len() > max {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame of {} bytes exceeds cap {}", payload.len(), max),
        ));
    }
    let header = (payload.len() as u32).to_be_bytes();
    // One vectored-ish write keeps small frames in a single segment.
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
    buf.extend_from_slice(&header);
    buf.extend_from_slice(payload);
    w.write_all(&buf)?;
    w.flush()
}

/// Outcome of one [`FrameReader::poll_frame`].
#[derive(Debug)]
pub enum FramePoll {
    /// A complete frame's payload.
    Frame(Vec<u8>),
    /// No complete frame available yet (partial progress is retained).
    Empty,
    /// Peer closed cleanly at a frame boundary.
    Closed,
}

/// Incremental frame reader with partial-progress buffering.
pub struct FrameReader {
    buf: Vec<u8>,
    max: usize,
}

impl Default for FrameReader {
    fn default() -> Self {
        FrameReader::new(MAX_FRAME_BYTES)
    }
}

impl FrameReader {
    /// Reader enforcing `max` payload bytes per frame.
    pub fn new(max: usize) -> FrameReader {
        FrameReader { buf: Vec::new(), max }
    }

    /// Try to pop one complete frame out of the internal buffer.
    fn take_buffered(&mut self) -> io::Result<Option<Vec<u8>>> {
        if self.buf.len() < HEADER_BYTES {
            return Ok(None);
        }
        let len = u32::from_be_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
        if len > self.max {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("frame length {len} exceeds cap {}", self.max),
            ));
        }
        if self.buf.len() < HEADER_BYTES + len {
            return Ok(None);
        }
        let payload = self.buf[HEADER_BYTES..HEADER_BYTES + len].to_vec();
        self.buf.drain(..HEADER_BYTES + len);
        Ok(Some(payload))
    }

    /// One non-blocking-ish poll: pop a buffered frame if one is
    /// complete, otherwise attempt a single read (honoring the stream's
    /// read timeout) and try again. Timeouts are `Empty`, not errors —
    /// the caller distinguishes "no frame yet" from `Closed` (EOF at a
    /// frame boundary), which blocking [`FrameReader::read_frame`]
    /// cannot report separately from a stop request.
    pub fn poll_frame(&mut self, r: &mut impl Read) -> io::Result<FramePoll> {
        if let Some(payload) = self.take_buffered()? {
            return Ok(FramePoll::Frame(payload));
        }
        let mut chunk = [0u8; 16 * 1024];
        match r.read(&mut chunk) {
            Ok(0) => {
                if self.buf.is_empty() {
                    Ok(FramePoll::Closed)
                } else {
                    Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        format!("peer closed mid-frame with {} bytes pending", self.buf.len()),
                    ))
                }
            }
            Ok(n) => {
                self.buf.extend_from_slice(&chunk[..n]);
                Ok(match self.take_buffered()? {
                    Some(payload) => FramePoll::Frame(payload),
                    None => FramePoll::Empty,
                })
            }
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut | io::ErrorKind::Interrupted
                ) =>
            {
                Ok(FramePoll::Empty)
            }
            Err(e) => Err(e),
        }
    }

    /// Read until one complete frame is available, `stop()` turns true,
    /// or the peer closes.
    ///
    /// Returns `Ok(Some(payload))` for a frame, `Ok(None)` for a clean
    /// end (EOF at a frame boundary, or stop requested). EOF in the
    /// middle of a frame is `UnexpectedEof`. Timeout-style errors
    /// (`WouldBlock`/`TimedOut`/`Interrupted`) just re-poll `stop` —
    /// partial frames survive them.
    pub fn read_frame(
        &mut self,
        r: &mut impl Read,
        stop: &dyn Fn() -> bool,
    ) -> io::Result<Option<Vec<u8>>> {
        let mut chunk = [0u8; 16 * 1024];
        loop {
            if let Some(payload) = self.take_buffered()? {
                return Ok(Some(payload));
            }
            if stop() {
                return Ok(None);
            }
            match r.read(&mut chunk) {
                Ok(0) => {
                    return if self.buf.is_empty() {
                        Ok(None)
                    } else {
                        Err(io::Error::new(
                            io::ErrorKind::UnexpectedEof,
                            format!("peer closed mid-frame with {} bytes pending", self.buf.len()),
                        ))
                    };
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock
                            | io::ErrorKind::TimedOut
                            | io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const NEVER: &dyn Fn() -> bool = &|| false;

    fn framed(payloads: &[&[u8]]) -> Vec<u8> {
        let mut out = Vec::new();
        for p in payloads {
            write_frame(&mut out, p).unwrap();
        }
        out
    }

    #[test]
    fn round_trip_single_frame() {
        let wire = framed(&[b"{\"x\":1}"]);
        assert_eq!(&wire[..HEADER_BYTES], &[0, 0, 0, 7]);
        let mut r = FrameReader::default();
        let mut cur = io::Cursor::new(wire);
        assert_eq!(r.read_frame(&mut cur, NEVER).unwrap().unwrap(), b"{\"x\":1}");
        assert!(r.read_frame(&mut cur, NEVER).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn pipelined_frames_in_one_read() {
        let wire = framed(&[b"one", b"", b"three"]);
        let mut r = FrameReader::default();
        let mut cur = io::Cursor::new(wire);
        assert_eq!(r.read_frame(&mut cur, NEVER).unwrap().unwrap(), b"one");
        assert_eq!(r.read_frame(&mut cur, NEVER).unwrap().unwrap(), b"");
        assert_eq!(r.read_frame(&mut cur, NEVER).unwrap().unwrap(), b"three");
        assert!(r.read_frame(&mut cur, NEVER).unwrap().is_none());
    }

    #[test]
    fn mid_frame_eof_is_an_error() {
        let mut wire = framed(&[b"abcdef"]);
        wire.truncate(wire.len() - 2);
        let mut r = FrameReader::default();
        let err = r.read_frame(&mut io::Cursor::new(wire), NEVER).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn oversized_frames_rejected_both_ways() {
        let big = vec![0u8; 9];
        let mut out = Vec::new();
        // Writer-side cap.
        let mut small = FrameReader::new(8);
        write_frame(&mut out, &big).unwrap();
        // Reader-side cap fires from the header alone.
        let err = small.read_frame(&mut io::Cursor::new(out), NEVER).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        let mut w = io::Cursor::new(Vec::new());
        assert!(write_frame(&mut w, &vec![0u8; MAX_FRAME_BYTES + 1]).is_err());
        // The configurable writer cap reports both size and bound.
        let err = write_frame_capped(&mut io::Cursor::new(Vec::new()), &big, 8).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains('9') && msg.contains("cap 8"), "{msg}");
    }

    /// A reader that yields timeouts between single-byte reads — the
    /// worst-case socket. Partial frames must survive.
    struct Trickle {
        data: Vec<u8>,
        i: usize,
        flip: bool,
    }

    impl Read for Trickle {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.flip = !self.flip;
            if self.flip {
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "trickle"));
            }
            if self.i >= self.data.len() {
                return Ok(0);
            }
            buf[0] = self.data[self.i];
            self.i += 1;
            Ok(1)
        }
    }

    #[test]
    fn partial_frames_survive_timeouts() {
        let wire = framed(&[b"slow", b"wire"]);
        let mut t = Trickle { data: wire, i: 0, flip: false };
        let mut r = FrameReader::default();
        assert_eq!(r.read_frame(&mut t, NEVER).unwrap().unwrap(), b"slow");
        assert_eq!(r.read_frame(&mut t, NEVER).unwrap().unwrap(), b"wire");
        assert!(r.read_frame(&mut t, NEVER).unwrap().is_none());
    }

    #[test]
    fn poll_frame_distinguishes_empty_from_closed() {
        // Timeout-only stream: Empty forever, partial progress retained.
        struct Timeouts;
        impl Read for Timeouts {
            fn read(&mut self, _buf: &mut [u8]) -> io::Result<usize> {
                Err(io::Error::new(io::ErrorKind::WouldBlock, "no data"))
            }
        }
        let mut r = FrameReader::default();
        assert!(matches!(r.poll_frame(&mut Timeouts).unwrap(), FramePoll::Empty));

        // Pipelined frames pop one per poll, then EOF is Closed.
        let wire = framed(&[b"a", b"bb"]);
        let mut cur = io::Cursor::new(wire);
        let mut r = FrameReader::default();
        match r.poll_frame(&mut cur).unwrap() {
            FramePoll::Frame(p) => assert_eq!(p, b"a"),
            other => panic!("expected frame, got {other:?}"),
        }
        match r.poll_frame(&mut cur).unwrap() {
            FramePoll::Frame(p) => assert_eq!(p, b"bb"),
            other => panic!("expected frame, got {other:?}"),
        }
        assert!(matches!(r.poll_frame(&mut cur).unwrap(), FramePoll::Closed));

        // Mid-frame EOF is still a hard error.
        let mut wire = framed(&[b"abcdef"]);
        wire.truncate(wire.len() - 2);
        let mut r = FrameReader::default();
        let mut cur = io::Cursor::new(wire);
        let err = loop {
            match r.poll_frame(&mut cur) {
                Ok(_) => continue,
                Err(e) => break e,
            }
        };
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn stop_flag_ends_read_cleanly() {
        struct Forever;
        impl Read for Forever {
            fn read(&mut self, _buf: &mut [u8]) -> io::Result<usize> {
                Err(io::Error::new(io::ErrorKind::WouldBlock, "never data"))
            }
        }
        let mut r = FrameReader::default();
        assert!(r.read_frame(&mut Forever, &|| true).unwrap().is_none());
    }
}
