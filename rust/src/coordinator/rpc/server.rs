//! The RPC server: a std-only async shim over the coordinator. One
//! nonblocking accept loop plus **two threads per connection** — a
//! *reader* that decodes frames, enforces the client's quotas and
//! submits to the coordinator, and a *completer* that owns the socket's
//! write half, waits on the per-job result channels, and writes
//! responses as they complete. Submission therefore never blocks on
//! earlier jobs: a client may pipeline hundreds of `submit` frames and
//! receive the responses out of order (correlated by request id), which
//! is what keeps the coordinator's batcher fed from a single connection.
//!
//! The thread budget is bounded by connections (2/conn), not by jobs —
//! job execution stays on the coordinator's worker pool. This is the
//! same blocking-core/async-edge split darkfi's JSON-RPC server makes,
//! minus the executor dependency.
//!
//! ## Methods
//!
//! | method         | params                    | result                        |
//! |----------------|---------------------------|-------------------------------|
//! | `ping`         | —                         | `"pong"`                      |
//! | `submit`       | spec object               | job-result object             |
//! | `submit_batch` | `{"specs":[spec, ...]}`   | array of per-spec entries     |
//! | `metrics`      | —                         | rendered coordinator + wire tables |
//! | `shutdown`     | —                         | `"draining"` (server drains and exits) |
//!
//! Quotas are per connection (the wire client identity): a token-bucket
//! submission rate (`RateLimited` when dry) and an in-flight cap
//! (`TooManyInFlight`). Both shed load with typed errors instead of
//! stalling the socket, mirroring how the coordinator's bounded queues
//! shed with `Overloaded`.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::metrics::{ClientCounters, WireMetrics};
use crate::coordinator::request::JobResult;
use crate::coordinator::server::Coordinator;

use super::codec::{write_frame, FrameReader, MAX_FRAME_BYTES};
use super::json::Json;
use super::protocol::{
    result_to_json, spec_from_json, ErrorCode, Request, Response, ResponseBody, WireError,
};

/// Per-connection quota limits.
#[derive(Clone, Copy, Debug)]
pub struct QuotaConfig {
    /// Max jobs a connection may have in flight (accepted, result not
    /// yet delivered). 0 disables submission entirely.
    pub max_inflight: usize,
    /// Sustained submissions/second through the token bucket; `<= 0`
    /// means unlimited.
    pub rate_per_s: f64,
    /// Token-bucket depth: the burst a client may submit at line rate.
    pub burst: f64,
}

impl Default for QuotaConfig {
    fn default() -> QuotaConfig {
        QuotaConfig { max_inflight: 256, rate_per_s: 0.0, burst: 64.0 }
    }
}

/// A token bucket: `burst` capacity refilled at `rate_per_s`.
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// Bucket that starts full. `rate_per_s <= 0` disables limiting.
    pub fn new(rate_per_s: f64, burst: f64) -> TokenBucket {
        TokenBucket { rate: rate_per_s, burst: burst.max(1.0), tokens: burst.max(1.0), last: Instant::now() }
    }

    /// Take one token at time `now` (injectable for tests).
    pub fn try_take_at(&mut self, now: Instant) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Take one token now.
    pub fn try_take(&mut self) -> bool {
        self.try_take_at(Instant::now())
    }
}

/// Server tuning.
#[derive(Clone, Copy, Debug)]
pub struct RpcServerConfig {
    /// Per-frame payload cap.
    pub max_frame_bytes: usize,
    /// Per-connection quotas.
    pub quota: QuotaConfig,
    /// Socket read timeout — the interval at which a blocked reader
    /// rechecks the stop flag. Small enough for prompt shutdown, large
    /// enough to stay off the scheduler.
    pub read_timeout: Duration,
}

impl Default for RpcServerConfig {
    fn default() -> RpcServerConfig {
        RpcServerConfig {
            max_frame_bytes: MAX_FRAME_BYTES,
            quota: QuotaConfig::default(),
            read_timeout: Duration::from_millis(50),
        }
    }
}

/// How long the completer waits on an accepted job's result channel
/// before answering `Internal` — matches `serve_load::RESULT_TIMEOUT`'s
/// wedge-detection role.
const PENDING_TIMEOUT: Duration = Duration::from_secs(120);

/// Poll interval of the accept loop's stop check.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Work the reader hands its connection's completer.
enum Work {
    /// A fully-formed response (errors, ping, metrics, ...).
    Respond(Response),
    /// One accepted submission: respond when the result arrives.
    Wait { id: u64, rx: mpsc::Receiver<JobResult> },
    /// A batch: respond when every part resolves. Parts rejected at
    /// submission are already `Ready` error entries.
    WaitBatch { id: u64, parts: Vec<Slot> },
}

/// One entry of a pending response.
enum Slot {
    Wait(mpsc::Receiver<JobResult>),
    Ready(Json),
}

/// A batch entry: `{"result": ...}` or `{"error": {...}}` in the
/// response array.
fn batch_entry_ok(r: &JobResult) -> Json {
    Json::obj(vec![("result", result_to_json(r))])
}

fn batch_entry_err(e: &WireError) -> Json {
    let mut err = vec![
        ("code".to_string(), Json::Num(e.code.code() as f64)),
        ("message".to_string(), Json::Str(e.message.clone())),
    ];
    if let Some(d) = &e.data {
        err.push(("data".to_string(), d.clone()));
    }
    Json::obj(vec![("error", Json::Obj(err))])
}

/// The running RPC server. [`RpcServer::stop`] tears the whole edge down
/// (accept loop, then every connection's thread pair) and returns the
/// wire metrics for reporting.
pub struct RpcServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    drain_requested: Arc<AtomicBool>,
    wire: Arc<WireMetrics>,
    accept_thread: Option<thread::JoinHandle<()>>,
}

impl RpcServer {
    /// Bind `addr` and start serving `coord` in background threads.
    pub fn bind(coord: Arc<Coordinator>, addr: &str, cfg: RpcServerConfig) -> Result<RpcServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr().context("local_addr")?;
        listener.set_nonblocking(true).context("nonblocking listener")?;

        let stop = Arc::new(AtomicBool::new(false));
        let drain_requested = Arc::new(AtomicBool::new(false));
        let wire = Arc::new(WireMetrics::default());

        let accept_thread = {
            let stop = Arc::clone(&stop);
            let drain = Arc::clone(&drain_requested);
            let wire = Arc::clone(&wire);
            thread::Builder::new()
                .name("rpc-accept".into())
                .spawn(move || accept_loop(listener, coord, cfg, stop, drain, wire))
                .context("spawn accept loop")?
        };

        Ok(RpcServer {
            addr: local,
            stop,
            drain_requested,
            wire,
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound address (port resolved when binding `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wire metrics (live).
    pub fn wire_metrics(&self) -> &Arc<WireMetrics> {
        &self.wire
    }

    /// True once a client has called `shutdown` (or `stop` began).
    pub fn shutdown_requested(&self) -> bool {
        self.drain_requested.load(Ordering::SeqCst) || self.stop.load(Ordering::SeqCst)
    }

    /// Block until a `shutdown` request arrives.
    pub fn wait_shutdown(&self) {
        while !self.shutdown_requested() {
            thread::sleep(Duration::from_millis(20));
        }
    }

    /// Stop accepting, drain every connection's in-flight responses, and
    /// join all threads. Returns the wire metrics for reporting.
    pub fn stop(mut self) -> Arc<WireMetrics> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        Arc::clone(&self.wire)
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    cfg: RpcServerConfig,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    wire: Arc<WireMetrics>,
) {
    let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut seq = 0u64;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                seq += 1;
                let label = format!("{peer}#{seq}");
                let coord = Arc::clone(&coord);
                let stop = Arc::clone(&stop);
                let drain = Arc::clone(&drain);
                let wire = Arc::clone(&wire);
                let h = thread::Builder::new()
                    .name(format!("rpc-conn-{seq}"))
                    .spawn(move || serve_conn(stream, label, coord, cfg, stop, drain, wire))
                    .expect("spawn rpc connection thread");
                conns.push(h);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(ACCEPT_POLL);
            }
            // Transient accept errors (e.g. aborted handshake) — keep
            // serving; the listener itself is fine.
            Err(_) => thread::sleep(ACCEPT_POLL),
        }
        // Reap finished connections so the handle list stays bounded.
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
}

/// One connection: runs the reader loop inline, with a completer thread
/// owning the write half.
fn serve_conn(
    stream: TcpStream,
    label: String,
    coord: Arc<Coordinator>,
    cfg: RpcServerConfig,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    wire: Arc<WireMetrics>,
) {
    let counters = wire.register_client(&label);
    let inflight = Arc::new(AtomicUsize::new(0));

    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            wire.record_conn_closed();
            return;
        }
    };
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let _ = stream.set_nodelay(true);
    let _ = write_half.set_nodelay(true);

    let (work_tx, work_rx) = mpsc::channel::<Work>();
    let completer = {
        let wire = Arc::clone(&wire);
        let counters = Arc::clone(&counters);
        let inflight = Arc::clone(&inflight);
        thread::Builder::new()
            .name("rpc-completer".into())
            .spawn(move || completer_loop(write_half, work_rx, wire, counters, inflight))
            .expect("spawn rpc completer thread")
    };

    reader_loop(stream, &coord, &cfg, &stop, &drain, &wire, &counters, &inflight, &work_tx);

    // Dropping the sender lets the completer flush pending responses and
    // exit; join it before declaring the connection closed.
    drop(work_tx);
    let _ = completer.join();
    wire.record_conn_closed();
}

#[allow(clippy::too_many_arguments)]
fn reader_loop(
    mut stream: TcpStream,
    coord: &Coordinator,
    cfg: &RpcServerConfig,
    stop: &AtomicBool,
    drain: &AtomicBool,
    wire: &WireMetrics,
    counters: &ClientCounters,
    inflight: &AtomicUsize,
    work_tx: &mpsc::Sender<Work>,
) {
    let mut frames = FrameReader::new(cfg.max_frame_bytes);
    let mut bucket = TokenBucket::new(cfg.quota.rate_per_s, cfg.quota.burst);
    let stop_fn = || stop.load(Ordering::SeqCst);
    loop {
        let payload = match frames.read_frame(&mut stream, &stop_fn) {
            Ok(Some(p)) => p,
            // Clean close or stop — either way the reader is done.
            Ok(None) => return,
            Err(_) => {
                wire.record_protocol_error();
                return;
            }
        };
        wire.record_frame_in(counters, payload.len());

        let text = match std::str::from_utf8(&payload) {
            Ok(t) => t,
            Err(_) => {
                wire.record_protocol_error();
                respond_err(work_tx, 0, WireError::new(ErrorCode::ParseError, "frame is not UTF-8"));
                continue;
            }
        };
        let value = match Json::parse(text) {
            Ok(v) => v,
            Err(e) => {
                wire.record_protocol_error();
                respond_err(work_tx, 0, WireError::new(ErrorCode::ParseError, e));
                continue;
            }
        };
        let req = match Request::from_json(&value) {
            Ok(r) => r,
            Err(e) => {
                wire.record_protocol_error();
                // Echo the id when the shape at least carried one.
                let id = value.get("id").and_then(Json::as_u64).unwrap_or(0);
                respond_err(work_tx, id, e);
                continue;
            }
        };

        match req.method.as_str() {
            "ping" => {
                let _ = work_tx.send(Work::Respond(Response::result(req.id, Json::str("pong"))));
            }
            "metrics" => {
                let body = Json::obj(vec![
                    ("coordinator", Json::Str(coord.metrics_table().render())),
                    ("wire", Json::Str(wire.table().render())),
                ]);
                let _ = work_tx.send(Work::Respond(Response::result(req.id, body)));
            }
            "shutdown" => {
                drain.store(true, Ordering::SeqCst);
                let _ =
                    work_tx.send(Work::Respond(Response::result(req.id, Json::str("draining"))));
            }
            "submit" => {
                match admit_one(&req.params, coord, cfg, drain, wire, counters, inflight, &mut bucket)
                {
                    Ok(rx) => {
                        let _ = work_tx.send(Work::Wait { id: req.id, rx });
                    }
                    Err(e) => respond_err(work_tx, req.id, e),
                }
            }
            "submit_batch" => {
                let specs = match req.params.get("specs").and_then(Json::as_arr) {
                    Some(s) => s,
                    None => {
                        respond_err(
                            work_tx,
                            req.id,
                            WireError::new(ErrorCode::InvalidParams, "params.specs must be an array"),
                        );
                        continue;
                    }
                };
                let parts: Vec<Slot> = specs
                    .iter()
                    .map(|spec| {
                        match admit_one(spec, coord, cfg, drain, wire, counters, inflight, &mut bucket)
                        {
                            Ok(rx) => Slot::Wait(rx),
                            Err(e) => Slot::Ready(batch_entry_err(&e)),
                        }
                    })
                    .collect();
                let _ = work_tx.send(Work::WaitBatch { id: req.id, parts });
            }
            other => {
                respond_err(
                    work_tx,
                    req.id,
                    WireError::new(ErrorCode::MethodNotFound, format!("unknown method {other:?}")),
                );
            }
        }
    }
}

/// Decode + quota-check + submit one spec. The error is exactly what
/// goes on the wire.
#[allow(clippy::too_many_arguments)]
fn admit_one(
    params: &Json,
    coord: &Coordinator,
    cfg: &RpcServerConfig,
    drain: &AtomicBool,
    wire: &WireMetrics,
    counters: &ClientCounters,
    inflight: &AtomicUsize,
    bucket: &mut TokenBucket,
) -> Result<mpsc::Receiver<JobResult>, WireError> {
    let spec = spec_from_json(params)
        .map_err(|e| WireError::new(ErrorCode::InvalidParams, e))?;
    if drain.load(Ordering::SeqCst) {
        return Err(WireError::new(ErrorCode::ShuttingDown, "server is draining"));
    }
    if !bucket.try_take() {
        wire.record_rate_limited(counters);
        return Err(WireError::new(
            ErrorCode::RateLimited,
            format!("submission rate above {}/s", cfg.quota.rate_per_s),
        ));
    }
    if inflight.load(Ordering::SeqCst) >= cfg.quota.max_inflight {
        wire.record_inflight_limited(counters);
        return Err(WireError::new(
            ErrorCode::TooManyInFlight,
            format!("more than {} jobs in flight", cfg.quota.max_inflight),
        ));
    }
    match coord.submit_spec(spec) {
        Ok(rx) => {
            inflight.fetch_add(1, Ordering::SeqCst);
            wire.record_submit(counters);
            Ok(rx)
        }
        Err(e) => Err(WireError::from_submit_error(&e)),
    }
}

fn respond_err(work_tx: &mpsc::Sender<Work>, id: u64, err: WireError) {
    let _ = work_tx.send(Work::Respond(Response::error(id, err)));
}

/// A response being assembled by the completer.
struct Pending {
    id: u64,
    slots: Vec<Slot>,
    /// Batch responses render as an entry array even for one spec;
    /// single submits render the bare result object.
    batch: bool,
    since: Instant,
}

fn completer_loop(
    mut w: TcpStream,
    work_rx: mpsc::Receiver<Work>,
    wire: Arc<WireMetrics>,
    counters: Arc<ClientCounters>,
    inflight: Arc<AtomicUsize>,
) {
    let mut pending: Vec<Pending> = Vec::new();
    let mut open = true;
    let mut dead = false; // write half failed — stop responding, just drain

    while open || !pending.is_empty() {
        // Take new work; block briefly only when nothing is pending.
        let first = if pending.is_empty() {
            match work_rx.recv_timeout(Duration::from_millis(25)) {
                Ok(wk) => Some(wk),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    None
                }
            }
        } else {
            None
        };
        let mut batch_in: Vec<Work> = first.into_iter().collect();
        loop {
            match work_rx.try_recv() {
                Ok(wk) => batch_in.push(wk),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        for wk in batch_in {
            match wk {
                Work::Respond(resp) => {
                    write_response(&mut w, &resp, &wire, &counters, &mut dead);
                }
                Work::Wait { id, rx } => pending.push(Pending {
                    id,
                    slots: vec![Slot::Wait(rx)],
                    batch: false,
                    since: Instant::now(),
                }),
                Work::WaitBatch { id, parts } => pending.push(Pending {
                    id,
                    slots: parts,
                    batch: true,
                    since: Instant::now(),
                }),
            }
        }

        // Poll pending result channels.
        let mut i = 0;
        while i < pending.len() {
            let timed_out = pending[i].since.elapsed() > PENDING_TIMEOUT;
            let mut all_ready = true;
            for slot in pending[i].slots.iter_mut() {
                if let Slot::Wait(rx) = slot {
                    match rx.try_recv() {
                        Ok(result) => {
                            inflight.fetch_sub(1, Ordering::SeqCst);
                            wire.record_result(&counters);
                            *slot = Slot::Ready(batch_entry_ok(&result));
                        }
                        Err(mpsc::TryRecvError::Empty) if !timed_out => all_ready = false,
                        // Coordinator dropped the reply channel, or the
                        // wait timed out: an internal failure, not a
                        // typed rejection.
                        Err(e) => {
                            inflight.fetch_sub(1, Ordering::SeqCst);
                            let msg = match e {
                                mpsc::TryRecvError::Disconnected => "result channel closed",
                                mpsc::TryRecvError::Empty => "result wait timed out",
                            };
                            *slot = Slot::Ready(batch_entry_err(&WireError::new(
                                ErrorCode::Internal,
                                msg,
                            )));
                        }
                    }
                }
            }
            if all_ready {
                let p = pending.swap_remove(i);
                let resp = assemble(p);
                write_response(&mut w, &resp, &wire, &counters, &mut dead);
            } else {
                i += 1;
            }
        }

        if !pending.is_empty() {
            thread::sleep(Duration::from_micros(200));
        }
    }
    let _ = w.shutdown(std::net::Shutdown::Write);
}

/// Build the final response from resolved slots.
fn assemble(p: Pending) -> Response {
    let ready: Vec<Json> = p
        .slots
        .into_iter()
        .map(|s| match s {
            Slot::Ready(v) => v,
            Slot::Wait(_) => unreachable!("assemble called with unresolved slot"),
        })
        .collect();
    if p.batch {
        return Response::result(p.id, Json::Arr(ready));
    }
    // Single submit: unwrap the {"result": ...} / {"error": ...} entry.
    let entry = ready.into_iter().next().expect("single submit has one slot");
    if let Some(result) = entry.get("result") {
        Response::result(p.id, result.clone())
    } else {
        let err = entry.get("error").expect("entry is result or error");
        let code = err
            .get("code")
            .and_then(Json::as_i64)
            .and_then(ErrorCode::from_code)
            .unwrap_or(ErrorCode::Internal);
        let message = err.get("message").and_then(Json::as_str).unwrap_or_default().to_string();
        Response::error(p.id, WireError { code, message, data: err.get("data").cloned() })
    }
}

fn write_response(
    w: &mut TcpStream,
    resp: &Response,
    wire: &WireMetrics,
    counters: &ClientCounters,
    dead: &mut bool,
) {
    if *dead {
        return;
    }
    if matches!(resp.body, ResponseBody::Error(_)) {
        wire.record_wire_error(counters);
    }
    let payload = resp.to_json().encode();
    if write_frame(w, payload.as_bytes()).is_err() || w.flush().is_err() {
        // Peer is gone; keep draining result channels so inflight
        // accounting stays truthful, but stop writing.
        *dead = true;
    } else {
        wire.record_frame_out(counters, payload.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_enforces_rate_and_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 2.0);
        // Burst of 2, then dry.
        assert!(b.try_take_at(t0));
        assert!(b.try_take_at(t0));
        assert!(!b.try_take_at(t0));
        // 100 ms refills exactly one token at 10/s.
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.try_take_at(t1));
        assert!(!b.try_take_at(t1));
        // Refill clamps at burst: a long idle spell yields 2, not 20.
        let t2 = t1 + Duration::from_secs(60);
        assert!(b.try_take_at(t2));
        assert!(b.try_take_at(t2));
        assert!(!b.try_take_at(t2));
    }

    #[test]
    fn token_bucket_zero_rate_is_unlimited() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(0.0, 1.0);
        for _ in 0..1000 {
            assert!(b.try_take_at(t0));
        }
    }

    #[test]
    fn batch_entries_have_the_documented_shape() {
        let r = JobResult {
            id: 1,
            kind: crate::coordinator::request::JobKind::DotHybrid,
            tier: crate::hybrid::registry::Tier::Paper,
            values: vec![2.0],
            latency_us: 10.0,
            batch_size: 1,
        };
        let ok = batch_entry_ok(&r);
        assert!(ok.get("result").is_some());
        let err = batch_entry_err(&WireError::new(ErrorCode::RateLimited, "slow down"));
        assert_eq!(
            err.get("error").unwrap().get("code").unwrap().as_i64(),
            Some(ErrorCode::RateLimited.code())
        );
    }

    #[test]
    fn assemble_unwraps_single_and_keeps_batch_array() {
        let entry = Json::obj(vec![("result", Json::str("x"))]);
        let single = assemble(Pending {
            id: 5,
            slots: vec![Slot::Ready(entry.clone())],
            batch: false,
            since: Instant::now(),
        });
        assert_eq!(single, Response::result(5, Json::str("x")));

        let batch = assemble(Pending {
            id: 6,
            slots: vec![
                Slot::Ready(entry),
                Slot::Ready(batch_entry_err(&WireError::new(ErrorCode::Overloaded, "full"))),
            ],
            batch: true,
            since: Instant::now(),
        });
        match batch.body {
            ResponseBody::Result(Json::Arr(entries)) => assert_eq!(entries.len(), 2),
            other => panic!("expected array result, got {other:?}"),
        }
    }

    #[test]
    fn assemble_maps_error_entries_to_wire_errors() {
        let resp = assemble(Pending {
            id: 9,
            slots: vec![Slot::Ready(batch_entry_err(&WireError::new(
                ErrorCode::ShuttingDown,
                "draining",
            )))],
            batch: false,
            since: Instant::now(),
        });
        match resp.body {
            ResponseBody::Error(e) => assert_eq!(e.code, ErrorCode::ShuttingDown),
            other => panic!("expected error, got {other:?}"),
        }
    }
}
