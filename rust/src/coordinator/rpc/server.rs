//! The RPC server: a std-only async shim over any [`Backend`]. One
//! nonblocking accept loop plus **two threads per connection** — a
//! *reader* that decodes frames, enforces the client's quotas and
//! submits to the backend, and a *completer* that owns the socket's
//! write half, polls the per-job tickets, and writes responses as they
//! complete. Submission therefore never blocks on earlier jobs: a
//! client may pipeline hundreds of `submit` frames and receive the
//! responses out of order (correlated by request id), which is what
//! keeps the backend's batcher fed from a single connection.
//!
//! The backend is a `dyn Backend`, so the same server binary is the
//! **worker** edge (over [`InProcess`](crate::coordinator::InProcess))
//! and the **router** edge (over
//! `cluster::ShardRouter`) — cluster mode is RpcServer composed twice.
//!
//! The thread budget is bounded by connections (2/conn), not by jobs —
//! job execution stays behind the backend. This is the same
//! blocking-core/async-edge split darkfi's JSON-RPC server makes, minus
//! the executor dependency.
//!
//! ## Methods
//!
//! | method         | params                    | result                        |
//! |----------------|---------------------------|-------------------------------|
//! | `ping`         | —                         | `"pong"`                      |
//! | `hello`        | `{"caps":["bin1", ...]}`  | `{"caps":[granted, ...]}`     |
//! | `submit`       | spec object               | job-result object             |
//! | `submit_batch` | `{"specs":[spec, ...]}`   | array of per-spec entries     |
//! | `metrics`      | —                         | rendered backend + wire tables |
//! | `health`       | —                         | `{"label":L,"queued":N}`      |
//! | `shutdown`     | —                         | `"draining"` (server drains and exits) |
//!
//! `health` is the cluster heartbeat: the router probes it per interval
//! and feeds the queue depth into its occupancy-based diversion.
//!
//! `hello` is the capability exchange: a client offering
//! [`wire::CAP_BINARY`](super::wire::CAP_BINARY) switches the
//! connection's *responses* to the binary payload envelope; requests are
//! accepted in either encoding unconditionally (the frame's first byte
//! discriminates), so negotiation only governs what the server sends.
//! Old clients never say hello and get pure JSON forever.
//!
//! Quotas are per connection (the wire client identity): a token-bucket
//! submission rate (`RateLimited` when dry) and an in-flight cap
//! (`TooManyInFlight`). Both shed load with typed errors instead of
//! stalling the socket, mirroring how the coordinator's bounded queues
//! shed with `Overloaded`.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::backend::{Backend, JobPoll, JobTicket};
use crate::coordinator::error::Error;
use crate::coordinator::metrics::{ClientCounters, WireMetrics};
use crate::coordinator::request::JobResult;

use super::codec::{write_frame_capped, FrameReader, MAX_FRAME_BYTES};
use super::json::Json;
use super::protocol::{
    error_from_json, error_to_json, result_to_json, spec_from_json, Request, Response,
    ResponseBody,
};

/// Per-connection quota limits.
#[derive(Clone, Copy, Debug)]
pub struct QuotaConfig {
    /// Max jobs a connection may have in flight (accepted, result not
    /// yet delivered). 0 disables submission entirely.
    pub max_inflight: usize,
    /// Sustained submissions/second through the token bucket; `<= 0`
    /// means unlimited.
    pub rate_per_s: f64,
    /// Token-bucket depth: the burst a client may submit at line rate.
    pub burst: f64,
}

impl Default for QuotaConfig {
    fn default() -> QuotaConfig {
        QuotaConfig { max_inflight: 256, rate_per_s: 0.0, burst: 64.0 }
    }
}

/// A token bucket: `burst` capacity refilled at `rate_per_s`.
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// Bucket that starts full. `rate_per_s <= 0` disables limiting.
    pub fn new(rate_per_s: f64, burst: f64) -> TokenBucket {
        TokenBucket { rate: rate_per_s, burst: burst.max(1.0), tokens: burst.max(1.0), last: Instant::now() }
    }

    /// Take one token at time `now` (injectable for tests).
    pub fn try_take_at(&mut self, now: Instant) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Take one token now.
    pub fn try_take(&mut self) -> bool {
        self.try_take_at(Instant::now())
    }
}

/// Server tuning.
#[derive(Clone, Copy, Debug)]
pub struct RpcServerConfig {
    /// Per-frame payload cap.
    pub max_frame_bytes: usize,
    /// Per-connection quotas.
    pub quota: QuotaConfig,
    /// Socket read timeout — the interval at which a blocked reader
    /// rechecks the stop flag. Small enough for prompt shutdown, large
    /// enough to stay off the scheduler.
    pub read_timeout: Duration,
}

impl Default for RpcServerConfig {
    fn default() -> RpcServerConfig {
        RpcServerConfig {
            max_frame_bytes: MAX_FRAME_BYTES,
            quota: QuotaConfig::default(),
            read_timeout: Duration::from_millis(50),
        }
    }
}

/// How long the completer waits on an accepted job's ticket before
/// forgetting it and answering `Internal` — matches
/// `serve_load::RESULT_TIMEOUT`'s wedge-detection role.
const PENDING_TIMEOUT: Duration = Duration::from_secs(120);

/// Poll interval of the accept loop's stop check.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Work the reader hands its connection's completer.
enum Work {
    /// A fully-formed response (errors, ping, metrics, ...).
    Respond(Response),
    /// One accepted submission: respond when the ticket resolves.
    Wait { id: u64, ticket: JobTicket },
    /// A batch: respond when every part resolves. Parts rejected at
    /// submission are already `Ready` error entries.
    WaitBatch { id: u64, parts: Vec<Slot> },
}

/// One entry of a pending response.
enum Slot {
    Wait(JobTicket),
    Ready(Json),
}

/// A batch entry: `{"result": ...}` or `{"error": {...}}` in the
/// response array.
///
/// Under `fault-inject`, authenticated results (those carrying a
/// `check` checksum) may have one value bit-flipped here — *after* the
/// worker computed the checksum, *before* serialization — modelling
/// corruption on the serving edge itself. The router's checksum
/// recompute is the cover for exactly this window.
fn batch_entry_ok(r: &JobResult) -> Json {
    #[cfg(feature = "fault-inject")]
    if r.check.is_some() && !r.values.is_empty() {
        if let Some(pick) = crate::util::faults::global().and_then(|inj| inj.draw()) {
            let mut r = r.clone();
            let i = (pick as usize >> 24) % r.values.len();
            r.values[i] = crate::util::faults::flip_f64_high_bit(r.values[i], pick);
            return Json::obj(vec![("result", result_to_json(&r))]);
        }
    }
    Json::obj(vec![("result", result_to_json(r))])
}

fn batch_entry_err(e: &Error) -> Json {
    Json::obj(vec![("error", error_to_json(e))])
}

/// The running RPC server. [`RpcServer::stop`] tears the whole edge down
/// (accept loop, then every connection's thread pair) and returns the
/// wire metrics for reporting.
pub struct RpcServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    drain_requested: Arc<AtomicBool>,
    wire: Arc<WireMetrics>,
    accept_thread: Option<thread::JoinHandle<()>>,
}

impl RpcServer {
    /// Bind `addr` and start serving `backend` in background threads.
    pub fn bind(
        backend: Arc<dyn Backend>,
        addr: &str,
        cfg: RpcServerConfig,
    ) -> Result<RpcServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr().context("local_addr")?;
        listener.set_nonblocking(true).context("nonblocking listener")?;

        let stop = Arc::new(AtomicBool::new(false));
        let drain_requested = Arc::new(AtomicBool::new(false));
        let wire = Arc::new(WireMetrics::default());

        let accept_thread = {
            let stop = Arc::clone(&stop);
            let drain = Arc::clone(&drain_requested);
            let wire = Arc::clone(&wire);
            thread::Builder::new()
                .name("rpc-accept".into())
                .spawn(move || accept_loop(listener, backend, cfg, stop, drain, wire))
                .context("spawn accept loop")?
        };

        Ok(RpcServer {
            addr: local,
            stop,
            drain_requested,
            wire,
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound address (port resolved when binding `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wire metrics (live).
    pub fn wire_metrics(&self) -> &Arc<WireMetrics> {
        &self.wire
    }

    /// True once a client has called `shutdown` (or `stop` began).
    pub fn shutdown_requested(&self) -> bool {
        self.drain_requested.load(Ordering::SeqCst) || self.stop.load(Ordering::SeqCst)
    }

    /// Block until a `shutdown` request arrives.
    pub fn wait_shutdown(&self) {
        while !self.shutdown_requested() {
            thread::sleep(Duration::from_millis(20));
        }
    }

    /// Stop accepting, drain every connection's in-flight responses, and
    /// join all threads. Returns the wire metrics for reporting.
    pub fn stop(mut self) -> Arc<WireMetrics> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        Arc::clone(&self.wire)
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    backend: Arc<dyn Backend>,
    cfg: RpcServerConfig,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    wire: Arc<WireMetrics>,
) {
    let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut seq = 0u64;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                seq += 1;
                let label = format!("{peer}#{seq}");
                let backend = Arc::clone(&backend);
                let stop = Arc::clone(&stop);
                let drain = Arc::clone(&drain);
                let wire = Arc::clone(&wire);
                let h = thread::Builder::new()
                    .name(format!("rpc-conn-{seq}"))
                    .spawn(move || serve_conn(stream, label, backend, cfg, stop, drain, wire))
                    .expect("spawn rpc connection thread");
                conns.push(h);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(ACCEPT_POLL);
            }
            // Transient accept errors (e.g. aborted handshake) — keep
            // serving; the listener itself is fine.
            Err(_) => thread::sleep(ACCEPT_POLL),
        }
        // Reap finished connections so the handle list stays bounded.
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
}

/// One connection: runs the reader loop inline, with a completer thread
/// owning the write half.
fn serve_conn(
    stream: TcpStream,
    label: String,
    backend: Arc<dyn Backend>,
    cfg: RpcServerConfig,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    wire: Arc<WireMetrics>,
) {
    let counters = wire.register_client(&label);
    let inflight = Arc::new(AtomicUsize::new(0));
    // Set by the reader when `hello` grants binary framing; read by the
    // completer for every response it encodes thereafter.
    let binary = Arc::new(AtomicBool::new(false));

    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            wire.record_conn_closed();
            return;
        }
    };
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let _ = stream.set_nodelay(true);
    let _ = write_half.set_nodelay(true);

    let (work_tx, work_rx) = mpsc::channel::<Work>();
    let completer = {
        let backend = Arc::clone(&backend);
        let wire = Arc::clone(&wire);
        let counters = Arc::clone(&counters);
        let inflight = Arc::clone(&inflight);
        let binary = Arc::clone(&binary);
        let max_frame = cfg.max_frame_bytes;
        thread::Builder::new()
            .name("rpc-completer".into())
            .spawn(move || {
                // A panic in the completer (codec bug, poisoned lock)
                // must not take the process down — it costs this one
                // connection, is counted, and the socket closes.
                let wire2 = Arc::clone(&wire);
                let body = std::panic::AssertUnwindSafe(move || {
                    completer_loop(
                        write_half, work_rx, backend, wire, counters, inflight, binary, max_frame,
                    )
                });
                if std::panic::catch_unwind(body).is_err() {
                    wire2.record_conn_panic();
                    eprintln!("[rpc] completer thread panicked; connection dropped");
                }
            })
            .expect("spawn rpc completer thread")
    };

    {
        let body = std::panic::AssertUnwindSafe(|| {
            reader_loop(
                stream, &*backend, &cfg, &stop, &drain, &wire, &counters, &inflight, &binary,
                &work_tx,
            )
        });
        if std::panic::catch_unwind(body).is_err() {
            wire.record_conn_panic();
            eprintln!("[rpc] reader thread panicked; connection dropped");
        }
    }

    // Dropping the sender lets the completer flush pending responses and
    // exit; join it before declaring the connection closed.
    drop(work_tx);
    let _ = completer.join();
    wire.record_conn_closed();
}

#[allow(clippy::too_many_arguments)]
fn reader_loop(
    mut stream: TcpStream,
    backend: &dyn Backend,
    cfg: &RpcServerConfig,
    stop: &AtomicBool,
    drain: &AtomicBool,
    wire: &WireMetrics,
    counters: &ClientCounters,
    inflight: &AtomicUsize,
    binary: &AtomicBool,
    work_tx: &mpsc::Sender<Work>,
) {
    let mut frames = FrameReader::new(cfg.max_frame_bytes);
    let mut bucket = TokenBucket::new(cfg.quota.rate_per_s, cfg.quota.burst);
    let stop_fn = || stop.load(Ordering::SeqCst);
    loop {
        let payload = match frames.read_frame(&mut stream, &stop_fn) {
            Ok(Some(p)) => p,
            // Clean close or stop — either way the reader is done.
            Ok(None) => return,
            Err(_) => {
                wire.record_protocol_error();
                return;
            }
        };
        wire.record_frame_in_encoded(counters, payload.len(), super::wire::is_binary(&payload));

        // Requests are accepted in either encoding regardless of what
        // `hello` negotiated — the first payload byte discriminates.
        let value = match super::wire::decode_payload(&payload) {
            Ok(v) => v,
            Err(e) => {
                wire.record_protocol_error();
                respond_err(work_tx, 0, Error::Parse(e));
                continue;
            }
        };
        let req = match Request::from_json(&value) {
            Ok(r) => r,
            Err(e) => {
                wire.record_protocol_error();
                // Echo the id when the shape at least carried one.
                let id = value.get("id").and_then(Json::as_u64).unwrap_or(0);
                respond_err(work_tx, id, e);
                continue;
            }
        };

        match req.method.as_str() {
            "ping" => {
                let _ = work_tx.send(Work::Respond(Response::result(req.id, Json::str("pong"))));
            }
            "hello" => {
                // Grant the intersection of the client's offered caps
                // and ours; unknown caps are ignored, not errors, so
                // future clients can offer more without breaking us.
                let offered = req.params.get("caps").and_then(Json::as_arr);
                let grant_binary = offered.map_or(false, |caps| {
                    caps.iter().any(|c| c.as_str() == Some(super::wire::CAP_BINARY))
                });
                let mut granted = Vec::new();
                if grant_binary {
                    binary.store(true, Ordering::SeqCst);
                    granted.push(Json::str(super::wire::CAP_BINARY));
                }
                let body = Json::obj(vec![("caps", Json::Arr(granted))]);
                let _ = work_tx.send(Work::Respond(Response::result(req.id, body)));
            }
            "metrics" => {
                let body = Json::obj(vec![
                    ("coordinator", Json::Str(backend.metrics_text())),
                    ("wire", Json::Str(wire.table().render())),
                ]);
                let _ = work_tx.send(Work::Respond(Response::result(req.id, body)));
            }
            "health" => {
                let body = Json::obj(vec![
                    ("label", Json::str(backend.label())),
                    ("queued", Json::Num(backend.queue_depth() as f64)),
                    (
                        "integrity_detections",
                        Json::Num(backend.integrity_detections() as f64),
                    ),
                    ("quarantined", Json::Num(backend.quarantined_workers() as f64)),
                ]);
                let _ = work_tx.send(Work::Respond(Response::result(req.id, body)));
            }
            "shutdown" => {
                drain.store(true, Ordering::SeqCst);
                let _ =
                    work_tx.send(Work::Respond(Response::result(req.id, Json::str("draining"))));
            }
            "submit" => {
                match admit_one(&req.params, backend, cfg, drain, wire, counters, inflight, &mut bucket)
                {
                    Ok(ticket) => {
                        let _ = work_tx.send(Work::Wait { id: req.id, ticket });
                    }
                    Err(e) => respond_err(work_tx, req.id, e),
                }
            }
            "submit_batch" => {
                let specs = match req.params.get("specs").and_then(Json::as_arr) {
                    Some(s) => s,
                    None => {
                        respond_err(
                            work_tx,
                            req.id,
                            Error::InvalidParams("params.specs must be an array".into()),
                        );
                        continue;
                    }
                };
                let parts: Vec<Slot> = specs
                    .iter()
                    .map(|spec| {
                        match admit_one(spec, backend, cfg, drain, wire, counters, inflight, &mut bucket)
                        {
                            Ok(ticket) => Slot::Wait(ticket),
                            Err(e) => Slot::Ready(batch_entry_err(&e)),
                        }
                    })
                    .collect();
                let _ = work_tx.send(Work::WaitBatch { id: req.id, parts });
            }
            other => {
                respond_err(
                    work_tx,
                    req.id,
                    Error::MethodNotFound(format!("unknown method {other:?}")),
                );
            }
        }
    }
}

/// Decode + quota-check + submit one spec. The error is exactly what
/// goes on the wire.
#[allow(clippy::too_many_arguments)]
fn admit_one(
    params: &Json,
    backend: &dyn Backend,
    cfg: &RpcServerConfig,
    drain: &AtomicBool,
    wire: &WireMetrics,
    counters: &ClientCounters,
    inflight: &AtomicUsize,
    bucket: &mut TokenBucket,
) -> Result<JobTicket, Error> {
    let spec = spec_from_json(params).map_err(Error::InvalidParams)?;
    if drain.load(Ordering::SeqCst) {
        return Err(Error::ShuttingDown);
    }
    if !bucket.try_take() {
        wire.record_rate_limited(counters);
        return Err(Error::RateLimited(format!(
            "submission rate above {}/s",
            cfg.quota.rate_per_s
        )));
    }
    if inflight.load(Ordering::SeqCst) >= cfg.quota.max_inflight {
        wire.record_inflight_limited(counters);
        return Err(Error::TooManyInFlight(format!(
            "more than {} jobs in flight",
            cfg.quota.max_inflight
        )));
    }
    let ticket = backend.submit(spec)?;
    inflight.fetch_add(1, Ordering::SeqCst);
    wire.record_submit(counters);
    Ok(ticket)
}

fn respond_err(work_tx: &mpsc::Sender<Work>, id: u64, err: Error) {
    let _ = work_tx.send(Work::Respond(Response::error(id, err)));
}

/// A response being assembled by the completer.
struct Pending {
    id: u64,
    slots: Vec<Slot>,
    /// Batch responses render as an entry array even for one spec;
    /// single submits render the bare result object.
    batch: bool,
    since: Instant,
}

#[allow(clippy::too_many_arguments)]
fn completer_loop(
    mut w: TcpStream,
    work_rx: mpsc::Receiver<Work>,
    backend: Arc<dyn Backend>,
    wire: Arc<WireMetrics>,
    counters: Arc<ClientCounters>,
    inflight: Arc<AtomicUsize>,
    binary: Arc<AtomicBool>,
    max_frame: usize,
) {
    let mut pending: Vec<Pending> = Vec::new();
    let mut open = true;
    let mut dead = false; // write half failed — stop responding, just drain

    while open || !pending.is_empty() {
        // Take new work; block briefly only when nothing is pending.
        let first = if pending.is_empty() {
            match work_rx.recv_timeout(Duration::from_millis(25)) {
                Ok(wk) => Some(wk),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    None
                }
            }
        } else {
            None
        };
        let mut batch_in: Vec<Work> = first.into_iter().collect();
        loop {
            match work_rx.try_recv() {
                Ok(wk) => batch_in.push(wk),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        for wk in batch_in {
            match wk {
                Work::Respond(resp) => {
                    let bin = binary.load(Ordering::SeqCst);
                    write_response(&mut w, &resp, &wire, &counters, bin, max_frame, &mut dead);
                }
                Work::Wait { id, ticket } => pending.push(Pending {
                    id,
                    slots: vec![Slot::Wait(ticket)],
                    batch: false,
                    since: Instant::now(),
                }),
                Work::WaitBatch { id, parts } => pending.push(Pending {
                    id,
                    slots: parts,
                    batch: true,
                    since: Instant::now(),
                }),
            }
        }

        // Poll pending tickets.
        let mut i = 0;
        while i < pending.len() {
            let timed_out = pending[i].since.elapsed() > PENDING_TIMEOUT;
            let mut all_ready = true;
            for slot in pending[i].slots.iter_mut() {
                if let Slot::Wait(ticket) = slot {
                    match backend.poll(ticket) {
                        JobPoll::Ready(Ok(result)) => {
                            inflight.fetch_sub(1, Ordering::SeqCst);
                            wire.record_result(&counters);
                            *slot = Slot::Ready(batch_entry_ok(&result));
                        }
                        // The backend lost the job (channel closed,
                        // worker link died): a typed completion error.
                        JobPoll::Ready(Err(e)) => {
                            inflight.fetch_sub(1, Ordering::SeqCst);
                            *slot = Slot::Ready(batch_entry_err(&e));
                        }
                        JobPoll::Pending if !timed_out => all_ready = false,
                        // Wait timed out: abandon the ticket so the
                        // backend releases its result channel.
                        JobPoll::Pending => {
                            backend.forget(ticket);
                            inflight.fetch_sub(1, Ordering::SeqCst);
                            *slot = Slot::Ready(batch_entry_err(&Error::Internal(
                                "result wait timed out".into(),
                            )));
                        }
                    }
                }
            }
            if all_ready {
                let p = pending.swap_remove(i);
                let resp = assemble(p);
                let bin = binary.load(Ordering::SeqCst);
                write_response(&mut w, &resp, &wire, &counters, bin, max_frame, &mut dead);
            } else {
                i += 1;
            }
        }

        if !pending.is_empty() {
            thread::sleep(Duration::from_micros(200));
        }
    }
    let _ = w.shutdown(std::net::Shutdown::Write);
}

/// Build the final response from resolved slots.
fn assemble(p: Pending) -> Response {
    let ready: Vec<Json> = p
        .slots
        .into_iter()
        .map(|s| match s {
            Slot::Ready(v) => v,
            Slot::Wait(_) => unreachable!("assemble called with unresolved slot"),
        })
        .collect();
    if p.batch {
        return Response::result(p.id, Json::Arr(ready));
    }
    // Single submit: unwrap the {"result": ...} / {"error": ...} entry.
    let entry = ready.into_iter().next().expect("single submit has one slot");
    if let Some(result) = entry.get("result") {
        Response::result(p.id, result.clone())
    } else {
        let err = entry.get("error").expect("entry is result or error");
        let err = error_from_json(err)
            .unwrap_or_else(|e| Error::Internal(format!("undecodable error entry: {e}")));
        Response::error(p.id, err)
    }
}

fn write_response(
    w: &mut TcpStream,
    resp: &Response,
    wire: &WireMetrics,
    counters: &ClientCounters,
    binary: bool,
    max_frame: usize,
    dead: &mut bool,
) {
    if *dead {
        return;
    }
    if matches!(resp.body, ResponseBody::Error(_)) {
        wire.record_wire_error(counters);
    }
    let payload = super::wire::encode_payload(&resp.to_json(), binary);
    if write_frame_capped(w, &payload, max_frame).is_err() || w.flush().is_err() {
        // Peer is gone; keep draining tickets so inflight accounting
        // stays truthful, but stop writing.
        *dead = true;
    } else {
        // Small responses stay pure JSON even on a binary connection —
        // classify by what actually went on the wire.
        wire.record_frame_out_encoded(counters, payload.len(), super::wire::is_binary(&payload));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::JobKind;
    use crate::hybrid::registry::Tier;

    #[test]
    fn token_bucket_enforces_rate_and_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 2.0);
        // Burst of 2, then dry.
        assert!(b.try_take_at(t0));
        assert!(b.try_take_at(t0));
        assert!(!b.try_take_at(t0));
        // 100 ms refills exactly one token at 10/s.
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.try_take_at(t1));
        assert!(!b.try_take_at(t1));
        // Refill clamps at burst: a long idle spell yields 2, not 20.
        let t2 = t1 + Duration::from_secs(60);
        assert!(b.try_take_at(t2));
        assert!(b.try_take_at(t2));
        assert!(!b.try_take_at(t2));
    }

    #[test]
    fn token_bucket_zero_rate_is_unlimited() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(0.0, 1.0);
        for _ in 0..1000 {
            assert!(b.try_take_at(t0));
        }
    }

    #[test]
    fn batch_entries_have_the_documented_shape() {
        let r = JobResult {
            id: 1,
            kind: JobKind::DotHybrid,
            tier: Tier::Paper,
            values: vec![2.0],
            latency_us: 10.0,
            batch_size: 1,
            check: None,
        };
        let ok = batch_entry_ok(&r);
        assert!(ok.get("result").is_some());
        let err = batch_entry_err(&Error::RateLimited("slow down".into()));
        assert_eq!(
            err.get("error").unwrap().get("code").unwrap().as_i64(),
            Some(-32004)
        );
    }

    #[test]
    fn assemble_unwraps_single_and_keeps_batch_array() {
        let entry = Json::obj(vec![("result", Json::str("x"))]);
        let single = assemble(Pending {
            id: 5,
            slots: vec![Slot::Ready(entry.clone())],
            batch: false,
            since: Instant::now(),
        });
        assert_eq!(single, Response::result(5, Json::str("x")));

        let overloaded = Error::Overloaded {
            kind: JobKind::DotHybrid,
            tier: Tier::Paper,
            queued: 8,
            capacity: 8,
        };
        let batch = assemble(Pending {
            id: 6,
            slots: vec![Slot::Ready(entry), Slot::Ready(batch_entry_err(&overloaded))],
            batch: true,
            since: Instant::now(),
        });
        match batch.body {
            ResponseBody::Result(Json::Arr(entries)) => assert_eq!(entries.len(), 2),
            other => panic!("expected array result, got {other:?}"),
        }
    }

    #[test]
    fn assemble_rebuilds_typed_error_entries() {
        let resp = assemble(Pending {
            id: 9,
            slots: vec![Slot::Ready(batch_entry_err(&Error::ShuttingDown))],
            batch: false,
            since: Instant::now(),
        });
        match resp.body {
            ResponseBody::Error(e) => assert_eq!(e, Error::ShuttingDown),
            other => panic!("expected error, got {other:?}"),
        }
    }
}
