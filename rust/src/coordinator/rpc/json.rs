//! Minimal JSON value, parser and writer (the offline registry has no
//! serde; this module is the serialization substrate of the wire
//! protocol, exactly as `util::bench` hand-rolls the `BENCH_*.json`
//! records).
//!
//! Guarantees the protocol layer relies on:
//!
//! * **Deterministic encoding** — objects serialize in insertion order
//!   with no whitespace, so a given protocol value has exactly one wire
//!   encoding (the golden fixtures in `tests/fixtures/rpc/` pin it).
//! * **Round-trip-exact numbers** — finite `f64`s are written with
//!   Rust's shortest-round-trip formatting; integral values within the
//!   exact-`f64` window are written without a fractional part. Non-finite
//!   values (a failed job reports `NaN`) encode as `null`; decoders that
//!   expect a float lane use [`Json::as_f64_or_nan`].
//! * **Bounded recursion** — parsing rejects nesting deeper than
//!   [`MAX_DEPTH`] instead of overflowing the stack on hostile input.

use std::fmt::Write as _;

/// Maximum nesting depth the parser accepts.
pub const MAX_DEPTH: usize = 128;

/// Largest magnitude at which every integer is exactly representable in
/// `f64` (2^53); integral numbers below it are encoded without `.0`.
const EXACT_INT: f64 = 9_007_199_254_740_992.0;

/// A parsed JSON value. Objects keep insertion order (`Vec`, not a map)
/// so encoding is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object from (key, value) pairs, preserving order.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// String value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Array of numbers; non-finite entries become `null` (the wire has
    /// no NaN literal).
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v)).collect())
    }

    /// Field of an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Finite number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Number or `null`-as-NaN — the decode of [`Json::arr_f64`] lanes.
    pub fn as_f64_or_nan(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            Json::Null => Some(f64::NAN),
            _ => None,
        }
    }

    /// Non-negative integral number.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v < EXACT_INT => Some(*v as u64),
            _ => None,
        }
    }

    /// Integral number.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(v) if v.fract() == 0.0 && v.abs() < EXACT_INT => Some(*v as i64),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// Array elements.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs.as_slice()),
            _ => None,
        }
    }

    /// Boolean value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Decode an f64 vector from an array field ( `null` → NaN).
    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64_or_nan).collect()
    }

    /// Compact deterministic encoding.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out);
        out
    }

    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => {
                if !v.is_finite() {
                    out.push_str("null");
                } else if v.fract() == 0.0 && v.abs() < EXACT_INT {
                    // `-0.0` intentionally collapses to `0`.
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    // Shortest decimal that round-trips to the same f64.
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (exactly one value, trailing whitespace
    /// allowed).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", c as char, self.i))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek() {
            None => Err("unexpected end of input".into()),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut xs = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                loop {
                    self.skip_ws();
                    xs.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(xs));
                        }
                        _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut fields = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    self.skip_ws();
                    let v = self.value(depth + 1)?;
                    fields.push((k, v));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
                    }
                }
            }
            Some(_) => self.number(),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number bytes");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?} at offset {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            // Fast path: copy a run of plain UTF-8 bytes verbatim.
            let run = self.i;
            while self
                .peek()
                .map(|c| c != b'"' && c != b'\\' && c >= 0x20)
                .unwrap_or(false)
            {
                self.i += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.b[run..self.i])
                    .map_err(|e| format!("invalid utf-8 in string: {e}"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: require the low half.
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("bad low surrogate".into());
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(char::from_u32(cp).ok_or("bad \\u code point")?);
                        }
                        other => return Err(format!("bad escape '\\{}'", other as char)),
                    }
                }
                _ => return Err("unterminated string".into()),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| "non-ascii \\u escape".to_string())?;
        self.i += 4;
        u32::from_str_radix(s, 16).map_err(|e| format!("bad \\u escape: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn scalars_round_trip() {
        for text in ["null", "true", "false", "0", "-7", "1.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(v.encode(), text);
        }
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::Num(1000.0).encode(), "1000");
    }

    #[test]
    fn nested_structures_round_trip_deterministically() {
        let v = Json::obj(vec![
            ("b", Json::Num(2.0)),
            ("a", Json::Arr(vec![Json::Null, Json::Bool(true), Json::str("x\"y\\z")])),
            ("o", Json::obj(vec![("k", Json::Num(-0.25))])),
        ]);
        let text = v.encode();
        // Insertion order preserved — "b" stays first.
        assert_eq!(text, "{\"b\":2,\"a\":[null,true,\"x\\\"y\\\\z\"],\"o\":{\"k\":-0.25}}");
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = Json::parse("{\"n\":3,\"s\":\"t\",\"a\":[1,null],\"b\":false}").unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("n").unwrap().as_i64(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("t"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("missing"), None);
        let lane = v.get("a").unwrap().f64_vec().unwrap();
        assert_eq!(lane[0], 1.0);
        assert!(lane[1].is_nan(), "null decodes to NaN in f64 lanes");
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_i64(), Some(-1));
    }

    #[test]
    fn nan_and_infinity_encode_as_null() {
        assert_eq!(Json::Num(f64::NAN).encode(), "null");
        assert_eq!(Json::Num(f64::INFINITY).encode(), "null");
        assert_eq!(Json::arr_f64(&[1.0, f64::NAN]).encode(), "[1,null]");
    }

    #[test]
    fn string_escapes_round_trip() {
        let s = "line\nbreak\ttab \"quote\" back\\slash µ ∞ \u{0001}";
        let enc = Json::Str(s.to_string()).encode();
        assert_eq!(Json::parse(&enc).unwrap().as_str(), Some(s));
        // Standard escapes parse.
        assert_eq!(Json::parse("\"\\u00e9\\/\"").unwrap().as_str(), Some("é/"));
        // Surrogate pair.
        assert_eq!(Json::parse("\"\\ud83d\\ude00\"").unwrap().as_str(), Some("😀"));
    }

    #[test]
    fn malformed_inputs_are_errors_not_panics() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "tru", "\"unterminated", "1.2.3", "[]x",
            "\"\\ud83d\"", "\"\\q\"", "{\"a\":1,}",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
        let deep = "[".repeat(MAX_DEPTH + 2) + &"]".repeat(MAX_DEPTH + 2);
        assert!(Json::parse(&deep).is_err(), "depth limit enforced");
    }

    #[test]
    fn numbers_round_trip_shortest() {
        check("json f64 round-trip", |rng| {
            let v = match rng.below(4) {
                0 => rng.uniform(-1.0, 1.0),
                1 => rng.uniform(-1e9, 1e9),
                2 => rng.range_i64(-1_000_000, 1_000_000) as f64,
                _ => rng.lognormal(0.0, 4.0) * rng.sign(),
            };
            let back = Json::parse(&Json::Num(v).encode())
                .map_err(|e| e.to_string())?
                .as_f64()
                .ok_or("not a number")?;
            crate::prop_assert!(back == v, "{v} -> {back}");
            Ok(())
        });
    }
}
