//! A blocking RPC client over one persistent connection, with pipelined
//! submission: `submit_spec` fires a frame and returns the request id
//! immediately, responses are collected (possibly out of order) by
//! `wait`/`next_response`/`try_response`. The socket load generator
//! drives the server exclusively through this type, and the
//! `rpc_pipeline` example shows the intended call shape.
//!
//! [`Remote`] wraps the client in the
//! [`Backend`](crate::coordinator::Backend) trait, so `serve_load` and
//! the benches can drive a network server through the same API as the
//! in-process coordinator.

use std::collections::HashMap;
use std::io;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::backend::{Backend, JobPoll, JobTicket};
use crate::coordinator::error::Error;
use crate::coordinator::request::{JobResult, JobSpec};
use crate::coordinator::server::DrainReport;
use crate::util::backoff::Backoff;

use super::codec::{write_frame, FramePoll, FrameReader};
use super::json::Json;
use super::protocol::{
    error_from_json, result_from_json, spec_to_json, Request, Response, ResponseBody,
};
use super::wire;

/// Read timeout used by [`RpcClient::try_response`] — one scheduling
/// quantum of patience, so a poll costs at most ~1 ms when the wire is
/// silent.
const TRY_READ_TIMEOUT: Duration = Duration::from_millis(1);

/// One persistent client connection.
pub struct RpcClient {
    stream: TcpStream,
    frames: FrameReader,
    next_id: u64,
    /// Responses that arrived while waiting for a different id.
    stash: HashMap<u64, Response>,
    /// Binary payload framing granted by the server's `hello` reply.
    /// Off until [`RpcClient::negotiate_binary`] succeeds, so a client
    /// that never negotiates speaks the pre-binary protocol verbatim.
    binary: bool,
}

/// Outcome of one submitted job: the result, or the server's typed
/// error for it.
pub type SubmitOutcome = std::result::Result<JobResult, Error>;

impl RpcClient {
    /// Connect once.
    pub fn connect(addr: &str) -> Result<RpcClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(RpcClient {
            stream,
            frames: FrameReader::default(),
            next_id: 1,
            stash: HashMap::new(),
            binary: false,
        })
    }

    /// Connect with retries over `total_wait` (the CI smoke test races
    /// the server's bind; a refused connection just means "not yet").
    /// Retries back off exponentially with jitter — N clients racing the
    /// same bind don't re-knock in lockstep — and the last sleep is
    /// clamped to the deadline.
    pub fn connect_retry(addr: &str, total_wait: Duration) -> Result<RpcClient> {
        let deadline = Instant::now() + total_wait;
        let mut backoff = Backoff::for_reconnect(Backoff::seed_for(addr));
        loop {
            match RpcClient::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(e.context(format!("server at {addr} never came up")));
                    }
                    std::thread::sleep(backoff.next_delay().min(deadline - now));
                }
            }
        }
    }

    fn send(&mut self, method: &str, params: Json) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = wire::encode_payload(&Request::new(id, method, params).to_json(), self.binary);
        write_frame(&mut self.stream, &frame).context("write request frame")?;
        Ok(id)
    }

    /// Whether the connection negotiated binary payload framing.
    pub fn binary(&self) -> bool {
        self.binary
    }

    /// Offer the server our capabilities (`hello`) and switch to binary
    /// payload framing if it grants [`wire::CAP_BINARY`]. A server
    /// predating `hello` answers method-not-found — that is a version
    /// mismatch, not a protocol error, and the connection stays on pure
    /// JSON. Returns whether binary framing is now active.
    pub fn negotiate_binary(&mut self) -> Result<bool> {
        let params =
            Json::obj(vec![("caps", Json::Arr(vec![Json::str(wire::CAP_BINARY)]))]);
        let resp = self.request("hello", params)?;
        match resp.body {
            ResponseBody::Result(v) => {
                let granted = v.get("caps").and_then(Json::as_arr).map_or(false, |caps| {
                    caps.iter().any(|c| c.as_str() == Some(wire::CAP_BINARY))
                });
                self.binary = granted;
                Ok(granted)
            }
            ResponseBody::Error(Error::MethodNotFound(_)) => Ok(false),
            ResponseBody::Error(e) => bail!("hello failed: {e}"),
        }
    }

    /// Read one response frame (blocking until the server answers).
    fn read_response(&mut self) -> Result<Response> {
        let never = || false;
        match self.frames.read_frame(&mut self.stream, &never) {
            Ok(Some(payload)) => decode_response(&payload),
            Ok(None) => bail!("server closed the connection"),
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
                bail!("server closed mid-frame")
            }
            Err(e) => Err(e).context("read response frame"),
        }
    }

    /// The next response from the wire, in arrival order (stashed
    /// responses are not consulted — use [`RpcClient::wait`] for
    /// correlation).
    pub fn next_response(&mut self) -> Result<Response> {
        self.read_response()
    }

    /// Non-blocking probe: one stashed or arrived response, or `None`
    /// when the wire is silent (after at most [`TRY_READ_TIMEOUT`]).
    /// A closed connection is an error, not `None`.
    pub fn try_response(&mut self) -> Result<Option<Response>> {
        self.stream
            .set_read_timeout(Some(TRY_READ_TIMEOUT))
            .context("set poll read timeout")?;
        let polled = self.frames.poll_frame(&mut self.stream);
        self.stream.set_read_timeout(None).context("clear poll read timeout")?;
        match polled {
            Ok(FramePoll::Frame(payload)) => Ok(Some(decode_response(&payload)?)),
            Ok(FramePoll::Empty) => Ok(None),
            Ok(FramePoll::Closed) => bail!("server closed the connection"),
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
                bail!("server closed mid-frame")
            }
            Err(e) => Err(e).context("poll response frame"),
        }
    }

    /// Non-blocking correlation probe: the response for `id` if it has
    /// arrived (stashing others that land first).
    pub fn try_take(&mut self, id: u64) -> Result<Option<Response>> {
        if let Some(r) = self.stash.remove(&id) {
            return Ok(Some(r));
        }
        while let Some(r) = self.try_response()? {
            if r.id == id {
                return Ok(Some(r));
            }
            self.stash.insert(r.id, r);
        }
        Ok(None)
    }

    /// Block until the response for `id` arrives, stashing any other
    /// ids that land first.
    pub fn wait(&mut self, id: u64) -> Result<Response> {
        if let Some(r) = self.stash.remove(&id) {
            return Ok(r);
        }
        loop {
            let r = self.read_response()?;
            if r.id == id {
                return Ok(r);
            }
            self.stash.insert(r.id, r);
        }
    }

    /// One blocking round trip.
    pub fn request(&mut self, method: &str, params: Json) -> Result<Response> {
        let id = self.send(method, params)?;
        self.wait(id)
    }

    /// Fire one submission without waiting; returns the request id to
    /// pass to [`RpcClient::wait_submit`]. This is the pipelining
    /// primitive: many fires, then collect.
    pub fn submit_spec(&mut self, spec: &JobSpec) -> Result<u64> {
        self.send("submit", spec_to_json(spec))
    }

    /// Collect one submission's outcome: the job result, or the typed
    /// error the server shed it with.
    pub fn wait_submit(&mut self, id: u64) -> Result<SubmitOutcome> {
        let resp = self.wait(id)?;
        submit_outcome(resp)
    }

    /// Blocking submit: fire and wait.
    pub fn call(&mut self, spec: &JobSpec) -> Result<SubmitOutcome> {
        let id = self.submit_spec(spec)?;
        self.wait_submit(id)
    }

    /// Fire a whole batch as one `submit_batch` frame without waiting;
    /// returns the request id to pass to
    /// [`RpcClient::wait_submit_batch`]. This is the coalescing
    /// primitive the cluster router flushes through.
    pub fn submit_batch_spec(&mut self, specs: &[JobSpec]) -> Result<u64> {
        let params = Json::obj(vec![(
            "specs",
            Json::Arr(specs.iter().map(spec_to_json).collect()),
        )]);
        self.send("submit_batch", params)
    }

    /// Collect a fired batch's per-spec outcomes, in submission order.
    pub fn wait_submit_batch(&mut self, id: u64) -> Result<Vec<SubmitOutcome>> {
        batch_outcomes(self.wait(id)?)
    }

    /// Submit a whole batch in one frame; returns per-spec outcomes in
    /// order.
    pub fn submit_batch(&mut self, specs: &[JobSpec]) -> Result<Vec<SubmitOutcome>> {
        let id = self.submit_batch_spec(specs)?;
        self.wait_submit_batch(id)
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        let resp = self.request("ping", Json::Null)?;
        match resp.body {
            ResponseBody::Result(v) if v.as_str() == Some("pong") => Ok(()),
            other => bail!("unexpected ping response: {other:?}"),
        }
    }

    /// The server's health snapshot: (backend label, total queued jobs).
    /// This is the cluster heartbeat the router's monitor loop calls.
    pub fn health(&mut self) -> Result<(String, i64)> {
        let resp = self.request("health", Json::Null)?;
        match resp.body {
            ResponseBody::Result(v) => {
                let label = v
                    .get("label")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("health without label"))?
                    .to_string();
                let queued = v
                    .get("queued")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| anyhow!("health without queued"))?;
                Ok((label, queued))
            }
            ResponseBody::Error(e) => bail!("health failed: {e}"),
        }
    }

    /// Extended health fields added with authenticated serving:
    /// `(lifetime integrity detections, quarantined workers)`. Servers
    /// predating these fields report `(0, 0)` — absence is not an
    /// error, so the probe stays compatible across versions.
    pub fn health_integrity(&mut self) -> Result<(u64, u64)> {
        let resp = self.request("health", Json::Null)?;
        match resp.body {
            ResponseBody::Result(v) => {
                let detections = v
                    .get("integrity_detections")
                    .and_then(Json::as_u64)
                    .unwrap_or(0);
                let quarantined = v.get("quarantined").and_then(Json::as_u64).unwrap_or(0);
                Ok((detections, quarantined))
            }
            ResponseBody::Error(e) => bail!("health failed: {e}"),
        }
    }

    /// Fetch the server's rendered metrics tables (coordinator + wire).
    pub fn server_metrics(&mut self) -> Result<(String, String)> {
        let resp = self.request("metrics", Json::Null)?;
        match resp.body {
            ResponseBody::Result(v) => {
                let coord = v
                    .get("coordinator")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("metrics without coordinator table"))?
                    .to_string();
                let wire = v
                    .get("wire")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("metrics without wire table"))?
                    .to_string();
                Ok((coord, wire))
            }
            ResponseBody::Error(e) => bail!("metrics failed: {e}"),
        }
    }

    /// Ask the server to drain and exit.
    pub fn shutdown_server(&mut self) -> Result<()> {
        let resp = self.request("shutdown", Json::Null)?;
        match resp.body {
            ResponseBody::Result(v) if v.as_str() == Some("draining") => Ok(()),
            other => bail!("unexpected shutdown response: {other:?}"),
        }
    }
}

fn decode_response(payload: &[u8]) -> Result<Response> {
    let v = wire::decode_payload(payload).map_err(|e| anyhow!("bad response payload: {e}"))?;
    Response::from_json(&v).map_err(|e| anyhow!("bad response frame: {e}"))
}

/// Parse a `submit_batch` response into per-spec outcomes, in order.
/// Shared by [`RpcClient::wait_submit_batch`] and the cluster router's
/// coalesced-flush resolution (which correlates batch responses by wire
/// id itself).
pub fn batch_outcomes(resp: Response) -> Result<Vec<SubmitOutcome>> {
    let entries = match resp.body {
        ResponseBody::Result(Json::Arr(entries)) => entries,
        ResponseBody::Error(e) => bail!("submit_batch failed wholesale: {e}"),
        other => bail!("submit_batch returned a non-array: {other:?}"),
    };
    entries
        .iter()
        .map(|entry| {
            if let Some(v) = entry.get("result") {
                let r = result_from_json(v).map_err(|e| anyhow!("bad job result: {e}"))?;
                Ok(Ok(r))
            } else if let Some(err) = entry.get("error") {
                let e = error_from_json(err).map_err(|e| anyhow!("bad batch error: {e}"))?;
                Ok(Err(e))
            } else {
                bail!("batch entry is neither result nor error")
            }
        })
        .collect()
}

fn submit_outcome(resp: Response) -> Result<SubmitOutcome> {
    match resp.body {
        ResponseBody::Result(v) => {
            let r = result_from_json(&v).map_err(|e| anyhow!("bad job result: {e}"))?;
            Ok(Ok(r))
        }
        ResponseBody::Error(e) => Ok(Err(e)),
    }
}

/// [`Backend`] over one RPC connection: the remote twin of
/// [`InProcess`](crate::coordinator::InProcess). Tickets are the wire
/// request ids; transport failures surface as [`Error::Unavailable`]
/// (the job may never have executed — backpressure, not a result).
///
/// `shutdown` asks the server to drain, then synthesizes a
/// [`DrainReport`] from this client's own counters: `accepted` is what
/// it fired, `completed` what it collected, `dropped` what it abandoned
/// — so the clean-drain invariant (`dropped == 0`) means *this client*
/// lost nothing, independent of other clients on the same server.
pub struct Remote {
    client: Mutex<RpcClient>,
    addr: String,
    /// Wire ids fired and not yet collected (the live ticket set).
    pending: Mutex<std::collections::HashSet<u64>>,
    submitted: AtomicU64,
    collected: AtomicU64,
    errored: AtomicU64,
    abandoned: AtomicU64,
}

impl Remote {
    /// Connect (with retry) and wrap, speaking pure JSON.
    pub fn connect(addr: &str, total_wait: Duration) -> std::result::Result<Remote, Error> {
        Remote::connect_with(addr, total_wait, false)
    }

    /// Connect (with retry) and wrap; when `binary` is set, offer the
    /// server binary payload framing via `hello` (falling back to pure
    /// JSON against servers that predate it).
    pub fn connect_with(
        addr: &str,
        total_wait: Duration,
        binary: bool,
    ) -> std::result::Result<Remote, Error> {
        let mut client = RpcClient::connect_retry(addr, total_wait)
            .map_err(|e| Error::Unavailable(format!("{addr}: {e:#}")))?;
        if binary {
            client
                .negotiate_binary()
                .map_err(|e| Error::Unavailable(format!("{addr}: {e:#}")))?;
        }
        Ok(Remote {
            client: Mutex::new(client),
            addr: addr.to_string(),
            pending: Mutex::new(std::collections::HashSet::new()),
            submitted: AtomicU64::new(0),
            collected: AtomicU64::new(0),
            errored: AtomicU64::new(0),
            abandoned: AtomicU64::new(0),
        })
    }

    fn unavailable(&self, e: anyhow::Error) -> Error {
        Error::Unavailable(format!("{}: {e:#}", self.addr))
    }
}

impl Backend for Remote {
    fn label(&self) -> &'static str {
        "rpc-client"
    }

    fn submit(&self, spec: JobSpec) -> std::result::Result<JobTicket, Error> {
        let mut client = self.client.lock().expect("client lock");
        let id = client.submit_spec(&spec).map_err(|e| self.unavailable(e))?;
        self.pending.lock().expect("pending lock").insert(id);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(JobTicket { id })
    }

    fn poll(&self, ticket: &JobTicket) -> JobPoll {
        if !self.pending.lock().expect("pending lock").contains(&ticket.id) {
            return JobPoll::Ready(Err(Error::Internal("unknown ticket".into())));
        }
        let polled = self.client.lock().expect("client lock").try_take(ticket.id);
        match polled {
            Ok(None) => JobPoll::Pending,
            Ok(Some(resp)) => {
                self.pending.lock().expect("pending lock").remove(&ticket.id);
                self.collected.fetch_add(1, Ordering::Relaxed);
                match submit_outcome(resp) {
                    Ok(Ok(r)) => JobPoll::Ready(Ok(r)),
                    Ok(Err(e)) => {
                        self.errored.fetch_add(1, Ordering::Relaxed);
                        JobPoll::Ready(Err(e))
                    }
                    Err(e) => JobPoll::Ready(Err(Error::Internal(format!("{e:#}")))),
                }
            }
            Err(e) => {
                self.pending.lock().expect("pending lock").remove(&ticket.id);
                self.abandoned.fetch_add(1, Ordering::Relaxed);
                JobPoll::Ready(Err(self.unavailable(e)))
            }
        }
    }

    fn forget(&self, ticket: &JobTicket) {
        if self.pending.lock().expect("pending lock").remove(&ticket.id) {
            self.abandoned.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn metrics_text(&self) -> String {
        match self.client.lock().expect("client lock").server_metrics() {
            Ok((coord, wire)) => format!("{coord}\n{wire}"),
            Err(e) => format!("metrics unavailable: {e:#}"),
        }
    }

    fn queue_depth(&self) -> i64 {
        self.client
            .lock()
            .expect("client lock")
            .health()
            .map(|(_, queued)| queued)
            .unwrap_or(0)
    }

    fn integrity_detections(&self) -> u64 {
        self.client
            .lock()
            .expect("client lock")
            .health_integrity()
            .map(|(d, _)| d)
            .unwrap_or(0)
    }

    fn quarantined_workers(&self) -> u64 {
        self.client
            .lock()
            .expect("client lock")
            .health_integrity()
            .map(|(_, q)| q)
            .unwrap_or(0)
    }

    fn shutdown(&self) -> std::result::Result<DrainReport, Error> {
        {
            let mut client = self.client.lock().expect("client lock");
            client.shutdown_server().map_err(|e| self.unavailable(e))?;
        }
        let uncollected = self.pending.lock().expect("pending lock").len() as u64;
        let submitted = self.submitted.load(Ordering::Relaxed);
        let collected = self.collected.load(Ordering::Relaxed);
        Ok(DrainReport {
            accepted: submitted,
            completed: collected,
            rejected: self.errored.load(Ordering::Relaxed),
            drained: 0,
            dropped: self.abandoned.load(Ordering::Relaxed) + uncollected,
        })
    }
}
