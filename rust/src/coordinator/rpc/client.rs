//! A blocking RPC client over one persistent connection, with pipelined
//! submission: `submit_spec` fires a frame and returns the request id
//! immediately, responses are collected (possibly out of order) by
//! `wait`/`next_response`. The socket load generator drives the server
//! exclusively through this type, and the `rpc_pipeline` example shows
//! the intended call shape.

use std::collections::HashMap;
use std::io;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::request::{JobResult, JobSpec};

use super::codec::{write_frame, FrameReader};
use super::json::Json;
use super::protocol::{
    result_from_json, spec_to_json, Request, Response, ResponseBody, WireError,
};

/// One persistent client connection.
pub struct RpcClient {
    stream: TcpStream,
    frames: FrameReader,
    next_id: u64,
    /// Responses that arrived while waiting for a different id.
    stash: HashMap<u64, Response>,
}

/// Outcome of one submitted job: the result, or the server's typed
/// error for it.
pub type SubmitOutcome = std::result::Result<JobResult, WireError>;

impl RpcClient {
    /// Connect once.
    pub fn connect(addr: &str) -> Result<RpcClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(RpcClient {
            stream,
            frames: FrameReader::default(),
            next_id: 1,
            stash: HashMap::new(),
        })
    }

    /// Connect with retries over `total_wait` (the CI smoke test races
    /// the server's bind; a refused connection just means "not yet").
    pub fn connect_retry(addr: &str, total_wait: Duration) -> Result<RpcClient> {
        let deadline = Instant::now() + total_wait;
        loop {
            match RpcClient::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e.context(format!("server at {addr} never came up")));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    fn send(&mut self, method: &str, params: Json) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = Request::new(id, method, params).to_json().encode();
        write_frame(&mut self.stream, frame.as_bytes()).context("write request frame")?;
        Ok(id)
    }

    /// Read one response frame (blocking until the server answers).
    fn read_response(&mut self) -> Result<Response> {
        let never = || false;
        match self.frames.read_frame(&mut self.stream, &never) {
            Ok(Some(payload)) => {
                let text = std::str::from_utf8(&payload).context("response is not UTF-8")?;
                let v = Json::parse(text).map_err(|e| anyhow!("bad response JSON: {e}"))?;
                Response::from_json(&v).map_err(|e| anyhow!("bad response frame: {e}"))
            }
            Ok(None) => bail!("server closed the connection"),
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
                bail!("server closed mid-frame")
            }
            Err(e) => Err(e).context("read response frame"),
        }
    }

    /// The next response from the wire, in arrival order (stashed
    /// responses are not consulted — use [`RpcClient::wait`] for
    /// correlation).
    pub fn next_response(&mut self) -> Result<Response> {
        self.read_response()
    }

    /// Block until the response for `id` arrives, stashing any other
    /// ids that land first.
    pub fn wait(&mut self, id: u64) -> Result<Response> {
        if let Some(r) = self.stash.remove(&id) {
            return Ok(r);
        }
        loop {
            let r = self.read_response()?;
            if r.id == id {
                return Ok(r);
            }
            self.stash.insert(r.id, r);
        }
    }

    /// One blocking round trip.
    pub fn request(&mut self, method: &str, params: Json) -> Result<Response> {
        let id = self.send(method, params)?;
        self.wait(id)
    }

    /// Fire one submission without waiting; returns the request id to
    /// pass to [`RpcClient::wait_submit`]. This is the pipelining
    /// primitive: many fires, then collect.
    pub fn submit_spec(&mut self, spec: &JobSpec) -> Result<u64> {
        self.send("submit", spec_to_json(spec))
    }

    /// Collect one submission's outcome: the job result, or the typed
    /// wire error the server shed it with.
    pub fn wait_submit(&mut self, id: u64) -> Result<SubmitOutcome> {
        let resp = self.wait(id)?;
        match resp.body {
            ResponseBody::Result(v) => {
                let r = result_from_json(&v).map_err(|e| anyhow!("bad job result: {e}"))?;
                Ok(Ok(r))
            }
            ResponseBody::Error(e) => Ok(Err(e)),
        }
    }

    /// Blocking submit: fire and wait.
    pub fn call(&mut self, spec: &JobSpec) -> Result<SubmitOutcome> {
        let id = self.submit_spec(spec)?;
        self.wait_submit(id)
    }

    /// Submit a whole batch in one frame; returns per-spec outcomes in
    /// order.
    pub fn submit_batch(&mut self, specs: &[JobSpec]) -> Result<Vec<SubmitOutcome>> {
        let params = Json::obj(vec![(
            "specs",
            Json::Arr(specs.iter().map(spec_to_json).collect()),
        )]);
        let resp = self.request("submit_batch", params)?;
        let entries = match resp.body {
            ResponseBody::Result(Json::Arr(entries)) => entries,
            ResponseBody::Error(e) => bail!("submit_batch failed wholesale: {}", e.message),
            other => bail!("submit_batch returned a non-array: {other:?}"),
        };
        entries
            .iter()
            .map(|entry| {
                if let Some(v) = entry.get("result") {
                    let r = result_from_json(v).map_err(|e| anyhow!("bad job result: {e}"))?;
                    Ok(Ok(r))
                } else if let Some(err) = entry.get("error") {
                    let code = err
                        .get("code")
                        .and_then(Json::as_i64)
                        .and_then(super::protocol::ErrorCode::from_code)
                        .ok_or_else(|| anyhow!("batch error entry without known code"))?;
                    let message =
                        err.get("message").and_then(Json::as_str).unwrap_or_default().to_string();
                    Ok(Err(WireError { code, message, data: err.get("data").cloned() }))
                } else {
                    bail!("batch entry is neither result nor error")
                }
            })
            .collect()
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        let resp = self.request("ping", Json::Null)?;
        match resp.body {
            ResponseBody::Result(v) if v.as_str() == Some("pong") => Ok(()),
            other => bail!("unexpected ping response: {other:?}"),
        }
    }

    /// Fetch the server's rendered metrics tables (coordinator + wire).
    pub fn server_metrics(&mut self) -> Result<(String, String)> {
        let resp = self.request("metrics", Json::Null)?;
        match resp.body {
            ResponseBody::Result(v) => {
                let coord = v
                    .get("coordinator")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("metrics without coordinator table"))?
                    .to_string();
                let wire = v
                    .get("wire")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("metrics without wire table"))?
                    .to_string();
                Ok((coord, wire))
            }
            ResponseBody::Error(e) => bail!("metrics failed: {}", e.message),
        }
    }

    /// Ask the server to drain and exit.
    pub fn shutdown_server(&mut self) -> Result<()> {
        let resp = self.request("shutdown", Json::Null)?;
        match resp.body {
            ResponseBody::Result(v) if v.as_str() == Some("draining") => Ok(()),
            other => bail!("unexpected shutdown response: {other:?}"),
        }
    }
}
