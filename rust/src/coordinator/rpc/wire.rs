//! Binary payload envelope: the frame-type discriminator that lets one
//! length-prefixed frame ([`super::codec`]) carry its bulk `f64` arrays
//! as raw little-endian bytes instead of decimal text.
//!
//! A frame's payload is one of two encodings, told apart by the first
//! byte:
//!
//! * **Pure JSON** — the PR 6 wire format, unchanged byte for byte. A
//!   JSON document starts with `{`, `[`, a digit, `"`, `t`, `f`, `n` or
//!   whitespace — never [`BIN_MAGIC`] (`0xBF`, an invalid UTF-8 start
//!   byte), so the discriminator costs nothing and old peers keep
//!   working.
//! * **Binary envelope** — `[0xBF][version=1]` followed by a
//!   `u32-LE`-length-prefixed JSON *control document* and a blob table:
//!   `u32 LE blob_count`, then per blob `u32 LE n` and `n` little-endian
//!   `f64`s. The control document is the ordinary JSON-RPC message with
//!   every bulk numeric array (length ≥ [`MIN_BLOB`], numbers/nulls
//!   only) replaced by the placeholder object `{"$bin":i,"n":len}`
//!   naming blob `i`.
//!
//! ## Bit-identity across encodings
//!
//! [`decode_payload`] of a binary envelope yields the *same [`Json`]
//! tree* that `Json::parse` yields for the pure-JSON encoding of the
//! same message, so everything downstream (spec/result decode,
//! execution, checksums) is structurally unable to differ:
//!
//! * non-finite values encode as `null` in JSON; a blob stores them as a
//!   canonical quiet NaN and decode maps NaN back to [`Json::Null`],
//! * `-0.0` collapses to `0` in JSON text; a blob stores the `+0.0` bits,
//! * every other finite `f64` round-trips its exact bits through either
//!   encoding (shortest-round-trip text on the JSON side, raw bits on
//!   the binary side).
//!
//! Small control frames (`ping`, `health`, errors — nothing worth
//! extracting) stay pure JSON even on a binary-negotiated connection;
//! [`encode_payload`] only pays for the envelope when a blob exists.
//!
//! Negotiation lives in the client/server `hello` exchange (capability
//! [`CAP_BINARY`]): a server that answers `hello` with the capability
//! may send binary response frames, a client that sent it may send
//! binary requests, and either side silently accepts binary frames
//! regardless (decode branches on the magic byte alone) — old peers
//! never see one.

use super::json::Json;

/// First payload byte of a binary envelope. `0xBF` is an invalid UTF-8
/// start byte, so no JSON text frame can begin with it.
pub const BIN_MAGIC: u8 = 0xBF;

/// Envelope version this build writes and accepts.
pub const BIN_VERSION: u8 = 1;

/// Wire capability token exchanged in `hello`.
pub const CAP_BINARY: &str = "bin1";

/// Smallest numeric array worth extracting into a blob: below this the
/// placeholder object costs about as much as the digits it saves.
pub const MIN_BLOB: usize = 8;

/// Placeholder key naming an extracted blob. No protocol message uses a
/// `$`-prefixed field, so a placeholder can't collide with real traffic.
const BIN_KEY: &str = "$bin";

/// True when `payload` is a binary envelope (vs pure JSON text).
pub fn is_binary(payload: &[u8]) -> bool {
    payload.first() == Some(&BIN_MAGIC)
}

/// The exact bits a blob stores for `v` — chosen so binary decode equals
/// JSON text round-trip: non-finite collapses to the canonical quiet NaN
/// (JSON writes `null`, decoded as NaN), `-0.0` to `+0.0` (JSON writes
/// `0`), everything else keeps its bits.
fn canonical_bits(v: f64) -> u64 {
    if !v.is_finite() {
        f64::NAN.to_bits()
    } else if v == 0.0 {
        0
    } else {
        v.to_bits()
    }
}

/// Replace every bulk numeric array in `v` with a placeholder, pushing
/// the values onto `blobs` in placeholder order.
fn extract_blobs(v: &Json, blobs: &mut Vec<Vec<f64>>) -> Json {
    match v {
        Json::Arr(items)
            if items.len() >= MIN_BLOB
                && items
                    .iter()
                    .all(|e| matches!(e, Json::Num(_) | Json::Null)) =>
        {
            let vals: Vec<f64> = items
                .iter()
                .map(|e| e.as_f64_or_nan().expect("matched Num | Null"))
                .collect();
            let idx = blobs.len();
            blobs.push(vals);
            Json::Obj(vec![
                (BIN_KEY.to_string(), Json::Num(idx as f64)),
                ("n".to_string(), Json::Num(items.len() as f64)),
            ])
        }
        Json::Arr(items) => Json::Arr(items.iter().map(|e| extract_blobs(e, blobs)).collect()),
        Json::Obj(fields) => Json::Obj(
            fields
                .iter()
                .map(|(k, e)| (k.clone(), extract_blobs(e, blobs)))
                .collect(),
        ),
        other => other.clone(),
    }
}

/// Resolve placeholders back into arrays (the inverse of
/// [`extract_blobs`], restoring the exact parse tree of the pure-JSON
/// encoding).
fn resolve_blobs(v: Json, blobs: &[Vec<f64>]) -> Result<Json, String> {
    match v {
        Json::Obj(fields) if fields.first().map(|(k, _)| k.as_str()) == Some(BIN_KEY) => {
            let idx = fields[0]
                .1
                .as_u64()
                .ok_or_else(|| "binary envelope: non-integer blob index".to_string())?
                as usize;
            let vals = blobs
                .get(idx)
                .ok_or_else(|| format!("binary envelope: blob {idx} out of range"))?;
            Ok(Json::Arr(
                vals.iter()
                    .map(|&x| if x.is_nan() { Json::Null } else { Json::Num(x) })
                    .collect(),
            ))
        }
        Json::Arr(items) => Ok(Json::Arr(
            items
                .into_iter()
                .map(|e| resolve_blobs(e, blobs))
                .collect::<Result<_, _>>()?,
        )),
        Json::Obj(fields) => Ok(Json::Obj(
            fields
                .into_iter()
                .map(|(k, e)| resolve_blobs(e, blobs).map(|e| (k, e)))
                .collect::<Result<_, _>>()?,
        )),
        other => Ok(other),
    }
}

/// Encode one JSON-RPC message for the wire. `binary: false` (or a
/// message with no bulk array) produces the pure-JSON text bytes of the
/// PR 6 wire format; otherwise the binary envelope.
pub fn encode_payload(v: &Json, binary: bool) -> Vec<u8> {
    if !binary {
        return v.encode().into_bytes();
    }
    let mut blobs: Vec<Vec<f64>> = Vec::new();
    let control = extract_blobs(v, &mut blobs);
    if blobs.is_empty() {
        return v.encode().into_bytes();
    }
    let json = control.encode().into_bytes();
    let blob_bytes: usize = blobs.iter().map(|b| 4 + 8 * b.len()).sum();
    let mut out = Vec::with_capacity(2 + 4 + json.len() + 4 + blob_bytes);
    out.push(BIN_MAGIC);
    out.push(BIN_VERSION);
    out.extend_from_slice(&(json.len() as u32).to_le_bytes());
    out.extend_from_slice(&json);
    out.extend_from_slice(&(blobs.len() as u32).to_le_bytes());
    for b in &blobs {
        out.extend_from_slice(&(b.len() as u32).to_le_bytes());
        for &x in b {
            out.extend_from_slice(&canonical_bits(x).to_le_bytes());
        }
    }
    out
}

/// Little-endian `u32` at `payload[*at..]`, advancing the cursor.
fn take_u32(payload: &[u8], at: &mut usize) -> Result<usize, String> {
    let end = at
        .checked_add(4)
        .filter(|&e| e <= payload.len())
        .ok_or_else(|| "binary envelope: truncated length field".to_string())?;
    let mut w = [0u8; 4];
    w.copy_from_slice(&payload[*at..end]);
    *at = end;
    Ok(u32::from_le_bytes(w) as usize)
}

/// Decode one frame payload of either encoding into its JSON-RPC message
/// tree. Pure-JSON payloads take the exact PR 6 path (UTF-8 check +
/// [`Json::parse`]); binary envelopes are validated structurally
/// (version, bounds, exact length) and yield the identical tree.
pub fn decode_payload(payload: &[u8]) -> Result<Json, String> {
    if !is_binary(payload) {
        let text = std::str::from_utf8(payload).map_err(|_| "frame is not UTF-8".to_string())?;
        return Json::parse(text);
    }
    if payload.len() < 2 {
        return Err("binary envelope: truncated header".to_string());
    }
    if payload[1] != BIN_VERSION {
        return Err(format!(
            "binary envelope: unsupported version {} (this build speaks {BIN_VERSION})",
            payload[1]
        ));
    }
    let mut at = 2usize;
    let json_len = take_u32(payload, &mut at)?;
    let json_end = at
        .checked_add(json_len)
        .filter(|&e| e <= payload.len())
        .ok_or_else(|| "binary envelope: control document overruns the frame".to_string())?;
    let text = std::str::from_utf8(&payload[at..json_end])
        .map_err(|_| "binary envelope: control document is not UTF-8".to_string())?;
    let control = Json::parse(text)?;
    at = json_end;
    let blob_count = take_u32(payload, &mut at)?;
    let mut blobs: Vec<Vec<f64>> = Vec::with_capacity(blob_count.min(64));
    for _ in 0..blob_count {
        let n = take_u32(payload, &mut at)?;
        let end = n
            .checked_mul(8)
            .and_then(|b| at.checked_add(b))
            .filter(|&e| e <= payload.len())
            .ok_or_else(|| "binary envelope: blob overruns the frame".to_string())?;
        let vals: Vec<f64> = payload[at..end]
            .chunks_exact(8)
            .map(|c| {
                let mut w = [0u8; 8];
                w.copy_from_slice(c);
                f64::from_bits(u64::from_le_bytes(w))
            })
            .collect();
        blobs.push(vals);
        at = end;
    }
    if at != payload.len() {
        return Err(format!(
            "binary envelope: {} trailing bytes after the blob table",
            payload.len() - at
        ));
    }
    resolve_blobs(control, &blobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dot_request(n: usize) -> Json {
        let xs: Vec<f64> = (0..n).map(|i| i as f64 * 0.5 - 1.0).collect();
        Json::obj(vec![
            ("jsonrpc", Json::str("2.0")),
            ("id", Json::Num(1.0)),
            ("method", Json::str("submit")),
            (
                "params",
                Json::obj(vec![
                    ("kind", Json::str("dot/hrfna")),
                    ("tier", Json::str("paper")),
                    (
                        "payload",
                        Json::obj(vec![
                            ("type", Json::str("dot")),
                            ("x", Json::arr_f64(&xs)),
                            ("y", Json::arr_f64(&xs)),
                        ]),
                    ),
                ]),
            ),
        ])
    }

    #[test]
    fn json_mode_is_byte_identical_to_plain_encode() {
        let msg = dot_request(32);
        assert_eq!(encode_payload(&msg, false), msg.encode().into_bytes());
    }

    #[test]
    fn small_frames_stay_pure_json_even_in_binary_mode() {
        let ping = Json::obj(vec![
            ("jsonrpc", Json::str("2.0")),
            ("id", Json::Num(3.0)),
            ("method", Json::str("ping")),
        ]);
        let payload = encode_payload(&ping, true);
        assert!(!is_binary(&payload));
        assert_eq!(payload, ping.encode().into_bytes());
    }

    #[test]
    fn binary_round_trip_restores_the_exact_parse_tree() {
        let msg = dot_request(64);
        let bin = encode_payload(&msg, true);
        assert!(is_binary(&bin));
        let from_bin = decode_payload(&bin).expect("binary decode");
        let from_json = decode_payload(&encode_payload(&msg, false)).expect("json decode");
        assert_eq!(from_bin, from_json);
        assert_eq!(from_bin, msg);
    }

    #[test]
    fn binary_is_much_smaller_than_text_for_bulk_operands() {
        // 17-significant-digit doubles dominate text frames; the blob is
        // a flat 8 bytes per element.
        let xs: Vec<f64> = (0..512).map(|i| (i as f64 * 0.7301).sin() * 1e3).collect();
        let msg = Json::obj(vec![
            ("id", Json::Num(1.0)),
            ("x", Json::arr_f64(&xs)),
        ]);
        let text = encode_payload(&msg, false);
        let bin = encode_payload(&msg, true);
        assert!(
            (bin.len() as f64) < 0.6 * text.len() as f64,
            "binary {} vs text {}",
            bin.len(),
            text.len()
        );
    }

    #[test]
    fn non_finite_and_negative_zero_match_the_json_path() {
        let xs = [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.0, 1.5, -2.25, 0.1, 3.0];
        let msg = Json::obj(vec![("x", Json::arr_f64(&xs))]);
        let via_bin = decode_payload(&encode_payload(&msg, true)).expect("binary");
        let via_text =
            Json::parse(&msg.encode()).expect("text parse");
        assert_eq!(via_bin, via_text);
        let got = via_bin.get("x").unwrap().f64_vec().unwrap();
        assert!(got[0].is_nan() && got[1].is_nan() && got[2].is_nan());
        assert_eq!(got[3].to_bits(), 0.0f64.to_bits(), "-0.0 collapses to +0.0");
        assert_eq!(got[4..], [1.5, -2.25, 0.1, 3.0]);
    }

    #[test]
    fn short_arrays_are_not_extracted() {
        let msg = Json::obj(vec![("x", Json::arr_f64(&[1.0, 2.0, 3.0]))]);
        assert!(!is_binary(&encode_payload(&msg, true)));
    }

    #[test]
    fn corrupt_envelopes_are_rejected_not_panicked() {
        let msg = dot_request(16);
        let good = encode_payload(&msg, true);
        assert!(decode_payload(&[BIN_MAGIC]).is_err(), "truncated header");
        assert!(
            decode_payload(&[BIN_MAGIC, 9, 0, 0, 0, 0]).is_err(),
            "unknown version"
        );
        let mut short = good.clone();
        short.truncate(good.len() - 3);
        assert!(decode_payload(&short).is_err(), "truncated blob");
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(decode_payload(&trailing).is_err(), "trailing bytes");
        // A control-length field pointing past the end must not slice OOB.
        let mut bad_len = good;
        bad_len[2..6].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_payload(&bad_len).is_err(), "oversize control length");
    }
}
