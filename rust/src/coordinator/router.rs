//! Admission control + lane routing: validates each payload against the
//! AOT shape buckets, pads dot vectors up to the bucket length, and maps
//! job kinds onto batch queues (one queue per kind; workers pull
//! concurrently, giving work-conserving scheduling).

use anyhow::{bail, Result};

use super::request::{JobKind, Payload};

/// AOT shape buckets (keep in sync with python/compile/model.py).
#[derive(Clone, Copy, Debug)]
pub struct ShapeBuckets {
    pub dot_n: usize,
    pub matmul_dim: usize,
}

impl Default for ShapeBuckets {
    fn default() -> ShapeBuckets {
        ShapeBuckets {
            dot_n: 4096,
            matmul_dim: 64,
        }
    }
}

/// Validate and normalize a payload for its lane; pads dot vectors with
/// zeros to the bucket size (zero products do not affect the sum).
pub fn admit(payload: &mut Payload, kind: JobKind, buckets: &ShapeBuckets) -> Result<()> {
    match (payload, kind) {
        (Payload::Dot { x, y }, JobKind::DotHybrid | JobKind::DotF32) => {
            if x.len() != y.len() {
                bail!("dot operands must have equal length");
            }
            if x.is_empty() {
                bail!("empty dot product");
            }
            if x.len() > buckets.dot_n {
                bail!("dot length {} exceeds bucket {}", x.len(), buckets.dot_n);
            }
            if !x.iter().chain(y.iter()).all(|v| v.is_finite()) {
                bail!("non-finite operand");
            }
            x.resize(buckets.dot_n, 0.0);
            y.resize(buckets.dot_n, 0.0);
            Ok(())
        }
        (Payload::Matmul { a, b, dim }, JobKind::MatmulHybrid | JobKind::MatmulF32) => {
            if *dim != buckets.matmul_dim {
                bail!("matmul dim {dim} != bucket {}", buckets.matmul_dim);
            }
            if a.len() != dim.pow(2) || b.len() != dim.pow(2) {
                bail!("matmul operand size mismatch");
            }
            if !a.iter().chain(b.iter()).all(|v| v.is_finite()) {
                bail!("non-finite operand");
            }
            Ok(())
        }
        _ => bail!("payload does not match lane {kind:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_padding() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Dot {
            x: vec![1.0; 100],
            y: vec![2.0; 100],
        };
        admit(&mut p, JobKind::DotHybrid, &b).unwrap();
        if let Payload::Dot { x, y } = &p {
            assert_eq!(x.len(), 4096);
            assert_eq!(y.len(), 4096);
            assert_eq!(x[99], 1.0);
            assert_eq!(x[100], 0.0);
            assert_eq!(y[4095], 0.0);
        } else {
            panic!()
        }
    }

    #[test]
    fn rejects_oversize_and_mismatch() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Dot {
            x: vec![0.0; 5000],
            y: vec![0.0; 5000],
        };
        assert!(admit(&mut p, JobKind::DotF32, &b).is_err());
        let mut p = Payload::Dot {
            x: vec![0.0; 10],
            y: vec![0.0; 11],
        };
        assert!(admit(&mut p, JobKind::DotF32, &b).is_err());
        let mut p = Payload::Dot {
            x: vec![f64::NAN; 4],
            y: vec![0.0; 4],
        };
        assert!(admit(&mut p, JobKind::DotF32, &b).is_err());
    }

    #[test]
    fn matmul_admission() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Matmul {
            a: vec![0.0; 64 * 64],
            b: vec![0.0; 64 * 64],
            dim: 64,
        };
        admit(&mut p, JobKind::MatmulHybrid, &b).unwrap();
        let mut p = Payload::Matmul {
            a: vec![0.0; 9],
            b: vec![0.0; 9],
            dim: 3,
        };
        assert!(admit(&mut p, JobKind::MatmulHybrid, &b).is_err());
    }

    #[test]
    fn kind_payload_mismatch_rejected() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Dot {
            x: vec![1.0],
            y: vec![1.0],
        };
        assert!(admit(&mut p, JobKind::MatmulF32, &b).is_err());
    }
}
