//! Admission control + lane routing: validates each payload against the
//! shape buckets, pads dot vectors up to the smallest fitting bucket, and
//! maps jobs onto (kind, tier, bucket) queues — one sharded queue per
//! lane, workers pull and steal concurrently, giving work-conserving
//! scheduling. Hybrid kinds get one lane per enabled precision tier;
//! FP32 kinds are tier-agnostic and occupy the [`Tier::Paper`] slot.

use super::error::Error;
use super::request::{JobKind, Payload};
use crate::hybrid::registry::Tier;

/// Queue routing key of one lane: (datapath kind, precision tier, shape
/// bucket). Batches popped from a lane are single-kind, single-tier and
/// single-shape by construction.
pub type LaneKey = (JobKind, Tier, usize);

/// Shape buckets. Hybrid dot jobs route to the smallest fitting bucket
/// (each bucket is its own planar lane); the FP32 dot lane is pinned to
/// the last (largest) bucket, which is the AOT graph shape (keep in sync
/// with python/compile/model.py).
#[derive(Clone, Debug)]
pub struct ShapeBuckets {
    /// Sorted ascending dot-length buckets.
    pub dot: Vec<usize>,
    pub matmul_dim: usize,
    /// Admission cap on RK4 steps per job.
    pub rk4_max_steps: u64,
    /// Precision tiers the hybrid lanes serve (ascending; must be
    /// non-empty). Escalation can only land on an enabled tier.
    pub tiers: Vec<Tier>,
}

impl Default for ShapeBuckets {
    fn default() -> ShapeBuckets {
        ShapeBuckets {
            dot: vec![512, 4096],
            matmul_dim: 64,
            rk4_max_steps: 4096,
            tiers: Tier::ALL.to_vec(),
        }
    }
}

/// RK4 jobs all share one lane; the bucket key is the state dimension.
pub const RK4_BUCKET: usize = 2;

impl ShapeBuckets {
    /// The AOT engine's frozen dot length (largest bucket).
    pub fn engine_dot_n(&self) -> usize {
        *self.dot.last().expect("ShapeBuckets.dot must be non-empty")
    }

    /// Smallest bucket that fits a dot operand of length `len`.
    pub fn dot_bucket(&self, len: usize) -> Option<usize> {
        self.dot.iter().copied().find(|&b| b >= len)
    }

    /// The cheapest *enabled* tier at or above `tier`, if any.
    pub fn enabled_tier_at_or_above(&self, tier: Tier) -> Option<Tier> {
        self.tiers.iter().copied().filter(|&t| t >= tier).min()
    }

    /// Every (kind, tier, bucket) lane this bucket set serves.
    pub fn lanes(&self) -> Vec<LaneKey> {
        assert!(!self.tiers.is_empty(), "ShapeBuckets.tiers must be non-empty");
        let mut lanes: Vec<LaneKey> = Vec::new();
        for &tier in &self.tiers {
            for &n in &self.dot {
                lanes.push((JobKind::DotHybrid, tier, n));
            }
            lanes.push((JobKind::MatmulHybrid, tier, self.matmul_dim));
            lanes.push((JobKind::Rk4Hybrid, tier, RK4_BUCKET));
            // FIR jobs of any admitted signal length share one lane per
            // tier; the bucket key is the signal-length cap.
            lanes.push((JobKind::FirHybrid, tier, self.engine_dot_n()));
        }
        lanes.push((JobKind::DotF32, Tier::Paper, self.engine_dot_n()));
        lanes.push((JobKind::MatmulF32, Tier::Paper, self.matmul_dim));
        lanes
    }
}

/// The shape bucket a payload *would* route to, without validating or
/// padding it. This is the cluster router's placement probe: the shard
/// ring hashes `(kind, tier, bucket)`, and the worker's own `admit`
/// still runs full validation on arrival. `None` when no bucket fits
/// (admit would reject too).
pub fn probe_bucket(payload: &Payload, kind: JobKind, buckets: &ShapeBuckets) -> Option<usize> {
    match (payload, kind) {
        (Payload::Dot { x, .. }, JobKind::DotF32) => {
            (x.len() <= buckets.engine_dot_n()).then_some(buckets.engine_dot_n())
        }
        (Payload::Dot { x, .. }, JobKind::DotHybrid) => buckets.dot_bucket(x.len()),
        (Payload::Matmul { .. }, JobKind::MatmulHybrid | JobKind::MatmulF32) => {
            Some(buckets.matmul_dim)
        }
        (Payload::Rk4 { .. }, JobKind::Rk4Hybrid) => Some(RK4_BUCKET),
        (Payload::Fir { x, .. }, JobKind::FirHybrid) => {
            (x.len() <= buckets.engine_dot_n()).then_some(buckets.engine_dot_n())
        }
        _ => None,
    }
}

/// Validate and normalize a payload for its lane; pads dot vectors with
/// zeros to the selected bucket (zero products do not affect the sum).
/// Returns the bucket key the job routes to.
pub fn admit(
    payload: &mut Payload,
    kind: JobKind,
    buckets: &ShapeBuckets,
) -> Result<usize, Error> {
    let reject = |msg: String| Err(Error::Rejected(msg));
    match (payload, kind) {
        (Payload::Dot { x, y }, JobKind::DotHybrid | JobKind::DotF32) => {
            if x.len() != y.len() {
                return reject("dot operands must have equal length".into());
            }
            if x.is_empty() {
                return reject("empty dot product".into());
            }
            if !x.iter().chain(y.iter()).all(|v| v.is_finite()) {
                return reject("non-finite operand".into());
            }
            // The FP32 lane runs the frozen AOT graph; hybrid lanes pick
            // the smallest planar bucket that fits.
            let bucket = if kind == JobKind::DotF32 {
                if x.len() > buckets.engine_dot_n() {
                    return reject(format!(
                        "dot length {} exceeds bucket {}",
                        x.len(),
                        buckets.engine_dot_n()
                    ));
                }
                buckets.engine_dot_n()
            } else {
                match buckets.dot_bucket(x.len()) {
                    Some(b) => b,
                    None => {
                        return reject(format!(
                            "dot length {} exceeds bucket {}",
                            x.len(),
                            buckets.engine_dot_n()
                        ))
                    }
                }
            };
            x.resize(bucket, 0.0);
            y.resize(bucket, 0.0);
            Ok(bucket)
        }
        (Payload::Matmul { a, b, dim }, JobKind::MatmulHybrid | JobKind::MatmulF32) => {
            if *dim != buckets.matmul_dim {
                return reject(format!("matmul dim {dim} != bucket {}", buckets.matmul_dim));
            }
            if a.len() != dim.pow(2) || b.len() != dim.pow(2) {
                return reject("matmul operand size mismatch".into());
            }
            if !a.iter().chain(b.iter()).all(|v| v.is_finite()) {
                return reject("non-finite operand".into());
            }
            Ok(buckets.matmul_dim)
        }
        (Payload::Rk4 { y0, mu, dt, steps }, JobKind::Rk4Hybrid) => {
            if y0.len() != RK4_BUCKET {
                return reject(format!("rk4 state must have dim {RK4_BUCKET}"));
            }
            if !y0.iter().all(|v| v.is_finite()) || !mu.is_finite() || !dt.is_finite() {
                return reject("non-finite rk4 parameter".into());
            }
            if *dt <= 0.0 {
                return reject("rk4 dt must be positive".into());
            }
            if *steps == 0 || *steps > buckets.rk4_max_steps {
                return reject(format!(
                    "rk4 steps {steps} outside (0, {}]",
                    buckets.rk4_max_steps
                ));
            }
            Ok(RK4_BUCKET)
        }
        (Payload::Fir { taps, x }, JobKind::FirHybrid) => {
            if taps.is_empty() || x.is_empty() {
                return reject("empty FIR taps or signal".into());
            }
            if taps.len() > x.len() {
                return reject(format!(
                    "FIR needs taps ({}) <= signal length ({})",
                    taps.len(),
                    x.len()
                ));
            }
            if x.len() > buckets.engine_dot_n() {
                return reject(format!(
                    "FIR signal length {} exceeds cap {}",
                    x.len(),
                    buckets.engine_dot_n()
                ));
            }
            if !taps.iter().chain(x.iter()).all(|v| v.is_finite()) {
                return reject("non-finite operand".into());
            }
            Ok(buckets.engine_dot_n())
        }
        _ => reject(format!("payload does not match lane {kind:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_padding_to_smallest_bucket() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Dot {
            x: vec![1.0; 100],
            y: vec![2.0; 100],
        };
        let bucket = admit(&mut p, JobKind::DotHybrid, &b).unwrap();
        assert_eq!(bucket, 512);
        if let Payload::Dot { x, y } = &p {
            assert_eq!(x.len(), 512);
            assert_eq!(y.len(), 512);
            assert_eq!(x[99], 1.0);
            assert_eq!(x[100], 0.0);
            assert_eq!(y[511], 0.0);
        } else {
            panic!()
        }
    }

    #[test]
    fn fp32_dot_pins_to_engine_bucket() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Dot {
            x: vec![1.0; 100],
            y: vec![2.0; 100],
        };
        let bucket = admit(&mut p, JobKind::DotF32, &b).unwrap();
        assert_eq!(bucket, 4096);
        if let Payload::Dot { x, .. } = &p {
            assert_eq!(x.len(), 4096);
        } else {
            panic!()
        }
    }

    #[test]
    fn rejects_oversize_and_mismatch() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Dot {
            x: vec![0.0; 5000],
            y: vec![0.0; 5000],
        };
        assert!(admit(&mut p, JobKind::DotF32, &b).is_err());
        assert!(admit(&mut p, JobKind::DotHybrid, &b).is_err());
        let mut p = Payload::Dot {
            x: vec![0.0; 10],
            y: vec![0.0; 11],
        };
        assert!(admit(&mut p, JobKind::DotF32, &b).is_err());
        let mut p = Payload::Dot {
            x: vec![f64::NAN; 4],
            y: vec![0.0; 4],
        };
        assert!(matches!(
            admit(&mut p, JobKind::DotF32, &b),
            Err(Error::Rejected(_))
        ));
    }

    #[test]
    fn probe_bucket_matches_admit() {
        let b = ShapeBuckets::default();
        let cases = vec![
            (Payload::Dot { x: vec![1.0; 100], y: vec![1.0; 100] }, JobKind::DotHybrid),
            (Payload::Dot { x: vec![1.0; 100], y: vec![1.0; 100] }, JobKind::DotF32),
            (Payload::Dot { x: vec![1.0; 600], y: vec![1.0; 600] }, JobKind::DotHybrid),
            (
                Payload::Matmul { a: vec![0.0; 64 * 64], b: vec![0.0; 64 * 64], dim: 64 },
                JobKind::MatmulHybrid,
            ),
            (Payload::Rk4 { y0: vec![2.0, 0.0], mu: 1.0, dt: 0.01, steps: 100 }, JobKind::Rk4Hybrid),
            (Payload::Fir { taps: vec![0.25, 0.5, 0.25], x: vec![1.0; 200] }, JobKind::FirHybrid),
        ];
        for (p, kind) in cases {
            let probed = probe_bucket(&p, kind, &b);
            let mut admitted = p.clone();
            let bucket = admit(&mut admitted, kind, &b).unwrap();
            assert_eq!(probed, Some(bucket), "probe disagrees with admit for {kind:?}");
        }
        // Oversize and mismatched payloads probe to None, mirroring reject.
        let p = Payload::Dot { x: vec![0.0; 5000], y: vec![0.0; 5000] };
        assert_eq!(probe_bucket(&p, JobKind::DotHybrid, &b), None);
        assert_eq!(probe_bucket(&p, JobKind::Rk4Hybrid, &b), None);
    }

    #[test]
    fn matmul_admission() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Matmul {
            a: vec![0.0; 64 * 64],
            b: vec![0.0; 64 * 64],
            dim: 64,
        };
        assert_eq!(admit(&mut p, JobKind::MatmulHybrid, &b).unwrap(), 64);
        let mut p = Payload::Matmul {
            a: vec![0.0; 9],
            b: vec![0.0; 9],
            dim: 3,
        };
        assert!(admit(&mut p, JobKind::MatmulHybrid, &b).is_err());
    }

    #[test]
    fn rk4_admission_bounds() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Rk4 { y0: vec![2.0, 0.0], mu: 1.0, dt: 0.01, steps: 100 };
        assert_eq!(admit(&mut p, JobKind::Rk4Hybrid, &b).unwrap(), RK4_BUCKET);
        let mut p = Payload::Rk4 { y0: vec![2.0, 0.0], mu: 1.0, dt: 0.01, steps: 0 };
        assert!(admit(&mut p, JobKind::Rk4Hybrid, &b).is_err());
        let mut p = Payload::Rk4 { y0: vec![2.0, 0.0], mu: 1.0, dt: -1.0, steps: 10 };
        assert!(admit(&mut p, JobKind::Rk4Hybrid, &b).is_err());
        let mut p = Payload::Rk4 { y0: vec![2.0], mu: 1.0, dt: 0.01, steps: 10 };
        assert!(admit(&mut p, JobKind::Rk4Hybrid, &b).is_err());
        let mut p = Payload::Rk4 {
            y0: vec![2.0, 0.0],
            mu: 1.0,
            dt: 0.01,
            steps: b.rk4_max_steps + 1,
        };
        assert!(admit(&mut p, JobKind::Rk4Hybrid, &b).is_err());
    }

    #[test]
    fn fir_admission_bounds() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Fir { taps: vec![0.5; 8], x: vec![1.0; 256] };
        assert_eq!(admit(&mut p, JobKind::FirHybrid, &b).unwrap(), b.engine_dot_n());
        if let Payload::Fir { x, .. } = &p {
            assert_eq!(x.len(), 256, "FIR signals are not padded");
        } else {
            panic!()
        }
        let mut p = Payload::Fir { taps: vec![], x: vec![1.0; 8] };
        assert!(admit(&mut p, JobKind::FirHybrid, &b).is_err());
        let mut p = Payload::Fir { taps: vec![0.5; 9], x: vec![1.0; 8] };
        assert!(admit(&mut p, JobKind::FirHybrid, &b).is_err());
        let mut p = Payload::Fir { taps: vec![0.5; 8], x: vec![1.0; 5000] };
        assert!(admit(&mut p, JobKind::FirHybrid, &b).is_err());
        assert_eq!(
            probe_bucket(
                &Payload::Fir { taps: vec![0.5; 8], x: vec![1.0; 5000] },
                JobKind::FirHybrid,
                &b
            ),
            None
        );
        let mut p = Payload::Fir { taps: vec![f64::NAN], x: vec![1.0; 8] };
        assert!(admit(&mut p, JobKind::FirHybrid, &b).is_err());
    }

    #[test]
    fn kind_payload_mismatch_rejected() {
        let b = ShapeBuckets::default();
        let mut p = Payload::Dot {
            x: vec![1.0],
            y: vec![1.0],
        };
        assert!(admit(&mut p, JobKind::MatmulF32, &b).is_err());
        assert!(admit(&mut p, JobKind::Rk4Hybrid, &b).is_err());
    }

    #[test]
    fn lane_enumeration_covers_all_kinds_and_tiers() {
        let b = ShapeBuckets::default();
        let lanes = b.lanes();
        // Hybrid kinds fan out per tier; FP32 kinds pin to one lane each.
        assert_eq!(lanes.len(), b.tiers.len() * (b.dot.len() + 3) + 2);
        for kind in JobKind::ALL {
            assert!(lanes.iter().any(|&(k, _, _)| k == kind), "{kind:?} missing");
        }
        for &tier in &b.tiers {
            assert!(
                lanes.iter().any(|&(k, t, _)| k == JobKind::DotHybrid && t == tier),
                "{tier:?} missing a hybrid dot lane"
            );
        }
        // FP32 lanes exist only in the Paper slot.
        assert!(lanes
            .iter()
            .all(|&(k, t, _)| k.is_hybrid() || t == Tier::Paper));
    }

    #[test]
    fn single_tier_config_shrinks_the_lane_set() {
        let b = ShapeBuckets {
            tiers: vec![Tier::Paper],
            ..ShapeBuckets::default()
        };
        let lanes = b.lanes();
        assert_eq!(lanes.len(), b.dot.len() + 5);
        assert!(lanes.iter().all(|&(_, t, _)| t == Tier::Paper));
    }

    #[test]
    fn enabled_tier_lookup_respects_the_configured_set() {
        let b = ShapeBuckets::default();
        assert_eq!(b.enabled_tier_at_or_above(Tier::Lo), Some(Tier::Lo));
        let b = ShapeBuckets {
            tiers: vec![Tier::Paper, Tier::Wide],
            ..ShapeBuckets::default()
        };
        assert_eq!(b.enabled_tier_at_or_above(Tier::Lo), Some(Tier::Paper));
        assert_eq!(b.enabled_tier_at_or_above(Tier::Wide), Some(Tier::Wide));
        let b = ShapeBuckets {
            tiers: vec![Tier::Lo],
            ..ShapeBuckets::default()
        };
        assert_eq!(b.enabled_tier_at_or_above(Tier::Paper), None);
    }
}
