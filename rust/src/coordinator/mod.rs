//! Layer-3 coordinator: the serving system around the HRFNA runtime.
//!
//! The paper's contribution is the numeric format (L1/L2), so L3 is the
//! system a deployment needs around it: typed requests, admission control
//! that routes jobs onto (kind, precision-tier, shape-bucket) lanes with
//! bound-driven tier escalation, sharded bounded batch queues with work
//! stealing and explicit backpressure, worker threads that execute whole
//! batches on the planar residue lanes (one-pass block encode → lane
//! kernels → bulk CRT of requested outputs) under the lane tier's
//! context from the [`crate::hybrid::ContextRegistry`], a shared
//! byte-bounded [`OpCache`] of block-encoded reusable operands (matmul
//! weights, FIR taps) keyed by content digest + tier, per-tier
//! histogram metrics, load generators and a drain-reporting shutdown.
//!
//! Every execution topology sits behind one seam: the [`Backend`]
//! trait (submit → ticket → poll/wait). [`InProcess`] runs jobs on the
//! owned [`Coordinator`]; with `--features rpc`, `rpc::Remote` drives a
//! server over a socket and [`cluster`]'s `ShardRouter` consistent-hash
//! places lanes across a worker fleet with health-driven diversion and
//! failover. `serve_load`, the benches, and the CLI drive a
//! `&dyn Backend` and don't know which one they got.
//!
//! Errors are one enum end to end: [`Error`] carries admission,
//! backpressure, transport, and protocol failures, and its
//! `wire_code()` is the stable JSON-RPC code table — worker → router →
//! client hops re-encode it losslessly.

pub mod backend;
pub mod batcher;
pub mod cluster;
pub mod error;
pub mod hybrid_exec;
pub mod metrics;
pub mod op_cache;
pub mod request;
pub mod router;
#[cfg(feature = "rpc")]
pub mod rpc;
pub mod serve_load;
pub mod server;

pub use backend::{Backend, InProcess, JobPoll, JobTicket, DEFAULT_WAIT};
pub use cluster::{parse_workers, HashRing, HealthState, Membership, WorkerSpec};
pub use error::Error;
#[allow(deprecated)]
pub use error::SubmitError;
pub use hybrid_exec::ExecMode;
pub use op_cache::{CachedOperand, OpCache};
pub use request::{Job, JobKind, JobResult, JobSpec, Payload};
pub use router::LaneKey;
pub use serve_load::{closed_loop, open_loop, LoadReport};
pub use server::{Coordinator, CoordinatorConfig, DrainReport};

// Re-exported so serving callers need only the coordinator module.
pub use crate::hybrid::registry::{ContextRegistry, Tier};
