//! Layer-3 coordinator: the serving system around the HRFNA runtime.
//!
//! The paper's contribution is the numeric format (L1/L2), so L3 is the
//! system a deployment needs around it: typed requests, a router that
//! assigns jobs to format lanes, a fixed-shape batcher (AOT executables
//! have frozen shapes — requests are bucketed and padded into them),
//! worker threads driving the PJRT engine, block-exponent encode/decode
//! bridging reals ↔ residue tensors, and metrics.

pub mod request;
pub mod hybrid_exec;
pub mod batcher;
pub mod router;
pub mod metrics;
pub mod server;

pub use request::{Job, JobKind, JobResult, Payload};
pub use server::{Coordinator, CoordinatorConfig};
