//! Layer-3 coordinator: the serving system around the HRFNA runtime.
//!
//! The paper's contribution is the numeric format (L1/L2), so L3 is the
//! system a deployment needs around it: typed requests, admission control
//! that routes jobs onto (kind, precision-tier, shape-bucket) lanes with
//! bound-driven tier escalation, sharded bounded batch queues with work
//! stealing and explicit backpressure, worker threads that execute whole
//! batches on the planar residue lanes (one-pass block encode → lane
//! kernels → bulk CRT of requested outputs) under the lane tier's
//! context from the [`crate::hybrid::ContextRegistry`], per-tier
//! histogram metrics, load generators and a drain-reporting shutdown.
//!
//! With `--features rpc` the [`rpc`] module adds the network edge: a
//! length-prefix-framed JSON-RPC server/client pair that carries the
//! same typed backpressure (and the tier/tolerance admission fields)
//! over TCP, plus a socket-level load generator.

pub mod request;
pub mod hybrid_exec;
pub mod batcher;
pub mod router;
pub mod metrics;
#[cfg(feature = "rpc")]
pub mod rpc;
pub mod serve_load;
pub mod server;

pub use hybrid_exec::ExecMode;
pub use request::{Job, JobKind, JobResult, JobSpec, Payload, SubmitError};
pub use router::LaneKey;
pub use serve_load::{closed_loop, open_loop, LoadReport};
pub use server::{Coordinator, CoordinatorConfig, DrainReport};

// Re-exported so serving callers need only the coordinator module.
pub use crate::hybrid::registry::{ContextRegistry, Tier};
