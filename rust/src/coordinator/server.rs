//! The coordinator server: per-(kind, tier, bucket) lanes of sharded,
//! bounded batch queues, worker threads executing whole batches on the
//! planar engine (or the scalar reference datapath) under the lane's
//! precision-tier context, and a drain-before-join shutdown that reports
//! exactly what happened to every accepted job.
//!
//! The coordinator owns a [`ContextRegistry`] instead of a single
//! context: hybrid jobs are admitted with a *requested* tier plus an
//! optional tolerance, and admission escalates them to the cheapest
//! enabled tier whose formal bound covers the request (counted in the
//! per-tier metrics). Paper-tier traffic is bit-identical to the
//! historical single-context path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, BatchQueue, PushError};
use super::error::Error;
use super::hybrid_exec::{execute_batch_cached, ExecError, ExecMode};
use super::metrics::Metrics;
use super::op_cache::OpCache;
use super::request::{Job, JobKind, JobResult, JobSpec, Payload};
use super::router::{admit, LaneKey, ShapeBuckets};
use crate::hybrid::registry::{ContextRegistry, Tier};
use crate::runtime::EngineHandle;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads per (kind, tier, bucket) lane; also the shard
    /// count of each lane's queue.
    pub workers_per_lane: usize,
    pub batch: BatchPolicy,
    pub buckets: ShapeBuckets,
    /// Hybrid datapath: planar batched lanes (default) or the scalar
    /// `Hrfna` reference (benchmark baseline).
    pub exec: ExecMode,
    /// Byte budget of the shared encoded-operand cache (block-encoded
    /// matmul weight planes and FIR tap vectors, keyed by content
    /// digest + tier). `0` disables the cache entirely — every job
    /// takes the cold-encode path, bit-identical either way.
    pub op_cache_bytes: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy::default(),
            buckets: ShapeBuckets::default(),
            exec: ExecMode::Planar,
            op_cache_bytes: 32 << 20,
        }
    }
}

/// What `shutdown` observed while draining: every accepted job must be
/// accounted for (`dropped == 0` is the invariant the integration tests
/// assert).
#[derive(Clone, Copy, Debug)]
pub struct DrainReport {
    /// Jobs accepted into queues over the coordinator's lifetime.
    pub accepted: u64,
    /// Jobs whose result was delivered (including error results).
    pub completed: u64,
    /// Submissions rejected (admission failures + overload shedding).
    pub rejected: u64,
    /// Jobs still queued when shutdown began — executed during the drain.
    pub drained: u64,
    /// Accepted jobs that never completed (must be 0 on a clean drain).
    pub dropped: u64,
}

impl DrainReport {
    /// True iff every accepted job was executed and replied to.
    pub fn is_clean(&self) -> bool {
        self.dropped == 0 && self.accepted == self.completed
    }
}

impl std::fmt::Display for DrainReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "drain: accepted {} completed {} rejected {} drained-in-queue {} dropped {}",
            self.accepted, self.completed, self.rejected, self.drained, self.dropped
        )
    }
}

/// The running coordinator. Dropping it shuts the workers down cleanly;
/// prefer [`Coordinator::shutdown`] to also get the drain report.
pub struct Coordinator {
    queues: Arc<BTreeMap<LaneKey, BatchQueue>>,
    registry: Arc<ContextRegistry>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    cfg: CoordinatorConfig,
    op_cache: Option<Arc<OpCache>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start workers over a loaded engine and the tier registry.
    pub fn start(
        engine: EngineHandle,
        registry: Arc<ContextRegistry>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        let shards = cfg.workers_per_lane.max(1);
        let mut queues = BTreeMap::new();
        for key in cfg.buckets.lanes() {
            queues.insert(key, BatchQueue::sharded(cfg.batch, shards));
        }
        let queues = Arc::new(queues);
        let metrics = Arc::new(Metrics::default());
        // Claim cursors start at each already-constructed tier's current
        // totals so pre-serving events (client warmup on a registry
        // context) are not credited to the first lane. Tiers built
        // lazily later start their cursors at zero, which matches their
        // zeroed counters.
        for tier in Tier::ALL {
            if let Some(ctx) = registry.peek(tier) {
                let pre = ctx.snapshot();
                metrics.seed_norm_cursor(tier, pre.norms, pre.guard_norms, pre.reconstructions);
            }
        }
        let op_cache = (cfg.op_cache_bytes > 0).then(|| Arc::new(OpCache::new(cfg.op_cache_bytes)));
        let mut workers = Vec::new();
        let keys: Vec<LaneKey> = queues.keys().copied().collect();
        for key in keys {
            let (kind, tier, bucket) = key;
            for widx in 0..shards {
                let queues = Arc::clone(&queues);
                let engine = engine.clone();
                let registry = Arc::clone(&registry);
                let metrics = Arc::clone(&metrics);
                let op_cache = op_cache.clone();
                let mode = cfg.exec;
                workers.push(
                    thread::Builder::new()
                        .name(format!(
                            "lane-{}-{}-{bucket}-{widx}",
                            kind.label().replace('/', "-"),
                            tier.label()
                        ))
                        .spawn(move || {
                            let q = queues.get(&key).unwrap();
                            while let Some((batch, stolen)) = q.next_batch_for(widx) {
                                if stolen {
                                    metrics.record_steal(kind, tier);
                                }
                                let size = batch.len();
                                let t0 = Instant::now();
                                let results = execute_batch_cached(
                                    &engine,
                                    &registry,
                                    mode,
                                    kind,
                                    tier,
                                    &batch,
                                    op_cache.as_deref(),
                                    Some(&metrics),
                                );
                                metrics.record_batch(kind, tier, size, t0.elapsed());
                                // Per-lane normalization accounting: hand
                                // the tier context's running totals to
                                // its claim cursor — every event is
                                // counted exactly once across concurrent
                                // workers (per-kind attribution of
                                // simultaneous windows is approximate).
                                // FP32 lanes never touch a tier context.
                                if kind.is_hybrid() {
                                    if let Some(ctx) = registry.peek(tier) {
                                        let ops = ctx.snapshot();
                                        metrics.record_norm_totals(
                                            kind,
                                            tier,
                                            ops.norms,
                                            ops.guard_norms,
                                            ops.reconstructions,
                                        );
                                    }
                                }
                                for (job, r) in batch.into_iter().zip(results) {
                                    let latency_us =
                                        job.submitted.elapsed().as_secs_f64() * 1e6;
                                    metrics.record(kind, tier, latency_us, job.payload.macs());
                                    // Plain execution failures keep the
                                    // historical NaN-valued result shape;
                                    // integrity failures travel typed so
                                    // corrupted values are never delivered
                                    // as values.
                                    let reply = match r {
                                        Ok(out) => Ok(JobResult {
                                            id: job.id,
                                            kind,
                                            tier,
                                            values: out.values,
                                            latency_us,
                                            batch_size: size,
                                            check: out.check,
                                        }),
                                        Err(ExecError::Job(e)) => {
                                            crate::log_error!(
                                                "job {} failed: {e:#}",
                                                job.id
                                            );
                                            Ok(JobResult {
                                                id: job.id,
                                                kind,
                                                tier,
                                                values: vec![f64::NAN],
                                                latency_us,
                                                batch_size: size,
                                                check: None,
                                            })
                                        }
                                        Err(ExecError::Integrity(msg)) => {
                                            metrics.record_integrity(kind, tier);
                                            crate::log_error!(
                                                "job {} integrity failure: {msg}",
                                                job.id
                                            );
                                            Err(Error::IntegrityFailure(msg))
                                        }
                                    };
                                    let _ = job.reply.send(reply);
                                }
                            }
                        })
                        .expect("spawn lane worker"),
                );
            }
        }
        Coordinator {
            queues,
            registry,
            metrics,
            next_id: AtomicU64::new(1),
            cfg,
            op_cache,
            workers,
        }
    }

    /// The active configuration.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// The shared encoded-operand cache, when enabled
    /// (`op_cache_bytes > 0`).
    pub fn op_cache(&self) -> Option<&Arc<OpCache>> {
        self.op_cache.as_ref()
    }

    /// Drop every cached encoded operand and advance the auth epoch.
    /// Call whenever cached planes could go stale or lose trust — e.g.
    /// after rebuilding the tier registry with different contexts
    /// (today's [`ContextRegistry`] is immutable once built, so this is
    /// the hook a rebuild path would use), on auth-key rotation, or
    /// when recovering a quarantined worker pool.
    pub fn invalidate_op_cache(&self) {
        if let Some(c) = &self.op_cache {
            c.invalidate_all();
        }
    }

    /// The tier registry this coordinator serves from.
    pub fn registry(&self) -> &Arc<ContextRegistry> {
        &self.registry
    }

    /// Serving metrics table with correct per-kind worker counts (a kind
    /// with several tier/bucket lanes has `lanes × workers_per_lane`
    /// threads feeding its shared occupancy accumulator).
    pub fn metrics_table(&self) -> crate::util::table::Table {
        let lanes = self.cfg.buckets.lanes();
        let wpl = self.cfg.workers_per_lane.max(1);
        self.metrics.table_with(&|kind: JobKind| {
            wpl * lanes.iter().filter(|&&(k, _, _)| k == kind).count().max(1)
        })
    }

    /// Resolve the tier a hybrid spec will execute on: clamp the
    /// requested tier to the enabled set, then bound-escalate over the
    /// payload's magnitude envelope and tolerance, then clamp again
    /// (escalation may land between enabled tiers). Returns the tier
    /// plus whether a *bound check* actually forced an escalation (a
    /// plain clamp onto the enabled set is not one). `Err` when no tier
    /// covers the request — an uncovered resolution means the admission
    /// contract ("run on a tier whose formal bound covers you") cannot
    /// be met, and the coordinator rejects rather than silently serving
    /// a result outside the client's stated tolerance — or when no
    /// enabled lane sits at or above the resolution.
    fn resolve_tier(
        &self,
        requested: Tier,
        payload: &Payload,
        tolerance: Option<f64>,
        authenticated: bool,
    ) -> Result<(Tier, bool), Error> {
        let base = self
            .cfg
            .buckets
            .enabled_tier_at_or_above(requested)
            .ok_or_else(|| {
                Error::Rejected(format!(
                    "no enabled tier at or above requested {requested:?}"
                ))
            })?;
        let res = self
            .registry
            .resolve(base, &payload.envelope(), tolerance, authenticated);
        if !res.covered {
            return Err(Error::Rejected(format!(
                "no tier's formal bound covers the request \
                 (requested {requested:?}, failed check {:?}, tolerance {tolerance:?})",
                res.reason
            )));
        }
        let tier = self
            .cfg
            .buckets
            .enabled_tier_at_or_above(res.tier)
            .ok_or_else(|| {
                Error::Rejected(format!(
                    "escalation to {:?} ({:?}) has no enabled lane",
                    res.tier, res.reason
                ))
            })?;
        Ok((tier, res.escalations > 0))
    }

    /// Submit a [`JobSpec`] (kind, payload, requested tier, tolerance);
    /// returns the receiver for its result, or a typed error (`Rejected`
    /// for admission failures — including a tolerance that not even the
    /// top tier's formal bound covers — `Overloaded` when the lane's
    /// bounded queue is full: the backpressure contract). Hybrid jobs
    /// may be escalated past their requested tier; the bump is counted
    /// in the metrics and the result's `tier` reports where they
    /// actually ran. Build specs with the builders:
    /// `coord.submit(JobSpec::dot(x, y).tier(Tier::Wide))`.
    pub fn submit(
        &self,
        spec: JobSpec,
    ) -> Result<mpsc::Receiver<Result<JobResult, Error>>, Error> {
        let JobSpec { kind, mut payload, tier: requested, tolerance, auth } = spec;
        let metric_tier = if kind.is_hybrid() { requested } else { Tier::Paper };
        // Authentication needs MAC-carrying residue lanes: dot/fir dots
        // verify through the dual-MAC windows, matmul through Freivalds.
        // FP32 lanes have no residues and RK4's stateful integration has
        // no per-job verification hook, so `auth` on those is rejected
        // up front rather than silently served unverified.
        if auth
            && !matches!(
                kind,
                JobKind::DotHybrid | JobKind::FirHybrid | JobKind::MatmulHybrid
            )
        {
            self.metrics.record_rejected(kind, metric_tier);
            return Err(Error::Rejected(format!(
                "authenticated serving is not supported for {} \
                 (MAC lanes require a dot/fir/matmul hybrid lane)",
                kind.label()
            )));
        }
        let bucket = match admit(&mut payload, kind, &self.cfg.buckets) {
            Ok(b) => b,
            Err(e) => {
                self.metrics.record_rejected(kind, metric_tier);
                return Err(e);
            }
        };
        // Tier resolution happens strictly before any encoding: the
        // envelope is read off the admitted payload, the bound checks
        // run on static tier configs.
        let tier = if kind.is_hybrid() {
            match self.resolve_tier(requested, &payload, tolerance, auth) {
                Ok((t, bound_escalated)) => {
                    if bound_escalated {
                        self.metrics.record_escalation(kind, t);
                    }
                    t
                }
                Err(e) => {
                    self.metrics.record_rejected(kind, metric_tier);
                    return Err(e);
                }
            }
        } else {
            Tier::Paper
        };
        let (tx, rx) = mpsc::channel();
        let job = Job {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            kind,
            payload,
            tier,
            bucket,
            auth,
            submitted: Instant::now(),
            reply: tx,
        };
        let q = self
            .queues
            .get(&(kind, tier, bucket))
            .expect("admitted (kind, tier, bucket) has a lane");
        match q.try_push(job) {
            Ok(()) => {
                self.metrics.record_accepted(kind, tier);
                Ok(rx)
            }
            Err(PushError::Full(_)) => {
                self.metrics.record_rejected(kind, tier);
                Err(Error::Overloaded {
                    kind,
                    tier,
                    queued: q.len(),
                    capacity: q.policy.capacity.saturating_mul(q.shard_count()),
                })
            }
            Err(PushError::Closed(_)) => Err(Error::ShuttingDown),
        }
    }

    /// Submit a spec and block for the result (integrity failures of
    /// authenticated jobs surface as their typed error).
    pub fn call(&self, spec: JobSpec) -> Result<JobResult, Error> {
        let rx = self.submit(spec)?;
        rx.recv_timeout(Duration::from_secs(120))
            .map_err(|e| Error::Internal(format!("job timed out: {e}")))?
    }

    /// Pre-PR7 name of [`Coordinator::submit`].
    #[deprecated(note = "renamed to Coordinator::submit (one JobSpec entry point)")]
    pub fn submit_spec(
        &self,
        spec: JobSpec,
    ) -> Result<mpsc::Receiver<Result<JobResult, Error>>, Error> {
        self.submit(spec)
    }

    /// Pre-PR7 name of [`Coordinator::call`].
    #[deprecated(note = "renamed to Coordinator::call (one JobSpec entry point)")]
    pub fn call_spec(&self, spec: JobSpec) -> Result<JobResult, Error> {
        self.call(spec)
    }

    /// Close all queues, drain every in-flight and queued batch, join the
    /// workers, and report what happened to every accepted job.
    pub fn shutdown(mut self) -> DrainReport {
        let drained: u64 = self.queues.values().map(|q| q.len() as u64).sum();
        for q in self.queues.values() {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let accepted = self.metrics.total_accepted();
        let completed = self.metrics.total_jobs();
        DrainReport {
            accepted,
            completed,
            rejected: self.metrics.total_rejected(),
            drained,
            dropped: accepted.saturating_sub(completed),
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for q in self.queues.values() {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// Engine-dependent tests live in rust/tests/integration_serve.rs,
// rust/tests/integration_saturation.rs and rust/tests/integration_tiers.rs.
