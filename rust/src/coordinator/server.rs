//! The coordinator server: per-(kind, bucket) lanes of sharded, bounded
//! batch queues, worker threads executing whole batches on the planar
//! engine (or the scalar reference datapath), and a drain-before-join
//! shutdown that reports exactly what happened to every accepted job.

use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, BatchQueue, PushError};
use super::hybrid_exec::{execute_batch, ExecMode};
use super::metrics::Metrics;
use super::request::{Job, JobKind, JobResult, Payload, SubmitError};
use super::router::{admit, ShapeBuckets};
use crate::hybrid::HrfnaContext;
use crate::runtime::EngineHandle;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads per (kind, bucket) lane; also the shard count of
    /// each lane's queue.
    pub workers_per_lane: usize,
    pub batch: BatchPolicy,
    pub buckets: ShapeBuckets,
    /// Hybrid datapath: planar batched lanes (default) or the scalar
    /// `Hrfna` reference (benchmark baseline).
    pub exec: ExecMode,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy::default(),
            buckets: ShapeBuckets::default(),
            exec: ExecMode::Planar,
        }
    }
}

/// What `shutdown` observed while draining: every accepted job must be
/// accounted for (`dropped == 0` is the invariant the integration tests
/// assert).
#[derive(Clone, Copy, Debug)]
pub struct DrainReport {
    /// Jobs accepted into queues over the coordinator's lifetime.
    pub accepted: u64,
    /// Jobs whose result was delivered (including error results).
    pub completed: u64,
    /// Submissions rejected (admission failures + overload shedding).
    pub rejected: u64,
    /// Jobs still queued when shutdown began — executed during the drain.
    pub drained: u64,
    /// Accepted jobs that never completed (must be 0 on a clean drain).
    pub dropped: u64,
}

impl DrainReport {
    /// True iff every accepted job was executed and replied to.
    pub fn is_clean(&self) -> bool {
        self.dropped == 0 && self.accepted == self.completed
    }
}

impl std::fmt::Display for DrainReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "drain: accepted {} completed {} rejected {} drained-in-queue {} dropped {}",
            self.accepted, self.completed, self.rejected, self.drained, self.dropped
        )
    }
}

/// The running coordinator. Dropping it shuts the workers down cleanly;
/// prefer [`Coordinator::shutdown`] to also get the drain report.
pub struct Coordinator {
    queues: Arc<BTreeMap<(JobKind, usize), BatchQueue>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    cfg: CoordinatorConfig,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start workers over a loaded engine and an HRFNA context.
    pub fn start(
        engine: EngineHandle,
        hrfna: Arc<HrfnaContext>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        let shards = cfg.workers_per_lane.max(1);
        let mut queues = BTreeMap::new();
        for key in cfg.buckets.lanes() {
            queues.insert(key, BatchQueue::sharded(cfg.batch, shards));
        }
        let queues = Arc::new(queues);
        let metrics = Arc::new(Metrics::default());
        // Claim cursors start at the context's current totals so
        // pre-serving events are not credited to the first lane.
        let pre = hrfna.snapshot();
        metrics.seed_norm_cursor(pre.norms, pre.guard_norms);
        let mut workers = Vec::new();
        let keys: Vec<(JobKind, usize)> = queues.keys().copied().collect();
        for key in keys {
            let (kind, bucket) = key;
            for widx in 0..shards {
                let queues = Arc::clone(&queues);
                let engine = engine.clone();
                let hrfna = Arc::clone(&hrfna);
                let metrics = Arc::clone(&metrics);
                let mode = cfg.exec;
                workers.push(
                    thread::Builder::new()
                        .name(format!(
                            "lane-{}-{bucket}-{widx}",
                            kind.label().replace('/', "-")
                        ))
                        .spawn(move || {
                            let q = queues.get(&key).unwrap();
                            while let Some((batch, stolen)) = q.next_batch_for(widx) {
                                if stolen {
                                    metrics.record_steal(kind);
                                }
                                let size = batch.len();
                                let t0 = Instant::now();
                                let results =
                                    execute_batch(&engine, &hrfna, mode, kind, &batch);
                                metrics.record_batch(kind, size, t0.elapsed());
                                // Per-lane normalization accounting: hand
                                // the shared context's running totals to
                                // the claim cursor — every event is
                                // counted exactly once across concurrent
                                // workers (per-kind attribution of
                                // simultaneous windows is approximate).
                                let ops = hrfna.snapshot();
                                metrics.record_norm_totals(
                                    kind,
                                    ops.norms,
                                    ops.guard_norms,
                                );
                                for (job, r) in batch.into_iter().zip(results) {
                                    let latency_us =
                                        job.submitted.elapsed().as_secs_f64() * 1e6;
                                    let values = match r {
                                        Ok(v) => v,
                                        Err(e) => {
                                            crate::log_error!(
                                                "job {} failed: {e:#}",
                                                job.id
                                            );
                                            vec![f64::NAN]
                                        }
                                    };
                                    metrics.record(kind, latency_us, job.payload.macs());
                                    let _ = job.reply.send(JobResult {
                                        id: job.id,
                                        kind,
                                        values,
                                        latency_us,
                                        batch_size: size,
                                    });
                                }
                            }
                        })
                        .expect("spawn lane worker"),
                );
            }
        }
        Coordinator {
            queues,
            metrics,
            next_id: AtomicU64::new(1),
            cfg,
            workers,
        }
    }

    /// The active configuration.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Serving metrics table with correct per-kind worker counts (a kind
    /// with several bucket lanes has `lanes × workers_per_lane` threads
    /// feeding its shared occupancy accumulator).
    pub fn metrics_table(&self) -> crate::util::table::Table {
        let lanes = self.cfg.buckets.lanes();
        let wpl = self.cfg.workers_per_lane.max(1);
        self.metrics.table_with(&|kind: JobKind| {
            wpl * lanes.iter().filter(|&&(k, _)| k == kind).count().max(1)
        })
    }

    /// Submit a job; returns the receiver for its result, or a typed
    /// error (`Rejected` for admission failures, `Overloaded` when the
    /// lane's bounded queue is full — the backpressure contract).
    pub fn submit(
        &self,
        kind: JobKind,
        mut payload: Payload,
    ) -> Result<mpsc::Receiver<JobResult>, SubmitError> {
        let bucket = match admit(&mut payload, kind, &self.cfg.buckets) {
            Ok(b) => b,
            Err(e) => {
                self.metrics.record_rejected(kind);
                return Err(e);
            }
        };
        let (tx, rx) = mpsc::channel();
        let job = Job {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            kind,
            payload,
            bucket,
            submitted: Instant::now(),
            reply: tx,
        };
        let q = self
            .queues
            .get(&(kind, bucket))
            .expect("admitted bucket has a lane");
        match q.try_push(job) {
            Ok(()) => {
                self.metrics.record_accepted(kind);
                Ok(rx)
            }
            Err(PushError::Full(_)) => {
                self.metrics.record_rejected(kind);
                Err(SubmitError::Overloaded {
                    kind,
                    queued: q.len(),
                    capacity: q.policy.capacity.saturating_mul(q.shard_count()),
                })
            }
            Err(PushError::Closed(_)) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Submit and block for the result.
    pub fn call(&self, kind: JobKind, payload: Payload) -> Result<JobResult> {
        let rx = self.submit(kind, payload)?;
        Ok(rx
            .recv_timeout(Duration::from_secs(120))
            .map_err(|e| anyhow::anyhow!("job timed out: {e}"))?)
    }

    /// Close all queues, drain every in-flight and queued batch, join the
    /// workers, and report what happened to every accepted job.
    pub fn shutdown(mut self) -> DrainReport {
        let drained: u64 = self.queues.values().map(|q| q.len() as u64).sum();
        for q in self.queues.values() {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let accepted = self.metrics.total_accepted();
        let completed = self.metrics.total_jobs();
        DrainReport {
            accepted,
            completed,
            rejected: self.metrics.total_rejected(),
            drained,
            dropped: accepted.saturating_sub(completed),
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for q in self.queues.values() {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// Engine-dependent tests live in rust/tests/integration_serve.rs and
// rust/tests/integration_saturation.rs.
