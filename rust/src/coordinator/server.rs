//! The coordinator server: worker threads pulling batches from per-lane
//! queues and driving the PJRT engine; Python never runs here.

use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, BatchQueue};
use super::hybrid_exec::{decode_matrix, decode_scalar, encode_block};
use super::metrics::Metrics;
use super::request::{Job, JobKind, JobResult, Payload};
use super::router::{admit, ShapeBuckets};
use crate::hybrid::HrfnaContext;
use crate::runtime::pjrt::Tensor;
use crate::runtime::EngineHandle;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads per lane.
    pub workers_per_lane: usize,
    pub batch: BatchPolicy,
    pub buckets: ShapeBuckets,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            workers_per_lane: 2,
            batch: BatchPolicy::default(),
            buckets: ShapeBuckets::default(),
        }
    }
}

/// The running coordinator. Dropping it shuts the workers down cleanly.
pub struct Coordinator {
    queues: Arc<BTreeMap<JobKind, BatchQueue>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    cfg: CoordinatorConfig,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start workers over a loaded engine and an HRFNA context.
    pub fn start(
        engine: EngineHandle,
        hrfna: Arc<HrfnaContext>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        let mut queues = BTreeMap::new();
        for &kind in &JobKind::ALL {
            queues.insert(kind, BatchQueue::new(cfg.batch));
        }
        let queues = Arc::new(queues);
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::new();
        for &kind in &JobKind::ALL {
            for widx in 0..cfg.workers_per_lane {
                let queues = Arc::clone(&queues);
                let engine = engine.clone();
                let hrfna = Arc::clone(&hrfna);
                let metrics = Arc::clone(&metrics);
                let buckets = cfg.buckets;
                workers.push(
                    thread::Builder::new()
                        .name(format!("lane-{}-{widx}", kind.label().replace('/', "-")))
                        .spawn(move || {
                            let q = queues.get(&kind).unwrap();
                            while let Some(batch) = q.next_batch() {
                                metrics.record_batch(kind);
                                let size = batch.len();
                                for job in batch {
                                    let r = execute_job(&engine, &hrfna, &buckets, &job);
                                    let latency_us =
                                        job.submitted.elapsed().as_secs_f64() * 1e6;
                                    let values = match r {
                                        Ok(v) => v,
                                        Err(e) => {
                                            crate::log_error!(
                                                "job {} failed: {e:#}",
                                                job.id
                                            );
                                            vec![f64::NAN]
                                        }
                                    };
                                    metrics.record(kind, latency_us, job.payload.macs());
                                    let _ = job.reply.send(JobResult {
                                        id: job.id,
                                        kind,
                                        values,
                                        latency_us,
                                        batch_size: size,
                                    });
                                }
                            }
                        })
                        .expect("spawn lane worker"),
                );
            }
        }
        Coordinator {
            queues,
            metrics,
            next_id: AtomicU64::new(1),
            cfg,
            workers,
        }
    }

    /// Submit a job; returns the receiver for its result.
    pub fn submit(
        &self,
        kind: JobKind,
        mut payload: Payload,
    ) -> Result<mpsc::Receiver<JobResult>> {
        admit(&mut payload, kind, &self.cfg.buckets)?;
        let (tx, rx) = mpsc::channel();
        let job = Job {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            kind,
            payload,
            submitted: Instant::now(),
            reply: tx,
        };
        self.queues.get(&kind).unwrap().push(job);
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn call(&self, kind: JobKind, payload: Payload) -> Result<JobResult> {
        let rx = self.submit(kind, payload)?;
        Ok(rx
            .recv_timeout(Duration::from_secs(120))
            .map_err(|e| anyhow::anyhow!("job timed out: {e}"))?)
    }

    /// Close all queues and join workers.
    pub fn shutdown(mut self) {
        for q in self.queues.values() {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for q in self.queues.values() {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Execute one admitted job against the engine.
fn execute_job(
    engine: &EngineHandle,
    hrfna: &HrfnaContext,
    buckets: &ShapeBuckets,
    job: &Job,
) -> Result<Vec<f64>> {
    match (&job.payload, job.kind) {
        (Payload::Dot { x, y }, JobKind::DotF32) => {
            let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
            let out = engine
                .execute(
                    "fp32_dot",
                    vec![
                        Tensor::F32(xf, vec![buckets.dot_n]),
                        Tensor::F32(yf, vec![buckets.dot_n]),
                    ],
                )?
                .into_f32()?;
            Ok(vec![out[0] as f64])
        }
        (Payload::Dot { x, y }, JobKind::DotHybrid) => {
            let k = hrfna.k();
            let n = buckets.dot_n;
            let ex = encode_block(x, hrfna);
            let ey = encode_block(y, hrfna);
            let m: Vec<i64> = hrfna.cfg.moduli.iter().map(|&v| v as i64).collect();
            let out = engine
                .execute(
                    "hybrid_dot",
                    vec![
                        Tensor::I64(ex.residues, vec![k, n]),
                        Tensor::I64(ey.residues, vec![k, n]),
                        Tensor::I64(m, vec![k]),
                    ],
                )?
                .into_i64()?;
            Ok(vec![decode_scalar(&out, ex.f + ey.f, hrfna)])
        }
        (Payload::Matmul { a, b, dim }, JobKind::MatmulF32) => {
            let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
            let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            let out = engine
                .execute(
                    "fp32_matmul",
                    vec![
                        Tensor::F32(af, vec![*dim, *dim]),
                        Tensor::F32(bf, vec![*dim, *dim]),
                    ],
                )?
                .into_f32()?;
            Ok(out.into_iter().map(|v| v as f64).collect())
        }
        (Payload::Matmul { a, b, dim }, JobKind::MatmulHybrid) => {
            let k = hrfna.k();
            let d = *dim;
            let ea = encode_block(a, hrfna);
            let eb = encode_block(b, hrfna);
            let m: Vec<i64> = hrfna.cfg.moduli.iter().map(|&v| v as i64).collect();
            let out = engine
                .execute(
                    "hybrid_matmul",
                    vec![
                        Tensor::I64(ea.residues, vec![k, d, d]),
                        Tensor::I64(eb.residues, vec![k, d, d]),
                        Tensor::I64(m, vec![k]),
                    ],
                )?
                .into_i64()?;
            Ok(decode_matrix(&out, d * d, ea.f + eb.f, hrfna))
        }
        _ => anyhow::bail!("payload/kind mismatch escaped admission"),
    }
}

// Engine-dependent tests live in rust/tests/integration_serve.rs (they
// need compiled artifacts).
